"""Workload DFG builders for DRAGON (paper §4: AI and non-AI workloads).

dfg_lm       — the 10 assigned LM architectures (via core.trace) as DSim DFGs
dfg_classic  — the paper's own evaluation set: CNNs, LSTMs, DLRMs, BERT
dfg_nonai    — non-AI workloads: stencil, sort, graph-BFS (paper's non-AI claim)
"""
from repro.workloads.dfg_classic import (  # noqa: F401
    bert_base,
    bert_large,
    dlrm,
    lstm,
    resnet50,
    vgg16,
)
from repro.workloads.dfg_gnn import gcn, graphsage  # noqa: F401
from repro.workloads.dfg_lm import lm_cell, lm_workloads  # noqa: F401
from repro.workloads.dfg_nonai import bfs_graph, merge_sort, stencil2d  # noqa: F401

WORKLOAD_FAMILIES = {
    "vision": ("resnet50", "vgg16"),
    "language": ("bert_base", "bert_large", "lstm"),
    "recommendation": ("dlrm",),
    "graph": ("gcn", "graphsage"),
    "non_ai": ("stencil2d", "merge_sort", "bfs_graph"),
}


def get_workload(name: str, **kw):
    import repro.workloads.dfg_classic as c
    import repro.workloads.dfg_gnn as gg
    import repro.workloads.dfg_nonai as n

    for mod in (c, gg, n):
        if hasattr(mod, name):
            return getattr(mod, name)(**kw)
    raise KeyError(f"unknown workload {name!r}")
