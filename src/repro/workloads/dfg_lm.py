"""The 10 assigned LM architectures as DRAGON workload DFGs.

This is role (1) of the assigned architectures (DESIGN.md §4): each
(arch x shape) cell becomes an operator-level dataflow graph consumed by
DSim/DOpt.  Role (2) — the real runnable JAX models — lives in
``repro.models``; tests cross-check the two.
"""
from __future__ import annotations

from repro.configs import SHAPES, all_archs, get_config
from repro.core.graph import Graph
from repro.core.trace import trace_lm


def lm_cell(arch: str, shape: str) -> Graph:
    """DFG for one (architecture x shape) cell."""
    return trace_lm(get_config(arch), SHAPES[shape])


def lm_workloads(shape: str = "train_4k", archs: list[str] | None = None) -> dict[str, Graph]:
    """All assigned architectures traced at one shape (runnable cells only)."""
    out = {}
    for a in archs or all_archs():
        cfg = get_config(a)
        if shape == "long_500k" and not cfg.subquadratic():
            continue
        out[a] = trace_lm(cfg, SHAPES[shape])
    return out
