"""GNN workloads (paper Table 1 claims GNN support: 'DLRMs/Transformers/GNNs').

Message-passing layers as DFGs: sparse gather (neighbor features, mainMem-
bound), per-edge/per-node dense transforms (systolic), and scatter-reduce
aggregation (macTree). Two standard models:

  * GCN:  H' = σ(Â H W)         — aggregate then transform
  * GraphSAGE (mean): H' = σ([H | mean_N(H)] W)
"""
from __future__ import annotations

from repro.core.graph import ELEMWISE, GATHER, Graph, GraphBuilder, MATMUL, REDUCTION

BYTES = 2.0


def _mp_layer(b: GraphBuilder, name: str, n_nodes: float, n_edges: float,
              d_in: float, d_out: float, mode: str, concat_self: bool = False):
    mult = 3.0 if mode == "train" else 1.0
    feat = n_nodes * d_in * BYTES
    edge_feat = n_edges * d_in * BYTES
    # neighbor gather: irregular reads of node features along edges
    b.add(f"{name}.gather", GATHER, n_edges * d_in,
          main_read=edge_feat, gbuf_write=edge_feat,
          alloc=edge_feat, dims=(n_edges, d_in, 1.0))
    # scatter-reduce aggregation (sum/mean over incident edges)
    b.add(f"{name}.aggregate", REDUCTION, n_edges * d_in * mult,
          gbuf_read=edge_feat * mult, gbuf_write=feat * mult,
          alloc=edge_feat + feat, dims=(n_nodes, d_in, 1.0))
    # dense transform
    k = d_in * (2.0 if concat_self else 1.0)
    w = k * d_out * BYTES
    b.add(f"{name}.transform", MATMUL, 2.0 * n_nodes * k * d_out * mult,
          gbuf_read=(n_nodes * k * BYTES + w) * mult,
          gbuf_write=n_nodes * d_out * BYTES * mult,
          main_read=w * (2.0 if mode == "train" else 1.0),
          main_write=w if mode == "train" else 0.0,
          alloc=n_nodes * (k + d_out) * BYTES + w,
          dims=(n_nodes, d_out, k))
    b.add(f"{name}.act", ELEMWISE, n_nodes * d_out * mult,
          gbuf_read=n_nodes * d_out * BYTES, gbuf_write=n_nodes * d_out * BYTES,
          alloc=2 * n_nodes * d_out * BYTES, dims=(n_nodes * d_out, 1.0, 1.0))


def gcn(n_nodes: int = 1 << 20, avg_degree: int = 16, d: int = 256,
        layers: int = 3, n_classes: int = 64, mode: str = "inference") -> Graph:
    """GCN on an ogbn-products-scale graph."""
    b = GraphBuilder()
    e = float(n_nodes * avg_degree)
    dims = [d] * layers + [n_classes]
    for i in range(layers):
        _mp_layer(b, f"L{i}", float(n_nodes), e, float(dims[i]), float(dims[i + 1]), mode)
    return b.build()


def graphsage(n_nodes: int = 1 << 20, avg_degree: int = 16, d: int = 256,
              layers: int = 2, mode: str = "inference") -> Graph:
    """GraphSAGE-mean with self-concat."""
    b = GraphBuilder()
    e = float(n_nodes * avg_degree)
    for i in range(layers):
        _mp_layer(b, f"L{i}", float(n_nodes), e, float(d), float(d), mode,
                  concat_self=True)
    return b.build()
