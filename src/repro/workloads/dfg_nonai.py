"""Non-AI workload DFGs (the paper's 'Non-AI Workloads' column, Table 1).

The paper ingests LLVM IR / Python ASTs; here the three canonical kernels
are emitted directly as operator DFGs with exact op/byte counts — the same
representation the paper's frontend would produce after its scheduling pass
(§11.1).  All are memory- or control-dominated, exercising the vector /
macTree / fpu compute classes rather than the systolic array.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import ELEMWISE, GATHER, MISC, REDUCTION, GraphBuilder, Graph

BYTES = 4.0  # fp32 for scientific/non-AI kernels


def stencil2d(n: int = 4096, iters: int = 8) -> Graph:
    """Jacobi 5-point stencil on an n x n grid, ``iters`` sweeps.

    Per point per sweep: 4 adds + 1 mul = 5 FLOPs; reads 5 neighbours
    (perfect reuse leaves ~1 fresh read/point from the streaming row
    buffer), writes 1.
    """
    b = GraphBuilder()
    pts = float(n * n)
    for it in range(iters):
        b.add(
            f"sweep{it}",
            ELEMWISE,
            pts * 5.0,
            gbuf_read=pts * 3.0 * BYTES,  # 3 rows resident
            gbuf_write=pts * BYTES,
            main_read=pts * BYTES,  # stream grid in
            main_write=pts * BYTES,  # stream grid out
            alloc=3.0 * n * BYTES * 64,  # 3-row working set (64 cols blocked)
            dims=(pts, 1.0, 1.0),
        )
    return b.build()


def merge_sort(n: int = 1 << 24) -> Graph:
    """Bottom-up merge sort of n fp32 keys: log2(n) passes, each streaming
    the full array with ~1 compare+select per element."""
    b = GraphBuilder()
    passes = int(np.log2(n))
    for p in range(passes):
        b.add(
            f"pass{p}",
            MISC,  # compare/branch -> fpu
            float(n) * 2.0,  # compare + select
            gbuf_read=float(n) * BYTES,
            gbuf_write=float(n) * BYTES,
            main_read=float(n) * BYTES,
            main_write=float(n) * BYTES,
            alloc=2.0 * min(n, 1 << 16) * BYTES,  # double-buffered run window
            dims=(float(n), 1.0, 1.0),
        )
    return b.build()


def bfs_graph(n_vertices: int = 1 << 20, avg_degree: int = 16, frontier_rounds: int = 12) -> Graph:
    """Level-synchronous BFS over a sparse graph in CSR.

    Each round gathers neighbour lists (random access — mainMem latency
    bound) and updates the frontier bitmap.  Round sizes follow the classic
    expanding/contracting frontier profile.
    """
    b = GraphBuilder()
    # frontier fraction per round (expand then contract)
    profile = np.array([0.001, 0.01, 0.05, 0.2, 0.4, 0.2, 0.08, 0.03, 0.01, 0.004, 0.001, 0.0005])
    profile = profile[:frontier_rounds] / profile[:frontier_rounds].sum()
    edges = float(n_vertices * avg_degree)
    for r, frac in enumerate(profile):
        e = edges * float(frac)
        v = n_vertices * float(frac)
        b.add(
            f"round{r}.expand",
            GATHER,
            e * 2.0,  # visited-check + dist update per edge
            main_read=e * (BYTES + 4.0),  # neighbour id + random-access visit flag
            gbuf_read=v * BYTES,
            gbuf_write=e * 0.3 * BYTES,  # next-frontier appends
            alloc=min(v * BYTES, 2.0e6),
            dims=(e, 1.0, 1.0),
        )
        b.add(
            f"round{r}.compact",
            REDUCTION,
            e * 1.0,
            gbuf_read=e * 0.3 * BYTES,
            gbuf_write=v * BYTES,
            alloc=min(e * 0.3 * BYTES, 2.0e6),
            dims=(e * 0.3, 1.0, 1.0),
        )
    return b.build()
