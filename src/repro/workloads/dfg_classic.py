"""The paper's own evaluation workloads as DFGs (paper §8.1, Fig. 4, Table 3).

CNNs (ResNet-50, VGG-16), LSTM, DLRM, BERT — the 'vision / language /
recommendation' families of the paper's Table 3 technology-importance study.

Counts follow the standard closed forms:
  conv:   2 * H*W*Cin*Cout*k^2 / stride^2 FLOPs per image
  matmul: 2*M*K*N
  lstm:   4 gates, 2 matmuls per gate step
  dlrm:   embedding gathers (mainMem-bound) + bottom/top MLP + feature interact
"""
from __future__ import annotations

from repro.core.graph import CONV, ELEMWISE, GATHER, MATMUL, REDUCTION, SOFTMAX, GraphBuilder, Graph

BYTES = 2.0  # bf16


def _conv(b: GraphBuilder, name: str, H: int, W: int, cin: int, cout: int, k: int, stride: int, batch: float, mode: str):
    mult = 3.0 if mode == "train" else 1.0
    ho, wo = H // stride, W // stride
    flops = 2.0 * batch * ho * wo * cin * cout * k * k * mult
    act_in = batch * H * W * cin * BYTES
    act_out = batch * ho * wo * cout * BYTES
    w_bytes = cin * cout * k * k * BYTES
    b.add(
        name,
        CONV,
        flops,
        gbuf_read=(act_in + w_bytes) * mult,
        gbuf_write=act_out * mult,
        main_read=w_bytes * (2.0 if mode == "train" else 1.0),
        main_write=w_bytes if mode == "train" else 0.0,
        alloc=act_in + act_out + w_bytes,
        # im2col view: M = out pixels, N = cout, K = cin*k*k
        dims=(batch * ho * wo, cout, cin * k * k),
    )
    return ho, wo


def _fc(b: GraphBuilder, name: str, M: float, K: float, N: float, mode: str):
    mult = 3.0 if mode == "train" else 1.0
    w = K * N * BYTES
    b.add(
        name,
        MATMUL,
        2.0 * M * K * N * mult,
        gbuf_read=(M * K * BYTES + w) * mult,
        gbuf_write=M * N * BYTES * mult,
        main_read=w * (2.0 if mode == "train" else 1.0),
        main_write=w if mode == "train" else 0.0,
        alloc=(M * K + M * N) * BYTES + w,
        dims=(M, N, K),
    )


def resnet50(batch: int = 32, mode: str = "inference") -> Graph:
    """ResNet-50 (ImageNet 224x224) — bottleneck blocks."""
    b = GraphBuilder()
    H = W = 224
    H, W = _conv(b, "stem", H, W, 3, 64, 7, 2, batch, mode)
    H, W = H // 2, W // 2  # maxpool
    cin = 64
    stages = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
    for si, (width, blocks, stride0) in enumerate(stages):
        for bi in range(blocks):
            s = stride0 if bi == 0 else 1
            _conv(b, f"s{si}b{bi}.c1", H, W, cin, width, 1, 1, batch, mode)
            H2, W2 = _conv(b, f"s{si}b{bi}.c2", H, W, width, width, 3, s, batch, mode)
            _conv(b, f"s{si}b{bi}.c3", H2, W2, width, width * 4, 1, 1, batch, mode)
            if bi == 0:
                _conv(b, f"s{si}b{bi}.proj", H, W, cin, width * 4, 1, s, batch, mode)
            H, W, cin = H2, W2, width * 4
            b.add(f"s{si}b{bi}.relu", ELEMWISE, batch * H * W * cin,
                  gbuf_read=batch * H * W * cin * BYTES, gbuf_write=batch * H * W * cin * BYTES,
                  alloc=2 * batch * H * W * cin * BYTES, dims=(batch * H * W * cin, 1.0, 1.0))
    _fc(b, "fc", batch, 2048, 1000, mode)
    return b.build()


def vgg16(batch: int = 32, mode: str = "inference") -> Graph:
    b = GraphBuilder()
    H = W = 224
    cfg = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]
    cin = 3
    for si, (width, n) in enumerate(cfg):
        for i in range(n):
            _conv(b, f"s{si}c{i}", H, W, cin, width, 3, 1, batch, mode)
            cin = width
        H, W = H // 2, W // 2  # maxpool
    _fc(b, "fc1", batch, 512 * 7 * 7, 4096, mode)
    _fc(b, "fc2", batch, 4096, 4096, mode)
    _fc(b, "fc3", batch, 4096, 1000, mode)
    return b.build()


def lstm(batch: int = 64, seq: int = 128, d: int = 1024, layers: int = 4, mode: str = "inference") -> Graph:
    """Stacked LSTM; the recurrent matmuls are sequential (one vertex per
    layer carrying seq-many steps; K dim keeps utilization honest)."""
    b = GraphBuilder()
    mult = 3.0 if mode == "train" else 1.0
    for li in range(layers):
        # input + recurrent projections for 4 gates, per timestep
        w = (d * 4 * d * 2) * BYTES
        flops = 2.0 * batch * seq * d * 4 * d * 2 * mult
        b.add(
            f"l{li}.gates",
            MATMUL,
            flops,
            gbuf_read=(batch * seq * d * 2 * BYTES + w * seq) * mult,
            gbuf_write=batch * seq * 4 * d * BYTES * mult,
            main_read=w * (2.0 if mode == "train" else 1.0),
            main_write=w if mode == "train" else 0.0,
            alloc=batch * d * 8 * BYTES + w,
            dims=(batch, 4 * d, 2 * d),  # per-step M=batch (sequential dep)
        )
        b.add(f"l{li}.cell", ELEMWISE, batch * seq * d * 8 * mult,
              gbuf_read=batch * seq * d * 4 * BYTES, gbuf_write=batch * seq * d * BYTES,
              alloc=batch * d * 6 * BYTES, dims=(batch * seq * d, 1.0, 1.0))
    _fc(b, "proj", batch * seq, d, 32000, mode)
    return b.build()


def dlrm(batch: int = 2048, n_tables: int = 26, emb_dim: int = 128, rows: float = 1e6, mode: str = "inference") -> Graph:
    """DLRM: sparse embedding gathers (mainMem-dominated) + MLPs + interaction."""
    b = GraphBuilder()
    mult = 3.0 if mode == "train" else 1.0
    # bottom MLP 13 -> 512 -> 256 -> 128
    for i, (k, n) in enumerate([(13, 512), (512, 256), (256, emb_dim)]):
        _fc(b, f"bot{i}", batch, k, n, mode)
    # embedding lookups: random-access reads of emb_dim vectors per table
    lookup_bytes = batch * emb_dim * BYTES
    b.add(
        "emb_gather",
        GATHER,
        batch * n_tables * emb_dim,
        main_read=lookup_bytes * n_tables,
        gbuf_write=lookup_bytes * n_tables,
        alloc=lookup_bytes * n_tables,
        dims=(batch * n_tables, emb_dim, 1.0),
    )
    # pairwise interaction: batch x (27 x 128) @ (128 x 27)
    F = n_tables + 1
    b.add("interact", MATMUL, 2.0 * batch * F * F * emb_dim * mult,
          gbuf_read=batch * F * emb_dim * BYTES * mult,
          gbuf_write=batch * F * F * BYTES * mult,
          alloc=batch * (F * emb_dim + F * F) * BYTES,
          dims=(batch * F, F, emb_dim))
    # top MLP
    top_in = F * (F - 1) // 2 + emb_dim
    for i, (k, n) in enumerate([(top_in, 1024), (1024, 512), (512, 256), (256, 1)]):
        _fc(b, f"top{i}", batch, k, n, mode)
    return b.build()


def _bert(layers: int, d: int, heads: int, seq: int, batch: int, mode: str) -> Graph:
    b = GraphBuilder()
    mult = 3.0 if mode == "train" else 1.0
    hd = d // heads
    T = float(batch * seq)
    for i in range(layers):
        _fc(b, f"L{i}.qkv", T, d, 3 * d, mode)
        # scores + av (full bidirectional attention)
        sc = 2.0 * batch * heads * seq * seq * hd * mult
        s_bytes = batch * heads * seq * seq * BYTES
        b.add(f"L{i}.scores", MATMUL, sc, gbuf_read=2 * T * d * BYTES * mult,
              gbuf_write=s_bytes * mult, alloc=2 * T * d * BYTES + s_bytes,
              dims=(batch * heads * seq, seq, hd))
        b.add(f"L{i}.softmax", SOFTMAX, batch * heads * seq * seq * 5 * mult,
              gbuf_read=s_bytes, gbuf_write=s_bytes, alloc=s_bytes,
              dims=(batch * heads * seq * seq, 1.0, 1.0))
        b.add(f"L{i}.av", MATMUL, sc, gbuf_read=(s_bytes + T * d * BYTES) * mult,
              gbuf_write=T * d * BYTES * mult, alloc=s_bytes + 2 * T * d * BYTES,
              dims=(batch * heads * seq, hd, seq))
        _fc(b, f"L{i}.o", T, d, d, mode)
        _fc(b, f"L{i}.ff1", T, d, 4 * d, mode)
        b.add(f"L{i}.gelu", ELEMWISE, T * 4 * d * 4 * mult, gbuf_read=T * 4 * d * BYTES,
              gbuf_write=T * 4 * d * BYTES, alloc=2 * T * 4 * d * BYTES,
              dims=(T * 4 * d, 1.0, 1.0))
        _fc(b, f"L{i}.ff2", T, 4 * d, d, mode)
        b.add(f"L{i}.ln", REDUCTION, T * d * 8 * mult, gbuf_read=T * d * BYTES,
              gbuf_write=T * d * BYTES, alloc=T * d * BYTES, dims=(T * d, 1.0, 1.0))
    _fc(b, "pooler", float(batch), d, d, mode)
    return b.build()


def bert_base(batch: int = 32, seq: int = 384, mode: str = "inference") -> Graph:
    return _bert(12, 768, 12, seq, batch, mode)


def bert_large(batch: int = 32, seq: int = 384, mode: str = "inference") -> Graph:
    return _bert(24, 1024, 16, seq, batch, mode)
