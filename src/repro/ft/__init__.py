from repro.ft.straggler import FailureInjector, SimulatedFailure, StragglerMonitor  # noqa: F401
