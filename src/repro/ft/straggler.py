"""Straggler detection + simulated-failure machinery for the train loop.

On a real multi-host deployment each host reports its step wall-time; the
coordinator compares against the fleet EWMA.  In this single-process harness
the monitor tracks per-step times, flags >k-sigma outliers (slow data feed,
GC pause, a simulated slow device), and the trainer responds per policy:
log, skip-and-rebalance, or (for persistent stragglers) trigger a
checkpoint-restore cycle excluding the bad host — exercised by
tests/test_fault_tolerance.py with injected failures.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class StragglerMonitor:
    alpha: float = 0.1  # EWMA weight
    k_sigma: float = 4.0
    warmup_steps: int = 5
    ewma: float = 0.0
    ewvar: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if this step is a straggler."""
        self.n += 1
        if self.n <= self.warmup_steps:
            # warmup covers jit compilation; re-prime at the steady state so
            # the (huge) compile step never inflates the baseline
            self.ewma = dt if self.n == 1 else (1 - self.alpha) * self.ewma + self.alpha * dt
            self.ewvar = max(self.ewvar, (dt - self.ewma) ** 2)
            if self.n == self.warmup_steps:
                self.ewma = dt
                self.ewvar = (0.25 * dt) ** 2
            return False
        resid = dt - self.ewma
        is_straggler = resid > self.k_sigma * max(self.ewvar, 1e-12) ** 0.5 and dt > 1.5 * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        self.ewvar = (1 - self.alpha) * self.ewvar + self.alpha * resid * resid
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler

    def reprime(self, dt: float) -> None:
        """Reset the baseline to ``dt``, exactly like the end-of-warmup reset
        above: used when a known regime change (a cold compile in the serving
        path, a device swap) makes the old EWMA meaningless — the expensive
        step is recorded as the new steady state, never flagged."""
        self.n = max(self.n + 1, self.warmup_steps)
        self.ewma = dt
        self.ewvar = (0.25 * dt) ** 2


class SimulatedFailure(RuntimeError):
    """Raised by fault-injection hooks to emulate device/host loss."""


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    fail_at: tuple = ()
    slow_at: tuple = ()
    slow_secs: float = 0.05
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected device loss at step {step}")
        if step in self.slow_at:
            time.sleep(self.slow_secs)
