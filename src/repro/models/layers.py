"""Core neural-net layers shared by the whole zoo (pure JAX, functional).

Attention has three execution paths, all numerically validated against
``kernels.ref.reference_attention``:

  * ``chunked_attention`` — pure-jnp flash-semantics attention: a lax.scan
    over the *static list of causal (q_block, kv_block) pairs* with online
    softmax. Computes exactly the lower-triangular half (no masked-block
    waste), touches K/V once per q-block — the same FLOP/byte profile as a
    flash kernel, so the multi-pod dry-run lowers this path and its
    cost_analysis is honest. Portable to any backend.
  * Pallas ``flash_attention`` (kernels/) — the TPU runtime path.
  * ``decode_attention`` — single-query attention against a KV cache.

Layout convention: activations are [B, S, d_model]; per-head tensors are
[B, S, H, D] (transposed to [B, H, S, D] only inside attention).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30  # finite mask bias: keeps every softmax intermediate finite


@functools.lru_cache(maxsize=256)
def _mm_vjp(subscripts: str):
    """custom-VJP einsum: bf16 operands + f32 accumulation in BOTH passes.

    Plain autodiff transposes an f32-accumulating einsum with an f32
    cotangent, promoting the bf16 weight operand to f32 — and XLA then
    hoists that convert BEFORE the ZeRO-3/TP all-gather, doubling every
    weight/activation collective. The explicit backward keeps all dot
    operands (cotangent included) in the compute dtype, which is also the
    standard mixed-precision recipe on TPU."""
    a, rest = subscripts.split(",")
    b, c = rest.split("->")

    @jax.custom_vjp
    def f(x, w):
        return jnp.einsum(subscripts, x, w, preferred_element_type=jnp.float32)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        g16 = g.astype(x.dtype)
        dx = jnp.einsum(f"{c},{b}->{a}", g16, w.astype(x.dtype),
                        preferred_element_type=jnp.float32).astype(x.dtype)
        dw = jnp.einsum(f"{a},{c}->{b}", x, g16,
                        preferred_element_type=jnp.float32).astype(w.dtype)
        return dx, dw

    f.defvjp(fwd, bwd)
    return f


def mm(subscripts: str, x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    """Matmul with bf16 operands + fp32 accumulation (MXU-native), output
    cast back to the activation dtype. See _mm_vjp for why the backward is
    explicit (§Perf hillclimb 2)."""
    out = _mm_vjp(subscripts)(x, w.astype(x.dtype))
    return out.astype(out_dtype or x.dtype)


# --------------------------------------------------------------------------- #
# norms / rope / mlp
# --------------------------------------------------------------------------- #


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_act(gate: jax.Array, up: Optional[jax.Array], kind: str) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up
    if kind == "gelu":
        return jax.nn.gelu(gate)
    if kind == "relu2":
        r = jax.nn.relu(gate)
        return r * r
    raise ValueError(kind)


# --------------------------------------------------------------------------- #
# chunked (flash-semantics) attention — pure jnp, exact causal half
# --------------------------------------------------------------------------- #


def _pick_block(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (vision's 1601 = 7 x 229
    patches won't divide a 512 block; blocks of 229 will)."""
    b = min(target, S)
    while S % b:
        b -= 1
    return b


def _causal_pairs(nq: int, nk: int, block_q: int, block_k: int, causal: bool, off: int = 0):
    """Static (qi, kj) block-pair list; causal keeps kj*bk <= qi_end + off."""
    pairs = []
    for qi in range(nq):
        q_end = (qi + 1) * block_q - 1 + off
        for kj in range(nk):
            if causal and kj * block_k > q_end:
                continue
            pairs.append((qi, kj))
    qis = np.array([p[0] for p in pairs], np.int32)
    kjs = np.array([p[1] for p in pairs], np.int32)
    return qis, kjs


def _chunked_attention_fwd_impl(q, k, v, *, causal, scale, block_q, block_k, kv_len=None):
    """Pair-list scan forward. Returns (out, lse [B,Hq,Sq])."""
    out, lse = _chunked_attention_core(
        q, k, v, causal=causal, scale=scale, block_q=block_q, block_k=block_k, kv_len=kv_len
    )
    return out, lse


def chunked_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    kv_len: Optional[int] = None,  # static valid KV prefix (padded tail masked)
) -> jax.Array:
    """Flash-semantics attention with a flash-style custom VJP: the backward
    saves only (q, k, v, out, lse) and recomputes score blocks — plain
    autodiff-of-scan would checkpoint the full accumulator at every pair
    step (~tens of GB/layer at 4k seq).

    When Skv has no usable divisor (vision's 1601 patches are PRIME — an
    unpadded block search degrades to block_k=1 and a 102k-step scan), K/V
    are padded to a block multiple and masked via ``kv_len``."""
    Skv = k.shape[2]
    bk = _pick_block(Skv, block_k)
    if bk < min(block_k, 128) and Skv > 128:  # pathological divisor: pad
        padded = -(-Skv // min(block_k, Skv)) * min(block_k, Skv)
        cfgpad = [(0, 0), (0, 0), (0, padded - Skv), (0, 0)]
        k = jnp.pad(k, cfgpad)
        v = jnp.pad(v, cfgpad)
        kv_len = Skv if kv_len is None else kv_len
    f = _chunked_attention_vjp(causal, scale if scale is not None else q.shape[-1] ** -0.5,
                               _pick_block(q.shape[2], block_q),
                               _pick_block(k.shape[2], block_k), kv_len)
    return f(q, k, v)


@functools.lru_cache(maxsize=64)
def _chunked_attention_vjp(causal: bool, scale: float, block_q: int, block_k: int,
                           kv_len: Optional[int] = None):
    kw = dict(causal=causal, scale=scale, block_q=block_q, block_k=block_k, kv_len=kv_len)

    def fwd_only(q, k, v):
        out, _ = _chunked_attention_fwd_impl(q, k, v, **kw)
        return out

    @jax.custom_vjp
    def attn(q, k, v):
        return fwd_only(q, k, v)

    def attn_fwd(q, k, v):
        out, lse = _chunked_attention_fwd_impl(q, k, v, **kw)
        return out, (q, k, v, out, lse)

    def attn_bwd(res, do):
        q, k, v, out, lse = res
        dq, dk, dv = _chunked_attention_bwd_impl(q, k, v, out, lse, do, **kw)
        return dq, dk, dv

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def _chunked_attention_core(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    kv_len: Optional[int] = None,
):
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = _pick_block(Sq, block_q)
    block_k = _pick_block(Skv, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    # causal offset: query position p attends key positions <= p + (Skv - Sq)
    off = Skv - Sq

    qis, kjs = _causal_pairs(nq, nk, block_q, block_k, causal, off)
    qis, kjs = jnp.asarray(qis), jnp.asarray(kjs)

    qb = q.reshape(B, Hkv, group, nq, block_q, D)  # blocked, GQA-grouped
    kb = k.reshape(B, Hkv, nk, block_k, D)
    vb = v.reshape(B, Hkv, nk, block_k, D)

    acc0 = jnp.zeros((nq, B, Hkv, group, block_q, D), jnp.float32)
    m0 = jnp.full((nq, B, Hkv, group, block_q), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nq, B, Hkv, group, block_q), jnp.float32)

    def step(carry, idx):
        acc, m, l = carry
        qi, kj = idx
        qt = jax.lax.dynamic_index_in_dim(qb, qi, 3, keepdims=False)  # [B,Hkv,G,bq,D]
        kt = jax.lax.dynamic_index_in_dim(kb, kj, 2, keepdims=False)  # [B,Hkv,bk,D]
        vt = jax.lax.dynamic_index_in_dim(vb, kj, 2, keepdims=False)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk",
            qt.astype(jnp.float32),
            kt.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ) * scale
        if causal or kv_len is not None:
            # arithmetic mask bias (NO predicate tensors): XLA hoists
            # loop-"invariant" mask computations out of the pair scan at the
            # broadcast shape — a where(pred,...) here materializes a
            # [pairs, B, H, bq, bk] pred buffer (9.7 GB at 4k seq). The f32
            # bias hoists at [pairs, bq, bk] (a few MB) and fuses into the add.
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            bias = jnp.zeros((block_q, block_k), jnp.float32)
            if causal:
                bias = bias + jnp.clip((kpos - qpos - off).astype(jnp.float32), 0.0, 1.0) * NEG_INF
            if kv_len is not None:  # padded KV tail
                bias = bias + jnp.clip((kpos - (kv_len - 1)).astype(jnp.float32), 0.0, 1.0) * NEG_INF
            s = s + bias

        m_prev = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_prev = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # finite NEG_INF bias keeps every intermediate finite: exp(-inf-gap)
        # guards are unnecessary (m starts at -inf but kj=0 is always the
        # first pair per q block, making m finite from step one)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        a_new = a_prev * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vt.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        return (acc, m, l), None

    # checkpoint the pair step: without this, backward-of-scan saves every
    # step's s/p matrices and causal-mask predicates ([pairs, B, H, bq, bk]
    # — tens of GB at 4k seq); recomputing them from the tiny slices is free
    (acc, m, l), _ = jax.lax.scan(jax.checkpoint(step), (acc0, m0, l0), (qis, kjs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 0, 3)  # [B,Hkv,G,nq,bq,D]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # [nq,B,Hkv,G,bq]
    lse = jnp.moveaxis(lse, 0, 3).reshape(B, Hq, Sq)
    return out.reshape(B, Hq, Sq, D).astype(q.dtype), lse


def _chunked_attention_bwd_impl(
    q, k, v, out, lse, do, *, causal, scale, block_q, block_k, kv_len=None
):
    """Flash-attention backward: recompute P per block pair from (q,k,lse),
    accumulate dq/dk/dv. No per-step residuals beyond the carries."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    nq, nk = Sq // block_q, Skv // block_k
    off = Skv - Sq
    qis, kjs = _causal_pairs(nq, nk, block_q, block_k, causal, off)
    qis, kjs = jnp.asarray(qis), jnp.asarray(kjs)

    qf = q.astype(jnp.float32).reshape(B, Hkv, group, nq, block_q, D)
    kf = k.astype(jnp.float32).reshape(B, Hkv, nk, block_k, D)
    vf = v.astype(jnp.float32).reshape(B, Hkv, nk, block_k, D)
    dof = do.astype(jnp.float32).reshape(B, Hkv, group, nq, block_q, D)
    outf = out.astype(jnp.float32).reshape(B, Hkv, group, nq, block_q, D)
    lseb = lse.reshape(B, Hkv, group, nq, block_q)
    # Di = rowsum(dO * O)
    Di = jnp.sum(dof * outf, axis=-1)  # [B,Hkv,G,nq,bq]

    dq0 = jnp.zeros_like(qf)
    dk0 = jnp.zeros_like(kf)
    dv0 = jnp.zeros_like(vf)

    def step(carry, idx):
        dq, dk, dv = carry
        qi, kj = idx
        qt = jax.lax.dynamic_index_in_dim(qf, qi, 3, keepdims=False)  # [B,H,G,bq,D]
        kt = jax.lax.dynamic_index_in_dim(kf, kj, 2, keepdims=False)  # [B,H,bk,D]
        vt = jax.lax.dynamic_index_in_dim(vf, kj, 2, keepdims=False)
        dot = jax.lax.dynamic_index_in_dim(dof, qi, 3, keepdims=False)
        lset = jax.lax.dynamic_index_in_dim(lseb, qi, 3, keepdims=False)  # [B,H,G,bq]
        dit = jax.lax.dynamic_index_in_dim(Di, qi, 3, keepdims=False)

        s = jnp.einsum("bhgqd,bhkd->bhgqk", qt, kt, preferred_element_type=jnp.float32) * scale
        if causal or kv_len is not None:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            if causal:
                s = s + jnp.clip((kpos - qpos - off).astype(jnp.float32), 0.0, 1.0) * NEG_INF
            if kv_len is not None:
                s = s + jnp.clip((kpos - (kv_len - 1)).astype(jnp.float32), 0.0, 1.0) * NEG_INF
        p = jnp.exp(s - lset[..., None])  # [B,H,G,bq,bk]
        dvt = jnp.einsum("bhgqk,bhgqd->bhkd", p, dot)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dot, vt)
        ds = p * (dp - dit[..., None]) * scale
        dqt = jnp.einsum("bhgqk,bhkd->bhgqd", ds, kt)
        dkt = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qt)
        dq = dq.at[:, :, :, qi].add(dqt)
        dk = dk.at[:, :, kj].add(dkt)
        dv = dv.at[:, :, kj].add(dvt)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(jax.checkpoint(step), (dq0, dk0, dv0), (qis, kjs))
    return (
        dq.reshape(B, Hq, Sq, D).astype(q.dtype),
        dk.reshape(B, Hkv, Skv, D).astype(k.dtype),
        dv.reshape(B, Hkv, Skv, D).astype(v.dtype),
    )


def decode_attention(
    q: jax.Array,  # [B, Hq, 1, D]
    k: jax.Array,  # [B, Hkv, Skv, D]  (cache, padded)
    v: jax.Array,
    cache_len: jax.Array,  # [B] or scalar: valid prefix length
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Hq, _, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, group, D)
    s = jnp.einsum(
        "bhgd,bhkd->bhgk", qg.astype(jnp.float32), k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale
    pos = jnp.arange(Skv)[None, None, None, :]
    valid = pos < jnp.reshape(cache_len, (-1, 1, 1, 1))
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgk,bhkd->bhgd", p, v.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


def attention(
    q: jax.Array,  # [B, S, Hq, D]
    k: jax.Array,  # [B, S, Hkv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    use_flash: bool = False,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """Dispatch [B,S,H,D] tensors to the right attention path."""
    qt, kt, vt = (jnp.swapaxes(x, 1, 2) for x in (q, k, v))
    if use_flash:
        from repro.kernels import flash_attention

        o = flash_attention(qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k)
    else:
        o = chunked_attention(qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k)
    return jnp.swapaxes(o, 1, 2)
