"""Mamba1 (selective scan) and Mamba2 (SSD) blocks, train + decode paths.

Train-time scans are *chunked*: an associative scan inside fixed-size chunks
(parallel, MXU/VPU-friendly) with a lax.scan carrying the SSM state across
chunks — the standard hardware-efficient formulation, and the only one whose
activation footprint fits HBM at seq 4k x batch 256 (a full associative scan
over time would materialize T x B x d_inner x d_state).

Decode is the exact single-step recurrence (O(1) per token) — this is what
makes the ``long_500k`` cell runnable for the SSM/hybrid archs.

Numerics: state math in fp32 throughout; parameters fp32; activations cast
to the model dtype at block boundaries.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import rms_norm


# --------------------------------------------------------------------------- #
# depthwise causal conv1d (window d_conv) + single-step update
# --------------------------------------------------------------------------- #


def causal_conv1d(x: jax.Array, w: jax.Array, b: Optional[jax.Array]) -> jax.Array:
    """x: [B, S, C]; w: [K, C] depthwise kernel; causal (left) padding."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):  # K is 4: unrolled adds beat a conv op for this window
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def conv_step(x_t: jax.Array, conv_buf: jax.Array, w: jax.Array, b: Optional[jax.Array]):
    """Single decode step. x_t: [B, C]; conv_buf: [B, K-1, C] (past inputs).
    Returns (y_t [B, C], new_buf)."""
    K = w.shape[0]
    window = jnp.concatenate([conv_buf, x_t[:, None, :]], axis=1)  # [B, K, C]
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x_t.dtype), window[:, 1:, :]


# --------------------------------------------------------------------------- #
# Mamba1 selective scan (diagonal A), chunked associative scan
# --------------------------------------------------------------------------- #


def selective_scan(
    u: jax.Array,  # [B, S, C]       input (post conv + silu)
    dt: jax.Array,  # [B, S, C]      per-channel timestep (post softplus)
    A: jax.Array,  # [C, N]          negative (=-exp(A_log))
    Bm: jax.Array,  # [B, S, N]
    Cm: jax.Array,  # [B, S, N]
    D: jax.Array,  # [C]
    chunk: int = 64,
    state0: Optional[jax.Array] = None,  # [B, C, N]
):
    """Returns (y [B, S, C], final_state [B, C, N]).

    Recurrence per (channel c, state n):
      s_t = exp(dt_t A_cn) s_{t-1} + dt_t B_tn u_tc ;   y_tc = sum_n C_tn s_tn + D_c u_tc
    """
    B_, S, C = u.shape
    N = A.shape[1]
    assert S % chunk == 0, (S, chunk)
    nchunks = S // chunk

    uf = u.astype(jnp.float32).reshape(B_, nchunks, chunk, C)
    dtf = dt.astype(jnp.float32).reshape(B_, nchunks, chunk, C)
    Bf = Bm.astype(jnp.float32).reshape(B_, nchunks, chunk, N)
    Cf = Cm.astype(jnp.float32).reshape(B_, nchunks, chunk, N)
    Af = A.astype(jnp.float32)

    def chunk_step(state, xs):  # state: [B, C, N]
        uc, dtc, Bc, Cc = xs  # [B, chunk, C], ..., [B, chunk, N]
        # per-step decay a_t = exp(dt A) [B,chunk,C,N]; input b_t = dt B u
        dA = dtc[..., None] * Af[None, None]  # [B,chunk,C,N]
        a = jnp.exp(dA)
        b = (dtc * uc)[..., None] * Bc[:, :, None, :]  # [B,chunk,C,N]

        # associative scan over the chunk: (a, b) o (a', b') = (a a', a' b + b')
        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])

        a_cum, b_cum = jax.lax.associative_scan(comb, (a, b), axis=1)
        s = a_cum * state[:, None] + b_cum  # [B,chunk,C,N]
        y = jnp.einsum("btcn,btn->btc", s, Cc)
        return s[:, -1], y

    state = state0.astype(jnp.float32) if state0 is not None else jnp.zeros((B_, C, N), jnp.float32)
    xs = (
        jnp.moveaxis(uf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    # checkpoint: the [B, chunk, C, N] decay/cumsum intermediates dominate
    # activation memory if saved per chunk step
    state, ys = jax.lax.scan(jax.checkpoint(chunk_step), state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, S, C)
    y = y + u.astype(jnp.float32) * D.astype(jnp.float32)
    return y.astype(u.dtype), state


def selective_scan_step(
    u_t: jax.Array,  # [B, C]
    dt_t: jax.Array,  # [B, C]
    A: jax.Array,  # [C, N]
    B_t: jax.Array,  # [B, N]
    C_t: jax.Array,  # [B, N]
    D: jax.Array,  # [C]
    state: jax.Array,  # [B, C, N] fp32
):
    uf, dtf = u_t.astype(jnp.float32), dt_t.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A[None])  # [B,C,N]
    b = (dtf * uf)[..., None] * B_t[:, None, :]
    state = a * state + b
    y = jnp.einsum("bcn,bn->bc", state, C_t.astype(jnp.float32)) + uf * D
    return y.astype(u_t.dtype), state


# --------------------------------------------------------------------------- #
# Mamba2 SSD (scalar-per-head decay), chunked — jnp path + single step
# --------------------------------------------------------------------------- #


def ssd_scan(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]     (post softplus)
    A: jax.Array,  # [H]           negative
    Bm: jax.Array,  # [B, S, N]
    Cm: jax.Array,  # [B, S, N]
    chunk: int = 64,
    state0: Optional[jax.Array] = None,  # [B, H, N, P]
):
    """Chunked SSD (Mamba2): intra-chunk attention-like matmuls + inter-chunk
    state carry. Exactly equals the per-step recurrence (kernels/ref.py)."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(B_, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(B_, nc, chunk, H)
    Bf = Bm.astype(jnp.float32).reshape(B_, nc, chunk, N)
    Cf = Cm.astype(jnp.float32).reshape(B_, nc, chunk, N)
    Af = A.astype(jnp.float32)

    def chunk_step(state, xs):  # state [B, H, N, P]
        xc, dtc, Bc, Cc = xs
        dA = dtc * Af[None, None]  # [B,chunk,H]
        cum = jnp.cumsum(dA, axis=1)  # [B,chunk,H] log-decay from chunk start
        total = cum[:, -1]  # [B,H]

        # contribution of the carried-in state: y_in[t] = exp(cum_t) C_t . state
        y_in = jnp.einsum("bth,btn,bhnp->bthp", jnp.exp(cum), Cc, state)

        # intra-chunk: y_intra[t] = sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t.B_s) x_s
        li = cum[:, :, None, :] - cum[:, None, :, :]  # [B,t,s,H]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
        cb = jnp.einsum("btn,bsn->bts", Cc, Bc)  # [B,t,s]
        w = decay * cb[..., None] * dtc[:, None, :, :]  # [B,t,s,H]
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xc)

        # state update: s' = exp(total) s + sum_s exp(total - cum_s) dt_s B_s x_s
        g = jnp.exp(total[:, None] - cum)  # [B,chunk,H]
        ds = jnp.einsum("bsh,bsn,bshp->bhnp", g * dtc, Bc, xc)
        state = jnp.exp(total)[..., None, None] * state + ds
        return state, y_in + y_intra

    state = state0.astype(jnp.float32) if state0 is not None else jnp.zeros((B_, H, N, P), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    # checkpoint: the [B, t, s, H] intra-chunk decay tensor is the big one
    state, ys = jax.lax.scan(jax.checkpoint(chunk_step), state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, S, H, P)
    return y.astype(x.dtype), state


def ssd_step(
    x_t: jax.Array,  # [B, H, P]
    dt_t: jax.Array,  # [B, H]
    A: jax.Array,  # [H]
    B_t: jax.Array,  # [B, N]
    C_t: jax.Array,  # [B, N]
    state: jax.Array,  # [B, H, N, P] fp32
):
    decay = jnp.exp(dt_t.astype(jnp.float32) * A[None])  # [B,H]
    upd = dt_t[..., None, None] * B_t[:, None, :, None] * x_t[:, :, None, :]
    state = decay[..., None, None] * state + upd.astype(jnp.float32)
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), state)
    return y.astype(x_t.dtype), state
