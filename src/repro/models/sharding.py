"""Logical-axis -> mesh-axis sharding rules (GSPMD/pjit).

The production mesh axes are ("data", "model") single-pod and
("pod", "data", "model") multi-pod (launch/mesh.py).  Sharding policy:

  * batch            -> ("pod", "data")   pure DP across pods, DP within
  * TP dims          -> "model"           heads / ff / experts / vocab / d_inner
  * FSDP (ZeRO-3)    -> params' "embed" dim over fsdp_axes (cfg.fsdp);
                        large-MoE configs extend fsdp_axes to ("data","pod")
                        so 1T-param optimizer state fits HBM
  * activations      -> tokens over ("pod","data"), d_model over "model"
                        (sequence-parallel-style residual sharding keeps the
                        remat-saved activations HBM-light)

All helpers silently drop mesh axes that don't exist on the current mesh, so
the same model code runs on the single-pod, multi-pod and 1-device CPU mesh.
"""
from __future__ import annotations

import contextvars
from contextlib import contextmanager
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import defs as D

BATCH_AXES = ("pod", "data")
TP_AXIS = "model"

# --------------------------------------------------------------------------- #
# parallelism policy (§Perf hillclimb): "tp" (default) uses the mesh's model
# axis for tensor parallelism; "dp" folds it into data parallelism + ZeRO-3 —
# for ≤13B dense models at 1M-token batches the per-layer TP activation
# gathers (~1 TB/dev/step) dwarf the ZeRO-3 parameter traffic (~50 GB), so
# "dp" is ~20x less collective-bound. Selected per (arch, shape) by
# launch.policy.parallelism_for.
# --------------------------------------------------------------------------- #

_POLICY: contextvars.ContextVar = contextvars.ContextVar("parallelism", default="tp")


@contextmanager
def parallelism(mode: str):
    assert mode in ("tp", "dp"), mode
    tok = _POLICY.set(mode)
    try:
        yield
    finally:
        _POLICY.reset(tok)


def current_parallelism() -> str:
    return _POLICY.get()


def _dp_mode() -> bool:
    return _POLICY.get() == "dp"


def fsdp_axes_for(cfg) -> tuple:
    """ZeRO-3 axes policy: large MoE shards params/optimizer over data AND
    pod (1T-param optimizer state cannot fit otherwise)."""
    if not getattr(cfg, "fsdp", False):
        return ()
    if getattr(cfg, "moe", None) is not None and cfg.moe.n_experts >= 64:
        return ("data", "pod")
    return ("data",)

# logical axis -> mesh axes (None = replicated). "embed" is resolved per-config.
_TP_AXES = {"vocab", "heads", "kv_heads", "ff", "experts", "d_inner"}


def _filter(mesh_axes: Sequence[str], want) -> Optional[tuple]:
    """Keep only axes present on the mesh; None if nothing survives."""
    if want is None:
        return None
    if isinstance(want, str):
        want = (want,)
    got = tuple(a for a in want if a in mesh_axes)
    return got or None


def logical_to_spec(axes: tuple, mesh_axes: Sequence[str], fsdp_axes=()) -> P:
    """Map a tuple of logical axis names to a PartitionSpec (policy-aware).

    TP dims claim mesh axes FIRST (priority), then batch, then FSDP "embed" —
    so e.g. lm_head ("embed", "vocab") keeps vocab on "model" even when
    dp-mode extends the fsdp axes (vocab sharding keeps the chunked-xent
    head gradient sharded instead of all-gathered per chunk)."""
    out: list = [None] * len(axes)
    used: set = set()
    dp = _dp_mode()

    def take(want):
        got = _filter(mesh_axes, want)
        if got is None:
            return None
        got = tuple(a for a in got if a not in used)
        if not got:
            return None
        used.update(got)
        return got if len(got) > 1 else got[0]

    # pass 1: TP dims ("vocab" stays model-sharded even in dp-mode)
    for i, name in enumerate(axes):
        if name in _TP_AXES:
            if name == "vocab" or not dp:
                out[i] = take(TP_AXIS)
    # pass 2: batch
    for i, name in enumerate(axes):
        if name == "batch":
            ba = BATCH_AXES + ((TP_AXIS,) if dp else ())
            out[i] = take(ba)
    # pass 3: fsdp embed
    for i, name in enumerate(axes):
        if name == "embed":
            fa = tuple(fsdp_axes) + ((TP_AXIS,) if dp and fsdp_axes else ())
            out[i] = take(fa)
    return P(*out)


# logical dims whose mesh axis must NOT be relocated when it doesn't divide:
# moving "model" onto head_dim makes every attention dot reshard (XLA
# "involuntary full rematerialization") — replicating KV/Q projections over
# model is far cheaper (the GQA-TP standard when kv_heads < TP degree).
_NO_RELOCATE = {"heads", "kv_heads"}


def repair_spec(spec: P, shape: tuple, mesh: Mesh, axes_names: tuple = (), relocate: bool = True) -> P:
    """Make ``spec`` valid for explicit in_shardings on ``shape``:

    1. drop any mesh-axis assignment whose shard count does not divide the
       dimension (jit argument shardings must divide evenly);
    2. relocate each dropped mesh axis onto the largest *free* dim that it
       does divide (granite's vocab 49155 -> d_model; decode caches ->
       sequence dim), EXCEPT axes dropped from head dims (_NO_RELOCATE),
       which replicate instead. The §Perf log discusses the consequences.
    """
    sizes = dict(mesh.shape)

    def nshards(entry) -> int:
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in names:
            n *= sizes.get(a, 1)
        return n

    entries = list(spec) + [None] * (len(shape) - len(spec))
    names = tuple(axes_names) + (None,) * (len(shape) - len(axes_names))
    dropped = []
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is not None and dim % nshards(e) != 0:
            if relocate and names[i] not in _NO_RELOCATE:
                dropped.append(e)
            entries[i] = None

    def astuple(e):
        return () if e is None else (e if isinstance(e, tuple) else (e,))

    for e in dropped:
        # prefer a free dim; else EXTEND an existing entry if the combined
        # shard count still divides (granite: d=4096 takes (data, model))
        frees = [
            (dim, i) for i, (ee, dim) in enumerate(zip(entries, shape))
            if ee is None and dim % nshards(e) == 0 and dim > 1
        ]
        if frees:
            _, i = max(frees)
            entries[i] = e
            continue
        exts = [
            (dim, i) for i, (ee, dim) in enumerate(zip(entries, shape))
            if ee is not None and not set(astuple(ee)) & set(astuple(e))
            and dim % (nshards(ee) * nshards(e)) == 0
        ]
        if exts:
            _, i = max(exts)
            entries[i] = astuple(entries[i]) + astuple(e)
    return P(*entries)


def param_specs(defs, mesh: Mesh, fsdp_axes=()):
    """PartitionSpec tree for a ParamDef tree (divisibility-repaired)."""
    ax = mesh.axis_names
    return jax.tree.map(
        lambda d: repair_spec(
            logical_to_spec(d.axes, ax, fsdp_axes), d.shape, mesh, d.axes
        ),
        defs,
        is_leaf=D.is_def,
    )


def param_shardings(defs, mesh: Mesh, fsdp_axes=()):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(defs, mesh, fsdp_axes)
    )


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """[B, ...] tokens: batch over ("pod","data"[,"model" in dp]), rest replicated."""
    ba = BATCH_AXES + ((TP_AXIS,) if _dp_mode() else ())
    b = _filter(mesh.axis_names, ba)
    return P(b, *([None] * extra_dims))


def constrain(x, mesh: Optional[Mesh], *axes):
    """with_sharding_constraint with mesh-axis names; no-op off-mesh.

    Drops (without relocation) any axis whose shard count does not divide
    the dimension — sharding 40 heads 16-ways would force GSPMD padding
    inside every attention einsum.
    """
    if mesh is None or mesh.empty:
        return x
    if _dp_mode():
        # model axis joins the batch axes; feature dims unshard
        def tr(a):
            if a == TP_AXIS or a == (TP_AXIS,):
                return None
            if isinstance(a, tuple) and set(a) <= set(BATCH_AXES):
                return tuple(a) + (TP_AXIS,)
            return a

        axes = tuple(tr(a) for a in axes)
    resolved = tuple(_filter(mesh.axis_names, a) for a in axes)
    resolved = tuple(
        (r if r is None or len(r) > 1 else r[0]) for r in resolved
    )
    spec = repair_spec(P(*resolved), x.shape, mesh, relocate=False)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


def constrain_logical(x, mesh: Optional[Mesh], *names):
    """Policy-aware activation constraint using LOGICAL axis names
    ("batch"/"vocab"/"heads"/...), repaired against x.shape. Relocation is
    ON: a non-dividing vocab axis moves to batch/seq dims (token sharding)
    rather than leaving huge logits under-sharded."""
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(tuple(names), mesh.axis_names, ())
    spec = repair_spec(spec, x.shape, mesh, tuple(names), relocate=True)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


def activation_spec(mesh: Mesh) -> P:
    """[B, S, d] hidden state: (pod,data) on batch, model on d."""
    ax = mesh.axis_names
    b = _filter(ax, BATCH_AXES)
    m = _filter(ax, TP_AXIS)
    return P(b, None, m if m is None or len(m) > 1 else m[0])
