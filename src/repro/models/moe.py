"""Mixture-of-Experts layer: capacity-based token dispatch, GSPMD-friendly.

Scale constraints drive the design (kimi-k2: 384 experts, 1M tokens/step,
top-8 => 8.4M assignment slots):

  * NO [tokens, experts, capacity] one-hot dispatch tensor (the GShard einsum
    formulation) — at 384 experts that is ~10^13 elements.  Instead tokens are
    scattered into a [E, C, d] buffer at (expert_id, position) and gathered
    back; overflow drops via scatter mode='drop'.
  * position-in-expert comes from a *hierarchical distributed cumsum*: the
    assignment axis is reshaped to [blocks, A/blocks] with blocks matching the
    (pod, data) sharding, so the inner cumsum is shard-local and only the tiny
    [blocks, E] block-sum cumsum crosses shards.  No all-gather of the
    one-hot; no distributed sort.
  * expert weights are sharded over "model" (expert parallelism); the buffer
    capacity dim over ("pod","data") — GSPMD inserts the all-to-all that
    physically moves tokens to their expert's shard.

Aux losses: switch-style load-balance loss + router z-loss, both returned.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import runtime
from repro.models.sharding import constrain


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array
    z_loss: jax.Array
    dropped_frac: jax.Array


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def moe_capacity(n_tokens: int, n_experts: int, top_k: int, capacity_factor: float, multiple: int = 128) -> int:
    c = int(np.ceil(n_tokens * top_k / n_experts * capacity_factor))
    return max(_round_up(c, multiple), multiple)


def distributed_cumsum(x: jax.Array, blocks: int) -> jax.Array:
    """Exclusive cumsum over axis 0 of [A, E], hierarchical in ``blocks``
    shard-aligned chunks (axis 0 is sharded over (pod, data))."""
    A, E = x.shape
    assert A % blocks == 0, (A, blocks)
    xb = x.reshape(blocks, A // blocks, E)
    inner = jnp.cumsum(xb, axis=1)  # inclusive, shard-local
    block_tot = inner[:, -1, :]  # [blocks, E]
    block_off = jnp.cumsum(block_tot, axis=0) - block_tot  # exclusive over blocks
    out = inner - xb + block_off[:, None, :]  # exclusive overall
    return out.reshape(A, E)


def moe_ffn(
    x: jax.Array,  # [T, d] tokens (flattened batch*seq)
    router_w: jax.Array,  # [d, E]
    w_gate: jax.Array,  # [E, d, f]
    w_up: jax.Array,  # [E, d, f]
    w_down: jax.Array,  # [E, f, d]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    mlp_kind: str = "swiglu",
    cumsum_blocks: int = 32,
    mesh=None,
) -> MoEOut:
    T, d = x.shape
    E = router_w.shape[1]
    C = moe_capacity(T, E, top_k, capacity_factor)

    # ---- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32))
    logits = constrain(logits, mesh, ("pod", "data"), "model")
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, top_k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # aux losses
    me = jnp.mean(probs, axis=0)  # [E] mean router prob
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eids, E, dtype=jnp.float32), axis=1), axis=0
    )  # [E] fraction of tokens routed (top-k hits)
    aux = E * jnp.sum(me * ce) / top_k
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- positions within expert (hierarchical cumsum, no sort) -----------
    A = T * top_k
    flat_e = eids.reshape(A)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)  # [A, E] sharded (pod,data) x model
    onehot = constrain(onehot, mesh, ("pod", "data"), "model")
    blocks = int(np.gcd(cumsum_blocks, A))
    pos = distributed_cumsum(onehot, blocks)  # exclusive counts
    pos = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # [A] position in expert
    dropped = (pos >= C).astype(jnp.float32)

    # ---- dispatch: scatter tokens into [E, C, d] ---------------------------
    tok_idx = jnp.repeat(jnp.arange(T), top_k)
    x_rep = jnp.take(x, tok_idx, axis=0)  # [A, d]
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[flat_e, pos].set(x_rep, mode="drop")  # overflow tokens dropped
    buf = constrain(buf, mesh, "model", ("pod", "data"), None)

    # ---- expert FFN ---------------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    if mlp_kind == "swiglu":
        u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    else:
        h = jax.nn.gelu(g)
    h = constrain(h, mesh, "model", ("pod", "data"), None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))
    out_buf = constrain(out_buf, mesh, "model", ("pod", "data"), None)

    # ---- combine: gather back and weight ------------------------------------
    flat_pos_ok = jnp.where(dropped > 0, C, pos)  # OOB -> fill 0
    y_rep = out_buf.at[flat_e, flat_pos_ok].get(mode="fill", fill_value=0)  # [A, d]
    y = jnp.sum(
        (y_rep * gate_vals.reshape(A, 1).astype(y_rep.dtype)).reshape(T, top_k, d), axis=1
    )
    y = constrain(y, mesh, ("pod", "data"), "model")
    return MoEOut(y=y, aux_loss=aux, z_loss=z, dropped_frac=jnp.mean(dropped))


def moe_ffn_shardmap(
    x: jax.Array,  # [T, d] GLOBAL tokens (sharded over data axes outside)
    router_w: jax.Array,  # [d, E]
    w_gate: jax.Array,  # [E, d, f]
    w_up: jax.Array,
    w_down: jax.Array,  # [E, f, d]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    mlp_kind: str = "swiglu",
    mesh=None,
    fsdp_axes: tuple = (),
    compute_dtype=jnp.bfloat16,
) -> MoEOut:
    """Expert-parallel MoE via an explicit SPMD map — the at-scale path.

    GSPMD cannot partition the dispatch scatter (it replicates the [E,C,d]
    buffer and all-reduces it: ~170 TB/step for kimi-k2). Under the
    SPMD-mapped body (runtime.spmd_map) every collective is explicit and
    minimal:

      * tokens stay on their (pod, data) shard for the whole block — routing,
        dispatch and combine are LOCAL (GShard per-shard capacity semantics);
      * x's model-sharded d dim is all-gathered once ([T_loc, d], bf16);
      * expert weights (sharded "experts"->model, d->fsdp axes) are
        ZeRO-3-gathered over the fsdp axes JUST-IN-TIME, cast to bf16 BEFORE
        the gather (halves link bytes vs f32);
      * each model shard computes only its E/ep experts for all local
        tokens; the combine is one psum over "model".

    Autodiff through the SPMD map transposes the gathers into reduce-scatters,
    giving the ZeRO-3 gradient schedule for free.
    """
    assert mesh is not None and "model" in mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ep = mesh.shape["model"]
    T, d = x.shape
    E = router_w.shape[1]
    assert E % ep == 0, (E, ep)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    T_loc = T // n_data
    C = moe_capacity(T_loc, E, top_k, capacity_factor, multiple=4)
    fsdp = tuple(a for a in fsdp_axes if a in mesh.axis_names)

    def body(x_loc, rw, wg, wu, wd):
        # x_loc [T_loc, d_loc] -> [T_loc, d]
        if mesh.shape["model"] > 1:
            x_full = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)
        else:
            x_full = x_loc
        logits = jnp.einsum("td,de->te", x_full.astype(jnp.float32), rw.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eids = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(eids, E, dtype=jnp.float32), axis=1), axis=0)
        aux = E * jnp.sum(me * ce) / top_k
        z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

        # local positions within each expert (exclusive cumsum of one-hot)
        A = T_loc * top_k
        flat_e = eids.reshape(A)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)
        pos = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)
        dropped = (pos >= C).astype(jnp.float32)

        # my experts only
        j = jax.lax.axis_index("model")
        e_loc = E // ep
        local_e = flat_e - j * e_loc  # in [0, e_loc) if mine
        mine = (local_e >= 0) & (local_e < e_loc)
        scatter_e = jnp.where(mine, local_e, e_loc)  # OOB -> dropped
        scatter_p = jnp.where(dropped > 0, C, pos)

        # index-based dispatch: scatter token INDICES (int32, tiny), gather
        # once — never materializes the [T_loc*top_k, d] replicated tokens
        tok_idx = jnp.repeat(jnp.arange(T_loc), top_k)
        inv = jnp.full((e_loc, C), T_loc, jnp.int32)  # sentinel = OOB row
        inv = inv.at[scatter_e, scatter_p].set(tok_idx, mode="drop")
        xd = x_full.astype(compute_dtype)
        buf = jnp.take(xd, inv.reshape(-1), axis=0, mode="fill", fill_value=0)
        buf = buf.reshape(e_loc, C, d)

        # ZeRO-3 just-in-time weight gather (bf16 over the wire)
        def gather_w(w, axis):
            w = w.astype(compute_dtype)
            for a in fsdp:
                w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
            return w

        # bf16 operands + f32 accumulation: keeps the ZeRO-3 weight gathers
        # and the dispatch buffer in bf16 through XLA (see layers.mm)
        g = jnp.einsum("ecd,edf->ecf", buf, gather_w(wg, 1),
                       preferred_element_type=jnp.float32).astype(compute_dtype)
        if mlp_kind == "swiglu":
            u = jnp.einsum("ecd,edf->ecf", buf, gather_w(wu, 1),
                           preferred_element_type=jnp.float32).astype(compute_dtype)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
        else:
            h = jax.nn.gelu(g)
        out_buf = jnp.einsum("ecf,efd->ecd", h, gather_w(wd, 2),
                             preferred_element_type=jnp.float32).astype(compute_dtype)

        # combine one top-k slot at a time ([T_loc, d] each) — never the
        # full [T_loc*top_k, d]
        gv = gate_vals.reshape(T_loc, top_k)
        se = scatter_e.reshape(T_loc, top_k)
        sp = scatter_p.reshape(T_loc, top_k)
        y = jnp.zeros((T_loc, d), compute_dtype)
        for s in range(top_k):
            ys = out_buf.at[se[:, s], sp[:, s]].get(mode="fill", fill_value=0)
            y = y + ys * gv[:, s : s + 1].astype(compute_dtype)
        y = jax.lax.psum(y, "model")  # combine expert contributions
        # aux losses: identical across model; average over data shards
        aux = jax.lax.pmean(aux, data_axes) if data_axes else aux
        z = jax.lax.pmean(z, data_axes) if data_axes else z
        dfrac = jax.lax.pmean(jnp.mean(dropped), data_axes) if data_axes else jnp.mean(dropped)
        return y, aux, z, dfrac

    P = jax.sharding.PartitionSpec
    d_spec = fsdp[0] if len(fsdp) == 1 else (tuple(fsdp) if fsdp else None)
    out = runtime.spmd_map(
        body,
        mesh=mesh,
        in_specs=(
            P(data_axes, "model"),      # x: tokens over data, d over model
            P(None, None),              # router replicated
            P("model", d_spec, None),   # w_gate [E, d, f]
            P("model", d_spec, None),   # w_up
            P("model", None, d_spec),   # w_down [E, f, d]
        ),
        out_specs=(P(data_axes, None), P(), P(), P()),
        check=False,
    )(x, router_w, w_gate, w_up, w_down)
    y, aux, z, dfrac = out
    return MoEOut(y=y.astype(x.dtype), aux_loss=aux, z_loss=z, dropped_frac=dfrac)


def moe_ffn_dense_ref(x, router_w, w_gate, w_up, w_down, *, top_k, mlp_kind="swiglu"):
    """No-capacity oracle: every token sees its full top-k experts (tests)."""
    T, d = x.shape
    E = router_w.shape[1]
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    gate_vals, eids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    def expert(e, xt):
        g = xt @ w_gate[e].astype(xt.dtype)
        if mlp_kind == "swiglu":
            h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * (xt @ w_up[e].astype(xt.dtype))
        else:
            h = jax.nn.gelu(g)
        return h @ w_down[e].astype(xt.dtype)

    all_out = jnp.stack([expert(e, x) for e in range(E)])  # [E, T, d]
    y = jnp.zeros_like(x)
    for s in range(top_k):
        sel = all_out[eids[:, s], jnp.arange(T)]  # [T, d]
        y = y + sel * gate_vals[:, s : s + 1].astype(x.dtype)
    return y
