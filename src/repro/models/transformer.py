"""Transformer spine: dense / MoE / VLM(cross-attn) / audio(multi-codebook)
families. Parameters are declared as ParamDef trees (defs.py) with per-layer
arrays stacked on a leading "layers" dim and applied via lax.scan (+remat),
so a 61-layer 1T-param model lowers to a small HLO.

Layer pattern handling:
  * homogeneous stacks (dense/moe)       -> single scan over L layers
  * periodic patterns (vlm: 4 self + 1 cross; handled in model.py for
    hybrid) -> scan over GROUPS whose body runs an inner scan over the
    homogeneous sub-stack plus the special layer, keeping HLO size O(1) in
    depth.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import defs as D
from repro.models.layers import (
    apply_rope,
    attention,
    decode_attention,
    mlp_act,
    mm,
    rms_norm,
)
from repro.models.moe import moe_ffn
from repro.models.sharding import constrain

P_ = D.ParamDef


# --------------------------------------------------------------------------- #
# param definitions
# --------------------------------------------------------------------------- #


def attn_defs(cfg: ModelConfig, L: int, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    defs = {
        "ln1": P_((L, cfg.d_model) if d_in is None else (L, d), ("layers", None), "ones"),
        "wq": P_((L, d, H, hd), ("layers", "embed", "heads", None)),
        "wk": P_((L, d, KV, hd), ("layers", "embed", "kv_heads", None)),
        "wv": P_((L, d, KV, hd), ("layers", "embed", "kv_heads", None)),
        "wo": P_((L, H * hd, cfg.d_model), ("layers", "heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = P_((L, H, hd), ("layers", "heads", None), "zeros")
        defs["bk"] = P_((L, KV, hd), ("layers", "kv_heads", None), "zeros")
        defs["bv"] = P_((L, KV, hd), ("layers", "kv_heads", None), "zeros")
    return defs


def mlp_defs(cfg: ModelConfig, L: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    defs = {
        "ln2": P_((L, d), ("layers", None), "ones"),
        "w_gate": P_((L, d, f), ("layers", "embed", "ff")),
        "w_down": P_((L, f, d), ("layers", "ff", "embed")),
    }
    if cfg.mlp_type == "swiglu":
        defs["w_up"] = P_((L, d, f), ("layers", "embed", "ff"))
    return defs


def moe_defs(cfg: ModelConfig, L: int) -> dict:
    d, e = cfg.d_model, cfg.moe
    f = e.d_ff_expert
    return {
        "ln2": P_((L, d), ("layers", None), "ones"),
        "router": P_((L, d, e.n_experts), ("layers", "embed", None), "normal", 0.1),
        "w_gate": P_((L, e.n_experts, d, f), ("layers", "experts", "embed", None)),
        "w_up": P_((L, e.n_experts, d, f), ("layers", "experts", "embed", None)),
        "w_down": P_((L, e.n_experts, f, d), ("layers", "experts", None, "embed")),
    }


def transformer_defs(cfg: ModelConfig) -> dict:
    V, d = cfg.vocab_size, cfg.d_model
    ncb = cfg.audio.n_codebooks if cfg.audio else 1
    defs: dict = {
        "embed": P_((ncb, V, d), (None, "vocab", "embed"), "embed", 0.02),
        "final_norm": P_((d,), (None,), "ones"),
        "lm_head": P_((ncb, d, V), (None, "embed", "vocab")),
    }
    if cfg.family == "moe":
        L = cfg.n_layers
        defs["layers"] = {**attn_defs(cfg, L), **moe_defs(cfg, L)}
    elif cfg.vision:
        k = cfg.vision.cross_attn_every
        n_cross = cfg.n_layers // k
        n_self = cfg.n_layers - n_cross
        assert n_self % n_cross == 0
        defs["layers"] = {**attn_defs(cfg, n_self), **mlp_defs(cfg, n_self)}
        cross = {**attn_defs(cfg, n_cross), **mlp_defs(cfg, n_cross)}
        cross["attn_gate"] = P_((n_cross,), ("layers",), "zeros")
        cross["mlp_gate"] = P_((n_cross,), ("layers",), "zeros")
        defs["cross_layers"] = cross
        defs["patch_proj"] = P_((cfg.vision.d_vision, d), (None, "embed"))
    else:  # dense / audio
        L = cfg.n_layers
        defs["layers"] = {**attn_defs(cfg, L), **mlp_defs(cfg, L)}
    return defs


# --------------------------------------------------------------------------- #
# blocks (single layer, weights WITHOUT the leading L dim)
# --------------------------------------------------------------------------- #


def _proj_qkv(cfg: ModelConfig, p: dict, x: jax.Array):
    q = mm("bsd,dhk->bshk", x, p["wq"])
    k = mm("bsd,dhk->bshk", x, p["wk"])
    v = mm("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def self_attn_block(cfg: ModelConfig, p: dict, h: jax.Array, positions: jax.Array, mesh=None):
    """Full-sequence causal self-attention sublayer. Returns (out, (k, v))."""
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    q, k, v = _proj_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, mesh, ("pod", "data"), None, "model", None)
    k = constrain(k, mesh, ("pod", "data"), None, "model", None)
    o = attention(q, k, v, causal=True, use_flash=False)
    B, S = h.shape[:2]
    out = mm("bshk,hkd->bsd", o, p["wo"].reshape(cfg.n_heads, cfg.hd, -1))
    return out, (k, v)


def self_attn_decode(cfg: ModelConfig, p: dict, h: jax.Array, k_cache, v_cache, lens, mesh=None):
    """One-token self-attention against a KV cache. h: [B, 1, d]; lens: [B]
    per-slot valid lengths (the new token lands at position lens[b]).
    Returns (out, new_k_cache, new_v_cache)."""
    B = h.shape[0]
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    q, k, v = _proj_qkv(cfg, p, x)
    pos = jnp.reshape(lens, (B, 1))
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    # per-slot insert at lens[b]
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, lens].set(k[:, 0].astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[bidx, lens].set(v[:, 0].astype(v_cache.dtype), mode="drop")
    o = decode_attention(
        jnp.swapaxes(q, 1, 2),
        jnp.swapaxes(k_cache, 1, 2).astype(q.dtype),
        jnp.swapaxes(v_cache, 1, 2).astype(q.dtype),
        lens + 1,
    )
    o = jnp.swapaxes(o, 1, 2)
    out = mm("bshk,hkd->bsd", o, p["wo"].reshape(cfg.n_heads, cfg.hd, -1))
    return out, k_cache, v_cache


def cross_attn_block(cfg: ModelConfig, p: dict, h: jax.Array, kv_k, kv_v, mesh=None):
    """Cross-attention against precomputed vision K/V [B, P, KV, hd]."""
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    q = mm("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    o = attention(q, kv_k.astype(q.dtype), kv_v.astype(q.dtype), causal=False, use_flash=False)
    out = mm("bshk,hkd->bsd", o, p["wo"].reshape(cfg.n_heads, cfg.hd, -1))
    return out


def vision_kv(cfg: ModelConfig, p: dict, vis: jax.Array):
    """K/V from projected vision embeddings for ONE cross layer."""
    k = mm("bpd,dhk->bphk", vis, p["wk"])
    v = mm("bpd,dhk->bphk", vis, p["wv"])
    if cfg.qkv_bias:
        k = k + p["bk"].astype(vis.dtype)
        v = v + p["bv"].astype(vis.dtype)
    return k, v


def mlp_block(cfg: ModelConfig, p: dict, h: jax.Array, mesh=None):
    x = rms_norm(h, p["ln2"], cfg.norm_eps)
    g = mm("bsd,df->bsf", x, p["w_gate"])
    g = constrain(g, mesh, ("pod", "data"), None, "model")
    up = None
    if cfg.mlp_type == "swiglu":
        up = mm("bsd,df->bsf", x, p["w_up"])
    a = mlp_act(g, up, cfg.mlp_type)
    return mm("bsf,fd->bsd", a, p["w_down"])


def moe_block(cfg: ModelConfig, p: dict, h: jax.Array, mesh=None):
    from repro.models.moe import moe_ffn_shardmap
    from repro.models.sharding import fsdp_axes_for

    B, S, d = h.shape
    x = rms_norm(h, p["ln2"], cfg.norm_eps)
    if mesh is not None and not mesh.empty and "model" in mesh.axis_names:
        out = moe_ffn_shardmap(
            x.reshape(B * S, d),
            p["router"], p["w_gate"], p["w_up"], p["w_down"],
            top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
            mlp_kind=cfg.mlp_type,
            mesh=mesh,
            fsdp_axes=fsdp_axes_for(cfg),
            compute_dtype=jnp.dtype(cfg.dtype),
        )
    else:
        out = moe_ffn(
            x.reshape(B * S, d),
            p["router"], p["w_gate"], p["w_up"], p["w_down"],
            top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
            mlp_kind=cfg.mlp_type,
            mesh=mesh,
        )
    return out.y.reshape(B, S, d).astype(h.dtype), out.aux_loss, out.z_loss


# --------------------------------------------------------------------------- #
# embedding / head / loss
# --------------------------------------------------------------------------- #


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array, dtype) -> jax.Array:
    """tokens: [B, S] or [B, S, ncb] (audio). Sum of codebook embeddings."""
    emb = params["embed"]
    if cfg.audio:
        out = 0.0
        for c in range(cfg.audio.n_codebooks):
            out = out + jnp.take(emb[c], tokens[..., c], axis=0)
        return out.astype(dtype)
    return jnp.take(emb[0], tokens, axis=0).astype(dtype)


def lm_logits(cfg: ModelConfig, params: dict, h: jax.Array, mesh=None) -> jax.Array:
    """[B, S, d] -> [B, S, (ncb,) V] fp32 logits."""
    from repro.models.sharding import constrain_logical

    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,cdv->bscv", hn, params["lm_head"].astype(hn.dtype))
    logits = constrain_logical(logits, mesh, "batch", None, None, "vocab")
    if not cfg.audio:
        logits = logits[:, :, 0, :]
    return logits.astype(jnp.float32)


def xent_loss(logits: jax.Array, labels: jax.Array, ignore: int = -1):
    """Mean token cross-entropy; labels broadcast against [..., V] logits."""
    V = logits.shape[-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels != ignore).astype(jnp.float32)
    loss = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss


def chunked_xent(cfg: ModelConfig, params: dict, h: jax.Array, labels: jax.Array,
                 mesh=None, chunk: int = 256, ignore: int = -1):
    """Cross-entropy WITHOUT materializing [B, S, V] logits.

    The head matmul + softmax run per sequence-chunk inside a checkpointed
    scan, so peak logits memory is [B, chunk, V] — decisive when V doesn't
    divide the model axis (granite's 49155) and the full fp32 logits would
    be ~13 GB/device. Identical value+grads to xent_loss(lm_logits(h))."""
    B, S, d = h.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    hb = jnp.moveaxis(h.reshape(B, nc, c, d), 1, 0)  # [nc, B, c, d]
    lb = jnp.moveaxis(labels.reshape((B, nc, c) + labels.shape[2:]), 1, 0)

    def body(acc, xs):
        hc, lc = xs
        logits = lm_logits(cfg, params, hc, mesh)  # [B, c, (ncb,) V] fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None].clip(0), axis=-1)[..., 0]
        mask = (lc != ignore).astype(jnp.float32)
        return (acc[0] + jnp.sum((lse - gold) * mask), acc[1] + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)), (hb, lb)
    )
    return tot / jnp.maximum(cnt, 1.0)
