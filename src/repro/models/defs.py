"""Parameter-definition system: one declarative source of truth per model.

A model's parameters are described as a pytree of ``ParamDef`` leaves
(shape + logical axis names + initializer).  From that single tree we derive

  * ``init_params``      — materialized arrays (smoke tests / real training),
  * ``abstract_params``  — ShapeDtypeStructs (the dry-run lowers against these,
                           so a 1T-param model never allocates),
  * ``partition_specs``  — PartitionSpec tree from logical-axis rules
                           (see models/sharding.py).

Logical axis names used across the zoo:
  "layers"   scan dimension over layers (never sharded)
  "vocab"    vocabulary dim                  -> "model"
  "heads"    attention-head dim (q)          -> "model"
  "kv_heads" attention-head dim (kv)         -> "model"
  "ff"       MLP hidden dim                  -> "model"
  "experts"  MoE expert dim                  -> "model"  (expert parallelism)
  "d_inner"  SSM channel dim                 -> "model"
  "embed"    d_model dim                     -> FSDP axes when cfg.fsdp
  "embed2"   second d_model-sized dim        -> never sharded (avoids 2D clash)
  None       unsharded dim
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis name per dim
    init: str = "normal"  # normal | zeros | ones | embed | ssm_a | conv
    scale: float = 1.0  # fan-in override multiplier
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "ssm_a":
        # mamba A_log init: log(1..16) tiled over the state dim
        n = d.shape[-1]
        a = jnp.tile(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), d.shape[:-1] + (1,))
        return a.astype(d.dtype)
    if d.init == "dt_bias":
        # mamba dt bias: softplus^-1 of dt in [1e-3, 1e-1], log-uniform-ish
        u = jnp.linspace(math.log(1e-3), math.log(1e-1), num=int(np.prod(d.shape)))
        dt = jnp.exp(u).reshape(d.shape)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(d.dtype)
    # normal / embed: truncated-normal-ish with 1/sqrt(fan_in)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    if d.init == "embed":
        fan_in = 1.0
    std = d.scale / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(d.dtype)


def init_params(defs, key: jax.Array):
    """Materialize a ParamDef tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs):
    """ShapeDtypeStruct tree — zero allocation (dry-run path)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def param_count(defs) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(defs, is_leaf=is_def))


def param_bytes(defs) -> int:
    return sum(
        int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
        for d in jax.tree.leaves(defs, is_leaf=is_def)
    )


def map_axes(defs, fn: Callable[[tuple], Any]):
    """Apply ``fn(axes_tuple) -> spec`` over the def tree (spec derivation)."""
    return jax.tree.map(lambda d: fn(d.axes), defs, is_leaf=is_def)
