"""Real JAX models for all 10 assigned architectures (DESIGN.md §4 role 2)."""
from repro.models.defs import (  # noqa: F401
    ParamDef,
    abstract_params,
    init_params,
    param_bytes,
    param_count,
)
from repro.models.model import Model, build_model  # noqa: F401
from repro.models.sharding import (  # noqa: F401
    activation_spec,
    batch_spec,
    param_shardings,
    param_specs,
)
