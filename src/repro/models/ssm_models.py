"""SSM-family model pieces: Mamba1 (falcon-mamba) and Mamba2+shared-attention
hybrid (zamba2). Param defs + per-layer apply functions (train seq + decode
step). Stacking/scanning over layers happens in model.py."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import defs as D
from repro.models.layers import mlp_act, mm, rms_norm
from repro.models.mamba import (
    causal_conv1d,
    conv_step,
    selective_scan,
    selective_scan_step,
    ssd_scan,
    ssd_step,
)
from repro.models.sharding import constrain

P_ = D.ParamDef


# --------------------------------------------------------------------------- #
# Mamba1 (falcon-mamba)
# --------------------------------------------------------------------------- #


def mamba1_defs(cfg: ModelConfig) -> dict:
    L, d, di = cfg.n_layers, cfg.d_model, cfg.d_inner
    s, dtr = cfg.ssm, cfg.dt_rank
    return {
        "norm": P_((L, d), ("layers", None), "ones"),
        "in_proj": P_((L, d, 2 * di), ("layers", "embed", "d_inner")),
        "conv_w": P_((L, s.d_conv, di), ("layers", None, "d_inner")),
        "conv_b": P_((L, di), ("layers", "d_inner"), "zeros"),
        "x_proj": P_((L, di, dtr + 2 * s.d_state), ("layers", "d_inner", None)),
        "dt_proj": P_((L, dtr, di), ("layers", None, "d_inner")),
        "dt_bias": P_((L, di), ("layers", "d_inner"), "dt_bias"),
        "A_log": P_((L, di, s.d_state), ("layers", "d_inner", None), "ssm_a"),
        "D": P_((L, di), ("layers", "d_inner"), "ones"),
        "out_proj": P_((L, di, d), ("layers", "d_inner", "embed")),
    }


def _mamba1_inner(cfg: ModelConfig, lp: dict, x: jax.Array, mesh):
    """Shared pre-scan computation. x: [B, S, d] normed input."""
    di, s, dtr = cfg.d_inner, cfg.ssm, cfg.dt_rank
    xz = mm("bsd,de->bse", x, lp["in_proj"])
    xz = constrain(xz, mesh, ("pod", "data"), None, "model")
    xi, zg = jnp.split(xz, 2, axis=-1)
    return xi, zg


def _mamba1_bcdt(cfg, lp, xi):
    s, dtr = cfg.ssm, cfg.dt_rank
    bcdt = mm("bse,ek->bsk", xi, lp["x_proj"])
    dt_low = bcdt[..., :dtr]
    Bc = bcdt[..., dtr : dtr + s.d_state].astype(jnp.float32)
    Cc = bcdt[..., dtr + s.d_state :].astype(jnp.float32)
    dt = mm("bsk,ke->bse", dt_low, lp["dt_proj"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    return dt, Bc, Cc


def mamba1_layer(cfg: ModelConfig, lp: dict, h: jax.Array, mesh=None, chunk: int = 64):
    """Full-sequence Mamba1 block. h: [B, S, d]."""
    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    xi, zg = _mamba1_inner(cfg, lp, x, mesh)
    xi = causal_conv1d(xi, lp["conv_w"], lp["conv_b"])
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(h.dtype)
    dt, Bc, Cc = _mamba1_bcdt(cfg, lp, xi)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, _ = selective_scan(xi, dt, A, Bc, Cc, lp["D"].astype(jnp.float32), chunk=chunk)
    y = y * jax.nn.silu(zg.astype(jnp.float32)).astype(h.dtype)
    out = mm("bse,ed->bsd", y, lp["out_proj"])
    return h + out


def mamba1_decode(cfg: ModelConfig, lp: dict, h: jax.Array, conv_buf, state, mesh=None):
    """One-token step. h: [B, 1, d]; conv_buf [B, K-1, di]; state [B, di, N]."""
    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    xi, zg = _mamba1_inner(cfg, lp, x, mesh)
    xi_t, conv_buf = conv_step(xi[:, 0], conv_buf, lp["conv_w"], lp["conv_b"])
    xi_t = jax.nn.silu(xi_t.astype(jnp.float32)).astype(h.dtype)
    dt, Bc, Cc = _mamba1_bcdt(cfg, lp, xi_t[:, None])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, state = selective_scan_step(
        xi_t, dt[:, 0], A, Bc[:, 0], Cc[:, 0], lp["D"].astype(jnp.float32), state
    )
    y = y[:, None] * jax.nn.silu(zg.astype(jnp.float32)).astype(h.dtype)
    out = mm("bse,ed->bsd", y, lp["out_proj"])
    return h + out, conv_buf, state


# --------------------------------------------------------------------------- #
# Mamba2 layer (zamba2 hybrid)
# --------------------------------------------------------------------------- #


def mamba2_defs(cfg: ModelConfig, L: int) -> dict:
    d, di, s = cfg.d_model, cfg.d_inner, cfg.ssm
    nh = di // s.head_dim
    N = s.d_state
    return {
        "norm": P_((L, d), ("layers", None), "ones"),
        "in_proj": P_((L, d, 2 * di + 2 * N + nh), ("layers", "embed", "d_inner")),
        "conv_w": P_((L, s.d_conv, di + 2 * N), ("layers", None, "d_inner")),
        "conv_b": P_((L, di + 2 * N), ("layers", "d_inner"), "zeros"),
        "dt_bias": P_((L, nh), ("layers", None), "dt_bias"),
        "A_log": P_((L, nh), ("layers", None), "ssm_a"),
        "D": P_((L, nh), ("layers", None), "ones"),
        "norm_g": P_((L, di), ("layers", "d_inner"), "ones"),
        "out_proj": P_((L, di, d), ("layers", "d_inner", "embed")),
    }


def _mamba2_split(cfg: ModelConfig, proj: jax.Array):
    di, N = cfg.d_inner, cfg.ssm.d_state
    nh = di // cfg.ssm.head_dim
    xi = proj[..., :di]
    zg = proj[..., di : 2 * di]
    Bc = proj[..., 2 * di : 2 * di + N]
    Cc = proj[..., 2 * di + N : 2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N :]
    return xi, zg, Bc, Cc, dt


def mamba2_layer(cfg: ModelConfig, lp: dict, h: jax.Array, mesh=None, chunk: int = 64):
    B, S, _ = h.shape
    di, s = cfg.d_inner, cfg.ssm
    nh, N = di // s.head_dim, s.d_state
    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    proj = mm("bsd,de->bse", x, lp["in_proj"])
    proj = constrain(proj, mesh, ("pod", "data"), None, "model")
    xi, zg, Bc, Cc, dt = _mamba2_split(cfg, proj)
    xbc = causal_conv1d(jnp.concatenate([xi, Bc, Cc], -1), lp["conv_w"], lp["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(h.dtype)
    xi, Bc, Cc = xbc[..., :di], xbc[..., di : di + N], xbc[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, _ = ssd_scan(
        xi.reshape(B, S, nh, s.head_dim), dt, A,
        Bc.astype(jnp.float32), Cc.astype(jnp.float32), chunk=chunk,
    )
    y = y.reshape(B, S, di) + xi * lp["D"].astype(jnp.float32).repeat(s.head_dim)[None, None]
    y = rms_norm(y * jax.nn.silu(zg.astype(jnp.float32)).astype(h.dtype), lp["norm_g"], cfg.norm_eps)
    out = mm("bse,ed->bsd", y.astype(h.dtype), lp["out_proj"])
    return h + out.astype(h.dtype)


def mamba2_decode(cfg: ModelConfig, lp: dict, h: jax.Array, conv_buf, state, mesh=None):
    """h: [B,1,d]; conv_buf [B, K-1, di+2N]; state [B, nh, N, hd_ssm] fp32."""
    B = h.shape[0]
    di, s = cfg.d_inner, cfg.ssm
    nh, N = di // s.head_dim, s.d_state
    x = rms_norm(h, lp["norm"], cfg.norm_eps)
    proj = mm("bsd,de->bse", x, lp["in_proj"])
    xi, zg, Bc, Cc, dt = _mamba2_split(cfg, proj)
    xbc_t, conv_buf = conv_step(
        jnp.concatenate([xi, Bc, Cc], -1)[:, 0], conv_buf, lp["conv_w"], lp["conv_b"]
    )
    xbc_t = jax.nn.silu(xbc_t.astype(jnp.float32)).astype(h.dtype)
    xi_t, B_t, C_t = xbc_t[..., :di], xbc_t[..., di : di + N], xbc_t[..., di + N :]
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, state = ssd_step(
        xi_t.reshape(B, nh, s.head_dim), dt_t, A,
        B_t.astype(jnp.float32), C_t.astype(jnp.float32), state,
    )
    y = y.reshape(B, di) + xi_t * lp["D"].astype(jnp.float32).repeat(s.head_dim)[None]
    y = rms_norm(
        y[:, None] * jax.nn.silu(zg.astype(jnp.float32)).astype(h.dtype),
        lp["norm_g"], cfg.norm_eps,
    )
    out = mm("bse,ed->bsd", y.astype(h.dtype), lp["out_proj"])
    return h + out.astype(h.dtype), conv_buf, state


# --------------------------------------------------------------------------- #
# zamba2 shared attention block (weights shared across invocations)
# --------------------------------------------------------------------------- #


def shared_block_defs(cfg: ModelConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ff = cfg.hybrid.shared_attn_mlp_ff
    return {
        "ln1": P_((2 * d,), (None,), "ones"),
        "wq": P_((2 * d, H, hd), (None, "heads", None)),
        "wk": P_((2 * d, KV, hd), (None, "kv_heads", None)),
        "wv": P_((2 * d, KV, hd), (None, "kv_heads", None)),
        "wo": P_((H * hd, d), ("heads", "embed")),
        "ln2": P_((d,), (None,), "ones"),
        "w_gate": P_((d, ff), ("embed", "ff")),
        "w_up": P_((d, ff), ("embed", "ff")),
        "w_down": P_((ff, d), ("ff", "embed")),
    }
