"""The Model API: build_model(cfg) -> Model with loss / prefill / decode_step.

All methods are pure functions of (params, inputs) suitable for jit/pjit;
``mesh`` only adds with_sharding_constraint annotations (no-op on 1 device).

Scan/remat structure (drives both compile time and the HBM footprint that
``compiled.memory_analysis()`` reports in the dry-run):
  * homogeneous layer stacks  -> lax.scan over stacked params
  * periodic patterns (VLM 4 self + 1 cross; zamba2 k mamba + shared attn)
    -> scan over groups, inner scan over the homogeneous run
  * cfg.remat: "full" checkpoints each scan body (save only the residual
    stream), "dots" saves matmul outputs, "none" disables.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import defs as D
from repro.models import ssm_models as S
from repro.models import transformer as T
from repro.models.layers import apply_rope, attention, decode_attention, mlp_act, mm, rms_norm
from repro.models.sharding import constrain, param_specs


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # full


# numerics-sensitive leaves stay fp32; everything else is pre-cast to the
# compute dtype BEFORE the layer scan so ZeRO-3 all-gathers and HBM weight
# reads move bf16, not fp32 (§Perf hillclimb 1, iteration 2: halves both)
_KEEP_F32 = {"norm", "ln1", "ln2", "norm_g", "final_norm", "A_log", "dt_bias",
             "D", "conv_b", "conv_w", "attn_gate", "mlp_gate", "router"}


def cast_layer_params(cfg: ModelConfig, tree: dict) -> dict:
    dt = jnp.dtype(cfg.dtype)

    def cast(k, x):
        if k in _KEEP_F32 or x.dtype != jnp.float32:
            return x
        return x.astype(dt)

    return {k: cast(k, v) for k, v in tree.items()}


def _precast(cfg: ModelConfig, params: dict) -> dict:
    out = dict(params)
    for key in ("layers", "shared", "cross_layers"):
        if key in params:
            out[key] = cast_layer_params(cfg, params[key])
    if "lm_head" in params and params["lm_head"].dtype == jnp.float32:
        out["lm_head"] = params["lm_head"].astype(jnp.dtype(cfg.dtype))
    return out


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------- #
# zamba2 shared attention block (full-seq + decode)
# --------------------------------------------------------------------------- #


def _shared_block(cfg: ModelConfig, sp: dict, h, h0, positions, mesh):
    """Full-sequence shared block. Returns (h_new, (k, v))."""
    xin = jnp.concatenate([h, h0], axis=-1)  # [B, S, 2d]
    x = rms_norm(xin, sp["ln1"], cfg.norm_eps)
    q = mm("bsd,dhk->bshk", x, sp["wq"])
    k = mm("bsd,dhk->bshk", x, sp["wk"])
    v = mm("bsd,dhk->bshk", x, sp["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, mesh, ("pod", "data"), None, "model", None)
    o = attention(q, k, v, causal=True, use_flash=False)
    a = mm("bshk,hkd->bsd", o, sp["wo"].reshape(cfg.n_heads, cfg.hd, -1))
    h = h + a
    x2 = rms_norm(h, sp["ln2"], cfg.norm_eps)
    g = mm("bsd,df->bsf", x2, sp["w_gate"])
    u = mm("bsd,df->bsf", x2, sp["w_up"])
    m = mm("bsf,fd->bsd", T.mlp_act(g, u, "swiglu"), sp["w_down"])
    return h + m, (k, v)


def _shared_block_decode(cfg: ModelConfig, sp: dict, h, h0, k_cache, v_cache, lens, mesh, seq_shard=False):
    B = h.shape[0]
    xin = jnp.concatenate([h, h0], axis=-1)
    x = rms_norm(xin, sp["ln1"], cfg.norm_eps)
    q = mm("bsd,dhk->bshk", x, sp["wq"])
    k = mm("bsd,dhk->bshk", x, sp["wk"])
    v = mm("bsd,dhk->bshk", x, sp["wv"])
    pos = jnp.reshape(lens, (B, 1))
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, lens].set(k[:, 0].astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[bidx, lens].set(v[:, 0].astype(v_cache.dtype), mode="drop")
    cache_axes = (None, ("pod", "data"), "model", None) if seq_shard else (("pod", "data"), None, "model", None)
    k_cache = constrain(k_cache, mesh, *cache_axes)
    v_cache = constrain(v_cache, mesh, *cache_axes)
    o = decode_attention(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k_cache, 1, 2).astype(q.dtype),
        jnp.swapaxes(v_cache, 1, 2).astype(q.dtype), lens + 1,
    )
    a = mm("bshk,hkd->bsd", jnp.swapaxes(o, 1, 2), sp["wo"].reshape(cfg.n_heads, cfg.hd, -1))
    h = h + a
    x2 = rms_norm(h, sp["ln2"], cfg.norm_eps)
    g = mm("bsd,df->bsf", x2, sp["w_gate"])
    u = mm("bsd,df->bsf", x2, sp["w_up"])
    m = mm("bsf,fd->bsd", T.mlp_act(g, u, "swiglu"), sp["w_down"])
    return h + m, k_cache, v_cache


# --------------------------------------------------------------------------- #
# Model
# --------------------------------------------------------------------------- #


@dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params --
    def param_defs(self) -> dict:
        cfg = self.cfg
        if cfg.family in ("dense", "audio", "vlm", "moe"):
            defs = T.transformer_defs(cfg)
        elif cfg.family == "ssm":
            defs = {
                "embed": D.ParamDef((1, cfg.vocab_size, cfg.d_model), (None, "vocab", "embed"), "embed", 0.02),
                "final_norm": D.ParamDef((cfg.d_model,), (None,), "ones"),
                "lm_head": D.ParamDef((1, cfg.d_model, cfg.vocab_size), (None, "embed", "vocab")),
                "layers": S.mamba1_defs(cfg),
            }
        elif cfg.family == "hybrid":
            defs = {
                "embed": D.ParamDef((1, cfg.vocab_size, cfg.d_model), (None, "vocab", "embed"), "embed", 0.02),
                "final_norm": D.ParamDef((cfg.d_model,), (None,), "ones"),
                "lm_head": D.ParamDef((1, cfg.d_model, cfg.vocab_size), (None, "embed", "vocab")),
                "layers": S.mamba2_defs(cfg, cfg.n_layers),
                "shared": S.shared_block_defs(cfg),
            }
        else:
            raise ValueError(cfg.family)
        if cfg.param_dtype != "float32":
            # weight matrices stored reduced-precision; norms/biases/SSM
            # constants stay fp32 for numerics
            pd = jnp.dtype(cfg.param_dtype)
            defs = jax.tree.map(
                lambda d: (
                    D.ParamDef(d.shape, d.axes, d.init, d.scale, pd)
                    if d.init in ("normal", "embed") else d
                ),
                defs,
                is_leaf=D.is_def,
            )
        return defs

    def init(self, key: jax.Array):
        return D.init_params(self.param_defs(), key)

    def abstract_params(self):
        return D.abstract_params(self.param_defs())

    def param_count(self) -> int:
        return D.param_count(self.param_defs())

    def specs(self, mesh, fsdp_axes=None):
        if fsdp_axes is None:
            fsdp_axes = self.fsdp_axes()
        return param_specs(self.param_defs(), mesh, fsdp_axes)

    def fsdp_axes(self) -> tuple:
        from repro.models.sharding import fsdp_axes_for

        return fsdp_axes_for(self.cfg)

    # ------------------------------------------------------------ forward --
    def forward(self, params, tokens, *, vision=None, mesh=None, collect_cache=False,
                max_len=0, head=True):
        """Full-sequence forward. tokens [B,S(,ncb)]; returns (logits, aux, caches)
        — or (hidden, aux, caches) when head=False (the loss path computes
        logits chunk-wise instead; see transformer.chunked_xent).
        """
        cfg = self.cfg
        dt = _dtype(cfg)
        B, Sq = tokens.shape[:2]
        params = _precast(cfg, params)
        h = T.embed_tokens(cfg, params, tokens, dt)
        h = constrain(h, mesh, ("pod", "data"), None, "model")
        positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
        aux = {"moe_aux": jnp.float32(0.0), "moe_z": jnp.float32(0.0)}
        caches: dict = {}

        if cfg.family in ("dense", "audio"):
            def body(hh, lp):
                a, kv = T.self_attn_block(cfg, lp, hh, positions, mesh)
                hh = hh + a
                hh = hh + T.mlp_block(cfg, lp, hh, mesh)
                hh = constrain(hh, mesh, ("pod", "data"), None, "model")
                return hh, kv if collect_cache else None

            h, ys = jax.lax.scan(_remat(body, cfg.remat), h, params["layers"])
            if collect_cache:
                caches["k"], caches["v"] = ys

        elif cfg.family == "moe":
            def body(hh, lp):
                a, kv = T.self_attn_block(cfg, lp, hh, positions, mesh)
                hh = hh + a
                m, la, lz = T.moe_block(cfg, lp, hh, mesh)
                hh = hh + m
                hh = constrain(hh, mesh, ("pod", "data"), None, "model")
                return hh, ((la, lz) if not collect_cache else (la, lz, kv))

            h, ys = jax.lax.scan(_remat(body, cfg.remat), h, params["layers"])
            if collect_cache:
                la, lz, kv = ys
                caches["k"], caches["v"] = kv
            else:
                la, lz = ys
            aux["moe_aux"], aux["moe_z"] = jnp.mean(la), jnp.mean(lz)

        elif cfg.family == "vlm":
            k = cfg.vision.cross_attn_every
            n_cross = cfg.n_layers // k
            vis = mm("bpe,ed->bpd", vision.astype(dt), params["patch_proj"])
            grouped = jax.tree.map(
                lambda x: x.reshape((n_cross, k - 1) + x.shape[1:]), params["layers"]
            )

            def self_body(hh, lp):
                a, kv = T.self_attn_block(cfg, lp, hh, positions, mesh)
                hh = hh + a
                hh = hh + T.mlp_block(cfg, lp, hh, mesh)
                return hh, kv if collect_cache else None

            def group_body(hh, xs):
                glp, clp = xs
                hh, kvs = jax.lax.scan(_remat(self_body, cfg.remat), hh, glp)
                kv_k, kv_v = T.vision_kv(cfg, clp, vis)
                a = T.cross_attn_block(cfg, clp, hh, kv_k, kv_v, mesh)
                hh = hh + a * jnp.tanh(clp["attn_gate"]).astype(dt)
                hh = hh + T.mlp_block(cfg, clp, hh, mesh) * jnp.tanh(clp["mlp_gate"]).astype(dt)
                hh = constrain(hh, mesh, ("pod", "data"), None, "model")
                return hh, (kvs, (kv_k, kv_v)) if collect_cache else None

            h, ys = jax.lax.scan(group_body, h, (grouped, params["cross_layers"]))
            if collect_cache:
                (sk, sv), (xk, xv) = ys[0], ys[1]
                caches["k"] = sk.reshape((-1,) + sk.shape[2:])
                caches["v"] = sv.reshape((-1,) + sv.shape[2:])
                caches["xk"], caches["xv"] = xk, xv

        elif cfg.family == "ssm":
            ck = _scan_chunk(Sq)

            def body(hh, lp):
                return S.mamba1_layer(cfg, lp, hh, mesh, chunk=ck), None

            h, _ = jax.lax.scan(_remat(body, cfg.remat), h, params["layers"])

        elif cfg.family == "hybrid":
            k = cfg.hybrid.attn_every
            G = cfg.n_layers // k
            h0 = h
            ck = _scan_chunk(Sq)
            grouped, tail = _split_groups(params["layers"], G, k)

            def inner(hh, lp):
                return S.mamba2_layer(cfg, lp, hh, mesh, chunk=ck), None

            def group_body(hh, glp):
                hh, _ = jax.lax.scan(_remat(inner, cfg.remat), hh, glp)
                hh, kv = _shared_block(cfg, params["shared"], hh, h0, positions, mesh)
                hh = constrain(hh, mesh, ("pod", "data"), None, "model")
                return hh, kv if collect_cache else None

            h, ys = jax.lax.scan(group_body, h, grouped)
            if tail is not None:  # trailing layers past the last shared block
                h, _ = jax.lax.scan(_remat(inner, cfg.remat), h, tail)
            if collect_cache:
                caches["k"], caches["v"] = ys
        else:
            raise ValueError(cfg.family)

        if not head:
            return h, aux, caches
        logits = T.lm_logits(cfg, params, h, mesh)
        return logits, aux, caches

    # --------------------------------------------------------------- loss --
    def loss(self, params, batch, *, mesh=None):
        h, aux, _ = self.forward(
            params, batch["tokens"], vision=batch.get("vision"), mesh=mesh, head=False
        )
        # few, large chunks: each chunk step pays a head-gradient reduction,
        # so chunk count (not size) drives the collective bill (§Perf)
        chunk = max(256, h.shape[1] // 4)
        loss = T.chunked_xent(self.cfg, params, h, batch["labels"], mesh=mesh, chunk=chunk)
        total = loss + 0.01 * aux["moe_aux"] + 1e-3 * aux["moe_z"]
        metrics = {"loss": loss, "moe_aux": aux["moe_aux"], "moe_z": aux["moe_z"],
                   "tokens": jnp.float32(np.prod(batch["labels"].shape))}
        return total, metrics

    # ------------------------------------------------------------ caching --
    def cache_dims(self) -> dict:
        cfg = self.cfg
        if cfg.family in ("dense", "audio", "moe"):
            return {"kind": "kv", "n_kv_layers": cfg.n_layers}
        if cfg.family == "vlm":
            k = cfg.vision.cross_attn_every
            return {"kind": "kv+x", "n_kv_layers": cfg.n_layers - cfg.n_layers // k,
                    "n_cross": cfg.n_layers // k}
        if cfg.family == "ssm":
            return {"kind": "ssm", "n_ssm_layers": cfg.n_layers}
        return {"kind": "hybrid", "n_ssm_layers": cfg.n_layers,
                "n_kv_layers": cfg.n_layers // cfg.hybrid.attn_every}

    def cache_struct(self, B: int, max_len: int) -> dict:
        """ShapeDtypeStruct tree for the decode cache (dry-run + init)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        KV, hd = cfg.n_kv_heads, cfg.hd
        dims = self.cache_dims()
        out: dict = {"len": jax.ShapeDtypeStruct((B,), jnp.int32)}
        if "n_kv_layers" in dims:
            L = dims["n_kv_layers"]
            out["k"] = jax.ShapeDtypeStruct((L, B, max_len, KV, hd), dt)
            out["v"] = jax.ShapeDtypeStruct((L, B, max_len, KV, hd), dt)
        if dims["kind"] == "kv+x":
            C, Pp = dims["n_cross"], cfg.vision.n_patches
            out["xk"] = jax.ShapeDtypeStruct((C, B, Pp, KV, hd), dt)
            out["xv"] = jax.ShapeDtypeStruct((C, B, Pp, KV, hd), dt)
        if dims["kind"] in ("ssm", "hybrid"):
            L, s, di = dims["n_ssm_layers"], cfg.ssm, cfg.d_inner
            if cfg.family == "ssm":
                out["conv"] = jax.ShapeDtypeStruct((L, B, s.d_conv - 1, di), dt)
                out["state"] = jax.ShapeDtypeStruct((L, B, di, s.d_state), jnp.float32)
            else:
                nh = di // s.head_dim
                out["conv"] = jax.ShapeDtypeStruct((L, B, s.d_conv - 1, di + 2 * s.d_state), dt)
                out["state"] = jax.ShapeDtypeStruct((L, B, nh, s.d_state, s.head_dim), jnp.float32)
        return out

    def init_cache(self, B: int, max_len: int) -> dict:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), self.cache_struct(B, max_len))

    def cache_specs(self, mesh, B: int, max_len: int, seq_shard: bool = False):
        """PartitionSpec tree matching cache_struct (divisibility-repaired)."""
        from repro.models.sharding import logical_to_spec, repair_spec

        ax = mesh.axis_names

        def spec(*names):
            return logical_to_spec(tuple(names), ax, ())

        dims = self.cache_dims()
        out = {"len": spec("batch")}
        kv_axes = (None, None, "batch", "kv_heads", None) if seq_shard else (None, "batch", None, "kv_heads", None)
        if "n_kv_layers" in dims:
            out["k"] = spec(*kv_axes)
            out["v"] = spec(*kv_axes)
        if dims["kind"] == "kv+x":
            out["xk"] = spec(None, "batch", None, "kv_heads", None)
            out["xv"] = spec(None, "batch", None, "kv_heads", None)
        if dims["kind"] in ("ssm", "hybrid"):
            out["conv"] = spec(None, "batch", None, "d_inner")
            if self.cfg.family == "ssm":
                out["state"] = spec(None, "batch", "d_inner", None)
            else:
                out["state"] = spec(None, "batch", "d_inner", None, None)
        struct = self.cache_struct(B, max_len)
        return jax.tree.map(
            lambda s, st: repair_spec(s, st.shape, mesh), out, struct,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )

    # ------------------------------------------------------------ prefill --
    def prefill(self, params, tokens, *, max_len: int, vision=None, mesh=None,
                length=None):
        """Process the prompt; returns (last_logits [B,(ncb,)V], cache).

        ``length`` (optional, may be traced) is the true prompt length when
        ``tokens`` is right-padded to a shape bucket: the head runs at
        position ``length - 1`` and ``cache["len"]`` is set to ``length``,
        so decode's length-masked attention never sees the padding's k/v
        rows.  Exact for causal kv-cache families only — SSM/hybrid prefill
        folds every position into the recurrent state, so bucketing would
        corrupt it; serving keeps exact-length prefill there.
        """
        cfg = self.cfg
        if length is not None and cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"bucketed prefill (length=) is invalid for family {cfg.family!r}: "
                "recurrent state absorbs padded positions"
            )
        B, Sq = tokens.shape[:2]
        h, _, caches = self.forward(
            params, tokens, vision=vision, mesh=mesh, collect_cache=True,
            max_len=max_len, head=False,
        )
        # head only at the last position: full [B, S, V] logits are never
        # needed for prefill and don't fit at 32k x 152k vocab
        if length is None:
            last_h = h[:, -1:]
            true_len = Sq
        else:
            # causal attention: position length-1 never attends the padding
            last_h = jax.lax.dynamic_slice_in_dim(h, length - 1, 1, axis=1)
            true_len = length
        logits = T.lm_logits(cfg, params, last_h, mesh)
        cache = {"len": jnp.full((B,), true_len, jnp.int32)}
        if "k" in caches:
            pad = max_len - Sq
            cache["k"] = jnp.pad(caches["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(caches["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        if "xk" in caches:
            cache["xk"], cache["xv"] = caches["xk"], caches["xv"]
        if cfg.family in ("ssm", "hybrid"):
            # rerun sequentially-cheap state collection: one extra pass that
            # keeps final conv window + state per layer
            cache.update(self._ssm_prefill_state(params, tokens, mesh=mesh))
        return logits[:, -1], cache

    def _ssm_prefill_state(self, params, tokens, mesh=None):
        cfg = self.cfg
        dt = _dtype(cfg)
        B, Sq = tokens.shape[:2]
        params = _precast(cfg, params)
        h = T.embed_tokens(cfg, params, tokens, dt)
        s, di = cfg.ssm, cfg.d_inner
        K = s.d_conv

        if cfg.family == "ssm":
            def body(hh, lp):
                x = rms_norm(hh, lp["norm"], cfg.norm_eps)
                xi, zg = S._mamba1_inner(cfg, lp, x, mesh)
                conv_buf = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):, :]
                xi = S.causal_conv1d(xi, lp["conv_w"], lp["conv_b"])
                xi = jax.nn.silu(xi.astype(jnp.float32)).astype(hh.dtype)
                dtt, Bc, Cc = S._mamba1_bcdt(cfg, lp, xi)
                A = -jnp.exp(lp["A_log"].astype(jnp.float32))
                y, st = S.selective_scan(xi, dtt, A, Bc, Cc, lp["D"].astype(jnp.float32),
                                         chunk=_scan_chunk(Sq))
                y = y * jax.nn.silu(zg.astype(jnp.float32)).astype(hh.dtype)
                hh = hh + mm("bse,ed->bsd", y, lp["out_proj"])
                return hh, (conv_buf, st)

            _, (conv, state) = jax.lax.scan(body, h, params["layers"])
            return {"conv": conv, "state": state}

        # hybrid
        k = cfg.hybrid.attn_every
        G = cfg.n_layers // k
        h0 = h
        positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
        grouped, tail = _split_groups(params["layers"], G, k)
        N = s.d_state

        def inner(hh, lp):
            x = rms_norm(hh, lp["norm"], cfg.norm_eps)
            proj = mm("bsd,de->bse", x, lp["in_proj"])
            xi, zg, Bc, Cc, dtt = S._mamba2_split(cfg, proj)
            xbc_in = jnp.concatenate([xi, Bc, Cc], -1)
            conv_buf = jnp.pad(xbc_in, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):, :]
            xbc = S.causal_conv1d(xbc_in, lp["conv_w"], lp["conv_b"])
            xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(hh.dtype)
            xi2, Bc2, Cc2 = xbc[..., :di], xbc[..., di:di + N], xbc[..., di + N:]
            dtt = jax.nn.softplus(dtt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
            A = -jnp.exp(lp["A_log"].astype(jnp.float32))
            nh = di // s.head_dim
            y, st = S.ssd_scan(xi2.reshape(B, Sq, nh, s.head_dim), dtt, A,
                               Bc2.astype(jnp.float32), Cc2.astype(jnp.float32),
                               chunk=_scan_chunk(Sq))
            y = y.reshape(B, Sq, di) + xi2 * lp["D"].astype(jnp.float32).repeat(s.head_dim)[None, None]
            y = rms_norm(y * jax.nn.silu(zg.astype(jnp.float32)).astype(hh.dtype),
                         lp["norm_g"], cfg.norm_eps)
            hh = hh + mm("bse,ed->bsd", y.astype(hh.dtype), lp["out_proj"])
            return hh, (conv_buf, st)  # st: [B, nh, N, P] — matches cache layout

        def group_body(hh, glp):
            hh, cs = jax.lax.scan(inner, hh, glp)
            hh, _ = _shared_block(cfg, params["shared"], hh, h0, positions, mesh)
            return hh, cs

        h, (conv, state) = jax.lax.scan(group_body, h, grouped)
        conv = conv.reshape((-1,) + conv.shape[2:])
        state = state.reshape((-1,) + state.shape[2:])
        if tail is not None:
            _, (tconv, tstate) = jax.lax.scan(inner, h, tail)
            conv = jnp.concatenate([conv, tconv], 0)
            state = jnp.concatenate([state, tstate], 0)
        return {"conv": conv, "state": state}

    # -------------------------------------------------------------- decode --
    def decode_step(self, params, tokens, cache, *, mesh=None, seq_shard=False):
        """tokens [B, 1(,ncb)]; returns (logits [B,(ncb,)V], new_cache)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        B = tokens.shape[0]
        lens = cache["len"]
        params = _precast(cfg, params)
        h = T.embed_tokens(cfg, params, tokens, dt)
        h = constrain(h, mesh, ("pod", "data"), None, "model")
        kv_axes = (None, None, ("pod", "data"), "kv_heads", None) if seq_shard \
            else (None, ("pod", "data"), None, "kv_heads", None)
        new_cache = dict(cache)

        if cfg.family in ("dense", "audio", "moe"):
            def body(hh, xs):
                lp, kc, vc = xs
                a, kc, vc = T.self_attn_decode(cfg, lp, hh, kc, vc, lens, mesh)
                hh = hh + a
                if cfg.family == "moe":
                    m, _, _ = T.moe_block(cfg, lp, hh, mesh)
                else:
                    m = T.mlp_block(cfg, lp, hh, mesh)
                return hh + m, (kc, vc)

            h, (kc, vc) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = kc, vc

        elif cfg.family == "vlm":
            k = cfg.vision.cross_attn_every
            n_cross = cfg.n_layers // k
            grouped = jax.tree.map(
                lambda x: x.reshape((n_cross, k - 1) + x.shape[1:]), params["layers"]
            )
            kg = cache["k"].reshape((n_cross, k - 1) + cache["k"].shape[1:])
            vg = cache["v"].reshape((n_cross, k - 1) + cache["v"].shape[1:])

            def self_body(hh, xs):
                lp, kc, vc = xs
                a, kc, vc = T.self_attn_decode(cfg, lp, hh, kc, vc, lens, mesh)
                hh = hh + a
                hh = hh + T.mlp_block(cfg, lp, hh, mesh)
                return hh, (kc, vc)

            def group_body(hh, xs):
                glp, gk, gv, clp, xk, xv = xs
                hh, (gk, gv) = jax.lax.scan(self_body, hh, (glp, gk, gv))
                a = T.cross_attn_block(cfg, clp, hh, xk, xv, mesh)
                hh = hh + a * jnp.tanh(clp["attn_gate"]).astype(dt)
                hh = hh + T.mlp_block(cfg, clp, hh, mesh) * jnp.tanh(clp["mlp_gate"]).astype(dt)
                return hh, (gk, gv)

            h, (kg, vg) = jax.lax.scan(
                group_body, h, (grouped, kg, vg, params["cross_layers"], cache["xk"], cache["xv"])
            )
            new_cache["k"] = kg.reshape((-1,) + kg.shape[2:])
            new_cache["v"] = vg.reshape((-1,) + vg.shape[2:])

        elif cfg.family == "ssm":
            def body(hh, xs):
                lp, cb, st = xs
                hh, cb, st = S.mamba1_decode(cfg, lp, hh, cb, st, mesh)
                return hh, (cb, st)

            h, (cb, st) = jax.lax.scan(body, h, (params["layers"], cache["conv"], cache["state"]))
            new_cache["conv"], new_cache["state"] = cb, st

        elif cfg.family == "hybrid":
            k = cfg.hybrid.attn_every
            G = cfg.n_layers // k
            h0 = h
            grouped, tail = _split_groups(params["layers"], G, k)
            n_main = G * k
            cb_main = cache["conv"][:n_main].reshape((G, k) + cache["conv"].shape[1:])
            st_main = cache["state"][:n_main].reshape((G, k) + cache["state"].shape[1:])

            def inner(hh, xs):
                lp, cb, st = xs
                hh, cb, st = S.mamba2_decode(cfg, lp, hh, cb, st, mesh)
                return hh, (cb, st)

            def group_body(hh, xs):
                glp, gcb, gst, kc, vc = xs
                hh, (gcb, gst) = jax.lax.scan(inner, hh, (glp, gcb, gst))
                hh, kc, vc = _shared_block_decode(
                    cfg, params["shared"], hh, h0, kc, vc, lens, mesh, seq_shard
                )
                return hh, (gcb, gst, kc, vc)

            h, (cbg, stg, kc, vc) = jax.lax.scan(group_body, h, (grouped, cb_main, st_main, cache["k"], cache["v"]))
            cbg = cbg.reshape((-1,) + cbg.shape[2:])
            stg = stg.reshape((-1,) + stg.shape[2:])
            if tail is not None:
                h, (tcb, tst) = jax.lax.scan(
                    inner, h, (tail, cache["conv"][n_main:], cache["state"][n_main:])
                )
                cbg = jnp.concatenate([cbg, tcb], 0)
                stg = jnp.concatenate([stg, tst], 0)
            new_cache["conv"] = cbg
            new_cache["state"] = stg
            new_cache["k"], new_cache["v"] = kc, vc
        else:
            raise ValueError(cfg.family)

        logits = T.lm_logits(cfg, params, h, mesh)
        new_cache["len"] = lens + 1
        return logits[:, -1], new_cache


def _scan_chunk(S: int) -> int:
    for c in (64, 32, 16, 8, 4, 2, 1):
        if S % c == 0:
            return c
    return 1


def _split_groups(layers, G: int, k: int):
    """Split a [L, ...] stacked-layer tree into ([G, k, ...], tail [L-G*k, ...]).

    Handles layer counts not divisible by the group period (e.g. zamba2's
    38 layers with a shared block every 6)."""
    L = jax.tree.leaves(layers)[0].shape[0]
    rem = L - G * k
    grouped = jax.tree.map(lambda x: x[: G * k].reshape((G, k) + x.shape[1:]), layers)
    tail = None if rem == 0 else jax.tree.map(lambda x: x[G * k :], layers)
    return grouped, tail


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
