"""DRAGON reproduction — differentiable hardware simulation & optimization.

The public surface is the typed façade::

    from repro import Session, Architecture, Workload

    rep = Session(Architecture("edge")).simulate(Workload("bert_base"))

Everything is imported lazily: ``import repro`` itself pulls in neither JAX
nor the engines, so CLIs and config tooling stay instant.  The engine layer
(``repro.core.*``) remains importable as-is — it is the numerical oracle
the façade wraps — but the legacy *top-level* engine spellings routed here
(``repro.simulate`` ...) emit a DeprecationWarning and forward; they go
away one release after the façade landed.
"""
from __future__ import annotations

_FACADE = {
    "Session": "repro.api",
    "Architecture": "repro.api",
    "Workload": "repro.api",
    "CacheStats": "repro.api",
    "SimReport": "repro.core.report",
    "OptResult": "repro.core.report",
    "FrontierResult": "repro.core.report",
    "Attribution": "repro.core.report",
    "Graph": "repro.core.graph",
    "MapperCfg": "repro.core.mapper",
    "ArchParams": "repro.core.params",
    "ArchSpec": "repro.core.params",
    "TechParams": "repro.core.params",
    "get_workload": "repro.workloads",
}

# one-release deprecation shims: the old free-function spellings, reachable
# from the top level but warning — use Session instead
_DEPRECATED = {
    "simulate": "repro.core.dsim",
    "simulate_stacked": "repro.core.dsim",
    "optimize": "repro.core.dopt",
    "derive_tech_targets": "repro.core.dopt",
    "pareto_dse": "repro.core.popsim",
    "load_arch": "repro.core.dhdl",
    "parse_arch": "repro.core.dhdl",
    "serialize_arch": "repro.core.dhdl",
}

__all__ = ["__version__", *_FACADE]


def _version() -> str:
    """Single-sourced from pyproject.toml: the installed distribution's
    metadata when packaged, the file itself in a source checkout."""
    try:
        from importlib.metadata import PackageNotFoundError, version

        return version("dragon-repro")
    except PackageNotFoundError:
        pass  # source checkout: fall through to pyproject.toml
    import pathlib
    import re

    try:
        text = (pathlib.Path(__file__).resolve().parents[2] / "pyproject.toml").read_text()
        m = re.search(r'^version\s*=\s*"([^"]+)"', text, re.M)
        if m:
            return m.group(1)
    except OSError:
        pass
    return "0+unknown"


def __getattr__(name: str):
    if name == "__version__":
        v = _version()
        globals()["__version__"] = v
        return v
    if name in _FACADE:
        import importlib

        value = getattr(importlib.import_module(_FACADE[name]), name)
        globals()[name] = value  # cache: __getattr__ only fires on misses
        return value
    if name in _DEPRECATED:
        import importlib
        import warnings

        warnings.warn(
            f"repro.{name} is deprecated; use repro.Session (see docs/api.md). "
            f"The engine spelling {_DEPRECATED[name]}.{name} remains available.",
            DeprecationWarning,
            stacklevel=2,
        )
        return getattr(importlib.import_module(_DEPRECATED[name]), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted({*globals(), *__all__, *_DEPRECATED})
