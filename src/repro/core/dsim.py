"""DSim — the hardware simulator (paper §5.3/§6).

simulate(): (TechParams, ArchParams, Graph) -> PerfEstimate
  Runtime = cycles / frequency                         (paper eq. 1)
  Energy  = Σ_mem reads·re + writes·we + leak·Runtime
          + Σ_comp ops·e_op + leak·Runtime             (paper §5.3)
  Area    = Σ areas                                    (paper eq. 2)
  Power   = Energy / Runtime                           (paper eq. 3)

Fully differentiable w.r.t. both parameter sets; jit/vmap/pjit-able.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.dgen import ConcreteHW, specialize
from repro.core.graph import Graph, workload_optimize
from repro.core.mapper import MapperCfg, MapState, map_workload
from repro.core.params import ArchParams, ArchSpec, TechParams


@jax.tree_util.register_dataclass
@dataclass
class PerfEstimate:
    """paper §5: P : Measurements -> R+  (+ useful breakdowns)."""

    runtime: jax.Array  # s
    energy: jax.Array  # J
    power: jax.Array  # W
    area: jax.Array  # mm^2
    cycles: jax.Array
    edp: jax.Array  # J*s
    energy_mem: jax.Array
    energy_comp: jax.Array
    energy_leak: jax.Array
    state: MapState

    def measurements(self) -> dict:
        return dict(runtime=self.runtime, energy=self.energy, power=self.power, area=self.area)


def _energy(chw: ConcreteHW, ms: MapState, runtime: jax.Array):
    e_mem_dyn = jnp.sum(ms.reads * chw.read_energy_pb + ms.writes * chw.write_energy_pb)
    e_comp_dyn = jnp.sum(ms.comp_ops * chw.energy_per_flop)
    e_leak = (jnp.sum(chw.mem_leakage) + jnp.sum(chw.comp_leakage)) * runtime
    return e_mem_dyn, e_comp_dyn, e_leak


def simulate_chw(chw: ConcreteHW, g: Graph, mcfg: MapperCfg = MapperCfg()) -> PerfEstimate:
    ms = map_workload(chw, g, mcfg)
    runtime = ms.cycles / chw.frequency
    e_mem, e_comp, e_leak = _energy(chw, ms, runtime)
    energy = e_mem + e_comp + e_leak
    area = chw.total_area
    return PerfEstimate(
        runtime=runtime,
        energy=energy,
        power=energy / jnp.maximum(runtime, 1e-30),
        area=area,
        cycles=ms.cycles,
        edp=energy * runtime,
        energy_mem=e_mem,
        energy_comp=e_comp,
        energy_leak=e_leak,
        state=ms,
    )


def simulate(
    tech: TechParams,
    arch: ArchParams,
    g: Graph,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    type_weights: jax.Array | None = None,
) -> PerfEstimate:
    """End-to-end differentiable: params -> CH -> mapping -> estimates."""
    chw = specialize(tech, arch, spec, type_weights)
    return simulate_chw(chw, g, mcfg)


@partial(jax.jit, static_argnames=("spec", "mcfg"))
def simulate_jit(tech, arch, g, spec: ArchSpec = ArchSpec(), mcfg: MapperCfg = MapperCfg()):
    return simulate(tech, arch, g, spec, mcfg)


def simulate_stacked(
    tech: TechParams,
    arch: ArchParams,
    gs: Graph,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    type_weights: jax.Array | None = None,
) -> PerfEstimate:
    """Batched simulate over a ``Graph.stack()``-ed workload axis.

    One hardware point, W workloads, one vmapped mapper dispatch — the
    multi-workload path shared by DOpt's loss and popsim's population DSE
    (compile time and runtime no longer scale with Python-level unrolling).
    Returns a PerfEstimate whose fields carry a leading [W] axis.
    """
    return jax.vmap(lambda g: simulate(tech, arch, g, spec, mcfg, type_weights))(gs)


def stacked_log_objective(
    tech: TechParams,
    arch: ArchParams,
    gs: Graph,
    objective: str = "edp",
    area_constraint: float | None = None,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    type_weights: jax.Array | None = None,
) -> tuple[jax.Array, PerfEstimate]:
    """Mean log objective across a stacked workload set (+ the batched
    estimates).  Log-objective keeps gradients scale-free across
    heterogeneous workloads."""
    perfs = simulate_stacked(tech, arch, gs, spec, mcfg, type_weights)
    return jnp.mean(jnp.log(objective_value(perfs, objective, area_constraint))), perfs


def objective_value(perf: PerfEstimate, objective: str, area_constraint: float | None = None) -> jax.Array:
    """Scalar optimization objective (paper §7 / Appendix C).

    area-constrained form: F = T * e^(a - A)  (paper §11.3), smooth-rectified
    so the penalty only binds above the constraint.
    """
    base = {
        "time": perf.runtime,
        "energy": perf.energy,
        "edp": perf.edp,
        "power": perf.power,
        "area": perf.area,
    }[objective]
    if area_constraint is not None:
        base = base * jnp.exp(jax.nn.softplus((perf.area - area_constraint) / area_constraint))
    return base
