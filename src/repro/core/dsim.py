"""DSim — the hardware simulator (paper §5.3/§6).

simulate(): (TechParams, ArchParams, Graph) -> PerfEstimate
  Runtime = cycles / frequency                         (paper eq. 1)
  Energy  = Σ_mem reads·re + writes·we + leak·Runtime
          + Σ_comp ops·e_op + leak·Runtime             (paper §5.3)
  Area    = Σ areas                                    (paper eq. 2)
  Power   = Energy / Runtime                           (paper eq. 3)

Fully differentiable w.r.t. both parameter sets; jit/vmap/pjit-able.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.dgen import ConcreteHW, specialize
from repro.core.graph import Graph, workload_optimize
from repro.core.mapper import MapperCfg, MapState, map_workload, map_workload_breakdown
from repro.core.params import ArchParams, ArchSpec, TechParams


@jax.tree_util.register_dataclass
@dataclass
class PerfEstimate:
    """paper §5: P : Measurements -> R+  (+ useful breakdowns)."""

    runtime: jax.Array  # s
    energy: jax.Array  # J
    power: jax.Array  # W
    area: jax.Array  # mm^2
    cycles: jax.Array
    edp: jax.Array  # J*s
    energy_mem: jax.Array
    energy_comp: jax.Array
    energy_leak: jax.Array
    state: MapState

    def measurements(self) -> dict:
        return dict(runtime=self.runtime, energy=self.energy, power=self.power, area=self.area)


def _energy(chw: ConcreteHW, ms: MapState, runtime: jax.Array):
    e_mem_dyn = jnp.sum(ms.reads * chw.read_energy_pb + ms.writes * chw.write_energy_pb)
    e_comp_dyn = jnp.sum(ms.comp_ops * chw.energy_per_flop)
    e_leak = (jnp.sum(chw.mem_leakage) + jnp.sum(chw.comp_leakage)) * runtime
    return e_mem_dyn, e_comp_dyn, e_leak


def simulate_chw(chw: ConcreteHW, g: Graph, mcfg: MapperCfg = MapperCfg()) -> PerfEstimate:
    ms = map_workload(chw, g, mcfg)
    runtime = ms.cycles / chw.frequency
    e_mem, e_comp, e_leak = _energy(chw, ms, runtime)
    energy = e_mem + e_comp + e_leak
    area = chw.total_area
    return PerfEstimate(
        runtime=runtime,
        energy=energy,
        power=energy / jnp.maximum(runtime, 1e-30),
        area=area,
        cycles=ms.cycles,
        edp=energy * runtime,
        energy_mem=e_mem,
        energy_comp=e_comp,
        energy_leak=e_leak,
        state=ms,
    )


def simulate(
    tech: TechParams,
    arch: ArchParams,
    g: Graph,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    type_weights: jax.Array | None = None,
) -> PerfEstimate:
    """End-to-end differentiable: params -> CH -> mapping -> estimates."""
    chw = specialize(tech, arch, spec, type_weights)
    return simulate_chw(chw, g, mcfg)


@partial(jax.jit, static_argnames=("spec", "mcfg"))
def simulate_jit(tech, arch, g, spec: ArchSpec = ArchSpec(), mcfg: MapperCfg = MapperCfg()):
    return simulate(tech, arch, g, spec, mcfg)


def simulate_breakdown(
    tech: TechParams,
    arch: ArchParams,
    g: Graph,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    type_weights: jax.Array | None = None,
) -> tuple[PerfEstimate, dict]:
    """Simulate + the per-level / per-vertex attribution arrays.

    The PerfEstimate is the ordinary :func:`simulate` result (same mapper
    dispatch, same numbers); the extras dict is what the façade's
    explainable :class:`repro.core.report.SimReport` is built from:

      * ``time_v`` / ``energy_v`` [V] — per-vertex wall time and energy
        (dynamic traffic + compute + leakage prorated by the vertex's time;
        vertex times/energies sum to the PerfEstimate totals — exactly
        under the associative/pallas dispatch, to the formulations' tested
        equivalence under ``scan_impl="ref"``);
      * ``e_level_dyn`` / ``e_level_leak`` [N_MEM] — per-memory-level energy;
      * ``e_comp_dyn`` / ``e_comp_leak`` [N_COMP] — per-compute-class energy;
      * ``t_level`` [N_MEM] — demanded transfer time per level.

    Fully differentiable (the breakdown is the same mapper math, un-reduced).
    """
    chw = specialize(tech, arch, spec, type_weights)
    perf = simulate_chw(chw, g, mcfg)
    bd = map_workload_breakdown(chw, g, mcfg)
    ms = perf.state
    leak_w = jnp.sum(chw.mem_leakage) + jnp.sum(chw.comp_leakage)
    e_v_dyn = (
        g.n_read @ chw.read_energy_pb
        + g.n_write @ chw.write_energy_pb
        + g.n_comp @ chw.energy_per_flop
    ) * bd["active"]
    extras = dict(
        time_v=bd["time_v"],
        energy_v=e_v_dyn + leak_w * bd["time_v"],
        tiles_v=bd["tiles_v"],
        t_comp_v=bd["t_comp_v"],
        t_main_exposed_v=bd["t_main_exposed_v"],
        t_level=bd["t_level"],
        e_level_dyn=ms.reads * chw.read_energy_pb + ms.writes * chw.write_energy_pb,
        e_level_leak=chw.mem_leakage * perf.runtime,
        e_comp_dyn=ms.comp_ops * chw.energy_per_flop,
        e_comp_leak=chw.comp_leakage * perf.runtime,
    )
    return perf, extras


def simulate_stacked(
    tech: TechParams,
    arch: ArchParams,
    gs: Graph,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    type_weights: jax.Array | None = None,
) -> PerfEstimate:
    """Batched simulate over a ``Graph.stack()``-ed workload axis.

    One hardware point, W workloads, one vmapped mapper dispatch — the
    multi-workload path shared by DOpt's loss and popsim's population DSE
    (compile time and runtime no longer scale with Python-level unrolling).
    Returns a PerfEstimate whose fields carry a leading [W] axis.
    """
    return jax.vmap(lambda g: simulate(tech, arch, g, spec, mcfg, type_weights))(gs)


def stacked_log_objective(
    tech: TechParams,
    arch: ArchParams,
    gs: Graph,
    objective: str = "edp",
    area_constraint: float | None = None,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    type_weights: jax.Array | None = None,
) -> tuple[jax.Array, PerfEstimate]:
    """Mean log objective across a stacked workload set (+ the batched
    estimates).  Log-objective keeps gradients scale-free across
    heterogeneous workloads."""
    perfs = simulate_stacked(tech, arch, gs, spec, mcfg, type_weights)
    return jnp.mean(jnp.log(objective_value(perfs, objective, area_constraint))), perfs


# --------------------------------------------------------------------------- #
# multi-objective layer: per-design metric vectors + constrained scalarization
# --------------------------------------------------------------------------- #

# the metric space multi-objective DSE optimizes over; order is the metric-
# vector layout shared by stacked_log_metrics / popsim / pareto
PARETO_METRICS = ("time", "energy", "area", "edp")


def stacked_log_metrics(perfs: PerfEstimate) -> jax.Array:
    """[4] log-metric vector of a batched estimate, in PARETO_METRICS order.

    Each entry is the mean log metric across the stacked workload axis (the
    log of the geometric-mean metric — scale-free across heterogeneous
    workloads, matching :func:`stacked_log_objective`'s reduction; area is
    workload-independent, so its mean is the identity).
    """
    return jnp.stack(
        [
            jnp.mean(jnp.log(perfs.runtime)),
            jnp.mean(jnp.log(perfs.energy)),
            jnp.mean(jnp.log(perfs.area)),
            jnp.mean(jnp.log(perfs.edp)),
        ]
    )


def budget_penalty(
    perfs: PerfEstimate,
    area_budget: jax.Array,
    power_budget: jax.Array,
    sharpness: float = 8.0,
) -> jax.Array:
    """Differentiable log-space budget penalty (smooth hinge on violation).

    For each budget B and worst-case metric m over the workload stack, the
    violation is ``v = log m - log B`` (relative, unit-free) and the penalty
    is ``softplus(sharpness * v) / sharpness`` — a smooth rectifier that is
    ~0 well under budget, ~v well over it, and everywhere differentiable
    (the finite-difference-checkable form the constraint tests rely on).
    ``jnp.inf`` disables a budget exactly: the violation is ``-inf``, the
    softplus and its gradient are exactly zero.  Budgets must be positive.
    """
    viol_area = jnp.log(jnp.max(perfs.area)) - jnp.log(area_budget)
    viol_power = jnp.log(jnp.max(perfs.power)) - jnp.log(power_budget)
    sp = lambda v: jax.nn.softplus(sharpness * v) / sharpness
    return sp(viol_area) + sp(viol_power)


def mixed_log_objective(
    tech: TechParams,
    arch: ArchParams,
    gs: Graph,
    weights: jax.Array,
    area_budget: jax.Array | float | None = None,
    power_budget: jax.Array | float | None = None,
    penalty_weight: jax.Array | float = 1.0,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    type_weights: jax.Array | None = None,
) -> tuple[jax.Array, PerfEstimate]:
    """Constrained scalarization of the PARETO_METRICS vector.

    ``weights`` [4] mixes the log metrics (a one-hot weight reproduces the
    corresponding single-objective ``stacked_log_objective`` exactly — the
    off terms are exact float zeros — which is what the population-vs-
    sequential equivalence tests pin).  Budgets are worst-case-over-
    workloads area/power ceilings applied as :func:`budget_penalty`, scaled
    by the schedulable ``penalty_weight``; ``None``/``inf`` disables one.
    The weights/budgets are *traced* values, so one compiled program serves
    every objective mix — each population member can descend a different
    one without retracing.
    """
    perfs = simulate_stacked(tech, arch, gs, spec, mcfg, type_weights)
    val = jnp.dot(jnp.asarray(weights, jnp.float32), stacked_log_metrics(perfs))
    ab = jnp.float32(jnp.inf) if area_budget is None else area_budget
    pb = jnp.float32(jnp.inf) if power_budget is None else power_budget
    return val + penalty_weight * budget_penalty(perfs, ab, pb), perfs


def objective_value(perf: PerfEstimate, objective: str, area_constraint: float | None = None) -> jax.Array:
    """Scalar optimization objective (paper §7 / Appendix C).

    area-constrained form: F = T * e^(a - A)  (paper §11.3), smooth-rectified
    so the penalty only binds above the constraint.
    """
    base = {
        "time": perf.runtime,
        "energy": perf.energy,
        "edp": perf.edp,
        "power": perf.power,
        "area": perf.area,
    }[objective]
    if area_constraint is not None:
        base = base * jnp.exp(jax.nn.softplus((perf.area - area_constraint) / area_constraint))
    return base
