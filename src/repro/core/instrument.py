"""Trace-count instrumentation for the compiled-program cache.

JAX re-executes a function's Python body only when it *traces* (compiles) a
new program; steady-state dispatches replay the cached executable without
touching Python.  A counter bumped at the top of a jitted body is therefore
an exact retrace probe: it increments once per compilation and never on a
cache hit.

The engine entry points (``dopt._dopt_step``, ``popsim._member_step``) and
every :class:`repro.api.Session` program call :func:`count_trace` with a tag;
``Session.stats`` and the cache tests read the counters back.  This is the
mechanism behind the façade's serving guarantee — "warm same-bucket calls
never retrace" is asserted, not assumed.
"""
from __future__ import annotations

from collections import Counter

_counts: Counter = Counter()


def count_trace(tag: str) -> None:
    """Record one trace of the program ``tag``.  Call this at the top of a
    jit-compiled function body: it runs at trace time only."""
    _counts[tag] += 1


def trace_count(tag: str | None = None, prefix: str | None = None) -> int:
    """Total traces recorded for ``tag``, for all tags starting with
    ``prefix``, or for everything."""
    if tag is not None:
        return _counts[tag]
    if prefix is not None:
        return sum(v for k, v in _counts.items() if k.startswith(prefix))
    return sum(_counts.values())


def snapshot() -> dict:
    """Immutable copy of all counters (for before/after deltas in tests)."""
    return dict(_counts)


def reset(prefix: str | None = None) -> None:
    """Clear counters (optionally only those under ``prefix``).  Test-only:
    resetting does not un-compile anything."""
    if prefix is None:
        _counts.clear()
    else:
        for k in [k for k in _counts if k.startswith(prefix)]:
            del _counts[k]
