"""DGen — the hardware model generator (paper §5.1).

Derives a differentiable hardware model H from
  * an architectural specification (ArchSpec: which units, which memory tech),
  * the device performance-model library (per memory technology, per logic
    primitive), and
  * the accelerator template library (systolicArray / vector / macTree / fpu).

H(unit, metric) in the paper is an algebraic expression; here it is a JAX
function of (TechParams, ArchParams).  ``specialize`` applies concrete
parameter assignments and returns a ConcreteHW pytree of metric values —
the paper's CH — which DSim and the mapper consume.  Everything is
differentiable w.r.t. both parameter sets.

Device models are CACTI-flavoured closed forms anchored at a 40 nm
reference (paper Alg. 6 uses reference tables at 40 nm).  They are
performance *models*, not SPICE: smooth, monotone, plausibly scaled.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import (
    COMP_CLS,
    MEM_CLS,
    MEM_TYPES,
    N_COMP,
    N_MEM,
    ArchParams,
    ArchSpec,
    TechParams,
)

# --------------------------------------------------------------------------- #
# Device library constants (reference @ 40nm), per memory technology
# order: (sram, rram, dram)
# --------------------------------------------------------------------------- #

_WRITE_LAT_MULT = np.array([1.0, 3.0, 1.2], np.float32)
_WRITE_EN_MULT = np.array([1.0, 8.0, 1.1], np.float32)
_PERIPH_DELAY_REF = np.array([0.25e-9, 0.35e-9, 2.0e-9], np.float32)  # s @40nm
_PERIPH_OVERHEAD = np.array([0.35, 0.25, 0.15], np.float32)  # area overhead frac
_LEAK_PERIPH_REF = np.array([2.0e-3, 1.5e-3, 0.5e-3], np.float32)  # W/mm^2 @40nm
_VDD = 0.9  # volts, fixed; node-dependence folded into energy refs

# logic primitive reference values @40nm: (adder, mult, ff)
_PRIM_DELAY = np.array([0.15e-9, 0.60e-9, 0.05e-9], np.float32)  # s
_PRIM_ENERGY = np.array([0.03e-12, 0.80e-12, 0.01e-12], np.float32)  # J
_PRIM_AREA = np.array([60.0, 800.0, 10.0], np.float32)  # um^2
_LEAK_LOGIC_REF = 4.0e-3  # W/mm^2 @40nm


@jax.tree_util.register_dataclass
@dataclass
class ConcreteHW:
    """The concrete hardware model CH (paper §3): every metric resolved to a
    real value.  Mem arrays are [N_MEM], comp arrays are [N_COMP]."""

    # memory metrics
    read_latency: jax.Array  # s
    write_latency: jax.Array  # s
    read_energy_pb: jax.Array  # J / byte
    write_energy_pb: jax.Array  # J / byte
    mem_leakage: jax.Array  # W
    mem_area: jax.Array  # mm^2
    mem_bw: jax.Array  # bytes / s
    capacity: jax.Array  # bytes
    # compute metrics
    flops_per_cycle: jax.Array  # FLOP / cycle per compute class
    energy_per_flop: jax.Array  # J / FLOP
    comp_leakage: jax.Array  # W
    comp_area: jax.Array  # mm^2
    # utilization-model unit dims (systolic rows/cols; lane width)
    sys_x: jax.Array
    sys_y: jax.Array
    vect_width: jax.Array
    # SoC
    frequency: jax.Array  # Hz (effective, timing-feasible)

    @property
    def total_area(self) -> jax.Array:
        return jnp.sum(self.mem_area) + jnp.sum(self.comp_area)

    @property
    def total_leakage(self) -> jax.Array:
        return jnp.sum(self.mem_leakage) + jnp.sum(self.comp_leakage)


# --------------------------------------------------------------------------- #
# Memory device models: memLib : MemTypes x MemMetrics -> Exprs  (paper §5.1)
# --------------------------------------------------------------------------- #


def _mem_metrics(
    tech: TechParams, arch: ArchParams, type_w: jax.Array, local_ports_scale: jax.Array
) -> dict:
    """Memory metrics for all N_MEM units.

    ``type_w``: [N_MEM, 3] technology-selection weights per memory unit
    (one-hot for a concrete ArchSpec; soft for DOpt2's differentiable
    technology selection).
    ``local_ports_scale``: localMem (register files / PE scratchpads) is
    *distributed* — aggregate bandwidth scales with the number of PEs.
    """
    bits = arch.capacity * 8.0
    bank_bits = arch.bank_size * 8.0
    n_banks = jnp.maximum(bits / bank_bits, 1.0)

    # geometry: square bank, side in um
    side = jnp.sqrt(bank_bits * tech.cell_area)
    global_wire = jnp.sqrt(n_banks) * side  # routing across the bank grid

    # distributed RC (fF/um * ohm/um * um^2 -> s; 1e-15 from fF)
    rc_bank = 0.5 * tech.mem_wire_resist * tech.mem_wire_cap * 1e-15 * side**2
    rc_global = 0.5 * tech.mem_wire_resist * tech.mem_wire_cap * 1e-15 * global_wire**2

    periph_delay = (type_w @ _PERIPH_DELAY_REF) * (tech.peripheral_node / 40.0)
    cell_lat = tech.cell_read_latency / jnp.maximum(tech.cell_access_device, 1e-3)

    read_latency = cell_lat + rc_bank + rc_global + periph_delay
    write_latency = read_latency * (type_w @ _WRITE_LAT_MULT)

    # energy per byte: cell read + wire charge (8 bits/byte); the wire term
    # grows with the sqrt of the bandwidth fabric (wider buses, longer
    # average route) — neutral at bw_scale = 1
    bw_scale = jnp.maximum(arch.bw_scale, 1e-3)
    wire_e_bit = tech.mem_wire_cap * (side + global_wire) * 1e-15 * _VDD**2 * jnp.sqrt(bw_scale)
    cell_e_bit = tech.cell_read_power * 1e-12
    read_energy_pb = 8.0 * (cell_e_bit + wire_e_bit)
    write_energy_pb = read_energy_pb * (type_w @ _WRITE_EN_MULT)

    # area: cells + peripheral overhead (smaller peripheral node -> less
    # overhead) + the wider port/wire fabric bought by bw_scale (neutral at
    # the 1.0 baseline, so provisioned bandwidth is never free)
    overhead = (type_w @ _PERIPH_OVERHEAD) * (tech.peripheral_node / 40.0)
    fabric = 1.0 + 0.10 * (bw_scale - 1.0)
    mem_area = bits * tech.cell_area * 1e-6 * (1.0 + overhead) * fabric  # mm^2

    # leakage: cells + peripheral logic
    leak_cells = tech.cell_leakage_power * 1e-9 * bits
    leak_periph = (type_w @ _LEAK_PERIPH_REF) * mem_area * overhead * jnp.sqrt(40.0 / tech.peripheral_node)
    mem_leakage = leak_cells + leak_periph

    # bandwidth: each port streams one bank row per access; localMem ports
    # replicate with the PE fabric (one port per 8 MACs)
    row_bytes = jnp.sqrt(bank_bits) / 8.0
    port_scale = jnp.ones(N_MEM).at[0].set(local_ports_scale)
    mem_bw = arch.n_read_ports * port_scale * row_bytes / read_latency * bw_scale

    return dict(
        read_latency=read_latency,
        write_latency=write_latency,
        read_energy_pb=read_energy_pb,
        write_energy_pb=write_energy_pb,
        mem_leakage=mem_leakage,
        mem_area=mem_area,
        mem_bw=mem_bw,
        capacity=arch.capacity,
    )


# --------------------------------------------------------------------------- #
# Logic primitive models: primLib : PrimitiveType x CompMetrics -> XExprs
# --------------------------------------------------------------------------- #


def _prim(tech_node: jax.Array, which: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(delay s, energy J, area um^2) for primitive ``which`` at ``node`` nm.

    Delay scales ~linearly with node, energy/area ~quadratically (classic
    Dennard-flavoured scaling; adequate for a differentiable target model).
    """
    s = tech_node / 40.0
    return _PRIM_DELAY[which] * s, _PRIM_ENERGY[which] * s**2, _PRIM_AREA[which] * s**2


# --------------------------------------------------------------------------- #
# Accelerator template library: accTempls (paper §5.1)
# --------------------------------------------------------------------------- #


def _comp_metrics(tech: TechParams, arch: ArchParams) -> dict:
    node = tech.node  # [N_COMP]
    add_d, add_e, add_a = _prim(node, 0)
    mul_d, mul_e, mul_a = _prim(node, 1)
    ff_d, ff_e, ff_a = _prim(node, 2)

    # wire adder per PE: RC over the PE's own extent
    pe_side = jnp.sqrt(mul_a + add_a + 3 * ff_a)  # um
    wire_d = 0.5 * tech.comp_wire_resist * tech.comp_wire_cap * 1e-15 * pe_side**2
    wire_e = tech.comp_wire_cap * pe_side * 1e-15 * _VDD**2

    # per-class unit counts and per-MAC composition
    sys_macs = arch.sys_arr_x * arch.sys_arr_y * arch.sys_arr_n
    vect_macs = arch.vect_width * arch.vect_n
    mtree_macs = arch.mtree_x * arch.mtree_y * arch.mtree_tile_x * arch.mtree_tile_y
    fpu_macs = arch.fpu_n

    macs = jnp.stack([sys_macs, vect_macs, mtree_macs, fpu_macs])
    flops_per_cycle = 2.0 * macs  # 1 MAC = 2 FLOPs

    # cycle-limiting path per class: systolic PE is mult+ff (pipelined),
    # vector lane mult+add (FMA), mac tree mult + log-depth adder stage,
    # fpu a slower multi-stage unit (modelled 2x mult path)
    tree_depth = jnp.log2(jnp.maximum(arch.mtree_x, 2.0))
    crit = jnp.stack(
        [
            mul_d[0] + ff_d[0] + wire_d[0],
            mul_d[1] + add_d[1] + wire_d[1],
            mul_d[2] + add_d[2] * 1.0 + wire_d[2] * tree_depth,
            2.0 * (mul_d[3] + add_d[3]),
        ]
    )

    # energy per MAC (J): mult + add + pipeline regs + wires
    e_mac = jnp.stack(
        [
            mul_e[0] + add_e[0] + 3 * ff_e[0] + wire_e[0],
            mul_e[1] + add_e[1] + 2 * ff_e[1] + wire_e[1],
            mul_e[2] + add_e[2] + ff_e[2] + wire_e[2],
            2.0 * (mul_e[3] + add_e[3]) + 4 * ff_e[3],
        ]
    )
    energy_per_flop = e_mac / 2.0

    # area mm^2: PEs + 20% routing/control overhead
    a_mac = jnp.stack(
        [
            mul_a[0] + add_a[0] + 3 * ff_a[0],
            mul_a[1] + add_a[1] + 2 * ff_a[1],
            mul_a[2] + add_a[2] + ff_a[2],
            4.0 * (mul_a[3] + add_a[3]),
        ]
    )
    comp_area = macs * a_mac * 1e-6 * 1.2

    # leakage: per-area density improves (shrinks) slowly with node
    comp_leakage = _LEAK_LOGIC_REF * comp_area * jnp.sqrt(40.0 / node)

    return dict(
        flops_per_cycle=flops_per_cycle,
        energy_per_flop=energy_per_flop,
        comp_leakage=comp_leakage,
        comp_area=comp_area,
        crit_path=crit,
    )


# --------------------------------------------------------------------------- #
# specialize: H x TA x AA -> CH  (paper §3)
# --------------------------------------------------------------------------- #


def specialize(
    tech: TechParams,
    arch: ArchParams,
    spec: ArchSpec = ArchSpec(),
    type_weights: jax.Array | None = None,
) -> ConcreteHW:
    """Evaluate the hardware model into a concrete metrics pytree.

    ``type_weights`` overrides the spec's hard memory-technology selection
    with soft weights [N_MEM, 3] (used by DOpt2's differentiable technology
    search); default is the one-hot encoding of ``spec.mem_type``.
    """
    if type_weights is None:
        tw = jax.nn.one_hot(jnp.asarray(spec.mem_type_idx()), len(MEM_TYPES), dtype=jnp.float32)
    else:
        tw = type_weights

    comp = _comp_metrics(tech, arch)
    total_macs = jnp.sum(comp["flops_per_cycle"]) / 2.0
    mem = _mem_metrics(tech, arch, tw, jnp.maximum(total_macs / 8.0, 1.0))

    mem_mask = jnp.asarray(spec.mem_mask())
    comp_mask = jnp.asarray(spec.comp_mask())

    # timing feasibility: the SoC clock cannot beat the slowest critical path
    f_max = 1.0 / jnp.max(jnp.where(comp_mask > 0, comp["crit_path"], 0.0))
    frequency = jnp.minimum(arch.frequency, f_max)

    return ConcreteHW(
        read_latency=mem["read_latency"],
        write_latency=mem["write_latency"],
        read_energy_pb=mem["read_energy_pb"],
        write_energy_pb=mem["write_energy_pb"],
        mem_leakage=mem["mem_leakage"] * mem_mask,
        mem_area=mem["mem_area"] * mem_mask,
        mem_bw=mem["mem_bw"],
        capacity=mem["capacity"],
        flops_per_cycle=comp["flops_per_cycle"] * comp_mask,
        energy_per_flop=comp["energy_per_flop"],
        comp_leakage=comp["comp_leakage"] * comp_mask,
        comp_area=comp["comp_area"] * comp_mask,
        sys_x=arch.sys_arr_x,
        sys_y=arch.sys_arr_y,
        vect_width=arch.vect_width,
        frequency=frequency,
    )
