"""DHDL — the DGen hardware description language (paper §5.1).

The paper's DGen consumes "user input architectures/technology represented
in a custom description language".  This module is that front-end: a small,
source-located ``.dhd`` text format that lowers onto the existing
differentiable parameter pytrees —

    .dhd text --parse--> ArchDef AST --compile--> (ArchSpec, ArchParams, TechParams)

``dgen.specialize`` consumes the result unchanged, so everything downstream
(DSim, the mapper, DOpt, popsim) works identically for text-described and
dataclass-built architectures, gradients included.

Grammar (EBNF; ``#`` and ``//`` start line comments)::

    file       := arch_decl*
    arch_decl  := "arch" IDENT ("inherits" IDENT)? "{" stmt* "}"
    stmt       := mem_block | comp_block | tech_block | assign
    mem_block  := "memory" MEMUNIT "{" assign* "}"
    comp_block := "compute" COMPUNIT "{" assign* "}"
    tech_block := "tech" "{" (assign | mem_block | comp_block)* "}"
    assign     := IDENT ("=" NUMBER UNIT? | "=" IDENT | "*=" NUMBER)
    MEMUNIT    := "localMem" | "globalBuf" | "mainMem"
    COMPUNIT   := "systolicArray" | "vector" | "macTree" | "fpu"

Semantics:

* ``inherits`` composes architectures: the parent chain is applied first
  (root to leaf) against the dataclass defaults, each child overriding
  field-by-field.  ``*=`` multiplies the *inherited* value, so a child can
  say ``capacity *= 2`` or ``cell_read_latency *= 0.5`` without repeating
  the parent's absolute numbers — the "per-tech multipliers" idiom.
* Values carry optional units (``GHz``/``MiB``/``ns``/``nm`` ...);
  each field accepts one unit family and is stored in the simulator's
  canonical unit (Hz, bytes, seconds, nm).
* ``memory`` blocks set the per-level hierarchy (type / capacity / banks
  or bank_size / read_ports / bw);  ``compute`` blocks set unit counts and
  dims;  ``tech`` holds technology: global ``node`` / ``peripheral_node`` /
  ``vdd`` plus per-memory and per-compute overrides.  ``vdd`` is folded
  into the energy reference fields at compile time (dgen fixes VDD and
  folds voltage dependence into the energy refs — the DSL keeps that
  contract).
* ``enabled = false`` in a memory/compute block removes the unit from the
  ArchSpec (its parameters remain in the pytrees, masked out by dgen).

Errors are precise and source-located::

    mobile.dhd:7:14: unknown unit 'GHzz' for field 'frequency' (expected one of: GHz, Hz, kHz, MHz)
          frequency = 2.0 GHzz
                          ^

``serialize_arch`` is the inverse of compile: it renders any
(spec, arch, tech) triple as canonical ``.dhd`` (base units, full float32
precision, fixed field order), so parse -> serialize -> parse is the
identity and text is a faithful interchange format for optimized designs.

The architecture library under ``repro/configs/arch/*.dhd`` is loaded with
``load_arch(name)`` / ``library_archs()``; user text can ``inherit`` any
library architecture by default.
"""
from __future__ import annotations

import dataclasses
import os
import re
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import (
    COMP_CLS,
    MEM_CLS,
    MEM_TYPES,
    N_COMP,
    N_MEM,
    ArchParams,
    ArchSpec,
    TechParams,
)

__all__ = [
    "DhdlError",
    "CompiledArch",
    "parse",
    "parse_arch",
    "compile_arch",
    "serialize_arch",
    "library_dir",
    "library_archs",
    "load_arch",
    "load_library",
]

_REF_VDD = 0.9  # dgen's fixed reference VDD the energy refs are folded at


# --------------------------------------------------------------------------- #
# errors
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Span:
    filename: str
    line: int  # 1-based
    col: int  # 1-based
    text: str  # the full source line

    def format(self, msg: str) -> str:
        caret = " " * (self.col - 1) + "^"
        return (
            f"{self.filename}:{self.line}:{self.col}: {msg}\n"
            f"    {self.text}\n"
            f"    {caret}"
        )


class DhdlError(ValueError):
    """A .dhd parse/compile error with source location."""

    def __init__(self, msg: str, span: Span | None = None):
        self.msg = msg
        self.span = span
        super().__init__(span.format(msg) if span else msg)


# --------------------------------------------------------------------------- #
# lexer
# --------------------------------------------------------------------------- #

_TOKEN_RE = re.compile(
    r"""
    (?P<ws>[ \t\r]+)
  | (?P<comment>(\#|//)[^\n]*)
  | (?P<nl>\n)
  | (?P<number>[+-]?(\d+\.?\d*|\.\d+)([eE][+-]?\d+)?)
  | (?P<ident>[A-Za-z_][A-Za-z0-9_.]*)
  | (?P<muleq>\*=)
  | (?P<punct>[{}=])
    """,
    re.VERBOSE,
)


@dataclass(frozen=True)
class Token:
    kind: str  # number | ident | muleq | punct | eof
    value: str
    span: Span


def _tokenize(src: str, filename: str) -> list[Token]:
    lines = src.split("\n")
    toks: list[Token] = []
    line, col = 1, 1
    pos = 0
    while pos < len(src):
        m = _TOKEN_RE.match(src, pos)
        if m is None:
            span = Span(filename, line, col, lines[line - 1])
            raise DhdlError(f"unexpected character {src[pos]!r}", span)
        kind = m.lastgroup
        text = m.group()
        if kind == "nl":
            line += 1
            col = 1
        else:
            if kind not in ("ws", "comment"):
                toks.append(Token(kind, text, Span(filename, line, col, lines[line - 1])))
            col += len(text)
        pos = m.end()
    eof_line = max(1, min(line, len(lines)))
    toks.append(Token("eof", "", Span(filename, line, col, lines[eof_line - 1])))
    return toks


# --------------------------------------------------------------------------- #
# AST
# --------------------------------------------------------------------------- #


@dataclass
class Assign:
    key: str
    op: str  # "=" | "*="
    value: float | str  # number, or bare identifier (type / enabled values)
    unit: str | None
    span: Span


@dataclass
class Block:
    section: str  # "memory" | "compute"
    unit: str  # localMem / ... / systolicArray / ...
    assigns: list[Assign]
    span: Span


@dataclass
class ArchDef:
    name: str
    parent: str | None
    assigns: list[Assign] = field(default_factory=list)  # top-level
    blocks: list[Block] = field(default_factory=list)  # memory/compute
    tech_assigns: list[Assign] = field(default_factory=list)  # tech globals
    tech_blocks: list[Block] = field(default_factory=list)  # tech per-unit
    span: Span | None = None
    filename: str = "<dhd>"


class _Parser:
    def __init__(self, toks: list[Token]):
        self.toks = toks
        self.i = 0

    def peek(self) -> Token:
        return self.toks[self.i]

    def next(self) -> Token:
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind: str, value: str | None = None, what: str = "") -> Token:
        t = self.next()
        if t.kind != kind or (value is not None and t.value != value):
            want = value if value is not None else kind
            got = t.value if t.kind != "eof" else "end of file"
            raise DhdlError(f"expected {want!r}{' ' + what if what else ''}, got {got!r}", t.span)
        return t

    # ---------------------------------------------------------------- file
    def parse_file(self, filename: str) -> list[ArchDef]:
        defs = []
        while self.peek().kind != "eof":
            t = self.peek()
            if t.kind == "ident" and t.value == "arch":
                defs.append(self.parse_arch_decl(filename))
            else:
                raise DhdlError(f"expected 'arch' declaration, got {t.value!r}", t.span)
        return defs

    def parse_arch_decl(self, filename: str) -> ArchDef:
        kw = self.expect("ident", "arch")
        name = self.expect("ident", what="(architecture name)")
        parent = None
        if self.peek().kind == "ident" and self.peek().value == "inherits":
            self.next()
            parent = self.expect("ident", what="(parent architecture name)").value
        self.expect("punct", "{")
        d = ArchDef(name=name.value, parent=parent, span=kw.span, filename=filename)
        while not (self.peek().kind == "punct" and self.peek().value == "}"):
            t = self.peek()
            if t.kind == "eof":
                raise DhdlError(f"unclosed '{{' in arch {d.name!r}", t.span)
            if t.kind == "ident" and t.value in ("memory", "compute"):
                d.blocks.append(self.parse_block())
            elif t.kind == "ident" and t.value == "tech":
                self.parse_tech(d)
            else:
                d.assigns.append(self.parse_assign())
        self.next()  # }
        return d

    # ---------------------------------------------------------------- blocks
    def parse_block(self) -> Block:
        kw = self.next()  # memory | compute
        unit = self.expect("ident", what=f"({kw.value} unit name)")
        universe = MEM_CLS if kw.value == "memory" else COMP_CLS
        if unit.value not in universe:
            raise DhdlError(
                f"unknown {kw.value} unit {unit.value!r} (expected one of: {', '.join(universe)})",
                unit.span,
            )
        self.expect("punct", "{")
        assigns = []
        while not (self.peek().kind == "punct" and self.peek().value == "}"):
            if self.peek().kind == "eof":
                raise DhdlError(f"unclosed '{{' in {kw.value} {unit.value!r}", self.peek().span)
            assigns.append(self.parse_assign())
        self.next()
        return Block(section=kw.value, unit=unit.value, assigns=assigns, span=kw.span)

    def parse_tech(self, d: ArchDef) -> None:
        self.next()  # tech
        self.expect("punct", "{")
        while not (self.peek().kind == "punct" and self.peek().value == "}"):
            t = self.peek()
            if t.kind == "eof":
                raise DhdlError("unclosed '{' in tech block", t.span)
            if t.kind == "ident" and t.value in ("memory", "compute"):
                d.tech_blocks.append(self.parse_block())
            else:
                d.tech_assigns.append(self.parse_assign())
        self.next()

    # ---------------------------------------------------------------- assign
    def parse_assign(self) -> Assign:
        key = self.next()
        if key.kind != "ident":
            raise DhdlError(f"expected a field name, got {key.value!r}", key.span)
        op = self.next()
        if not (op.kind == "muleq" or (op.kind == "punct" and op.value == "=")):
            raise DhdlError(f"expected '=' or '*=' after {key.value!r}, got {op.value!r}", op.span)
        val = self.next()
        if op.kind == "muleq":
            if val.kind != "number":
                raise DhdlError(f"'*=' takes a bare multiplier, got {val.value!r}", val.span)
            return Assign(key.value, "*=", float(val.value), None, key.span)
        if val.kind == "ident":
            return Assign(key.value, "=", val.value, None, key.span)
        if val.kind != "number":
            raise DhdlError(f"expected a value after '=', got {val.value!r}", val.span)
        unit = None
        if self.peek().kind == "ident" and self.peek().value not in _KEYWORDS:
            # a unit suffix — any identifier immediately following a number
            # that is not the start of the next statement
            nxt = self.toks[self.i + 1] if self.i + 1 < len(self.toks) else None
            follows_assign = nxt is not None and (
                nxt.kind == "muleq" or (nxt.kind == "punct" and nxt.value == "=")
            )
            if not follows_assign:
                unit = self.next().value
        return Assign(key.value, "=", float(val.value), unit, key.span)


_KEYWORDS = {"arch", "inherits", "memory", "compute", "tech"}


def parse(src: str, filename: str = "<dhd>") -> list[ArchDef]:
    """Parse ``.dhd`` source into a list of ArchDef ASTs."""
    return _Parser(_tokenize(src, filename)).parse_file(filename)


# --------------------------------------------------------------------------- #
# unit tables + field schemas
# --------------------------------------------------------------------------- #

_FREQ = {"hz": 1.0, "khz": 1e3, "mhz": 1e6, "ghz": 1e9}
_BYTES = {
    "b": 1.0, "kib": 2.0**10, "mib": 2.0**20, "gib": 2.0**30, "tib": 2.0**40,
    "kb": 1e3, "mb": 1e6, "gb": 1e9, "tb": 1e12,
}
_TIME = {"s": 1.0, "ms": 1e-3, "us": 1e-6, "ns": 1e-9, "ps": 1e-12}
_NM = {"nm": 1.0}
_NONE: dict[str, float] = {}

# (pytree, field, unit-family) — index comes from the enclosing block's unit
_TOP_FIELDS = {"frequency": ("arch", "frequency", _FREQ)}

_MEM_FIELDS = {
    "capacity": ("arch", "capacity", _BYTES),
    "bank_size": ("arch", "bank_size", _BYTES),
    "read_ports": ("arch", "n_read_ports", _NONE),
    "bw": ("arch", "bw_scale", _NONE),
    "bw_scale": ("arch", "bw_scale", _NONE),
}
_MEM_SPECIAL = ("type", "banks", "enabled")

_COMP_FIELDS = {
    "systolicArray": {"x": "sys_arr_x", "y": "sys_arr_y", "count": "sys_arr_n"},
    "vector": {"width": "vect_width", "count": "vect_n"},
    "macTree": {"x": "mtree_x", "y": "mtree_y", "tile_x": "mtree_tile_x", "tile_y": "mtree_tile_y"},
    "fpu": {"count": "fpu_n"},
}

_TECH_GLOBAL = ("node", "peripheral_node", "vdd")

_TECH_MEM_FIELDS = {
    "wire_cap": ("tech", "mem_wire_cap", _NONE),
    "wire_resist": ("tech", "mem_wire_resist", _NONE),
    "cell_read_latency": ("tech", "cell_read_latency", _TIME),
    "cell_access_device": ("tech", "cell_access_device", _NONE),
    "cell_read_power": ("tech", "cell_read_power", _NONE),  # pJ/bit
    "cell_leakage_power": ("tech", "cell_leakage_power", _NONE),  # nW/bit
    "cell_area": ("tech", "cell_area", _NONE),  # um^2/bit
    "peripheral_node": ("tech", "peripheral_node", _NM),
}

_TECH_COMP_FIELDS = {
    "node": ("tech", "node", _NM),
    "wire_cap": ("tech", "comp_wire_cap", _NONE),
    "wire_resist": ("tech", "comp_wire_resist", _NONE),
}


def _unit_factor(a: Assign, family: dict[str, float]) -> float:
    if a.unit is None:
        return 1.0
    f = family.get(a.unit.lower())
    if f is None:
        expected = ", ".join(sorted(family, key=str.lower)) if family else "no unit"
        raise DhdlError(
            f"unknown unit {a.unit!r} for field {a.key!r} (expected: {expected})", a.span
        )
    return f


def _numeric(a: Assign) -> float:
    if isinstance(a.value, str):
        raise DhdlError(f"field {a.key!r} expects a number, got {a.value!r}", a.span)
    return float(a.value)


def _no_muleq(a: Assign) -> None:
    if a.op == "*=":
        raise DhdlError(f"field {a.key!r} does not support '*=' (use '=')", a.span)


def _as_bool(a: Assign) -> bool:
    _no_muleq(a)
    if isinstance(a.value, str):
        if a.value in ("true", "yes", "on"):
            return True
        if a.value in ("false", "no", "off"):
            return False
        raise DhdlError(f"field 'enabled' expects true/false or 0/1, got {a.value!r}", a.span)
    return bool(a.value)


# --------------------------------------------------------------------------- #
# compiler
# --------------------------------------------------------------------------- #


@dataclass
class CompiledArch:
    """A compiled .dhd architecture: the exact triple dgen.specialize eats."""

    name: str
    spec: ArchSpec
    arch: ArchParams
    tech: TechParams

    def specialize(self):
        from repro.core.dgen import specialize

        return specialize(self.tech, self.arch, self.spec)

    def simulate(self, g, mcfg=None):
        from repro.core.dsim import simulate
        from repro.core.mapper import MapperCfg

        return simulate(self.tech, self.arch, g, self.spec, mcfg or MapperCfg())


class _State:
    """Mutable lowering state: numpy copies of the default pytrees."""

    def __init__(self) -> None:
        self.arch = {
            f.name: np.array(getattr(ArchParams.default(), f.name), np.float32)
            for f in dataclasses.fields(ArchParams)
        }
        self.tech = {
            f.name: np.array(getattr(TechParams.default(), f.name), np.float32)
            for f in dataclasses.fields(TechParams)
        }
        self.mem_type = list(ArchSpec().mem_type)
        self.mem_enabled = [True] * N_MEM
        self.comp_enabled = [True] * N_COMP
        self.vdd = _REF_VDD

    # ------------------------------------------------------------- setters
    def set_field(self, tree: str, fname: str, idx: int | None, a: Assign, family: dict):
        store = self.arch if tree == "arch" else self.tech
        cur = store[fname]
        if a.op == "*=":
            mult = _numeric(a)
            if mult <= 0:
                raise DhdlError(f"multiplier for {a.key!r} must be > 0, got {mult}", a.span)
            if idx is None and cur.ndim == 0:
                store[fname] = np.float32(cur * mult)
            elif idx is None:
                cur *= np.float32(mult)
            else:
                cur[idx] = np.float32(cur[idx] * mult)
            return
        v = _numeric(a) * _unit_factor(a, family)
        if v <= 0 and a.key != "enabled":
            raise DhdlError(f"field {a.key!r} must be > 0, got {v}", a.span)
        if idx is None and cur.ndim == 0:
            store[fname] = np.float32(v)
        elif idx is None:
            cur[...] = np.float32(v)
        else:
            cur[idx] = np.float32(v)


def _apply_mem_block(st: _State, b: Block, tech_section: bool) -> None:
    i = MEM_CLS.index(b.unit)
    fields = _TECH_MEM_FIELDS if tech_section else _MEM_FIELDS
    seen = {a.key for a in b.assigns}
    if not tech_section and "banks" in seen and "bank_size" in seen:
        span = next(a.span for a in b.assigns if a.key == "banks")
        raise DhdlError(f"memory {b.unit!r} sets both 'banks' and 'bank_size'; pick one", span)
    deferred: list[Assign] = []
    for a in b.assigns:
        if not tech_section and a.key == "type":
            _no_muleq(a)
            if not isinstance(a.value, str) or a.value not in MEM_TYPES:
                raise DhdlError(
                    f"memory type must be one of: {', '.join(MEM_TYPES)}; got {a.value!r}", a.span
                )
            st.mem_type[i] = a.value
        elif not tech_section and a.key == "enabled":
            st.mem_enabled[i] = _as_bool(a)
        elif not tech_section and a.key == "banks":
            deferred.append(a)  # needs the block's capacity applied first
        elif a.key in fields:
            tree, fname, family = fields[a.key]
            st.set_field(tree, fname, i, a, family)
        else:
            where = "tech memory" if tech_section else "memory"
            known = sorted(fields) + ([] if tech_section else [k for k in _MEM_SPECIAL])
            raise DhdlError(
                f"unknown {where} field {a.key!r} (expected one of: {', '.join(known)})", a.span
            )
    for a in deferred:
        n = _numeric(a)
        if a.op == "*=" or n < 1:
            raise DhdlError(f"'banks' expects '=' and a count >= 1, got {a.op} {n}", a.span)
        st.arch["bank_size"][i] = np.float32(st.arch["capacity"][i] / np.float32(n))


def _apply_comp_block(st: _State, b: Block, tech_section: bool) -> None:
    i = COMP_CLS.index(b.unit)
    for a in b.assigns:
        if not tech_section and a.key == "enabled":
            st.comp_enabled[i] = _as_bool(a)
        elif tech_section and a.key in _TECH_COMP_FIELDS:
            tree, fname, family = _TECH_COMP_FIELDS[a.key]
            st.set_field(tree, fname, i, a, family)
        elif not tech_section and a.key in _COMP_FIELDS[b.unit]:
            st.set_field("arch", _COMP_FIELDS[b.unit][a.key], None, a, _NONE)
        else:
            known = sorted(_TECH_COMP_FIELDS) if tech_section else sorted(
                list(_COMP_FIELDS[b.unit]) + ["enabled"]
            )
            where = "tech compute" if tech_section else f"compute {b.unit!r}"
            raise DhdlError(
                f"unknown {where} field {a.key!r} (expected one of: {', '.join(known)})", a.span
            )


def _apply_def(st: _State, d: ArchDef) -> None:
    for a in d.assigns:
        if a.key in _TOP_FIELDS:
            tree, fname, family = _TOP_FIELDS[a.key]
            st.set_field(tree, fname, None, a, family)
        else:
            raise DhdlError(
                f"unknown architecture field {a.key!r} "
                f"(expected one of: {', '.join(sorted(_TOP_FIELDS))}, "
                "or a memory/compute/tech block)",
                a.span,
            )
    for b in d.blocks:
        (_apply_mem_block if b.section == "memory" else _apply_comp_block)(st, b, False)
    for a in d.tech_assigns:
        if a.key == "node":
            st.set_field("tech", "node", None, a, _NM)
        elif a.key == "peripheral_node":
            st.set_field("tech", "peripheral_node", None, a, _NM)
        elif a.key == "vdd":
            v = st.vdd * _numeric(a) if a.op == "*=" else _numeric(a)
            if not (0.1 <= v <= 2.0):
                raise DhdlError(f"vdd must be in [0.1, 2.0] volts, got {v}", a.span)
            st.vdd = v
        else:
            raise DhdlError(
                f"unknown tech field {a.key!r} (expected one of: {', '.join(_TECH_GLOBAL)}, "
                "or a memory/compute block)",
                a.span,
            )
    for b in d.tech_blocks:
        (_apply_mem_block if b.section == "memory" else _apply_comp_block)(st, b, True)


def _resolve_chain(d: ArchDef, env: dict[str, ArchDef]) -> list[ArchDef]:
    chain = [d]
    seen = {d.name}
    cur = d
    while cur.parent is not None:
        parent = env.get(cur.parent)
        if parent is None:
            raise DhdlError(
                f"arch {cur.name!r} inherits unknown architecture {cur.parent!r} "
                f"(known: {', '.join(sorted(env)) or 'none'})",
                cur.span,
            )
        if parent.name in seen:
            raise DhdlError(
                f"inheritance cycle: {' -> '.join(c.name for c in reversed(chain))} -> {parent.name}",
                cur.span,
            )
        seen.add(parent.name)
        chain.append(parent)
        cur = parent
    return list(reversed(chain))  # root first


def compile_arch(d: ArchDef | str, env: dict[str, ArchDef] | None = None) -> CompiledArch:
    """Lower an ArchDef (or a name looked up in ``env``) to the pytrees."""
    env = env or {}
    if isinstance(d, str):
        if d not in env:
            raise DhdlError(f"unknown architecture {d!r} (known: {', '.join(sorted(env)) or 'none'})")
        d = env[d]
    st = _State()
    for link in _resolve_chain(d, env):
        _apply_def(st, link)
    # fold VDD into the energy reference fields (dgen fixes VDD = 0.9 and
    # keeps voltage dependence inside the energy refs): dynamic energy ~ V^2,
    # leakage ~ V
    if st.vdd != _REF_VDD:
        r = np.float32(st.vdd / _REF_VDD)
        st.tech["cell_read_power"] = np.asarray(st.tech["cell_read_power"] * r * r, np.float32)
        st.tech["cell_leakage_power"] = np.asarray(st.tech["cell_leakage_power"] * r, np.float32)
    spec = ArchSpec(
        mem_units=tuple(m for m, e in zip(MEM_CLS, st.mem_enabled) if e),
        comp_units=tuple(c for c, e in zip(COMP_CLS, st.comp_enabled) if e),
        mem_type=tuple(st.mem_type),
    )
    if not spec.comp_units:
        raise DhdlError(f"arch {d.name!r} disables every compute unit", d.span)
    arch = ArchParams(**{k: jnp.asarray(v, jnp.float32) for k, v in st.arch.items()})
    tech = TechParams(**{k: jnp.asarray(v, jnp.float32) for k, v in st.tech.items()})
    return CompiledArch(name=d.name, spec=spec, arch=arch, tech=tech)


def build_env(defs) -> dict[str, ArchDef]:
    """Index ArchDefs by name, rejecting duplicates."""
    env: dict[str, ArchDef] = {}
    for d in defs:
        if d.name in env:
            raise DhdlError(
                f"duplicate architecture {d.name!r} (first defined in {env[d.name].filename})",
                d.span,
            )
        env[d.name] = d
    return env


def parse_arch(
    src: str,
    name: str | None = None,
    filename: str = "<dhd>",
    env: dict[str, ArchDef] | None = None,
) -> CompiledArch:
    """Parse + compile one architecture from source text.

    ``name`` selects among multiple declarations (default: the last one).
    ``env`` supplies inheritable architectures; by default the library is
    visible, so ``arch mine inherits datacenter { ... }`` just works.
    """
    defs = parse(src, filename)
    if not defs:
        raise DhdlError(f"no 'arch' declaration found in {filename}")
    base_env = dict(load_library()) if env is None else dict(env)
    base_env.update(build_env(defs))  # local declarations shadow the library;
    # duplicates *within* the source are an error (build_env raises)
    target = defs[-1].name if name is None else name
    if target not in base_env:
        raise DhdlError(f"architecture {target!r} not found in {filename}")
    return compile_arch(base_env[target], base_env)


# --------------------------------------------------------------------------- #
# serializer: (spec, arch, tech) -> canonical .dhd
# --------------------------------------------------------------------------- #


def _fmt(x) -> str:
    # full float32 precision: repr of the double that the float32 equals —
    # reparsing to float32 is bit-exact
    return repr(float(np.float32(x)))


def serialize_arch(
    ca: CompiledArch | None = None,
    *,
    name: str | None = None,
    spec: ArchSpec | None = None,
    arch: ArchParams | None = None,
    tech: TechParams | None = None,
) -> str:
    """Render an architecture as canonical ``.dhd`` text.

    Canonical form: every field explicit, base units (Hz / bytes / seconds /
    nm), fixed order, full float32 precision — so compile(parse(text)) is
    pytree-identical to the input and re-serialization is byte-identical.
    """
    if ca is not None:
        name, spec, arch, tech = ca.name, ca.spec, ca.arch, ca.tech
    assert spec is not None and arch is not None and tech is not None
    name = name or "anonymous"
    a = {f.name: np.asarray(getattr(arch, f.name), np.float32) for f in dataclasses.fields(ArchParams)}
    t = {f.name: np.asarray(getattr(tech, f.name), np.float32) for f in dataclasses.fields(TechParams)}

    out = [f"arch {name} {{", f"  frequency = {_fmt(a['frequency'])}"]
    for i, m in enumerate(MEM_CLS):
        out.append(f"  memory {m} {{")
        out.append(f"    enabled = {'true' if m in spec.mem_units else 'false'}")
        out.append(f"    type = {spec.mem_type[i]}")
        out.append(f"    capacity = {_fmt(a['capacity'][i])}")
        out.append(f"    bank_size = {_fmt(a['bank_size'][i])}")
        out.append(f"    read_ports = {_fmt(a['n_read_ports'][i])}")
        out.append(f"    bw_scale = {_fmt(a['bw_scale'][i])}")
        out.append("  }")
    comp_keys = _COMP_FIELDS
    for c in COMP_CLS:
        out.append(f"  compute {c} {{")
        out.append(f"    enabled = {'true' if c in spec.comp_units else 'false'}")
        for key, fname in comp_keys[c].items():
            out.append(f"    {key} = {_fmt(a[fname])}")
        out.append("  }")
    out.append("  tech {")
    for i, m in enumerate(MEM_CLS):
        out.append(f"    memory {m} {{")
        for key, (_, fname, _fam) in _TECH_MEM_FIELDS.items():
            out.append(f"      {key} = {_fmt(t[fname][i])}")
        out.append("    }")
    for i, c in enumerate(COMP_CLS):
        out.append(f"    compute {c} {{")
        for key, (_, fname, _fam) in _TECH_COMP_FIELDS.items():
            out.append(f"      {key} = {_fmt(t[fname][i])}")
        out.append("    }")
    out.append("  }")
    out.append("}")
    return "\n".join(out) + "\n"


# --------------------------------------------------------------------------- #
# architecture library (repro/configs/arch/*.dhd)
# --------------------------------------------------------------------------- #

_LIB_CACHE: dict[str, ArchDef] | None = None


def library_dir() -> str:
    import repro.configs

    return os.path.join(os.path.dirname(repro.configs.__file__), "arch")


def load_library(refresh: bool = False) -> dict[str, ArchDef]:
    """Parse every ``.dhd`` under the library dir into one environment."""
    global _LIB_CACHE
    if _LIB_CACHE is not None and not refresh:
        return _LIB_CACHE
    env: dict[str, ArchDef] = {}
    d = library_dir()
    if os.path.isdir(d):
        defs = []
        for fn in sorted(os.listdir(d)):
            if fn.endswith(".dhd"):
                with open(os.path.join(d, fn)) as f:
                    defs.extend(parse(f.read(), filename=fn))
        env = build_env(defs)
    _LIB_CACHE = env
    return env


def library_archs() -> list[str]:
    return sorted(load_library())


def load_arch(name: str) -> CompiledArch:
    """Compile a named library architecture (e.g. ``load_arch("edge")``)."""
    env = load_library()
    if name not in env:
        raise DhdlError(f"unknown library architecture {name!r} (known: {', '.join(sorted(env))})")
    return compile_arch(env[name], env)
