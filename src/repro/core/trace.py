"""Workload tracer: ModelConfig x ShapeConfig -> dataflow Graph.

Emits an operator-level DFG with exact FLOP / byte counts for every assigned
architecture family (dense GQA transformer, MoE, Mamba1 SSM, Mamba2 hybrid,
VLM cross-attention, audio-token decoder).  These graphs feed DSim/DOpt (the
paper's 'modern AI workloads') and are cross-checked against the compiled
HLO FLOPs of the real JAX models in tests.

Conventions:
  * bf16 operands: 2 bytes/element.
  * train mode: fwd FLOPs x3 (fwd + 2x bwd), weight gradients written back.
  * decode mode: S_q = 1 against a KV cache of length S (read from mainMem).
  * weights stream from mainMem each use (the mapper's prefetch/tiling decides
    what is actually resident — see mapper.py).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.graph import (
    CONV,
    ELEMWISE,
    GATHER,
    Graph,
    GraphBuilder,
    MATMUL,
    MISC,
    REDUCTION,
    SCAN,
    SOFTMAX,
)

BYTES = 2.0  # bf16


def _mm(b: GraphBuilder, name: str, M: float, K: float, N: float, *, mode: str, w_resident: bool = False):
    """A weight matmul [M,K]x[K,N]: activations in globalBuf, weights from mainMem."""
    mult = 3.0 if mode == "train" else 1.0
    flops = 2.0 * M * K * N * mult
    w_bytes = K * N * BYTES
    act_in = M * K * BYTES
    act_out = M * N * BYTES
    b.add(
        name,
        MATMUL,
        flops,
        gbuf_read=(act_in + w_bytes) * mult,
        gbuf_write=act_out * mult,
        main_read=0.0 if w_resident else w_bytes * (2.0 if mode == "train" else 1.0),
        main_write=w_bytes if mode == "train" else 0.0,  # weight grads
        alloc=act_in + act_out + w_bytes,
        dims=(M, N, K),
    )


def _ew(b: GraphBuilder, name: str, elems: float, flops_per: float, *, mode: str, kind: int = ELEMWISE):
    mult = 3.0 if mode == "train" else 1.0
    b.add(
        name,
        kind,
        elems * flops_per * mult,
        gbuf_read=elems * BYTES * mult,
        gbuf_write=elems * BYTES * mult,
        alloc=2 * elems * BYTES,
        dims=(elems, 1.0, 1.0),
    )


def _attention(b: GraphBuilder, name: str, Bq: float, Sq: float, Skv: float, nh: int, kv: int, hd: int, *, mode: str, causal: bool, kv_from_main: float = 0.0):
    """Scores + softmax + AV.  ``kv_from_main``: bytes of KV cache streamed
    from main memory (decode)."""
    mult = 3.0 if mode == "train" else 1.0
    frac = 0.5 if (causal and Sq == Skv) else 1.0
    score_flops = 2.0 * Bq * nh * Sq * Skv * hd * frac * mult
    kv_bytes = Bq * kv * Skv * hd * 2 * BYTES  # K and V
    q_bytes = Bq * nh * Sq * hd * BYTES
    s_bytes = Bq * nh * Sq * Skv * frac * BYTES
    b.add(
        name + ".scores",
        MATMUL,
        score_flops,
        gbuf_read=(q_bytes + kv_bytes / 2) * mult,
        gbuf_write=s_bytes * mult,
        main_read=kv_from_main / 2,
        alloc=q_bytes + kv_bytes / 2 + s_bytes,
        dims=(Bq * nh * Sq, Skv * frac, hd),
    )
    _ew(b, name + ".softmax", Bq * nh * Sq * Skv * frac, 5.0, mode=mode, kind=SOFTMAX)
    b.add(
        name + ".av",
        MATMUL,
        score_flops,
        gbuf_read=(s_bytes + kv_bytes / 2) * mult,
        gbuf_write=q_bytes * mult,
        main_read=kv_from_main / 2,
        alloc=s_bytes + kv_bytes / 2 + q_bytes,
        dims=(Bq * nh * Sq, hd, Skv * frac),
    )


def trace_lm(cfg: ModelConfig, shape: ShapeConfig) -> Graph:
    """Build the operator DFG for one (architecture x shape) cell."""
    mode = shape.kind  # train | prefill | decode
    B = float(shape.global_batch)
    S = 1.0 if mode == "decode" else float(shape.seq_len)
    Skv = float(shape.seq_len)
    d, V = float(cfg.d_model), float(cfg.vocab_size)
    T = B * S  # tokens processed this step
    b = GraphBuilder()

    # ---- embedding (gather) -------------------------------------------------
    n_emb = cfg.audio.n_codebooks if cfg.audio else 1
    b.add(
        "embed",
        GATHER,
        T * d * n_emb,
        main_read=T * d * n_emb * BYTES,
        gbuf_write=T * d * BYTES,
        alloc=T * d * BYTES,
        dims=(T, d, 1.0),
    )
    if cfg.vision:
        P = float(cfg.vision.n_patches)
        _mm(b, "patch_proj", B * P, float(cfg.vision.d_vision), d, mode=mode)

    # ---- layers -------------------------------------------------------------
    nh, kv, hd, ff = cfg.n_heads, cfg.n_kv_heads, cfg.hd, float(cfg.d_ff)

    def dense_attn_layer(i: int, prefix: str, kv_len: float, d_in: float = None):
        di = d_in or d
        _ew(b, f"{prefix}{i}.norm1", T * d, 8.0, mode=mode, kind=REDUCTION)
        _mm(b, f"{prefix}{i}.qkv", T, di, (nh + 2 * kv) * hd, mode=mode)
        _ew(b, f"{prefix}{i}.rope", T * nh * hd, 6.0, mode=mode)
        kv_main = B * kv * kv_len * hd * 2 * BYTES if mode == "decode" else 0.0
        _attention(b, f"{prefix}{i}.attn", B, S, kv_len, nh, kv, hd, mode=mode, causal=True, kv_from_main=kv_main)
        _mm(b, f"{prefix}{i}.o", T, nh * hd, d, mode=mode)

    def mlp(i: int, prefix: str, width: float):
        _ew(b, f"{prefix}{i}.norm2", T * d, 8.0, mode=mode, kind=REDUCTION)
        nmat = 3 if cfg.mlp_type == "swiglu" else 2
        _mm(b, f"{prefix}{i}.mlp_up", T, d, width * (nmat - 1), mode=mode)
        _ew(b, f"{prefix}{i}.act", T * width, 4.0, mode=mode)
        _mm(b, f"{prefix}{i}.mlp_down", T, width, d, mode=mode)

    if cfg.family in ("dense", "audio", "vlm"):
        for i in range(cfg.n_layers):
            is_cross = cfg.vision and (i + 1) % cfg.vision.cross_attn_every == 0
            if is_cross:
                P = float(cfg.vision.n_patches)
                _ew(b, f"L{i}.norm1", T * d, 8.0, mode=mode, kind=REDUCTION)
                _mm(b, f"L{i}.q", T, d, nh * hd, mode=mode)
                _mm(b, f"L{i}.kv_img", B * P, d, 2 * kv * hd, mode=mode)
                _attention(b, f"L{i}.xattn", B, S, P, nh, kv, hd, mode=mode, causal=False)
                _mm(b, f"L{i}.o", T, nh * hd, d, mode=mode)
            else:
                dense_attn_layer(i, "L", Skv)
            mlp(i, "L", ff)

    elif cfg.family == "moe":
        e = cfg.moe
        for i in range(cfg.n_layers):
            dense_attn_layer(i, "L", Skv)
            _ew(b, f"L{i}.norm2", T * d, 8.0, mode=mode, kind=REDUCTION)
            _mm(b, f"L{i}.router", T, d, e.n_experts, mode=mode)
            _ew(b, f"L{i}.topk", T * e.n_experts, 3.0, mode=mode, kind=REDUCTION)
            # dispatch + expert FFN (top_k experts active per token) + combine
            mult = 3.0 if mode == "train" else 1.0
            tok = T * e.top_k
            w_bytes = e.n_experts * 3 * d * e.d_ff_expert * BYTES
            # weights of ALL routed-to experts stream from main memory — the
            # hallmark mainMem pressure of MoE (capped by total expert bytes)
            act_expert_w = min(w_bytes, tok * 3 * d * e.d_ff_expert * BYTES)
            b.add(
                f"L{i}.dispatch",
                GATHER,
                tok * d,
                gbuf_read=T * d * BYTES * mult,
                gbuf_write=tok * d * BYTES * mult,
                alloc=(T + tok) * d * BYTES,
                dims=(tok, d, 1.0),
            )
            b.add(
                f"L{i}.experts",
                MATMUL,
                2.0 * tok * 3 * d * e.d_ff_expert * mult,
                gbuf_read=(tok * d * BYTES + act_expert_w) * mult,
                gbuf_write=tok * d * BYTES * mult,
                main_read=act_expert_w * (2.0 if mode == "train" else 1.0),
                main_write=w_bytes if mode == "train" else 0.0,
                alloc=tok * d * BYTES * 2 + act_expert_w,
                dims=(tok, e.d_ff_expert, d),
            )
            b.add(
                f"L{i}.combine",
                GATHER,
                tok * d * 2,
                gbuf_read=tok * d * BYTES * mult,
                gbuf_write=T * d * BYTES * mult,
                alloc=(T + tok) * d * BYTES,
                dims=(T, d, 1.0),
            )

    elif cfg.family == "ssm":
        s, di, dtr = cfg.ssm, float(cfg.d_inner), float(cfg.dt_rank)
        for i in range(cfg.n_layers):
            _ew(b, f"L{i}.norm", T * d, 8.0, mode=mode, kind=REDUCTION)
            _mm(b, f"L{i}.in_proj", T, d, 2 * di, mode=mode)
            b.add(
                f"L{i}.conv1d",
                CONV,
                2.0 * T * di * s.d_conv * (3.0 if mode == "train" else 1.0),
                gbuf_read=T * di * BYTES,
                gbuf_write=T * di * BYTES,
                alloc=2 * T * di * BYTES,
                dims=(T * di, 1.0, s.d_conv),
            )
            _mm(b, f"L{i}.x_proj", T, di, dtr + 2 * s.d_state, mode=mode)
            _mm(b, f"L{i}.dt_proj", T, dtr, di, mode=mode)
            # selective scan: per (token, channel): state update 3*d_state
            # FLOPs + output reduction 2*d_state
            _ew(b, f"L{i}.sel_scan", T * di, 5.0 * s.d_state, mode=mode, kind=SCAN)
            _ew(b, f"L{i}.gate", T * di, 4.0, mode=mode)
            _mm(b, f"L{i}.out_proj", T, di, d, mode=mode)

    elif cfg.family == "hybrid":
        s, di = cfg.ssm, float(cfg.d_inner)
        nssm = di // s.head_dim
        h = cfg.hybrid
        for i in range(cfg.n_layers):
            _ew(b, f"L{i}.norm", T * d, 8.0, mode=mode, kind=REDUCTION)
            _mm(b, f"L{i}.in_proj", T, d, 2 * di + 2 * nssm * s.d_state + nssm, mode=mode)
            b.add(
                f"L{i}.conv1d",
                CONV,
                2.0 * T * (di + 2 * nssm * s.d_state) * s.d_conv,
                gbuf_read=T * di * BYTES,
                gbuf_write=T * di * BYTES,
                alloc=2 * T * di * BYTES,
                dims=(T * di, 1.0, s.d_conv),
            )
            # SSD: intra-chunk matmuls dominate; ~4 * T * di * d_state FLOPs
            _ew(b, f"L{i}.ssd", T * di, 6.0 * s.d_state, mode=mode, kind=SCAN)
            _mm(b, f"L{i}.out_proj", T, di, d, mode=mode)
            if (i + 1) % h.attn_every == 0:
                # shared attention block on concat(hidden, embed): 2d -> heads
                _ew(b, f"L{i}.snorm", T * 2 * d, 8.0, mode=mode, kind=REDUCTION)
                _mm(b, f"L{i}.sqkv", T, 2 * d, (nh + 2 * kv) * hd, mode=mode, w_resident=True)
                kv_main = B * kv * Skv * hd * 2 * BYTES if mode == "decode" else 0.0
                _attention(b, f"L{i}.sattn", B, S, Skv, nh, kv, hd, mode=mode, causal=True, kv_from_main=kv_main)
                _mm(b, f"L{i}.so", T, nh * hd, d, mode=mode, w_resident=True)
                _mm(b, f"L{i}.smlp_up", T, d, 3 * h.shared_attn_mlp_ff - h.shared_attn_mlp_ff, mode=mode, w_resident=True)
                _mm(b, f"L{i}.smlp_down", T, h.shared_attn_mlp_ff, d, mode=mode, w_resident=True)
    else:
        raise ValueError(cfg.family)

    # ---- head ---------------------------------------------------------------
    _ew(b, "final_norm", T * d, 8.0, mode=mode, kind=REDUCTION)
    _mm(b, "logits", T, d, V * n_emb, mode=mode)
    if mode == "train":
        _ew(b, "xent", T * V, 6.0, mode=mode, kind=SOFTMAX)

    return b.build()


# --------------------------------------------------------------------------- #
# Model-FLOPs formulas for validation (6ND and friends)
# --------------------------------------------------------------------------- #


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6 * N_active * D for train; 2 * N_active * D for inference."""
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    n = cfg.active_param_count()
    per_tok = 6.0 * n if shape.kind == "train" else 2.0 * n
    return per_tok * tokens
