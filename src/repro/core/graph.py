"""Workload dataflow graphs (paper §4).

A workload is a DAG of operator vertices.  For JAX-friendliness the graph is
a struct-of-arrays: per-vertex resource stats (the paper's "vertex state"
inputs: compute ops per compute class, bytes read/written/allocated per
memory unit) plus matmul-ish dims for utilization modelling and an op-kind
tag.  Edges are kept for the graph-level compiler passes (compute-merge,
bridge partitioning — paper Alg. 3); the mapper consumes vertices in
topological order, as the paper's MAPWORKLOAD does after workloadOptimize.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import COMP_IDX, MEM_IDX, N_COMP, N_MEM

# op kinds
MATMUL, ELEMWISE, REDUCTION, SCAN, GATHER, SOFTMAX, CONV, MISC = range(8)
KIND_NAMES = ("matmul", "elemwise", "reduction", "scan", "gather", "softmax", "conv", "misc")

# routing of op kinds onto compute classes (fractions of the op's FLOPs):
#                         sysArr vector macTree fpu
_KIND_ROUTE = np.array(
    [
        [1.00, 0.00, 0.00, 0.00],  # matmul  -> systolic array
        [0.00, 1.00, 0.00, 0.00],  # elemwise-> vector
        [0.00, 0.20, 0.80, 0.00],  # reduction -> mac tree (+ vector epilogue)
        [0.00, 0.90, 0.00, 0.10],  # scan    -> vector w/ fpu control
        [0.00, 0.50, 0.00, 0.50],  # gather  -> address calc on fpu
        [0.00, 0.60, 0.40, 0.00],  # softmax -> vector exp + tree reductions
        [1.00, 0.00, 0.00, 0.00],  # conv    -> systolic array
        [0.00, 0.00, 0.00, 1.00],  # misc    -> fpu
    ],
    np.float32,
)


@dataclass
class Graph:
    """Struct-of-arrays DFG.  All data arrays have leading dim V."""

    n_comp: jax.Array  # [V, N_COMP] FLOPs routed per compute class
    n_read: jax.Array  # [V, N_MEM]  bytes read
    n_write: jax.Array  # [V, N_MEM]  bytes written
    n_alloc: jax.Array  # [V, N_MEM]  bytes that must be resident (working set)
    dims: jax.Array  # [V, 3]  (M, N, K) for utilization modelling
    op_kind: jax.Array  # [V] int32
    edges: jax.Array  # [E, 2] int32 (src, dst)
    names: tuple = field(default=())  # static metadata

    @property
    def n_vertices(self) -> int:
        return self.n_comp.shape[0]

    @property
    def total_flops(self) -> jax.Array:
        return jnp.sum(self.n_comp)

    def pad_to(self, v: int) -> "Graph":
        """Pad vertex arrays to ``v`` (no-op vertices) for batched DSE."""
        cur = self.n_comp.shape[0]
        if cur == v:
            return self
        assert cur < v, (cur, v)
        p = v - cur

        def pad(x):
            cfg = [(0, p)] + [(0, 0)] * (x.ndim - 1)
            return jnp.pad(x, cfg)

        return Graph(
            n_comp=pad(self.n_comp),
            n_read=pad(self.n_read),
            n_write=pad(self.n_write),
            n_alloc=pad(self.n_alloc),
            dims=pad(self.dims),
            op_kind=pad(self.op_kind),
            edges=self.edges,
            names=self.names + ("pad",) * p,
        )

    @staticmethod
    def stack(graphs: "list[Graph]") -> "Graph":
        """Stack workloads into one Graph with a leading workload axis W.

        Every data array becomes [W, V_max, ...] (vertex lists padded with
        no-op vertices via :meth:`pad_to`; the mapper prices no-op vertices
        at zero cycles and excludes them from the tile/memory-time
        diagnostics, so padding is exact for the whole MapState).  This is the batched-workload
        convention shared by DOpt's multi-workload loss and popsim's
        population DSE: simulate is vmapped over the leading axis.  Edges are
        ragged across workloads and unused by the mapper, so the stacked
        graph carries an empty edge list.
        """
        assert graphs, "Graph.stack needs at least one graph"
        vmax = max(g.n_vertices for g in graphs)
        gs = [g.pad_to(vmax) for g in graphs]
        stk = lambda f: jnp.stack([getattr(g, f) for g in gs])
        return Graph(
            n_comp=stk("n_comp"),
            n_read=stk("n_read"),
            n_write=stk("n_write"),
            n_alloc=stk("n_alloc"),
            dims=stk("dims"),
            op_kind=stk("op_kind"),
            edges=jnp.zeros((len(gs), 0, 2), jnp.int32),
            names=tuple(g.names for g in gs),
        )


jax.tree_util.register_dataclass(
    Graph,
    data_fields=["n_comp", "n_read", "n_write", "n_alloc", "dims", "op_kind", "edges"],
    meta_fields=["names"],
)


class GraphBuilder:
    """Imperative construction (numpy), immutable Graph output."""

    def __init__(self):
        self._rows: list[dict] = []
        self._edges: list[tuple[int, int]] = []
        self._last: int | None = None

    def add(
        self,
        name: str,
        kind: int,
        flops: float,
        *,
        gbuf_read: float = 0.0,
        gbuf_write: float = 0.0,
        main_read: float = 0.0,
        main_write: float = 0.0,
        alloc: float = 0.0,
        dims: tuple[float, float, float] = (1.0, 1.0, 1.0),
        deps: list[int] | None = None,
        chain: bool = True,
    ) -> int:
        """Add a vertex; returns its index.

        ``alloc`` is the on-chip working set (globalBuf).  localMem traffic is
        modelled as operand/register traffic proportional to FLOPs.
        """
        vid = len(self._rows)
        local = flops * 1.0  # ~1 byte of register-file traffic per FLOP
        n_read = np.zeros(N_MEM, np.float32)
        n_write = np.zeros(N_MEM, np.float32)
        n_alloc = np.zeros(N_MEM, np.float32)
        n_read[MEM_IDX["localMem"]] = local
        n_write[MEM_IDX["localMem"]] = local * 0.5
        n_read[MEM_IDX["globalBuf"]] = gbuf_read
        n_write[MEM_IDX["globalBuf"]] = gbuf_write
        n_read[MEM_IDX["mainMem"]] = main_read
        n_write[MEM_IDX["mainMem"]] = main_write
        n_alloc[MEM_IDX["globalBuf"]] = alloc
        n_alloc[MEM_IDX["mainMem"]] = main_read + main_write
        self._rows.append(
            dict(
                name=name,
                kind=kind,
                n_comp=_KIND_ROUTE[kind] * np.float32(flops),
                n_read=n_read,
                n_write=n_write,
                n_alloc=n_alloc,
                dims=np.asarray(dims, np.float32),
            )
        )
        if deps is not None:
            for d in deps:
                self._edges.append((d, vid))
        elif chain and self._last is not None:
            self._edges.append((self._last, vid))
        self._last = vid
        return vid

    def build(self) -> Graph:
        assert self._rows, "empty graph"
        return Graph(
            n_comp=jnp.asarray(np.stack([r["n_comp"] for r in self._rows])),
            n_read=jnp.asarray(np.stack([r["n_read"] for r in self._rows])),
            n_write=jnp.asarray(np.stack([r["n_write"] for r in self._rows])),
            n_alloc=jnp.asarray(np.stack([r["n_alloc"] for r in self._rows])),
            dims=jnp.asarray(np.stack([r["dims"] for r in self._rows])),
            op_kind=jnp.asarray(np.array([r["kind"] for r in self._rows], np.int32)),
            edges=jnp.asarray(
                np.array(self._edges, np.int32).reshape(-1, 2)
                if self._edges
                else np.zeros((0, 2), np.int32)
            ),
            names=tuple(r["name"] for r in self._rows),
        )


# --------------------------------------------------------------------------- #
# Graph-level compiler passes (paper Alg. 3: workloadOptimize)
# --------------------------------------------------------------------------- #


def compute_merge(g: Graph, flops_threshold: float = 1e6) -> Graph:
    """Compute Merge Optimizer (paper Alg. 3): greedily merge consecutive
    small vertices (all below threshold) into one, summing their stats.
    Operates on the topological order; preserves total work exactly."""
    nc = np.asarray(g.n_comp)
    small = nc.sum(-1) < flops_threshold
    rows = []
    group: list[int] = []
    order = list(range(g.n_vertices))

    def flush():
        if group:
            rows.append(list(group))
            group.clear()

    for v in order:
        if small[v]:
            group.append(v)
            if sum(nc[group].sum(-1)) >= flops_threshold:
                flush()
        else:
            flush()
            rows.append([v])
    flush()

    def merge(x):
        x = np.asarray(x)
        return jnp.asarray(np.stack([x[idx].sum(0) for idx in rows]))

    dims = np.asarray(g.dims)
    kind = np.asarray(g.op_kind)
    return Graph(
        n_comp=merge(g.n_comp),
        n_read=merge(g.n_read),
        n_write=merge(g.n_write),
        n_alloc=jnp.asarray(
            np.stack([np.asarray(g.n_alloc)[idx].max(0) for idx in rows])
        ),
        dims=jnp.asarray(np.stack([dims[idx[0]] for idx in rows])),
        op_kind=jnp.asarray(np.array([kind[idx[0]] for idx in rows], np.int32)),
        edges=jnp.zeros((0, 2), jnp.int32),
        names=tuple("+".join(g.names[i] for i in idx) if len(idx) > 1 else g.names[idx[0]] for idx in rows),
    )


def workload_optimize(g: Graph, merge_threshold: float = 0.0) -> Graph:
    """paper §5.2 workloadOptimize: DFG partitioning + compute merge.
    The struct-of-arrays graph is already topologically ordered by
    construction; optionally merge small vertices."""
    if merge_threshold > 0:
        g = compute_merge(g, merge_threshold)
    return g
