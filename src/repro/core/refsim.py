"""Reference cycle-walker simulator (accuracy/speed baseline).

Stands in for the SCALE-Sim / Timeloop-class tools the paper compares
against (§8.1): an interpreted, per-tile, per-wave stepping simulator with
discrete bank-conflict and burst-quantization effects that the fast
closed-form DSim approximates.  Deliberately written as a Python loop over
numpy scalars — the point is the asymptotic *class* (stepped simulation),
which is what makes such tools slow.

DSim accuracy in `bench_sim_speed.py` is measured against this walker.
"""
from __future__ import annotations

import numpy as np

from repro.core.dgen import ConcreteHW
from repro.core.graph import Graph
from repro.core.params import COMP_IDX, MEM_IDX, N_COMP, N_MEM

_GBUF = MEM_IDX["globalBuf"]
_MAIN = MEM_IDX["mainMem"]
_LOCAL = MEM_IDX["localMem"]
_SYS = COMP_IDX["systolicArray"]


def _np(chw_field) -> np.ndarray:
    return np.asarray(chw_field, dtype=np.float64)


def reference_simulate(chw: ConcreteHW, g: Graph, headroom: float = 0.9) -> dict:
    """Walk the DFG tile-by-tile, wave-by-wave with discrete quantization.

    Returns dict(cycles, runtime, energy) — comparable to DSim output.
    """
    freq = float(chw.frequency)
    cap = _np(chw.capacity)
    bw = _np(chw.mem_bw)
    rlat = _np(chw.read_latency)
    wlat = _np(chw.write_latency)
    re_pb = _np(chw.read_energy_pb)
    we_pb = _np(chw.write_energy_pb)
    e_flop = _np(chw.energy_per_flop)
    rate = _np(chw.flops_per_cycle) * freq
    sx, sy = float(chw.sys_x), float(chw.sys_y)

    n_comp = np.asarray(g.n_comp, np.float64)
    n_read = np.asarray(g.n_read, np.float64)
    n_write = np.asarray(g.n_write, np.float64)
    n_alloc = np.asarray(g.n_alloc, np.float64)
    dims = np.asarray(g.dims, np.float64)

    total_cycles = 0.0
    e_dyn = 0.0
    bw_ema = 0.0
    occupancy = 0.0
    cap_g = cap[_GBUF] * headroom

    for v in range(n_comp.shape[0]):
        alloc = n_alloc[v][_GBUF]
        tiles = max(int(np.ceil(alloc / cap_g)), 1)
        M, N, K = dims[v]
        m_t = max(M / tiles, 1.0)

        # discrete wave stepping for the systolic array: each wave processes
        # a (sx x sy) output tile; waves quantize to whole cycles
        t_cls = np.zeros(N_COMP)
        for c in range(N_COMP):
            ops = n_comp[v][c] / tiles
            if ops <= 0:
                continue
            if c == _SYS:
                waves_m = int(np.ceil(m_t / sx))
                waves_n = int(np.ceil(max(N, 1.0) / sy))
                k_cycles = int(np.ceil(max(K, 1.0)))  # one K-step per cycle
                fill = sx + sy  # pipeline fill/drain per wave
                cyc = waves_m * waves_n * (k_cycles + fill)
                # cap at ideal rate (utilization can't exceed 1)
                cyc = max(cyc, ops / (rate[c] / freq))
                t_cls[c] = cyc / freq
            else:
                t_cls[c] = ops / rate[c]
        t_comp = float(t_cls.max())

        # memory: burst-quantized transfers + per-tile access latency +
        # pseudo-random bank conflicts (deterministic hash of vertex id)
        t_lvl = np.zeros(N_MEM)
        for m in range(N_MEM):
            per_tile = (n_read[v][m] + n_write[v][m]) / tiles
            if per_tile <= 0:
                continue
            burst = 64.0  # bytes per burst
            bursts = np.ceil(per_tile / burst)
            conflict = 1.0 + 0.08 * (((v * 2654435761) >> 16) % 100) / 100.0
            t_lvl[m] = (bursts * burst / bw[m]) * conflict + rlat[m] + wlat[m]
        t_onchip = max(t_lvl[_GBUF], t_lvl[_LOCAL])
        t_main = t_lvl[_MAIN]

        # paper Alg. 7: prefetch when space+bw available, STREAMING when over
        # capacity but bw available — either way main-memory time hides
        # whenever the bandwidth EMA has headroom
        can_hide = bw_ema < headroom
        tile_t = max(t_comp / 1.0, t_onchip)
        exposed = max(t_main - (tile_t if can_hide else 0.0), 0.0)
        t_vertex = tiles * (tile_t + exposed)

        # integer-cycle quantization per tile (cycle-walker behaviour)
        cyc_v = tiles * int(np.ceil((tile_t + exposed) * freq))
        total_cycles += cyc_v

        used_bw = (n_read[v][_GBUF] + n_write[v][_GBUF]) / max(t_vertex, 1e-30) / bw[_GBUF]
        bw_ema = 0.8 * bw_ema + 0.2 * min(used_bw, 2.0)
        occupancy = min(0.5 * occupancy + alloc, cap[_GBUF])

        e_dyn += float(np.sum(n_read[v] * re_pb) + np.sum(n_write[v] * we_pb))
        e_dyn += float(np.sum(n_comp[v] * e_flop))

    runtime = total_cycles / freq
    leak = float(np.sum(_np(chw.mem_leakage)) + np.sum(_np(chw.comp_leakage)))
    energy = e_dyn + leak * runtime
    return dict(cycles=total_cycles, runtime=runtime, energy=energy)
