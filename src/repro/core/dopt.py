"""DOpt — the hardware optimizer (paper §7, Appendix A/B).

Gradient descent on the *joint* space of technology and architectural
parameters, through the differentiable mapper.  One forward (simulate) +
backward (grad) = one epoch (paper §7).  Features:

  * objectives: time / energy / edp / power, optional area constraint
    F = obj * e^(a-A) (paper §11.3 / Appendix C);
  * optimization over tech params, arch params, or both;
  * log-space Adam (positive parameters, multiplicative updates) with
    realistic bounds clamping (paper Alg. 6 step 5);
  * technology-target derivation (paper §8.3): run until a target
    improvement factor is met, return the ranked order of technology
    parameters by accumulated |elasticity| — the paper's Table 3;
  * DOpt2: differentiable memory-technology selection via Gumbel-softmax
    over {sram, rram, dram} per memory unit, annealed.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import instrument
from repro.core.dsim import PARETO_METRICS, mixed_log_objective, stacked_log_objective
from repro.core.graph import Graph
from repro.core.mapper import MapperCfg
from repro.core.params import (
    COMP_CLS,
    MEM_CLS,
    MEM_TYPES,
    ArchParams,
    ArchSpec,
    TechParams,
    clamp_params,
)

# --------------------------------------------------------------------------- #
# log-space Adam over pytrees
# --------------------------------------------------------------------------- #


@jax.tree_util.register_dataclass
@dataclass
class AdamState:
    m: object
    v: object
    step: jax.Array  # dynamic! a static step would retrace every epoch


def adam_init(params) -> AdamState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamState(m=z, v=jax.tree.map(jnp.zeros_like, params), step=jnp.zeros((), jnp.int32))


def adam_update(grads, state: AdamState, lr: float, b1=0.9, b2=0.999, eps=1e-8):
    step = state.step + 1
    stepf = step.astype(jnp.float32)
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)
    mh = jax.tree.map(lambda m: m / (1 - jnp.power(b1, stepf)), m)
    vh = jax.tree.map(lambda v: v / (1 - jnp.power(b2, stepf)), v)
    upd = jax.tree.map(lambda m, v: -lr * m / (jnp.sqrt(v) + eps), mh, vh)
    return upd, AdamState(m=m, v=v, step=step)


def to_log(p):
    return jax.tree.map(lambda x: jnp.log(jnp.maximum(x, 1e-30)), p)


def from_log(z):
    return jax.tree.map(jnp.exp, z)


# --------------------------------------------------------------------------- #
# parameter naming (for importance ranking / Table 3)
# --------------------------------------------------------------------------- #

_TECH_FIELD_CLASSES = {
    "mem_wire_cap": MEM_CLS,
    "mem_wire_resist": MEM_CLS,
    "cell_read_latency": MEM_CLS,
    "cell_access_device": MEM_CLS,
    "cell_read_power": MEM_CLS,
    "cell_leakage_power": MEM_CLS,
    "cell_area": MEM_CLS,
    "peripheral_node": MEM_CLS,
    "comp_wire_cap": COMP_CLS,
    "comp_wire_resist": COMP_CLS,
    "node": COMP_CLS,
}


def tech_param_names() -> list[str]:
    names = []
    for f in dataclasses.fields(TechParams):
        for cls in _TECH_FIELD_CLASSES[f.name]:
            names.append(f"{cls}.{f.name}")
    return names


def _flatten_tech(t: TechParams) -> jax.Array:
    return jnp.concatenate([jnp.atleast_1d(getattr(t, f.name)) for f in dataclasses.fields(TechParams)])


# --------------------------------------------------------------------------- #
# DOpt driver
# --------------------------------------------------------------------------- #


@dataclass
class OptResult:
    tech: TechParams
    arch: ArchParams
    type_weights: jax.Array | None
    history: dict  # lists per metric
    importance: list[tuple[str, float]]  # ranked tech-parameter elasticities


def _default_chunk(steps: int, target_factor) -> int:
    """Epochs fused per device dispatch.

    Equal-size chunks (ceil-divided against a cap) so one optimize() call
    compiles at most two scan-program lengths, usually one — e.g. 200 steps
    -> 4x50, 60 steps -> 2x30.  The cap bounds compile time per program;
    with ``target_factor`` a smaller cap bounds how far past the target the
    fused scan can overshoot before the boundary check."""
    if steps <= 0:  # steps=0 is a valid no-op run (baseline read)
        return 1
    cap = 25 if target_factor is not None else 50
    n_chunks = -(-steps // cap)
    return -(-steps // n_chunks)


def guard_init() -> tuple:
    """Initial non-finite-containment guard carried through the scan:
    ``(lr_scale, last_metrics)``.  ``lr_scale`` multiplies the learning rate
    (1.0 until a fault halves it); ``last_metrics`` is the most recent
    *accepted* history row (NaN until the first finite epoch), emitted in
    place of a faulted epoch's metrics so history never carries the
    corruption."""
    return (jnp.float32(1.0), jnp.full((5,), jnp.nan, jnp.float32))


def _dopt_step(state, gstack: Graph, lr, mix, fault, spec, objective, area_constraint, opt_over, mcfg):
    """One DOpt epoch (forward + backward + Adam + in-jit log-space clamp),
    with in-jit non-finite containment.

    Top-level (not a closure) so the jitted chunk runner below caches across
    ``optimize()`` calls: the workload stack, lr and the objective mix are
    traced *arguments*, not baked-in constants, so any optimize() with
    matching shapes and static config reuses the compiled program.

    ``mix`` is the traced ``(weights, area_budget, power_budget,
    penalty_weight)`` tuple consumed when ``objective == "mixed"`` (the
    multi-objective scalarization); for string objectives it is carried but
    unused.

    ``fault`` is the traced chaos seam: a positive scalar poisons this
    epoch's loss and gradients with NaN *before* the containment check, so
    the rollback path is exercised by the exact machinery a real divergence
    would hit.  Containment: when the loss or any gradient leaf is
    non-finite, the epoch's parameter/Adam/type updates are rolled back
    (the previous state is re-emitted bit-for-bit), the guard's ``lr_scale``
    halves (recovering 2x per clean epoch, capped at 1.0), the elasticity
    contribution is zeroed, and the history row re-emits the last accepted
    metrics with the trailing fault flag set.  A fault-free epoch is
    bit-identical to the unguarded computation: the selects take the
    all-true branch and ``lr * 1.0`` is exact.
    """
    instrument.count_trace("dopt._dopt_step")  # retrace probe (trace-time only)
    tech_z, arch_z, type_logits, tstate, astate, ystate, guard = state
    lr_scale, last_metrics = guard
    dopt2 = opt_over == "both+types"

    def loss_fn(tz, az, tl):
        # batched multi-workload loss: one vmapped simulate over the stacked
        # workload axis; log-objective keeps gradients scale-free
        tw = None if tl is None else jax.nn.softmax(tl, -1)
        if objective == "mixed":
            w, ab, pb, pw = mix
            return mixed_log_objective(
                from_log(tz), from_log(az), gstack, w, ab, pb, pw, spec, mcfg, tw
            )
        return stacked_log_objective(
            from_log(tz), from_log(az), gstack, objective, area_constraint, spec, mcfg, tw
        )

    (val, perfs), grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2) if dopt2 else (0, 1), has_aux=True)(
        tech_z, arch_z, type_logits
    )
    # chaos seam: an injected fault corrupts loss+grads exactly like a real
    # numeric escape would, upstream of the containment logic
    poison = fault > 0
    val = jnp.where(poison, jnp.full_like(val, jnp.nan), val)
    grads = jax.tree.map(lambda g: jnp.where(poison, jnp.full_like(g, jnp.nan), g), grads)
    ok = jnp.isfinite(val)
    for leaf in jax.tree.leaves(grads):
        ok = ok & jnp.all(jnp.isfinite(leaf))
    g_tech, g_arch = grads[0], grads[1]
    prev = (tech_z, arch_z, type_logits, tstate, astate, ystate)
    lr_eff = lr * lr_scale
    if opt_over in ("tech", "both", "both+types"):
        upd, tstate = adam_update(g_tech, tstate, lr_eff)
        tech_z = jax.tree.map(lambda p, u: p + u, tech_z, upd)
    if opt_over in ("arch", "both", "both+types"):
        upd, astate = adam_update(g_arch, astate, lr_eff)
        arch_z = jax.tree.map(lambda p, u: p + u, arch_z, upd)
    if dopt2:
        upd, ystate = adam_update(grads[2], ystate, lr_eff * 4.0)
        type_logits = type_logits + upd
    # clamp to realistic bounds (paper Alg. 6) — log is monotone, so
    # clamping z against log(bounds) inside the jitted body replaces the
    # old out-of-jit exp/clip/log host round-trip
    tech_z = clamp_params(tech_z, *(to_log(b) for b in TechParams.bounds()))
    arch_z = clamp_params(arch_z, *(to_log(b) for b in ArchParams.bounds()))
    # containment: roll back to the last finite state when anything escaped
    cand = (tech_z, arch_z, type_logits, tstate, astate, ystate)
    tech_z, arch_z, type_logits, tstate, astate, ystate = jax.tree.map(
        lambda n_, o_: jnp.where(ok, n_, o_), cand, prev
    )
    lr_scale = jnp.where(ok, jnp.minimum(lr_scale * 2.0, 1.0), lr_scale * 0.5)
    # elasticity d log obj / d log param = gradient in log space (zeroed on
    # a faulted epoch so the importance accumulator never sees NaN)
    elast = jnp.where(ok, _flatten_tech(g_tech), jnp.zeros(len(tech_param_names()), jnp.float32))
    # history row: [objective, runtime, energy, area, edp] of workload 0,
    # re-emitting the last accepted row on a faulted epoch, + fault flag
    rt, en, ar = perfs.runtime[0], perfs.energy[0], perfs.area[0]
    row = jnp.where(ok, jnp.stack([val, rt, en, ar, rt * en]), last_metrics)
    metrics = jnp.concatenate([row, 1.0 - ok.astype(jnp.float32)[None]])
    guard = (lr_scale, row)
    return (tech_z, arch_z, type_logits, tstate, astate, ystate, guard), elast, metrics


@partial(
    jax.jit,
    static_argnames=("spec", "objective", "area_constraint", "opt_over", "mcfg", "n"),
    donate_argnums=(0, 1),
)
def _fused_chunk(state, elast_acc, gstack: Graph, lr, mix, faults, *, spec, objective, area_constraint, opt_over, mcfg, n: int):
    """``n`` device-resident epochs as one ``lax.scan`` dispatch.

    Param/Adam state is donated between chunks; elasticity accumulates
    on-device; the per-epoch metric history comes back as one stacked
    [n, 6] array (a single host transfer per chunk).  ``faults`` is the
    [n] chaos schedule scanned alongside (all-zero outside chaos tests)."""

    def body(c, fault):
        st, eacc = c
        st, elast, metrics = _dopt_step(st, gstack, lr, mix, fault, spec, objective, area_constraint, opt_over, mcfg)
        return (st, eacc + jnp.abs(elast)), metrics

    return jax.lax.scan(body, (state, elast_acc), faults, length=n)


def optimize(
    graphs: list[Graph] | Graph,
    tech: TechParams | None = None,
    arch: ArchParams | None = None,
    spec: ArchSpec = ArchSpec(),
    objective: str = "edp",
    area_constraint: float | None = None,
    opt_over: str = "both",  # tech | arch | both | both+types (DOpt2)
    steps: int = 200,
    lr: float = 0.05,
    mcfg: MapperCfg = MapperCfg(),
    target_factor: float | None = None,  # stop when obj improves by this factor
    log_every: int = 0,
    fused: bool = True,  # device-resident chunked-scan epochs (False: per-step loop)
    chunk: int | None = None,  # epochs per device dispatch when fused
    objective_weights=None,  # [4] PARETO_METRICS mix, for objective="mixed"
    area_budget: float | None = None,  # worst-case area ceiling (mm^2), mixed only
    power_budget: float | None = None,  # worst-case power ceiling (W), mixed only
    penalty_weight: float = 1.0,  # budget-penalty scale, mixed only
    nan_epochs: tuple = (),  # chaos seam: epochs whose loss/grads are NaN-poisoned
) -> OptResult:
    """DOpt driver.

    ``objective="mixed"`` descends the constrained scalarization of the
    (time, energy, area, edp) log-metric vector (dsim.mixed_log_objective):
    ``objective_weights`` mixes the metrics, ``area_budget``/``power_budget``
    apply smooth log-space penalties scaled by ``penalty_weight``.  The mix
    is a *traced* argument, so sequential calls with different mixes reuse
    one compiled program — this is the per-trajectory form of what
    popsim.pareto_dse runs as a vmapped population.

    ``fused=True`` (default) runs epochs device-resident: chunks of
    ``jax.lax.scan`` over the jitted step with the Adam/param state donated
    between dispatches, bounds clamping in log-space inside the jitted body,
    elasticity accumulated on-device, and the per-epoch metric history
    coming back as one stacked [chunk, 5] device array — a single host sync
    per chunk instead of five scalar transfers per epoch.  The
    ``target_factor`` early exit is evaluated at chunk boundaries, so the
    fused loop may run up to one chunk past the meeting epoch; history,
    elasticities and the returned params consistently cover every executed
    epoch.

    ``fused=False`` keeps a per-step Python loop: one jitted dispatch and
    one host sync per epoch, retraced per optimize() call — a conservative
    stand-in for the pre-fusion driver (the original additionally clamped
    out-of-jit and made five scalar transfers per epoch), retained for
    equivalence tests and before/after throughput benchmarks.

    ``graphs`` may be a single Graph, a list of Graphs, or an already
    ``Graph.stack()``-ed workload set (leading [W] axis) — the façade passes
    pre-bucketed stacks so same-shape calls share one compiled program.
    """
    if isinstance(graphs, Graph):
        gstack = graphs if graphs.n_comp.ndim == 3 else Graph.stack([graphs])
    else:
        gstack = Graph.stack(list(graphs))
    tech = tech or TechParams.default()
    arch = arch or ArchParams.default()

    tech_z, arch_z = to_log(tech), to_log(arch)
    dopt2 = opt_over == "both+types"
    type_logits = jnp.zeros((len(MEM_CLS), len(MEM_TYPES))) if dopt2 else None
    lr_arr = jnp.float32(lr)
    if objective == "mixed" and objective_weights is None:
        raise ValueError('objective="mixed" needs objective_weights (len-4 PARETO_METRICS mix)')
    if objective == "mixed" and area_constraint is not None:
        raise ValueError('objective="mixed" takes area_budget (log-space penalty), not area_constraint')
    if objective != "mixed" and not (
        objective_weights is None and area_budget is None and power_budget is None and penalty_weight == 1.0
    ):
        raise ValueError(
            "objective_weights/area_budget/power_budget/penalty_weight only apply to "
            f'objective="mixed" (got objective={objective!r}) — they would be silently ignored'
        )
    w = jnp.zeros(len(PARETO_METRICS)) if objective_weights is None else jnp.asarray(objective_weights, jnp.float32)
    if w.shape != (len(PARETO_METRICS),):
        raise ValueError(f"objective_weights must be shape {(len(PARETO_METRICS),)}, got {w.shape}")
    mix = (
        w,
        jnp.float32(jnp.inf if area_budget is None else area_budget),
        jnp.float32(jnp.inf if power_budget is None else power_budget),
        jnp.float32(penalty_weight),
    )
    static = dict(spec=spec, objective=objective, area_constraint=area_constraint, opt_over=opt_over, mcfg=mcfg)

    # chaos schedule: which epochs get their loss/grads NaN-poisoned inside
    # the jitted step (tests the rollback path with the real machinery)
    fault_np = np.zeros(steps, np.float32)
    for i in nan_epochs:
        if 0 <= int(i) < steps:
            fault_np[int(i)] = 1.0

    # the pre-fusion baseline: a per-call jitted step closure, exactly the
    # old driver's cost model (retraces every optimize() invocation, one
    # dispatch + host sync per epoch)
    step_jit = jax.jit(lambda st, flt: _dopt_step(st, gstack, lr_arr, mix, flt, **static))

    tstate, astate = adam_init(tech_z), adam_init(arch_z)
    ystate = adam_init(type_logits) if dopt2 else adam_init(jnp.zeros(1))
    state = (tech_z, arch_z, type_logits, tstate, astate, ystate, guard_init())
    elast_acc = jnp.zeros(len(tech_param_names()), jnp.float32)

    hist = dict(objective=[], runtime=[], energy=[], area=[], edp=[], fault=[])

    def _append(m: np.ndarray):
        hist["objective"] += m[:, 0].tolist()
        hist["runtime"] += m[:, 1].tolist()
        hist["energy"] += m[:, 2].tolist()
        hist["area"] += m[:, 3].tolist()
        hist["edp"] += m[:, 4].tolist()
        hist["fault"] += m[:, 5].tolist()

    def _target_met() -> bool:
        """True once the objective has improved by target_factor.  The fused
        path evaluates this at chunk boundaries, so it may run up to one
        chunk past the meeting epoch — history, elasticities and the
        returned params all consistently cover every executed epoch."""
        if target_factor is None or len(hist["edp"]) < 2:
            return False
        cur = np.asarray(hist["edp"] if objective == "edp" else np.exp(np.asarray(hist["objective"])))
        return bool(np.any(cur[0] / np.maximum(cur[1:], 1e-300) >= target_factor))

    executed = 0
    if fused:
        chunk = _default_chunk(steps, target_factor) if chunk is None else max(1, chunk)
        while executed < steps:
            n = min(chunk, steps - executed)
            faults = jnp.asarray(fault_np[executed:executed + n])
            (state, elast_acc), metrics = _fused_chunk(state, elast_acc, gstack, lr_arr, mix, faults, n=n, **static)
            executed += n
            _append(np.asarray(metrics))  # the one host sync per chunk
            if log_every:
                for i in range(executed - n, executed, log_every):
                    print(
                        f"  dopt step {i:4d}  obj={hist['objective'][i]:.4f} "
                        f"runtime={hist['runtime'][i]:.3e}s energy={hist['energy'][i]:.3e}J"
                    )
            if _target_met():
                break
    else:
        for i in range(steps):
            state, elast, metrics = step_jit(state, jnp.float32(fault_np[i]))
            elast_acc = elast_acc + jnp.abs(elast)
            executed += 1
            _append(np.asarray(metrics)[None])
            if log_every and i % log_every == 0:
                print(
                    f"  dopt step {i:4d}  obj={hist['objective'][i]:.4f} "
                    f"runtime={hist['runtime'][i]:.3e}s energy={hist['energy'][i]:.3e}J"
                )
            if _target_met():
                break

    tech_z, arch_z, type_logits = state[0], state[1], state[2]
    elast_mean = np.asarray(elast_acc, np.float64) / max(executed, 1)
    ranked = sorted(zip(tech_param_names(), elast_mean), key=lambda kv: -kv[1])
    return OptResult(
        tech=from_log(tech_z),
        arch=from_log(arch_z),
        type_weights=None if not dopt2 else jax.nn.softmax(type_logits, -1),
        history=hist,
        importance=[(n, float(v)) for n, v in ranked],
    )


def derive_tech_targets(
    graphs,
    goal_factor: float = 100.0,
    objective: str = "edp",
    spec: ArchSpec = ArchSpec(),
    steps: int = 400,
    lr: float = 0.05,
) -> dict:
    """paper §8.3: derive technology targets for a goal_factor x improvement.

    Returns the targets (start -> end values per tech parameter), the ranked
    importance order, and the achieved factor — a single gradient-descent
    pass instead of a >1e5-point technology sweep.
    """
    # baseline objective at the default design point: a direct simulate —
    # not a throwaway optimize(steps=1, lr=0) that jit-compiles a full
    # gradient step just to read one forward value
    if isinstance(graphs, Graph) and graphs.n_comp.ndim == 3:
        gstack = graphs
    else:
        gstack = Graph.stack([graphs] if isinstance(graphs, Graph) else list(graphs))
    base_val, _ = stacked_log_objective(
        TechParams.default(), ArchParams.default(), gstack, objective, spec=spec
    )
    start = TechParams.default()
    res = optimize(
        graphs, tech=start, opt_over="tech", objective=objective, steps=steps, lr=lr, spec=spec, target_factor=goal_factor
    )
    start_f = np.asarray(_flatten_tech(start))
    end_f = np.asarray(_flatten_tech(res.tech))
    names = tech_param_names()
    targets = {
        n: dict(start=float(s), target=float(e), factor=float(s / max(e, 1e-300)))
        for n, s, e in zip(names, start_f, end_f)
    }
    edp0 = res.history["edp"][0]
    edp1 = res.history["edp"][-1]
    return dict(
        targets=targets,
        importance=res.importance,
        achieved_factor=edp0 / max(edp1, 1e-300),
        epochs=len(res.history["edp"]),
        history=res.history,
        baseline_objective=float(base_val),
    )
