"""DOpt — the hardware optimizer (paper §7, Appendix A/B).

Gradient descent on the *joint* space of technology and architectural
parameters, through the differentiable mapper.  One forward (simulate) +
backward (grad) = one epoch (paper §7).  Features:

  * objectives: time / energy / edp / power, optional area constraint
    F = obj * e^(a-A) (paper §11.3 / Appendix C);
  * optimization over tech params, arch params, or both;
  * log-space Adam (positive parameters, multiplicative updates) with
    realistic bounds clamping (paper Alg. 6 step 5);
  * technology-target derivation (paper §8.3): run until a target
    improvement factor is met, return the ranked order of technology
    parameters by accumulated |elasticity| — the paper's Table 3;
  * DOpt2: differentiable memory-technology selection via Gumbel-softmax
    over {sram, rram, dram} per memory unit, annealed.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsim import objective_value, simulate
from repro.core.graph import Graph
from repro.core.mapper import MapperCfg
from repro.core.params import (
    COMP_CLS,
    MEM_CLS,
    MEM_TYPES,
    ArchParams,
    ArchSpec,
    TechParams,
    clamp_params,
)

# --------------------------------------------------------------------------- #
# log-space Adam over pytrees
# --------------------------------------------------------------------------- #


@jax.tree_util.register_dataclass
@dataclass
class AdamState:
    m: object
    v: object
    step: jax.Array  # dynamic! a static step would retrace every epoch


def adam_init(params) -> AdamState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamState(m=z, v=jax.tree.map(jnp.zeros_like, params), step=jnp.zeros((), jnp.int32))


def adam_update(grads, state: AdamState, lr: float, b1=0.9, b2=0.999, eps=1e-8):
    step = state.step + 1
    stepf = step.astype(jnp.float32)
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)
    mh = jax.tree.map(lambda m: m / (1 - jnp.power(b1, stepf)), m)
    vh = jax.tree.map(lambda v: v / (1 - jnp.power(b2, stepf)), v)
    upd = jax.tree.map(lambda m, v: -lr * m / (jnp.sqrt(v) + eps), mh, vh)
    return upd, AdamState(m=m, v=v, step=step)


def to_log(p):
    return jax.tree.map(lambda x: jnp.log(jnp.maximum(x, 1e-30)), p)


def from_log(z):
    return jax.tree.map(jnp.exp, z)


# --------------------------------------------------------------------------- #
# parameter naming (for importance ranking / Table 3)
# --------------------------------------------------------------------------- #

_TECH_FIELD_CLASSES = {
    "mem_wire_cap": MEM_CLS,
    "mem_wire_resist": MEM_CLS,
    "cell_read_latency": MEM_CLS,
    "cell_access_device": MEM_CLS,
    "cell_read_power": MEM_CLS,
    "cell_leakage_power": MEM_CLS,
    "cell_area": MEM_CLS,
    "peripheral_node": MEM_CLS,
    "comp_wire_cap": COMP_CLS,
    "comp_wire_resist": COMP_CLS,
    "node": COMP_CLS,
}


def tech_param_names() -> list[str]:
    names = []
    for f in dataclasses.fields(TechParams):
        for cls in _TECH_FIELD_CLASSES[f.name]:
            names.append(f"{cls}.{f.name}")
    return names


def _flatten_tech(t: TechParams) -> jax.Array:
    return jnp.concatenate([jnp.atleast_1d(getattr(t, f.name)) for f in dataclasses.fields(TechParams)])


# --------------------------------------------------------------------------- #
# DOpt driver
# --------------------------------------------------------------------------- #


@dataclass
class OptResult:
    tech: TechParams
    arch: ArchParams
    type_weights: jax.Array | None
    history: dict  # lists per metric
    importance: list[tuple[str, float]]  # ranked tech-parameter elasticities


def _make_loss(graphs: list[Graph], spec: ArchSpec, objective: str, area_constraint, mcfg: MapperCfg):
    def loss(tech_z, arch_z, type_logits):
        tech = from_log(tech_z)
        arch = from_log(arch_z)
        tw = None if type_logits is None else jax.nn.softmax(type_logits, -1)
        total = 0.0
        perfs = []
        for g in graphs:
            perf = simulate(tech, arch, g, spec, mcfg, tw)
            total = total + jnp.log(objective_value(perf, objective, area_constraint))
            perfs.append(perf)
        # log-objective: scale-free gradients across heterogeneous workloads
        return total / len(graphs), perfs

    return loss


def optimize(
    graphs: list[Graph] | Graph,
    tech: TechParams | None = None,
    arch: ArchParams | None = None,
    spec: ArchSpec = ArchSpec(),
    objective: str = "edp",
    area_constraint: float | None = None,
    opt_over: str = "both",  # tech | arch | both | both+types (DOpt2)
    steps: int = 200,
    lr: float = 0.05,
    mcfg: MapperCfg = MapperCfg(),
    target_factor: float | None = None,  # stop when obj improves by this factor
    log_every: int = 0,
) -> OptResult:
    if isinstance(graphs, Graph):
        graphs = [graphs]
    tech = tech or TechParams.default()
    arch = arch or ArchParams.default()
    tlo, thi = TechParams.bounds()
    alo, ahi = ArchParams.bounds()

    tech_z, arch_z = to_log(tech), to_log(arch)
    dopt2 = opt_over == "both+types"
    type_logits = jnp.zeros((len(MEM_CLS), len(MEM_TYPES))) if dopt2 else None

    loss_fn = _make_loss(graphs, spec, objective, area_constraint, mcfg)

    @jax.jit
    def step_fn(tech_z, arch_z, type_logits, tstate, astate, ystate):
        (val, perfs), grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2) if dopt2 else (0, 1), has_aux=True)(
            tech_z, arch_z, type_logits
        )
        g_tech, g_arch = grads[0], grads[1]
        outs = {}
        if opt_over in ("tech", "both", "both+types"):
            upd, tstate = adam_update(g_tech, tstate, lr)
            tech_z_n = jax.tree.map(lambda p, u: p + u, tech_z, upd)
        else:
            tech_z_n = tech_z
        if opt_over in ("arch", "both", "both+types"):
            upd, astate = adam_update(g_arch, astate, lr)
            arch_z_n = jax.tree.map(lambda p, u: p + u, arch_z, upd)
        else:
            arch_z_n = arch_z
        if dopt2:
            upd, ystate = adam_update(grads[2], ystate, lr * 4.0)
            type_logits = type_logits + upd
        # elasticity d log obj / d log param = gradient in log space
        elast = _flatten_tech(g_tech)
        return tech_z_n, arch_z_n, type_logits, tstate, astate, ystate, val, elast, perfs[0].runtime, perfs[0].energy, perfs[0].area

    tstate, astate = adam_init(tech_z), adam_init(arch_z)
    ystate = adam_init(type_logits) if dopt2 else adam_init(jnp.zeros(1))

    hist = dict(objective=[], runtime=[], energy=[], area=[], edp=[])
    elast_acc = np.zeros(len(tech_param_names()), np.float64)
    obj0 = None
    for i in range(steps):
        tech_z, arch_z, type_logits, tstate, astate, ystate, val, elast, rt, en, ar = step_fn(
            tech_z, arch_z, type_logits, tstate, astate, ystate
        )
        # clamp to realistic bounds (paper Alg. 6)
        tech_z = to_log(clamp_params(from_log(tech_z), tlo, thi))
        arch_z = to_log(clamp_params(from_log(arch_z), alo, ahi))
        elast_acc += np.abs(np.asarray(elast, np.float64))
        v = float(val)
        hist["objective"].append(v)
        hist["runtime"].append(float(rt))
        hist["energy"].append(float(en))
        hist["area"].append(float(ar))
        hist["edp"].append(float(rt) * float(en))
        if obj0 is None:
            obj0 = hist["edp"][0] if objective == "edp" else np.exp(v)
        if log_every and i % log_every == 0:
            print(f"  dopt step {i:4d}  obj={v:.4f} runtime={rt:.3e}s energy={en:.3e}J")
        if target_factor is not None and i > 0:
            cur = hist["edp"][-1] if objective == "edp" else np.exp(v)
            if obj0 / max(cur, 1e-300) >= target_factor:
                break

    ranked = sorted(zip(tech_param_names(), elast_acc / max(len(hist["objective"]), 1)), key=lambda kv: -kv[1])
    return OptResult(
        tech=from_log(tech_z),
        arch=from_log(arch_z),
        type_weights=None if not dopt2 else jax.nn.softmax(type_logits, -1),
        history=hist,
        importance=[(n, float(v)) for n, v in ranked],
    )


def derive_tech_targets(
    graphs,
    goal_factor: float = 100.0,
    objective: str = "edp",
    spec: ArchSpec = ArchSpec(),
    steps: int = 400,
    lr: float = 0.05,
) -> dict:
    """paper §8.3: derive technology targets for a goal_factor x improvement.

    Returns the targets (start -> end values per tech parameter), the ranked
    importance order, and the achieved factor — a single gradient-descent
    pass instead of a >1e5-point technology sweep.
    """
    base = optimize(graphs, opt_over="tech", objective=objective, steps=1, lr=0.0, spec=spec)
    start = TechParams.default()
    res = optimize(
        graphs, tech=start, opt_over="tech", objective=objective, steps=steps, lr=lr, spec=spec, target_factor=goal_factor
    )
    start_f = np.asarray(_flatten_tech(start))
    end_f = np.asarray(_flatten_tech(res.tech))
    names = tech_param_names()
    targets = {
        n: dict(start=float(s), target=float(e), factor=float(s / max(e, 1e-300)))
        for n, s, e in zip(names, start_f, end_f)
    }
    edp0 = res.history["edp"][0]
    edp1 = res.history["edp"][-1]
    return dict(
        targets=targets,
        importance=res.importance,
        achieved_factor=edp0 / max(edp1, 1e-300),
        epochs=len(res.history["edp"]),
        history=res.history,
        baseline_objective=base.history["objective"][0],
    )
