"""Population-scale multi-objective DSE, sharded over the mesh.

The paper runs DOpt single-host on a single scalar objective.  At cluster
scale, DSE is a *population* of independent gradient-descent trajectories
(multi-start over the non-convex design/technology space, paper Fig. 3),
each descending its own constrained objective mix, evaluated against a
*set* of workloads — and the question architects ask is not "what is the
optimum" but "what does the latency/energy/area frontier look like, and
which design wins under a budget".

This module is that engine:

  * :func:`seed_population` — [P] starting points from the ``.dhd``
    architecture library plus log-space jitter (pristine library seeds are
    kept unjittered);
  * :func:`sample_objective_mixes` — per-member PARETO_METRICS weight
    vectors (Dirichlet over a metric subset, deterministic one-hot corners
    first so the front's extremes are always probed);
  * :func:`population_chunk` — ``n`` epochs of ``P`` independent Adam
    trajectories as ONE device dispatch: the per-member DOpt step
    (dsim.mixed_log_objective value_and_grad + log-space Adam + Alg.-6
    bounds clamping) vmapped over the member axis inside a ``lax.scan``
    over epochs, with the Adam/param state donated between dispatches and
    the per-epoch penalty weight supplied as a scan input so constraint
    schedules don't force chunk boundaries.  With a mesh, the same body
    runs under ``runtime.spmd_map`` with members sharded along a mesh axis
    — trajectories are independent, so there are no collectives;
  * :func:`pareto_dse` — the driver: seed, descend, extract the
    non-dominated front (core.pareto), and serialize every winner back to
    diffable ``.dhd`` text via dhdl.serialize_arch.

Against running the same trajectories as sequential ``optimize()`` calls,
the population engine removes the per-candidate host work (re-stacking the
workload set, re-initializing optimizer state, per-call dispatch + sync)
and batches the mapper across members — benchmarks/bench_pareto.py records
the member-epochs/sec of both paths.

Legacy single-objective helpers (init_population / population_objective /
make_dse_step / shard_population / dse_in_shardings) are kept: they are the
pjit-able DSE step the multi-pod dry-run lowers, proving DRAGON itself
distributes.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import instrument
from repro.core.dhdl import load_arch, serialize_arch
from repro.core.dopt import adam_init, adam_update, from_log, to_log
from repro.core.dsim import (
    PARETO_METRICS,
    mixed_log_objective,
    simulate_stacked,
    stacked_log_metrics,
    stacked_log_objective,
)
from repro.core.graph import Graph
from repro.core.mapper import MapperCfg
from repro.core.params import ArchParams, ArchSpec, TechParams, clamp_params
from repro.core.pareto import hv_ref_point, hypervolume, non_dominated_mask
from repro.kernels import runtime


# --------------------------------------------------------------------------- #
# population seeding: .dhd library starts + log-space jitter
# --------------------------------------------------------------------------- #


def seed_population(
    n: int,
    seeds: tuple[str, ...] = ("base", "edge", "datacenter"),
    key: jax.Array | None = None,
    sigma: float = 0.25,
) -> tuple[tuple[TechParams, ArchParams], ArchSpec, tuple[str, ...]]:
    """[P]-stacked (tech, arch) start points from named ``.dhd`` library
    architectures, round-robin over ``seeds`` with log-normal jitter.

    The first ``len(seeds)`` members are the pristine library designs
    (jitter only applies from the second pass over the seed list), so every
    described architecture is always present in the population exactly as
    written.  Jittered points are clamped into the Alg.-6 bounds.  All
    seeds must share one ArchSpec — the spec is static under vmap; mixing
    enabled-unit or memory-type variants needs separate populations.
    """
    if n < len(seeds):
        raise ValueError(f"population {n} smaller than seed list {seeds}")
    cas = [load_arch(nm) for nm in seeds]
    spec = cas[0].spec
    for nm, ca in zip(seeds, cas):
        if ca.spec != spec:
            raise ValueError(
                f"seed {nm!r} has ArchSpec {ca.spec}, expected {spec} "
                f"(population members share one static spec)"
            )
    key = jax.random.PRNGKey(0) if key is None else key
    member_names = tuple(seeds[i % len(seeds)] for i in range(n))
    jitter_mask = jnp.asarray([i >= len(seeds) for i in range(n)], jnp.float32)

    def stack_tree(get):
        leaves_list = [jax.tree.flatten(get(ca))[0] for ca in cas]
        treedef = jax.tree.structure(get(cas[0]))
        stacked = [
            jnp.stack([leaves_list[i % len(cas)][li] for i in range(n)])
            for li in range(len(leaves_list[0]))
        ]
        return jax.tree.unflatten(treedef, stacked)

    tech = stack_tree(lambda ca: ca.tech)
    arch = stack_tree(lambda ca: ca.arch)

    def jitter(tree, bounds, k):
        leaves, treedef = jax.tree.flatten(tree)
        keys = jax.random.split(k, len(leaves))
        lo_l = jax.tree.flatten(to_log(bounds[0]))[0]
        hi_l = jax.tree.flatten(to_log(bounds[1]))[0]
        out = []
        for leaf, kk, l, h in zip(leaves, keys, lo_l, hi_l):
            noise = sigma * jax.random.normal(kk, leaf.shape)
            moved = jnp.exp(jnp.clip(jnp.log(leaf) + noise, l, h))
            # pristine seeds bypass the log round-trip entirely: the first
            # pass over the seed list is the library design, bit for bit
            mask = jitter_mask.reshape((n,) + (1,) * (leaf.ndim - 1)) > 0
            out.append(jnp.where(mask, moved, leaf))
        return jax.tree.unflatten(treedef, out)

    kt, ka = jax.random.split(key)
    return (jitter(tech, TechParams.bounds(), kt), jitter(arch, ArchParams.bounds(), ka)), spec, member_names


def sample_objective_mixes(
    n: int,
    metrics: tuple[str, ...] = ("time", "energy", "area"),
    key: jax.Array | None = None,
    concentration: float = 0.7,
) -> jax.Array:
    """[P, 4] PARETO_METRICS weight vectors, one objective mix per member.

    The first ``len(metrics)`` members get deterministic one-hot corners
    (pure latency, pure energy, ...), so the frontier's extreme points are
    always descended; the rest draw Dirichlet(``concentration``) mixes over
    the chosen metric subset (concentration < 1 biases toward edges of the
    simplex — spread, not consensus).
    """
    idx = np.asarray([PARETO_METRICS.index(m) for m in metrics])
    key = jax.random.PRNGKey(1) if key is None else key
    alpha = jnp.full((len(idx),), jnp.float32(concentration))
    draws = jax.random.dirichlet(key, alpha, (n,))  # [n, k]
    corners = jnp.eye(len(idx), dtype=jnp.float32)
    k = min(n, len(idx))
    draws = draws.at[:k].set(corners[:k])
    w = jnp.zeros((n, len(PARETO_METRICS)), jnp.float32)
    return w.at[:, idx].set(draws)


# --------------------------------------------------------------------------- #
# the population chunk: P trajectories x n epochs, one dispatch
# --------------------------------------------------------------------------- #


def init_population_state(tech: TechParams, arch: ArchParams):
    """Optimizer state for [P]-stacked params: per-member log-space params +
    per-member Adam moments (vmapped adam_init, so AdamState.step is [P])."""
    tech_z, arch_z = to_log(tech), to_log(arch)
    return (tech_z, arch_z, jax.vmap(adam_init)(tech_z), jax.vmap(adam_init)(arch_z))


def _member_step(tech_z, arch_z, tstate, astate, weights, area_budget, power_budget,
                 gstack, lr, penalty_w, spec, mcfg, opt_over):
    """One epoch of one member — mirrors dopt._dopt_step exactly (same loss
    for a one-hot mix, same Adam, same in-jit log-space Alg.-6 clamp), which
    is what the population-vs-sequential equivalence tests pin.

    Non-finite containment, vmapped per member: if a member's loss or
    gradients go non-finite, its parameter/Adam update is rolled back (the
    member freezes at its last finite state) while the rest of the
    population keeps descending — one diverging trajectory cannot poison
    its neighbours or the final front.  A finite epoch is bit-identical to
    the unguarded step (the selects take the all-true branch)."""
    instrument.count_trace("popsim._member_step")  # retrace probe (trace-time only)

    def loss_fn(tz, az):
        return mixed_log_objective(
            from_log(tz), from_log(az), gstack, weights, area_budget, power_budget,
            penalty_w, spec, mcfg,
        )

    (val, perfs), (g_t, g_a) = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(tech_z, arch_z)
    ok = jnp.isfinite(val)
    for leaf in jax.tree.leaves((g_t, g_a)):
        ok = ok & jnp.all(jnp.isfinite(leaf))
    prev = (tech_z, arch_z, tstate, astate)
    if opt_over in ("tech", "both"):
        upd, tstate = adam_update(g_t, tstate, lr)
        tech_z = jax.tree.map(lambda p, u: p + u, tech_z, upd)
    if opt_over in ("arch", "both"):
        upd, astate = adam_update(g_a, astate, lr)
        arch_z = jax.tree.map(lambda p, u: p + u, arch_z, upd)
    tech_z = clamp_params(tech_z, *(to_log(b) for b in TechParams.bounds()))
    arch_z = clamp_params(arch_z, *(to_log(b) for b in ArchParams.bounds()))
    cand = (tech_z, arch_z, tstate, astate)
    tech_z, arch_z, tstate, astate = jax.tree.map(
        lambda n_, o_: jnp.where(ok, n_, o_), cand, prev
    )
    # per-epoch row: [scalarized value, log time, log energy, log area, log edp]
    return (tech_z, arch_z, tstate, astate), jnp.concatenate([val[None], stacked_log_metrics(perfs)])


def _population_scan(state, mixes, gstack, lr, pw_schedule, spec, mcfg, opt_over):
    """The un-jitted chunk body: scan over epochs of the vmapped member step.

    ``pw_schedule`` [n] supplies the (schedulable) budget-penalty weight per
    epoch as a scan input, so a whole constraint ramp runs in one dispatch.
    Returns (state', metrics [n, P, 5]).
    """
    step = partial(_member_step, spec=spec, mcfg=mcfg, opt_over=opt_over)

    def epoch(st, pw):
        new, m = jax.vmap(
            lambda t, a, ts, as_, w, ab, pb: step(t, a, ts, as_, w, ab, pb, gstack, lr, pw)
        )(*st, *mixes)
        return new, m

    return jax.lax.scan(epoch, state, pw_schedule)


@partial(jax.jit, static_argnames=("spec", "mcfg", "opt_over"), donate_argnums=(0,))
def _population_chunk_jit(state, mixes, gstack, lr, pw_schedule, *, spec, mcfg, opt_over):
    return _population_scan(state, mixes, gstack, lr, pw_schedule, spec, mcfg, opt_over)


_SHARDED_CACHE: dict = {}


def population_chunk(
    state,
    mixes,
    gstack: Graph,
    lr,
    pw_schedule,
    *,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
    opt_over: str = "both",
    mesh=None,
    axis: str = "pop",
):
    """Advance ``P`` independent Adam trajectories ``len(pw_schedule)``
    epochs device-resident, in one dispatch.

    * ``state``: ``init_population_state`` output (donated — do not reuse);
    * ``mixes``: ``(weights [P,4], area_budget [P], power_budget [P])``;
    * ``pw_schedule`` [n]: per-epoch budget-penalty weight (the constraint
      schedule), a traced scan input;
    * ``mesh``/``axis``: shard the member axis across mesh devices via
      ``runtime.spmd_map`` — members are independent, so the mapped body
      has no collectives; the mesh axis size must divide P.  ``mesh=None``
      (or a 1-device mesh) runs the plain jitted path.

    Returns ``(state', metrics [n, P, 5])`` with per-epoch rows
    ``[scalarized value, log time, log energy, log area, log edp]``.
    """
    if opt_over not in ("tech", "arch", "both"):
        # the population engine has no DOpt2 type-logits state; an unknown
        # opt_over would otherwise run a full descent that never moves
        raise ValueError(
            f"opt_over={opt_over!r} not supported by the population engine "
            "(use 'tech', 'arch' or 'both'; DOpt2 'both+types' is optimize()-only)"
        )
    lr = jnp.float32(lr)
    pw_schedule = jnp.asarray(pw_schedule, jnp.float32)
    if mesh is None or mesh.size == 1:
        return _population_chunk_jit(
            state, mixes, gstack, lr, pw_schedule, spec=spec, mcfg=mcfg, opt_over=opt_over
        )
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has axes {mesh.axis_names}, no {axis!r} axis")
    p = jax.tree.leaves(state[0])[0].shape[0]
    shards = mesh.shape[axis]
    if p % shards != 0:
        raise ValueError(
            f"mesh axis {axis!r}={shards} must divide the population (got P={p}) — "
            f"pad the population to a multiple of {shards}"
        )
    cache_key = (mesh, axis, spec, mcfg, opt_over, int(pw_schedule.shape[0]))
    fn = _SHARDED_CACHE.get(cache_key)
    if fn is None:
        body = partial(_population_scan, spec=spec, mcfg=mcfg, opt_over=opt_over)
        mapped = runtime.spmd_map(
            lambda st, mx, gs, lr_, pws: body(st, mx, gs, lr_, pws),
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(), P(), P()),
            out_specs=(P(axis), P(None, axis)),
        )
        # same donation contract as the single-device path: state is consumed
        fn = _SHARDED_CACHE[cache_key] = jax.jit(mapped, donate_argnums=(0,))
    return fn(state, mixes, gstack, lr, pw_schedule)


@partial(jax.jit, static_argnames=("spec", "mcfg"))
def population_log_metrics(
    tech: TechParams,
    arch: ArchParams,
    gstack: Graph,
    spec: ArchSpec = ArchSpec(),
    mcfg: MapperCfg = MapperCfg(),
):
    """Final-population evaluation: per-member ``[P, 4]`` log-metric vectors
    plus the worst-case-over-workloads raw area [P] and power [P] the budget
    feasibility check is defined on (matching dsim.budget_penalty)."""

    def one(ti, ai):
        perfs = simulate_stacked(ti, ai, gstack, spec, mcfg)
        return stacked_log_metrics(perfs), jnp.max(perfs.area), jnp.max(perfs.power)

    return jax.vmap(one)(tech, arch)


# --------------------------------------------------------------------------- #
# the driver: seed -> descend -> Pareto front -> .dhd winners
# --------------------------------------------------------------------------- #


@dataclass
class ParetoResult:
    tech: TechParams  # [P] final technology params
    arch: ArchParams  # [P] final architecture params
    spec: ArchSpec
    seeds: tuple[str, ...]  # per-member seed architecture names
    weights: np.ndarray  # [P, 4] objective mixes
    area_budget: np.ndarray  # [P]
    power_budget: np.ndarray  # [P]
    history: np.ndarray  # [steps, P, 5]: value + log metrics per epoch
    log_metrics: np.ndarray  # [P, 4] final log-metric vectors
    area: np.ndarray  # [P] final worst-case area (mm^2)
    power: np.ndarray  # [P] final worst-case power (W)
    feasible: np.ndarray  # [P] bool: meets budgets within tolerance
    front: np.ndarray  # indices of the non-dominated feasible subset
    front_log_metrics: np.ndarray  # [F, len(metrics)] points the front lives on
    hypervolume: float  # MC hypervolume of the front (log-metric space)
    hv_lo: np.ndarray  # sample-box lower corner the hypervolume used
    hv_ref: np.ndarray  # reference point (box upper corner) the hypervolume used
    winners: list  # one dict per front member, incl. serialized .dhd text


def pareto_dse(
    graphs: list[Graph] | Graph,
    seeds: tuple[str, ...] = ("base", "edge", "datacenter"),
    population: int = 24,
    steps: int = 24,
    lr: float = 0.1,
    metrics: tuple[str, ...] = ("time", "energy", "area"),
    area_budget: float | None = None,
    power_budget: float | None = None,
    penalty_weight: tuple[float, float] = (0.25, 4.0),
    budget_tol: float = 0.05,
    opt_over: str = "both",
    sigma: float = 0.25,
    concentration: float = 0.7,
    chunk: int | None = None,
    spec_override: ArchSpec | None = None,
    mcfg: MapperCfg = MapperCfg(),
    mesh=None,
    key: int | jax.Array = 0,
    hv_box: tuple | None = None,
) -> ParetoResult:
    """Population-scale constrained multi-objective DSE.

    Seeds ``population`` members from the ``.dhd`` library (+ log-space
    jitter), gives each its own objective mix over ``metrics`` (and the
    shared area/power budgets), advances all trajectories device-resident
    with the budget-penalty weight ramped geometrically across
    ``penalty_weight = (start, end)``, then extracts the feasible
    non-dominated front, its hypervolume, and serializes every winner back
    to canonical ``.dhd`` text.

    ``chunk`` bounds epochs per dispatch (default: all ``steps`` in one —
    the penalty schedule rides the scan input, so chunking is only a
    compile-time/host-visibility knob, not a semantic one).

    ``hv_box`` optionally fixes the hypervolume sample box as ``(lo, ref)``
    arrays in the selected log-metric space.  The default box is derived
    from this run's feasible points, which is fine for a single frontier
    but NOT comparable across runs — pass a common box (e.g. derived from
    the seed designs, as benchmarks/bench_pareto.py does) when tracking
    hypervolume as a trend metric; the box used is always recorded in
    ``hv_lo``/``hv_ref``.

    ``graphs`` may also be an already ``Graph.stack()``-ed workload set
    (leading [W] axis) — the façade passes pre-bucketed stacks.
    """
    if isinstance(graphs, Graph):
        gstack = graphs if graphs.n_comp.ndim == 3 else Graph.stack([graphs])
    else:
        gstack = Graph.stack(list(graphs))
    key = jax.random.PRNGKey(key) if isinstance(key, int) else key
    k_seed, k_mix = jax.random.split(key)

    (tech0, arch0), spec, member_seeds = seed_population(population, seeds, k_seed, sigma)
    if spec_override is not None:
        spec = spec_override
    weights = sample_objective_mixes(population, metrics, k_mix, concentration)
    ab = jnp.full((population,), jnp.float32(jnp.inf if area_budget is None else area_budget))
    pb = jnp.full((population,), jnp.float32(jnp.inf if power_budget is None else power_budget))
    mixes = (weights, ab, pb)

    w0, w1 = penalty_weight
    pw_schedule = jnp.asarray(np.geomspace(max(w0, 1e-6), max(w1, 1e-6), steps), jnp.float32)

    state = init_population_state(tech0, arch0)
    rows = []
    done = 0
    step_per_dispatch = steps if chunk is None else max(1, chunk)
    while done < steps:
        n = min(step_per_dispatch, steps - done)
        state, m = population_chunk(
            state, mixes, gstack, lr, pw_schedule[done : done + n],
            spec=spec, mcfg=mcfg, opt_over=opt_over, mesh=mesh,
        )
        rows.append(np.asarray(m))
        done += n
    history = np.concatenate(rows, axis=0) if rows else np.zeros((0, population, 5), np.float32)

    tech = from_log(state[0])
    arch = from_log(state[1])
    logm, area, power = population_log_metrics(tech, arch, gstack, spec, mcfg)
    logm, area, power = np.asarray(logm), np.asarray(area), np.asarray(power)

    tol = 1.0 + budget_tol
    # a member whose final metrics are non-finite (a divergence the in-step
    # freeze could not mask, or corrupted evaluation) is infeasible by
    # definition — it must never reach the front or the hypervolume box
    finite = np.isfinite(logm).all(axis=1) & np.isfinite(area) & np.isfinite(power)
    feasible = finite & (area <= np.asarray(ab) * tol) & (power <= np.asarray(pb) * tol)
    midx = np.asarray([PARETO_METRICS.index(m) for m in metrics])
    pts = jnp.asarray(logm[:, midx])
    front_mask = np.asarray(non_dominated_mask(pts, jnp.asarray(feasible)))
    front = np.nonzero(front_mask)[0]

    if front.size:
        fpts = pts[jnp.asarray(front)]
        if hv_box is not None:
            lo, ref = (jnp.asarray(b, jnp.float32) for b in hv_box)
        else:
            feas_pts = pts[jnp.asarray(np.nonzero(feasible)[0])] if feasible.any() else pts
            ref = hv_ref_point(feas_pts)
            lo = jnp.minimum(jnp.min(feas_pts, axis=0), ref)
        hv = float(hypervolume(fpts, ref, lo=lo))
        hv_lo, hv_ref = np.asarray(lo), np.asarray(ref)
        front_pts = np.asarray(fpts)
    else:
        hv = 0.0
        hv_lo = hv_ref = np.full(len(metrics), np.nan)
        front_pts = np.zeros((0, len(metrics)), np.float32)

    winners = []
    for i in front.tolist():
        t_i = jax.tree.map(lambda x: x[i], tech)
        a_i = jax.tree.map(lambda x: x[i], arch)
        text = serialize_arch(
            name=f"pareto_{member_seeds[i]}_{i}", spec=spec, arch=a_i, tech=t_i
        )
        winners.append(
            dict(
                index=i,
                seed=member_seeds[i],
                weights={m: float(weights[i, j]) for j, m in enumerate(PARETO_METRICS)},
                time_s=float(np.exp(logm[i, 0])),
                energy_j=float(np.exp(logm[i, 1])),
                area_mm2=float(area[i]),
                power_w=float(power[i]),
                edp=float(np.exp(logm[i, 3])),
                dhd=text,
            )
        )

    return ParetoResult(
        tech=tech,
        arch=arch,
        spec=spec,
        seeds=member_seeds,
        weights=np.asarray(weights),
        area_budget=np.asarray(ab),
        power_budget=np.asarray(pb),
        history=history,
        log_metrics=logm,
        area=area,
        power=power,
        feasible=feasible,
        front=front,
        front_log_metrics=front_pts,
        hypervolume=hv,
        hv_lo=hv_lo,
        hv_ref=hv_ref,
        winners=winners,
    )


# --------------------------------------------------------------------------- #
# legacy single-objective population helpers (pjit-able dry-run DSE step)
# --------------------------------------------------------------------------- #


def init_population(key: jax.Array, n: int, sigma: float = 0.3):
    """n jittered copies of the default design point (log-normal)."""
    tech, arch = TechParams.default(), ArchParams.default()
    leaves, treedef = jax.tree.flatten((tech, arch))
    keys = jax.random.split(key, len(leaves))
    pop = [
        jnp.exp(jnp.log(l)[None, ...] + sigma * jax.random.normal(k, (n,) + l.shape))
        for l, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, pop)


def population_objective(pop, graphs: Graph, objective: str = "edp", spec: ArchSpec = ArchSpec(), mcfg: MapperCfg = MapperCfg()):
    """[P] objectives for a population against stacked workloads.

    ``graphs``: a Graph whose arrays carry a leading workload axis W (padded
    to equal vertex count; see Graph.pad_to).  Result is the mean log
    objective across workloads, per candidate.
    """

    def one_candidate(tech, arch):
        # the same batched-workload path DOpt's loss uses (dsim.stacked_log_objective)
        val, _ = stacked_log_objective(tech, arch, graphs, objective, spec=spec, mcfg=mcfg)
        return val

    tech, arch = pop
    return jax.vmap(one_candidate)(tech, arch)


def make_dse_step(objective: str = "edp", lr: float = 0.05, spec: ArchSpec = ArchSpec()):
    """One population gradient-descent epoch: grads in log-space, SGD update."""

    def dse_step(pop, graphs: Graph):
        pop_z = to_log(pop)

        def loss(pz):
            return jnp.sum(population_objective(from_log(pz), graphs, objective, spec))

        grads = jax.grad(loss)(pop_z)
        new_z = jax.tree.map(lambda p, g: p - lr * g, pop_z, grads)
        new_pop = from_log(new_z)
        return new_pop, population_objective(new_pop, graphs, objective, spec)

    return dse_step


def shard_population(mesh, pop, pop_axes=("pod", "data")):
    """NamedShardings placing the population along pod+data axes."""
    axes = tuple(a for a in pop_axes if a in mesh.axis_names)
    spec = P(axes)
    return jax.tree.map(lambda x: jax.device_put(x, NamedSharding(mesh, spec)), pop)


def dse_in_shardings(mesh, pop, graphs):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    pop_s = jax.tree.map(lambda _: NamedSharding(mesh, P(axes)), pop)
    # guard like shard_population: meshes without a "model" axis replicate
    # the workloads instead of raising KeyError
    w = mesh.shape["model"] if "model" in mesh.axis_names else 0
    g_s = jax.tree.map(
        lambda x: NamedSharding(mesh, P("model") if w and x.ndim >= 1 and x.shape[0] % w == 0 else P()),
        graphs,
    )
    return (pop_s, g_s)
