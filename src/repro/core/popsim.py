"""Population-parallel design-space exploration, sharded over the mesh.

The paper runs DOpt single-host.  At cluster scale, DSE is a population of
independent gradient-descent candidates (multi-start over the non-convex
design/technology space, paper Fig. 3) evaluated against a *set* of
workloads.  We shard:

  * population axis -> mesh ("pod", "data") — candidates are independent;
  * workload axis   -> mesh ("model",)      — objectives all-reduce.

``dse_step`` is a pjit program lowered/compiled in the multi-pod dry-run
like every LM cell, proving DRAGON itself distributes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.dopt import from_log, to_log
from repro.core.dsim import stacked_log_objective
from repro.core.graph import Graph
from repro.core.mapper import MapperCfg
from repro.core.params import ArchParams, ArchSpec, TechParams


def init_population(key: jax.Array, n: int, sigma: float = 0.3):
    """n jittered copies of the default design point (log-normal)."""
    tech, arch = TechParams.default(), ArchParams.default()
    leaves, treedef = jax.tree.flatten((tech, arch))
    keys = jax.random.split(key, len(leaves))
    pop = [
        jnp.exp(jnp.log(l)[None, ...] + sigma * jax.random.normal(k, (n,) + l.shape))
        for l, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, pop)


def population_objective(pop, graphs: Graph, objective: str = "edp", spec: ArchSpec = ArchSpec(), mcfg: MapperCfg = MapperCfg()):
    """[P] objectives for a population against stacked workloads.

    ``graphs``: a Graph whose arrays carry a leading workload axis W (padded
    to equal vertex count; see Graph.pad_to).  Result is the mean log
    objective across workloads, per candidate.
    """

    def one_candidate(tech, arch):
        # the same batched-workload path DOpt's loss uses (dsim.stacked_log_objective)
        val, _ = stacked_log_objective(tech, arch, graphs, objective, spec=spec, mcfg=mcfg)
        return val

    tech, arch = pop
    return jax.vmap(one_candidate)(tech, arch)


def make_dse_step(objective: str = "edp", lr: float = 0.05, spec: ArchSpec = ArchSpec()):
    """One population gradient-descent epoch: grads in log-space, SGD update."""

    def dse_step(pop, graphs: Graph):
        pop_z = to_log(pop)

        def loss(pz):
            return jnp.sum(population_objective(from_log(pz), graphs, objective, spec))

        grads = jax.grad(loss)(pop_z)
        new_z = jax.tree.map(lambda p, g: p - lr * g, pop_z, grads)
        new_pop = from_log(new_z)
        return new_pop, population_objective(new_pop, graphs, objective, spec)

    return dse_step


def shard_population(mesh, pop, pop_axes=("pod", "data")):
    """NamedShardings placing the population along pod+data axes."""
    axes = tuple(a for a in pop_axes if a in mesh.axis_names)
    spec = P(axes)
    return jax.tree.map(lambda x: jax.device_put(x, NamedSharding(mesh, spec)), pop)


def dse_in_shardings(mesh, pop, graphs):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    pop_s = jax.tree.map(lambda _: NamedSharding(mesh, P(axes)), pop)
    # guard like shard_population: meshes without a "model" axis replicate
    # the workloads instead of raising KeyError
    w = mesh.shape["model"] if "model" in mesh.axis_names else 0
    g_s = jax.tree.map(
        lambda x: NamedSharding(mesh, P("model") if w and x.ndim >= 1 and x.shape[0] % w == 0 else P()),
        graphs,
    )
    return (pop_s, g_s)
