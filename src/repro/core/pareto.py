"""Pareto-front extraction and the hypervolume indicator, on-device.

Multi-objective DSE (popsim.pareto_dse) needs two primitives over a
population's metric vectors, both jnp-only so they run device-resident and
compose with jit/vmap:

  * :func:`non_dominated_mask` — which designs survive non-dominated
    filtering (all metrics are COSTS: smaller is better);
  * :func:`hypervolume` — the volume, w.r.t. a reference point, of the
    region dominated by a point set: the standard scalar indicator of
    front quality (bigger is better, monotone under adding non-dominated
    points).

Conventions:

* a point ``a`` dominates ``b`` iff ``all(a <= b)`` and ``any(a < b)``
  — duplicates do not dominate each other, so both survive filtering;
* hypervolume is exact for 2 objectives (staircase sweep) and a
  deterministic quasi-Monte-Carlo estimate for 3+ (fixed PRNG key).  With a
  shared sample box (``lo``/``key``), the MC estimate is *exactly* monotone
  under adding points: every sample dominated by S is dominated by any
  superset of S.  Pass the same ``lo`` and ``key`` when comparing fronts.

DSE metric vectors live in log space (popsim feeds ``stacked_log_metrics``
output), where hypervolume measures multiplicative — order-of-magnitude —
coverage of the latency/energy/area trade space, but nothing here assumes
it: any minimization metric space works.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dominates",
    "non_dominated_mask",
    "pareto_front",
    "hypervolume",
    "hv_ref_point",
]


def dominates(a: jax.Array, b: jax.Array) -> jax.Array:
    """``a`` dominates ``b`` (costs: all coords <=, at least one <).

    Broadcasts over leading axes: ``dominates(p[:, None], p[None, :])`` is
    the full [N, N] domination matrix.
    """
    return jnp.all(a <= b, axis=-1) & jnp.any(a < b, axis=-1)


def non_dominated_mask(points: jax.Array, feasible: jax.Array | None = None) -> jax.Array:
    """[N] bool mask of the non-dominated subset of ``points`` [N, M].

    ``feasible`` (optional [N] bool) removes constraint-violating designs
    *before* filtering: infeasible points neither enter the front nor
    shadow feasible ones.  O(N^2) pairwise — device-friendly and exact; the
    DSE populations this serves are O(10^2).
    """
    pts = jnp.asarray(points)
    if feasible is not None:
        # an infeasible point must not dominate anything: move it to +inf,
        # where it can only *be* dominated
        pts = jnp.where(jnp.asarray(feasible)[:, None], pts, jnp.inf)
    dom = dominates(pts[:, None, :], pts[None, :, :])  # dom[i, j]: i dominates j
    mask = ~jnp.any(dom, axis=0)
    if feasible is not None:
        mask = mask & jnp.asarray(feasible)
    return mask


def pareto_front(points, feasible=None) -> np.ndarray:
    """Host convenience: sorted indices of the non-dominated subset."""
    return np.nonzero(np.asarray(non_dominated_mask(points, feasible)))[0]


def _hv_exact_2d(pts: jax.Array, ref: jax.Array) -> jax.Array:
    """Exact 2-objective hypervolume: area of the dominated staircase.

    Points beyond ``ref`` are clipped to it — they dominate at most a
    measure-zero slice of the reference box, so clipping preserves the
    volume.  Dominated/duplicate points contribute zero height and need no
    pre-filtering.
    """
    p = jnp.minimum(pts, ref)
    order = jnp.lexsort((p[:, 1], p[:, 0]))  # by x, ties by y
    x, y = p[order, 0], p[order, 1]
    y_run = jax.lax.cummin(y)  # best y seen at or left of each x
    prev = jnp.concatenate([ref[1][None], y_run[:-1]])
    return jnp.sum((ref[0] - x) * jnp.maximum(prev - y_run, 0.0))


def hypervolume(
    points,
    ref,
    *,
    lo=None,
    n_samples: int = 16384,
    key: jax.Array | None = None,
) -> jax.Array:
    """Hypervolume of the region dominated by ``points`` [N, M] within the
    box ``[lo, ref]`` (costs; ``ref`` is the anti-ideal corner).

    * M == 2: exact (``lo``/``n_samples``/``key`` ignored).
    * M >= 3: quasi-Monte-Carlo with a fixed key — deterministic, and with
      a common ``lo``/``key`` exactly monotone under adding points (the
      dominated-sample set can only grow).  ``lo`` defaults to the
      pointwise minimum of ``points`` clipped to ``ref``; pass an explicit
      common ``lo`` when comparing the values of different fronts.
    """
    pts = jnp.atleast_2d(jnp.asarray(points, jnp.float32))
    m = pts.shape[-1]
    ref = jnp.broadcast_to(jnp.asarray(ref, jnp.float32), (m,))
    if m == 2:
        return _hv_exact_2d(pts, ref)
    lo = jnp.minimum(jnp.min(pts, axis=0), ref) if lo is None else jnp.asarray(lo, jnp.float32)
    key = jax.random.PRNGKey(0) if key is None else key
    u = jax.random.uniform(key, (int(n_samples), m), minval=lo, maxval=ref)
    covered = jnp.any(jnp.all(pts[:, None, :] <= u[None, :, :], axis=-1), axis=0)
    box = jnp.prod(jnp.maximum(ref - lo, 0.0))
    return box * jnp.mean(covered.astype(jnp.float32))


def hv_ref_point(points, margin: float = 0.1) -> jax.Array:
    """A reference (anti-ideal) point just beyond the worst of ``points``:
    per-axis max plus ``margin`` of the axis range (at least ``margin``
    absolute, so degenerate axes still leave room and boundary points
    contribute volume)."""
    pts = jnp.atleast_2d(jnp.asarray(points, jnp.float32))
    hi, lo = jnp.max(pts, axis=0), jnp.min(pts, axis=0)
    return hi + jnp.maximum(margin * (hi - lo), margin)
