"""The differentiable mapper (paper §5.2, Algorithms 1/2/7).

Maps a workload DFG onto a concrete hardware model CH and produces cycle
counts plus the memory/compute state the energy model consumes.

JAX adaptation of the paper's control flow (see DESIGN.md §3):

  * MAPVERTEX's vertex *splitting* when the working set exceeds memory
    capacity (Alg. 1 lines 20-23) becomes *continuous tiling*:
    ``n_tiles = ceil(alloc / 0.9*capacity)`` with a straight-through ceil —
    the forward value matches the discrete split count exactly, while the
    backward pass sees a smooth surrogate so capacity gradients exist.

  * PREFETCHVERTEX / Alg. 7's prefetch & streaming decisions
    (bw_util < 0.9 * bw_limit, size_util < 0.9 * size_limit) become hard
    gates forward + sigmoid surrogate gradients.

  * Appendix C stall-time gradients: ``t = max(t_mem, t_comp)`` — the
    subgradient of max flows only through the critical (non-hidden) term,
    exactly the paper's 'gradient is zero if latency is entirely hidden'.

Scan structure
--------------

Everything the mapper computes per vertex is elementwise except the two
inter-vertex carries Alg. 7 threads through the topological order:

  * decaying buffer occupancy   ``o' = min(0.5*o + alloc, capacity)``
  * bandwidth-utilization EMA   ``b' = 0.8*b + 0.2*x``

Both are first-order (min-)affine recurrences in the carry, with inputs
``alloc``/``x`` that depend only on the vertex (the EMA input is the
*demanded* bandwidth utilization — the no-overlap transfer time Alg. 7
inspects *before* granting prefetch — so it is independent of the gate it
feeds).  That makes the whole mapper parallel-depth:

  1. compute all per-vertex intrinsics elementwise ([V]-vectorized);
  2. run the two carries as ``jax.lax.associative_scan`` — O(log V) depth
     instead of O(V) for the 700+-vertex LM graphs, and it vmaps across
     populations for DSE;
  3. compute gates / exposed-time / cycles elementwise from the scanned
     prefix states and reduce.

``MapperCfg.scan_impl`` selects the implementation:

  * ``"auto"``   (default) — associative for graphs with >= 32 vertices;
    tiny graphs take the fully-fused sequential scan, whose single-loop
    dispatch is cheaper than the associative tree's op fan-out when V is
    small (the two are numerically equivalent, so this is pure dispatch);
  * ``"assoc"``  — always the associative-scan formulation above;
  * ``"ref"``    — the sequential ``lax.scan`` over vertices with the whole
    vertex computation inlined in the body (the pre-parallel structure),
    kept as the independent semantic oracle — tests/test_mapper_equiv.py
    asserts values and gradients match;
  * ``"pallas"`` — opt-in: the bw-EMA prefix dispatches through the
    ``kernels.sscan.affine_scan`` Pallas kernel
    (``runtime.dragon_pallas_call`` seam); occupancy stays associative.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.dgen import ConcreteHW
from repro.core.graph import Graph
from repro.core.params import COMP_IDX, MEM_IDX, N_COMP, N_MEM

_GBUF = MEM_IDX["globalBuf"]
_MAIN = MEM_IDX["mainMem"]
_LOCAL = MEM_IDX["localMem"]
_SYS = COMP_IDX["systolicArray"]
_VEC = COMP_IDX["vector"]

_OCC_DECAY = 0.5  # buffer-residency decay per vertex (Alg. 7 carry)
_BW_DECAY = 0.8  # bandwidth-EMA decay per vertex
_ASSOC_MIN_V = 32  # "auto": below this the fused sequential scan dispatches faster


# --------------------------------------------------------------------------- #
# straight-through helpers
# --------------------------------------------------------------------------- #


def ste(hard: jax.Array, soft: jax.Array) -> jax.Array:
    """Forward = hard (exact discrete semantics); backward = d soft."""
    return soft + jax.lax.stop_gradient(hard - soft)


def ceil_ste(x: jax.Array) -> jax.Array:
    return ste(jnp.ceil(x), x)


def gate_below_ste(x: jax.Array, thresh: jax.Array, tau: float = 0.1) -> jax.Array:
    """1.0 when x < thresh (hard forward), sigmoid surrogate backward."""
    hard = (x < thresh).astype(jnp.float32)
    soft = jax.nn.sigmoid((thresh - x) / (tau * jnp.abs(thresh) + 1e-30))
    return ste(hard, soft)


# --------------------------------------------------------------------------- #
# Mapper config + state
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MapperCfg:
    headroom: float = 0.9  # paper Alg. 7 thresholds
    prefetch: bool = True
    streaming: bool = True
    merge_threshold: float = 0.0  # compute-merge pass threshold (FLOPs)
    scan_impl: str = "auto"  # auto | assoc | ref | pallas (see module docstring)


@jax.tree_util.register_dataclass
@dataclass
class MapState:
    """paper ⟨z, ms, cs⟩: cycle count + memory state + compute state."""

    cycles: jax.Array
    reads: jax.Array  # [N_MEM] total bytes read
    writes: jax.Array  # [N_MEM] total bytes written
    comp_ops: jax.Array  # [N_COMP] total FLOPs issued
    peak_alloc: jax.Array  # [N_MEM] peak working set
    t_comp: jax.Array  # total compute-critical seconds (diagnostic)
    t_mem: jax.Array  # total memory-critical seconds (diagnostic)
    t_exposed_main: jax.Array  # main-memory time not hidden by prefetch
    bw_util: jax.Array  # [N_MEM] average bandwidth utilization
    n_tiles: jax.Array  # total vertex splits (diagnostic)


# --------------------------------------------------------------------------- #
# per-vertex intrinsics (carry-independent, [V]-vectorized)
# --------------------------------------------------------------------------- #


def _vertex_intrinsics(chw: ConcreteHW, g: Graph, cfg: MapperCfg) -> dict:
    """Everything MAPVERTEX computes that does not depend on the carry."""
    freq = chw.frequency
    cap_gbuf = chw.capacity[_GBUF] * cfg.headroom
    bw = chw.mem_bw  # [N_MEM] bytes/s

    alloc_gbuf = g.n_alloc[:, _GBUF]
    # ---------------- tiling (MAPVERTEX split, lines 20-23) -----------------
    tiles = jnp.maximum(ceil_ste(alloc_gbuf / cap_gbuf), 1.0)

    # ---------------- compute time per class --------------------------------
    # systolic array: discrete wave model (matches the cycle-walker's
    # semantics, differentiable through STE-ceil): each (sys_x x sys_y)
    # output tile streams K MACs + a fill/drain bubble of sx+sy cycles
    M, N, K = g.dims[:, 0], g.dims[:, 1], g.dims[:, 2]
    m_t = jnp.maximum(M / tiles, 1.0)
    waves_m = ceil_ste(m_t / chw.sys_x)
    waves_n = ceil_ste(jnp.maximum(N, 1.0) / chw.sys_y)
    k_cycles = ceil_ste(jnp.maximum(K, 1.0))
    fill = chw.sys_x + chw.sys_y
    cyc_sys_tile = waves_m * waves_n * (k_cycles + fill)
    ops_sys_tile = g.n_comp[:, _SYS] / tiles
    cyc_sys_tile = jnp.maximum(
        cyc_sys_tile, ops_sys_tile / jnp.maximum(chw.flops_per_cycle[_SYS], 1e-9)
    )
    t_sys = jnp.where(ops_sys_tile > 0, tiles * cyc_sys_tile / freq, 0.0)
    # other classes: rate model
    eff_rate = jnp.maximum(chw.flops_per_cycle, 1e-9) * freq  # [N_COMP] FLOP/s
    t_comp_cls = g.n_comp / eff_rate[None, :]
    t_comp = jnp.maximum(jnp.max(t_comp_cls.at[:, _SYS].set(0.0), axis=-1), t_sys)

    # ---------------- memory time per level ---------------------------------
    # burst-quantized transfers with the average bank-conflict factor of
    # the reference walker (mean of its 1.00-1.08 hash-spread) + per-tile
    # access latency
    conflict = 1.04
    t_lvl = (g.n_read + g.n_write) / bw[None, :] * conflict  # [V, N_MEM]
    t_tile_lat = tiles[:, None] * (chw.read_latency + chw.write_latency)[None, :]
    t_onchip = jnp.maximum(t_lvl[:, _GBUF] + t_tile_lat[:, _GBUF], t_lvl[:, _LOCAL])
    t_main = t_lvl[:, _MAIN] + t_tile_lat[:, _MAIN] * (g.n_alloc[:, _MAIN] > 0)
    t_core = jnp.maximum(t_comp, t_onchip)

    # ---------------- demanded bandwidth utilization (EMA input) ------------
    # the no-overlap (fully exposed) vertex time: what Alg. 7 inspects when
    # deciding whether bandwidth headroom exists — independent of the
    # prefetch/streaming decision it gates, so the EMA is a pure affine
    # recurrence
    t_full = tiles * ceil_ste((t_core + t_main) * freq / jnp.maximum(tiles, 1.0)) / freq
    bytes_gbuf = g.n_read[:, _GBUF] + g.n_write[:, _GBUF]
    used_bw = jnp.where(
        t_full > 0, bytes_gbuf / jnp.maximum(t_full, 1e-30) / bw[_GBUF], 0.0
    )
    bw_x = jnp.clip(used_bw, 0.0, 2.0)

    # no-op (padding) vertices cost nothing — this is what makes
    # Graph.stack()'s pad_to exactly free in the batched-workload path
    active = (
        jnp.sum(g.n_comp, -1)
        + jnp.sum(g.n_read, -1)
        + jnp.sum(g.n_write, -1)
        + jnp.sum(g.n_alloc, -1)
    ) > 0

    return dict(
        tiles=tiles,
        alloc_gbuf=alloc_gbuf,
        t_comp=t_comp,
        t_onchip=t_onchip,
        t_main=t_main,
        t_core=t_core,
        t_lvl=t_lvl,
        used_bw=used_bw,
        bw_x=bw_x,
        active=active.astype(jnp.float32),
    )


def _vertex_exec(chw: ConcreteHW, g: Graph, cfg: MapperCfg, iv: dict,
                 occ_prev: jax.Array, bw_prev: jax.Array) -> dict:
    """Per-vertex gates, exposed time and cycles — elementwise from the
    prefix carries.  Shared by the MapState reduction (:func:`_vertex_finish`)
    and the per-vertex diagnostics (:func:`map_workload_breakdown`)."""
    freq = chw.frequency

    # ---------------- prefetch / streaming gates (Alg. 7) -------------------
    can_prefetch = (
        gate_below_ste(occ_prev + iv["alloc_gbuf"] / iv["tiles"],
                       chw.capacity[_GBUF] * cfg.headroom)
        * gate_below_ste(bw_prev, cfg.headroom)
        * (1.0 if cfg.prefetch else 0.0)
    )
    # streaming: if over capacity but bw available, overlap main-mem
    # traffic with compute (set_execution = streaming)
    can_stream = gate_below_ste(bw_prev, cfg.headroom) * (1.0 if cfg.streaming else 0.0)
    hide = jnp.maximum(can_prefetch, can_stream)

    # exposed main-memory time: hidden behind compute when gated on
    t_main_exposed = jnp.maximum(iv["t_main"] - hide * iv["t_core"], 0.0)
    # integer-cycle quantization per tile (cycle-walker semantics, exact
    # forward via STE): decode-scale vertices cost whole cycles
    per_tile_cyc = (iv["t_core"] + t_main_exposed) * freq / iv["tiles"]
    t_vertex = iv["tiles"] * ceil_ste(per_tile_cyc) / freq * iv["active"]
    return dict(t_vertex=t_vertex, cycles_v=t_vertex * freq, t_main_exposed=t_main_exposed)


def _vertex_finish(chw: ConcreteHW, g: Graph, cfg: MapperCfg, iv: dict,
                   occ_prev: jax.Array, bw_prev: jax.Array) -> MapState:
    """The reductions into MapState, from the shared per-vertex execution."""
    ex = _vertex_exec(chw, g, cfg, iv, occ_prev, bw_prev)
    t_main_exposed = ex["t_main_exposed"]
    cycles_v = ex["cycles_v"]
    total_cyc = jnp.sum(cycles_v)
    return MapState(
        cycles=total_cyc,
        reads=jnp.sum(g.n_read, 0),
        writes=jnp.sum(g.n_write, 0),
        comp_ops=jnp.sum(g.n_comp, 0),
        peak_alloc=jnp.max(g.n_alloc, 0),
        t_comp=jnp.sum(iv["t_comp"]),
        t_mem=jnp.sum(iv["t_onchip"] * iv["active"]),
        t_exposed_main=jnp.sum(t_main_exposed),
        bw_util=jnp.stack(
            [
                jnp.float32(0.0),
                jnp.sum(iv["used_bw"] * cycles_v) / jnp.maximum(total_cyc, 1e-30),
                jnp.float32(0.0),
            ]
        ),
        # diagnostics also exclude no-op (padding) vertices, so Graph.stack's
        # pad_to is exact for the whole MapState, not just cycles
        n_tiles=jnp.sum(iv["tiles"] * iv["active"]),
    )


# --------------------------------------------------------------------------- #
# carry prefixes: associative (O(log V) depth) and sequential reference
# --------------------------------------------------------------------------- #


def _exclusive(after: jax.Array) -> jax.Array:
    """Shift an inclusive prefix to the state *before* each vertex (x0 = 0)."""
    return jnp.concatenate([jnp.zeros((1,), after.dtype), after[:-1]])


def affine_prefix_assoc(decay: float, add: jax.Array) -> jax.Array:
    """Inclusive prefix of ``s' = decay*s + add_i`` (s0 = 0), O(log V) depth.

    Elements are affine maps (a, b): s -> a*s + b; composition
    (later ∘ earlier) is (a1*a2, a2*b1 + b2), which is associative.
    """
    a = jnp.full_like(add, decay)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    _, after = jax.lax.associative_scan(combine, (a, add))
    return after


def minaffine_prefix_assoc(decay: float, add: jax.Array, cap: jax.Array) -> jax.Array:
    """Inclusive prefix of ``s' = min(decay*s + add_i, cap)`` (s0 = 0).

    Maps s -> min(a*s + b, c) are closed under composition
    (later (a2,b2,c2) ∘ earlier (a1,b1,c1) =
     (a1*a2, a2*b1 + b2, min(a2*c1 + b2, c2)) for a2 >= 0), so the clamped
    occupancy recurrence is still an associative scan.
    """
    a = jnp.full_like(add, decay)
    c = jnp.broadcast_to(cap, add.shape).astype(add.dtype)

    def combine(l, r):
        a1, b1, c1 = l
        a2, b2, c2 = r
        return a1 * a2, a2 * b1 + b2, jnp.minimum(a2 * c1 + b2, c2)

    _, b, c = jax.lax.associative_scan(combine, (a, add, c))
    return jnp.minimum(b, c)  # applied to s0 = 0


def _carry_prefixes(chw: ConcreteHW, cfg: MapperCfg, iv: dict) -> tuple[jax.Array, jax.Array]:
    """The two Alg.-7 carries as exclusive prefixes (pre-vertex states),
    honoring the pallas opt-in for the bw-EMA."""
    occ_after = minaffine_prefix_assoc(_OCC_DECAY, iv["alloc_gbuf"], chw.capacity[_GBUF])
    if cfg.scan_impl == "pallas":
        from repro.kernels.sscan import affine_scan

        bw_after = affine_scan(_BW_DECAY, 0.2 * iv["bw_x"])
    else:
        bw_after = affine_prefix_assoc(_BW_DECAY, 0.2 * iv["bw_x"])
    return _exclusive(occ_after), _exclusive(bw_after)


def _map_workload_assoc(chw: ConcreteHW, g: Graph, cfg: MapperCfg) -> MapState:
    iv = _vertex_intrinsics(chw, g, cfg)
    occ_prev, bw_prev = _carry_prefixes(chw, cfg, iv)
    return _vertex_finish(chw, g, cfg, iv, occ_prev, bw_prev)


def map_workload_breakdown(chw: ConcreteHW, g: Graph, cfg: MapperCfg = MapperCfg()) -> dict:
    """Per-vertex / per-level mapping diagnostics (the ``explain`` path).

    Runs the associative formulation's per-vertex pipeline but returns the
    arrays *before* the MapState reductions:

      * ``time_v`` / ``cycles_v`` [V] — each vertex's wall time and cycles
        (padding vertices are exactly zero);
      * ``t_comp_v`` [V] — compute-critical seconds per vertex;
      * ``t_main_exposed_v`` [V] — main-memory time not hidden by prefetch;
      * ``tiles_v`` [V] — MAPVERTEX split counts;
      * ``t_level`` [N_MEM] — total demanded (no-overlap) transfer time per
        memory level;
      * ``active`` [V] — 1.0 for real vertices, 0.0 for padding.

    Consistency with :func:`map_workload`: for ``scan_impl`` "auto" (V >=
    32, the façade's bucketed case), "assoc" and "pallas" the prefixes are
    the *same computation*, so the per-vertex cycles sum to
    ``MapState.cycles`` exactly.  Under the sequential reference
    (``"ref"``) the arrays come from the associative formulation and match
    to the formulations' tested equivalence (tests/test_mapper_equiv.py),
    not bit-exactly.  Differentiable like everything else in the mapper.
    """
    iv = _vertex_intrinsics(chw, g, cfg)
    occ_prev, bw_prev = _carry_prefixes(chw, cfg, iv)
    ex = _vertex_exec(chw, g, cfg, iv, occ_prev, bw_prev)
    return dict(
        time_v=ex["t_vertex"],
        cycles_v=ex["cycles_v"],
        t_comp_v=iv["t_comp"] * iv["active"],
        t_main_exposed_v=ex["t_main_exposed"] * iv["active"],
        tiles_v=iv["tiles"] * iv["active"],
        t_level=jnp.sum(iv["t_lvl"] * iv["active"][:, None], axis=0),
        active=iv["active"],
    )


def map_workload_scan(chw: ConcreteHW, g: Graph, cfg: MapperCfg = MapperCfg()) -> MapState:
    """Sequential-reference MAPWORKLOAD: one ``lax.scan`` over the
    (topologically ordered) vertex list with the whole per-vertex
    computation inlined in the body, O(V) depth.

    This is deliberately *not* written in terms of ``_vertex_intrinsics`` —
    it is the independent oracle the associative formulation is tested
    against, and its single fused loop body is also the cheapest dispatch
    for tiny graphs (the "auto" small-V path).
    """
    freq = chw.frequency
    cap_gbuf = chw.capacity[_GBUF] * cfg.headroom
    bw = chw.mem_bw  # [N_MEM] bytes/s

    def vertex_step(carry, v):
        n_comp, n_read, n_write, n_alloc, dims = v
        # ---------------- tiling (MAPVERTEX split, lines 20-23) -------------
        alloc_gbuf = n_alloc[_GBUF]
        tiles = jnp.maximum(ceil_ste(alloc_gbuf / cap_gbuf), 1.0)

        # ---------------- compute time per class ---------------------------
        M, N, K = dims[0], dims[1], dims[2]
        m_t = jnp.maximum(M / tiles, 1.0)
        waves_m = ceil_ste(m_t / chw.sys_x)
        waves_n = ceil_ste(jnp.maximum(N, 1.0) / chw.sys_y)
        k_cycles = ceil_ste(jnp.maximum(K, 1.0))
        fill = chw.sys_x + chw.sys_y
        cyc_sys_tile = waves_m * waves_n * (k_cycles + fill)
        ops_sys_tile = n_comp[_SYS] / tiles
        cyc_sys_tile = jnp.maximum(
            cyc_sys_tile, ops_sys_tile / jnp.maximum(chw.flops_per_cycle[_SYS], 1e-9)
        )
        t_sys = jnp.where(ops_sys_tile > 0, tiles * cyc_sys_tile / freq, 0.0)
        eff_rate = jnp.maximum(chw.flops_per_cycle, 1e-9) * freq  # FLOP/s
        t_comp_cls = n_comp / eff_rate
        t_comp = jnp.maximum(jnp.max(t_comp_cls.at[_SYS].set(0.0)), t_sys)

        # ---------------- memory time per level ----------------------------
        conflict = 1.04
        t_lvl = (n_read + n_write) / bw * conflict
        t_tile_lat = tiles * (chw.read_latency + chw.write_latency)
        t_onchip = jnp.maximum(t_lvl[_GBUF] + t_tile_lat[_GBUF], t_lvl[_LOCAL])
        t_main = t_lvl[_MAIN] + t_tile_lat[_MAIN] * (n_alloc[_MAIN] > 0)
        t_core = jnp.maximum(t_comp, t_onchip)

        # ---------------- prefetch / streaming gates (Alg. 7) --------------
        occupancy, bw_ema = carry["occupancy"], carry["bw_ema"]
        can_prefetch = (
            gate_below_ste(occupancy + alloc_gbuf / tiles, chw.capacity[_GBUF] * cfg.headroom)
            * gate_below_ste(bw_ema, cfg.headroom)
            * (1.0 if cfg.prefetch else 0.0)
        )
        can_stream = gate_below_ste(bw_ema, cfg.headroom) * (1.0 if cfg.streaming else 0.0)
        hide = jnp.maximum(can_prefetch, can_stream)

        t_main_exposed = jnp.maximum(t_main - hide * t_core, 0.0)
        per_tile_cyc = (t_core + t_main_exposed) * freq / tiles
        active = (jnp.sum(n_comp) + jnp.sum(n_read) + jnp.sum(n_write) + jnp.sum(n_alloc)) > 0
        t_vertex = tiles * ceil_ste(per_tile_cyc) / freq * active

        # ---------------- state updates -------------------------------------
        # the EMA input is the *demanded* (no-overlap) utilization — see
        # _vertex_intrinsics; this is what keeps the carry a pure affine
        # recurrence in the parallel formulation
        t_full = tiles * ceil_ste((t_core + t_main) * freq / jnp.maximum(tiles, 1.0)) / freq
        used_bw = jnp.where(
            t_full > 0, (n_read[_GBUF] + n_write[_GBUF]) / jnp.maximum(t_full, 1e-30) / bw[_GBUF], 0.0
        )
        new_bw = _BW_DECAY * bw_ema + 0.2 * jnp.clip(used_bw, 0.0, 2.0)
        new_occ = _OCC_DECAY * occupancy + alloc_gbuf  # decaying residency
        new_occ = jnp.minimum(new_occ, chw.capacity[_GBUF])

        out = dict(
            cycles=t_vertex * freq,
            t_comp=t_comp,
            t_mem=t_onchip * active,
            t_main_exposed=t_main_exposed,
            tiles=tiles * active,
            bw_now=used_bw,
        )
        return dict(occupancy=new_occ, bw_ema=new_bw), out

    carry0 = dict(occupancy=jnp.float32(0.0), bw_ema=jnp.float32(0.0))
    xs = (g.n_comp, g.n_read, g.n_write, g.n_alloc, g.dims)
    _, outs = jax.lax.scan(vertex_step, carry0, xs)

    total_cyc = jnp.sum(outs["cycles"])
    return MapState(
        cycles=total_cyc,
        reads=jnp.sum(g.n_read, 0),
        writes=jnp.sum(g.n_write, 0),
        comp_ops=jnp.sum(g.n_comp, 0),
        peak_alloc=jnp.max(g.n_alloc, 0),
        t_comp=jnp.sum(outs["t_comp"]),
        t_mem=jnp.sum(outs["t_mem"]),
        t_exposed_main=jnp.sum(outs["t_main_exposed"]),
        bw_util=jnp.stack(
            [
                jnp.float32(0.0),
                jnp.sum(outs["bw_now"] * outs["cycles"]) / jnp.maximum(total_cyc, 1e-30),
                jnp.float32(0.0),
            ]
        ),
        n_tiles=jnp.sum(outs["tiles"]),
    )


def map_workload(chw: ConcreteHW, g: Graph, cfg: MapperCfg = MapperCfg()) -> MapState:
    """MAPWORKLOAD (paper Alg. 1): map the vertex list onto CH, tiling /
    streaming / prefetching per vertex.  Dispatches on ``cfg.scan_impl``."""
    impl = cfg.scan_impl
    if impl == "auto":
        impl = "ref" if g.n_comp.shape[0] < _ASSOC_MIN_V else "assoc"
    if impl == "ref":
        return map_workload_scan(chw, g, cfg)
    if impl in ("assoc", "pallas"):
        return _map_workload_assoc(chw, g, cfg)
    raise ValueError(f"unknown MapperCfg.scan_impl {cfg.scan_impl!r}")
