"""The differentiable mapper (paper §5.2, Algorithms 1/2/7).

Maps a workload DFG onto a concrete hardware model CH and produces cycle
counts plus the memory/compute state the energy model consumes.

JAX adaptation of the paper's control flow (see DESIGN.md §3):

  * MAPVERTEX's vertex *splitting* when the working set exceeds memory
    capacity (Alg. 1 lines 20-23) becomes *continuous tiling*:
    ``n_tiles = ceil(alloc / 0.9*capacity)`` with a straight-through ceil —
    the forward value matches the discrete split count exactly, while the
    backward pass sees a smooth surrogate so capacity gradients exist.

  * PREFETCHVERTEX / Alg. 7's prefetch & streaming decisions
    (bw_util < 0.9 * bw_limit, size_util < 0.9 * size_limit) become hard
    gates forward + sigmoid surrogate gradients.

  * Appendix C stall-time gradients: ``t = max(t_mem, t_comp)`` — the
    subgradient of max flows only through the critical (non-hidden) term,
    exactly the paper's 'gradient is zero if latency is entirely hidden'.

The mapper is a single ``lax.scan`` over vertices; it is jit-able, grad-able
and vmap-able (population DSE).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.dgen import ConcreteHW
from repro.core.graph import Graph
from repro.core.params import COMP_IDX, MEM_IDX, N_COMP, N_MEM

_GBUF = MEM_IDX["globalBuf"]
_MAIN = MEM_IDX["mainMem"]
_LOCAL = MEM_IDX["localMem"]
_SYS = COMP_IDX["systolicArray"]
_VEC = COMP_IDX["vector"]


# --------------------------------------------------------------------------- #
# straight-through helpers
# --------------------------------------------------------------------------- #


def ste(hard: jax.Array, soft: jax.Array) -> jax.Array:
    """Forward = hard (exact discrete semantics); backward = d soft."""
    return soft + jax.lax.stop_gradient(hard - soft)


def ceil_ste(x: jax.Array) -> jax.Array:
    return ste(jnp.ceil(x), x)


def gate_below_ste(x: jax.Array, thresh: jax.Array, tau: float = 0.1) -> jax.Array:
    """1.0 when x < thresh (hard forward), sigmoid surrogate backward."""
    hard = (x < thresh).astype(jnp.float32)
    soft = jax.nn.sigmoid((thresh - x) / (tau * jnp.abs(thresh) + 1e-30))
    return ste(hard, soft)


# --------------------------------------------------------------------------- #
# Mapper config + state
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MapperCfg:
    headroom: float = 0.9  # paper Alg. 7 thresholds
    prefetch: bool = True
    streaming: bool = True
    merge_threshold: float = 0.0  # compute-merge pass threshold (FLOPs)


@jax.tree_util.register_dataclass
@dataclass
class MapState:
    """paper ⟨z, ms, cs⟩: cycle count + memory state + compute state."""

    cycles: jax.Array
    reads: jax.Array  # [N_MEM] total bytes read
    writes: jax.Array  # [N_MEM] total bytes written
    comp_ops: jax.Array  # [N_COMP] total FLOPs issued
    peak_alloc: jax.Array  # [N_MEM] peak working set
    t_comp: jax.Array  # total compute-critical seconds (diagnostic)
    t_mem: jax.Array  # total memory-critical seconds (diagnostic)
    t_exposed_main: jax.Array  # main-memory time not hidden by prefetch
    bw_util: jax.Array  # [N_MEM] average bandwidth utilization
    n_tiles: jax.Array  # total vertex splits (diagnostic)


def map_workload(chw: ConcreteHW, g: Graph, cfg: MapperCfg = MapperCfg()) -> MapState:
    """MAPWORKLOAD (paper Alg. 1): scan the (topologically ordered) vertex
    list, tiling / streaming / prefetching per vertex."""

    freq = chw.frequency
    cap_gbuf = chw.capacity[_GBUF] * cfg.headroom
    bw = chw.mem_bw  # [N_MEM] bytes/s

    def vertex_step(carry, v):
        n_comp, n_read, n_write, n_alloc, dims = v
        # ---------------- tiling (MAPVERTEX split, lines 20-23) -------------
        alloc_gbuf = n_alloc[_GBUF]
        tiles = jnp.maximum(ceil_ste(alloc_gbuf / cap_gbuf), 1.0)

        # ---------------- compute time per class ---------------------------
        # systolic array: discrete wave model (matches the cycle-walker's
        # semantics, differentiable through STE-ceil): each (sys_x x sys_y)
        # output tile streams K MACs + a fill/drain bubble of sx+sy cycles
        M, N, K = dims[0], dims[1], dims[2]
        m_t = jnp.maximum(M / tiles, 1.0)
        waves_m = ceil_ste(m_t / chw.sys_x)
        waves_n = ceil_ste(jnp.maximum(N, 1.0) / chw.sys_y)
        k_cycles = ceil_ste(jnp.maximum(K, 1.0))
        fill = chw.sys_x + chw.sys_y
        cyc_sys_tile = waves_m * waves_n * (k_cycles + fill)
        ops_sys_tile = n_comp[_SYS] / tiles
        cyc_sys_tile = jnp.maximum(
            cyc_sys_tile, ops_sys_tile / jnp.maximum(chw.flops_per_cycle[_SYS], 1e-9)
        )
        t_sys = jnp.where(ops_sys_tile > 0, tiles * cyc_sys_tile / freq, 0.0)
        # other classes: rate model
        eff_rate = jnp.maximum(chw.flops_per_cycle, 1e-9) * freq  # FLOP/s
        t_comp_cls = n_comp / eff_rate
        t_comp = jnp.maximum(jnp.max(t_comp_cls.at[_SYS].set(0.0)), t_sys)

        # ---------------- memory time per level ----------------------------
        # burst-quantized transfers with the average bank-conflict factor of
        # the reference walker (mean of its 1.00-1.08 hash-spread) + per-tile
        # access latency
        conflict = 1.04
        t_lvl = (n_read + n_write) / bw * conflict
        t_tile_lat = tiles * (chw.read_latency + chw.write_latency)
        t_onchip = jnp.maximum(t_lvl[_GBUF] + t_tile_lat[_GBUF], t_lvl[_LOCAL])
        t_main = t_lvl[_MAIN] + t_tile_lat[_MAIN] * (n_alloc[_MAIN] > 0)

        # ---------------- prefetch / streaming gates (Alg. 7) --------------
        occupancy, bw_ema = carry["occupancy"], carry["bw_ema"]
        can_prefetch = (
            gate_below_ste(occupancy + alloc_gbuf / tiles, chw.capacity[_GBUF] * cfg.headroom)
            * gate_below_ste(bw_ema, cfg.headroom)
            * (1.0 if cfg.prefetch else 0.0)
        )
        # streaming: if over capacity but bw available, overlap main-mem
        # traffic with compute (set_execution = streaming)
        can_stream = gate_below_ste(bw_ema, cfg.headroom) * (1.0 if cfg.streaming else 0.0)
        hide = jnp.maximum(can_prefetch, can_stream)

        # exposed main-memory time: hidden behind compute when gated on
        t_core = jnp.maximum(t_comp, t_onchip)
        t_main_exposed = jnp.maximum(t_main - hide * t_core, 0.0)
        # integer-cycle quantization per tile (cycle-walker semantics, exact
        # forward via STE): decode-scale vertices cost whole cycles
        per_tile_cyc = (t_core + t_main_exposed) * freq / tiles
        t_vertex = tiles * ceil_ste(per_tile_cyc) / freq

        # ---------------- state updates -------------------------------------
        used_bw = jnp.where(
            t_vertex > 0, (n_read[_GBUF] + n_write[_GBUF]) / jnp.maximum(t_vertex, 1e-30) / bw[_GBUF], 0.0
        )
        new_bw = 0.8 * bw_ema + 0.2 * jnp.clip(used_bw, 0.0, 2.0)
        new_occ = 0.5 * occupancy + alloc_gbuf  # decaying residency
        new_occ = jnp.minimum(new_occ, chw.capacity[_GBUF])

        out = dict(
            cycles=t_vertex * freq,
            t_comp=t_comp,
            t_mem=t_onchip,
            t_main_exposed=t_main_exposed,
            tiles=tiles,
            reads=n_read,
            writes=n_write,
            comp=n_comp,
            alloc=n_alloc,
            bw_now=used_bw,
        )
        return dict(occupancy=new_occ, bw_ema=new_bw), out

    carry0 = dict(occupancy=jnp.float32(0.0), bw_ema=jnp.float32(0.0))
    xs = (g.n_comp, g.n_read, g.n_write, g.n_alloc, g.dims)
    _, outs = jax.lax.scan(vertex_step, carry0, xs)

    total_t = jnp.sum(outs["cycles"]) / freq
    return MapState(
        cycles=jnp.sum(outs["cycles"]),
        reads=jnp.sum(outs["reads"], 0),
        writes=jnp.sum(outs["writes"], 0),
        comp_ops=jnp.sum(outs["comp"], 0),
        peak_alloc=jnp.max(outs["alloc"], 0),
        t_comp=jnp.sum(outs["t_comp"]),
        t_mem=jnp.sum(outs["t_mem"]),
        t_exposed_main=jnp.sum(outs["t_main_exposed"]),
        bw_util=jnp.stack(
            [
                jnp.float32(0.0),
                jnp.sum(outs["bw_now"] * outs["cycles"]) / jnp.maximum(jnp.sum(outs["cycles"]), 1e-30),
                jnp.float32(0.0),
            ]
        ),
        n_tiles=jnp.sum(outs["tiles"]),
    )
