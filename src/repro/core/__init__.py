"""DRAGON core — the paper's contribution as composable JAX modules.

DGen  : params.py + dgen.py     (hardware model generation)
DSim  : graph.py + trace.py + mapper.py + dsim.py (+ refsim.py baseline)
DOpt  : dopt.py (+ popsim.py distributed DSE)
"""
from repro.core.dgen import ConcreteHW, specialize  # noqa: F401
from repro.core.dhdl import (  # noqa: F401
    CompiledArch,
    DhdlError,
    library_archs,
    load_arch,
    parse_arch,
    serialize_arch,
)
from repro.core.dopt import OptResult, derive_tech_targets, optimize  # noqa: F401
from repro.core.dsim import (  # noqa: F401
    PARETO_METRICS,
    PerfEstimate,
    mixed_log_objective,
    simulate,
    simulate_chw,
    simulate_stacked,
    stacked_log_metrics,
    stacked_log_objective,
)
from repro.core.pareto import (  # noqa: F401
    hv_ref_point,
    hypervolume,
    non_dominated_mask,
    pareto_front,
)
from repro.core.popsim import (  # noqa: F401
    ParetoResult,
    pareto_dse,
    population_chunk,
    sample_objective_mixes,
    seed_population,
)
from repro.core.graph import Graph, GraphBuilder, workload_optimize  # noqa: F401
from repro.core.mapper import MapperCfg, MapState, map_workload, map_workload_scan  # noqa: F401
from repro.core.params import ArchParams, ArchSpec, TechParams  # noqa: F401
from repro.core.trace import model_flops, trace_lm  # noqa: F401
