"""DRAGON core — the paper's contribution as composable JAX modules.

DGen  : params.py + dgen.py     (hardware model generation)
DSim  : graph.py + trace.py + mapper.py + dsim.py (+ refsim.py baseline)
DOpt  : dopt.py (+ popsim.py distributed DSE)
"""
from repro.core.dgen import ConcreteHW, specialize  # noqa: F401
from repro.core.dhdl import (  # noqa: F401
    CompiledArch,
    DhdlError,
    library_archs,
    load_arch,
    parse_arch,
    serialize_arch,
)
from repro.core.dopt import OptResult, derive_tech_targets, optimize  # noqa: F401
from repro.core.dsim import (  # noqa: F401
    PerfEstimate,
    simulate,
    simulate_chw,
    simulate_stacked,
    stacked_log_objective,
)
from repro.core.graph import Graph, GraphBuilder, workload_optimize  # noqa: F401
from repro.core.mapper import MapperCfg, MapState, map_workload, map_workload_scan  # noqa: F401
from repro.core.params import ArchParams, ArchSpec, TechParams  # noqa: F401
from repro.core.trace import model_flops, trace_lm  # noqa: F401
