"""DRAGON parameter spaces (paper Table 2).

TechParams  — technology parameters (MemTechPars + CompTechPars)
ArchParams  — architectural parameters (MemArchPars + CompArchPars)

Both are registered JAX pytrees of positive float arrays so the whole
simulator is differentiable w.r.t. them.  Integer-valued parameters
(node, capacities, array dims, ...) are carried as floats and rounded
straight-through at the point of use (see mapper.py / dgen.py), which is
the JAX adaptation of the paper's Z-valued parameters.

Unit conventions (kept consistent across dgen/dsim):
  time    seconds        energy  joules        power  watts
  area    mm^2           length  micrometers   bytes  bytes
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

# Class universes (paper §3)
MEM_CLS = ("localMem", "globalBuf", "mainMem")
COMP_CLS = ("systolicArray", "vector", "macTree", "fpu")
MEM_TYPES = ("sram", "rram", "dram")
PRIMITIVES = ("adder", "mult", "ff")

N_MEM = len(MEM_CLS)
N_COMP = len(COMP_CLS)

MEM_IDX = {m: i for i, m in enumerate(MEM_CLS)}
COMP_IDX = {c: i for i, c in enumerate(COMP_CLS)}


def _f(x) -> jax.Array:
    return jnp.asarray(x, dtype=jnp.float32)


@jax.tree_util.register_dataclass
@dataclass
class TechParams:
    """Technology parameters.  Mem fields are [N_MEM] (per memory unit);
    comp fields are [N_COMP] (per compute unit)."""

    # --- MemTechPars (paper Table 2) ---
    mem_wire_cap: jax.Array  # fF / um of wire
    mem_wire_resist: jax.Array  # ohm / um of wire
    cell_read_latency: jax.Array  # s, intrinsic cell sensing latency
    cell_access_device: jax.Array  # relative access-device strength (1.0 = ref)
    cell_read_power: jax.Array  # pJ / bit dynamic read
    cell_leakage_power: jax.Array  # nW / bit standby leakage
    cell_area: jax.Array  # um^2 / bit
    peripheral_node: jax.Array  # nm, peripheral logic node
    # --- CompTechPars ---
    comp_wire_cap: jax.Array  # fF / um
    comp_wire_resist: jax.Array  # ohm / um
    node: jax.Array  # nm, logic node per compute class

    @staticmethod
    def default() -> "TechParams":
        """40nm-reference technology point (paper Alg. 6: 'table at 40nm').

        localMem / globalBuf default to SRAM-like cells, mainMem to DRAM.
        """
        return TechParams(
            mem_wire_cap=_f([0.20, 0.20, 0.25]),
            mem_wire_resist=_f([1.2, 1.2, 2.0]),
            cell_read_latency=_f([0.15e-9, 0.50e-9, 12e-9]),
            cell_access_device=_f([1.0, 1.0, 1.0]),
            cell_read_power=_f([0.004, 0.010, 2.0]),  # pJ/bit (dram incl. I/O)
            cell_leakage_power=_f([1.0e-3, 0.8e-3, 0.02e-3]),  # nW/bit
            cell_area=_f([0.30, 0.15, 0.0030]),  # um^2/bit
            peripheral_node=_f([40.0, 40.0, 40.0]),
            comp_wire_cap=_f([0.20] * N_COMP),
            comp_wire_resist=_f([1.2] * N_COMP),
            node=_f([40.0] * N_COMP),
        )

    @staticmethod
    def bounds() -> tuple["TechParams", "TechParams"]:
        """Realistic lower/upper bounds (paper Alg. 6 step 5)."""
        lo = TechParams(
            mem_wire_cap=_f([0.02] * N_MEM),
            mem_wire_resist=_f([0.1] * N_MEM),
            cell_read_latency=_f([0.01e-9, 0.05e-9, 1e-9]),
            cell_access_device=_f([0.25] * N_MEM),
            cell_read_power=_f([2e-4, 5e-4, 0.05]),
            cell_leakage_power=_f([1e-6] * N_MEM),
            cell_area=_f([0.01, 0.005, 1e-4]),
            peripheral_node=_f([3.0] * N_MEM),
            comp_wire_cap=_f([0.02] * N_COMP),
            comp_wire_resist=_f([0.1] * N_COMP),
            node=_f([3.0] * N_COMP),
        )
        hi = TechParams(
            mem_wire_cap=_f([1.0] * N_MEM),
            mem_wire_resist=_f([10.0] * N_MEM),
            cell_read_latency=_f([5e-9, 5e-9, 100e-9]),
            cell_access_device=_f([4.0] * N_MEM),
            cell_read_power=_f([0.05, 0.2, 20.0]),
            cell_leakage_power=_f([0.05] * N_MEM),
            cell_area=_f([2.0, 1.0, 0.05]),
            peripheral_node=_f([90.0] * N_MEM),
            comp_wire_cap=_f([1.0] * N_COMP),
            comp_wire_resist=_f([10.0] * N_COMP),
            node=_f([90.0] * N_COMP),
        )
        return lo, hi


@jax.tree_util.register_dataclass
@dataclass
class ArchParams:
    """Architectural parameters (design-time tunable)."""

    # systolic array
    sys_arr_x: jax.Array  # PE rows
    sys_arr_y: jax.Array  # PE cols
    sys_arr_n: jax.Array  # number of arrays
    # vector unit
    vect_width: jax.Array  # lanes
    vect_n: jax.Array  # units
    # mac tree
    mtree_x: jax.Array
    mtree_y: jax.Array
    mtree_tile_x: jax.Array
    mtree_tile_y: jax.Array
    # fpu
    fpu_n: jax.Array
    # SoC
    frequency: jax.Array  # Hz
    # memories: [N_MEM]
    capacity: jax.Array  # bytes
    bank_size: jax.Array  # bytes
    n_read_ports: jax.Array
    # bandwidth provisioning multiplier per level (1.0 = the port-derived
    # baseline).  Exposed by the .dhd description language as ``bw`` /
    # ``bw_scale``; extra bandwidth is not free — dgen charges wire area and
    # access energy for it, so DOpt can trade it off like any other knob.
    bw_scale: jax.Array

    @staticmethod
    def default() -> "ArchParams":
        """A TPU-v1-flavoured edge accelerator starting point."""
        return ArchParams(
            sys_arr_x=_f(128.0),
            sys_arr_y=_f(128.0),
            sys_arr_n=_f(2.0),
            vect_width=_f(256.0),
            vect_n=_f(4.0),
            mtree_x=_f(64.0),
            mtree_y=_f(8.0),
            mtree_tile_x=_f(8.0),
            mtree_tile_y=_f(8.0),
            fpu_n=_f(8.0),
            frequency=_f(0.94e9),
            capacity=_f([4 * 2**20, 24 * 2**20, 16 * 2**30]),
            bank_size=_f([32 * 2**10, 256 * 2**10, 8 * 2**20]),
            n_read_ports=_f([16.0, 8.0, 8.0]),
            bw_scale=_f([1.0, 1.0, 1.0]),
        )

    @staticmethod
    def bounds() -> tuple["ArchParams", "ArchParams"]:
        lo = ArchParams(
            sys_arr_x=_f(4.0), sys_arr_y=_f(4.0), sys_arr_n=_f(1.0),
            vect_width=_f(8.0), vect_n=_f(1.0),
            mtree_x=_f(4.0), mtree_y=_f(1.0), mtree_tile_x=_f(1.0), mtree_tile_y=_f(1.0),
            fpu_n=_f(1.0), frequency=_f(0.2e9),
            capacity=_f([2**16, 2**20, 2**30]),
            bank_size=_f([2**12, 2**14, 2**19]),
            n_read_ports=_f([1.0, 1.0, 1.0]),
            bw_scale=_f([0.25, 0.25, 0.25]),
        )
        hi = ArchParams(
            sys_arr_x=_f(1024.0), sys_arr_y=_f(1024.0), sys_arr_n=_f(64.0),
            vect_width=_f(4096.0), vect_n=_f(128.0),
            mtree_x=_f(1024.0), mtree_y=_f(256.0), mtree_tile_x=_f(64.0), mtree_tile_y=_f(64.0),
            fpu_n=_f(512.0), frequency=_f(3e9),
            capacity=_f([64 * 2**20, 512 * 2**20, 256 * 2**30]),
            bank_size=_f([2**20, 2**23, 2**26]),
            n_read_ports=_f([64.0, 64.0, 64.0]),
            bw_scale=_f([16.0, 16.0, 16.0]),
        )
        return lo, hi


@dataclass(frozen=True)
class ArchSpec:
    """Architectural specification (paper §5.1): which units exist and
    which memory technology backs each memory unit.  Static (not a pytree)."""

    mem_units: tuple[str, ...] = MEM_CLS
    comp_units: tuple[str, ...] = COMP_CLS
    mem_type: tuple[str, ...] = ("sram", "sram", "dram")  # per MEM_CLS entry

    def mem_type_idx(self) -> np.ndarray:
        return np.array([MEM_TYPES.index(t) for t in self.mem_type], dtype=np.int32)

    def comp_mask(self) -> np.ndarray:
        return np.array([1.0 if c in self.comp_units else 0.0 for c in COMP_CLS], np.float32)

    def mem_mask(self) -> np.ndarray:
        return np.array([1.0 if m in self.mem_units else 0.0 for m in MEM_CLS], np.float32)


def clamp_params(p, lo, hi):
    return jax.tree.map(lambda x, l, h: jnp.clip(x, l, h), p, lo, hi)
