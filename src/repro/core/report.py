"""Explainable result objects for the DRAGON façade (`repro.api`).

The engines return raw device pytrees (PerfEstimate, dopt.OptResult,
popsim.ParetoResult) — right for composing JAX programs, wrong for humans
and services.  This module is the typed, frozen, JSON-able layer the
:class:`repro.api.Session` methods return:

  * :class:`SimReport`     — ``Session.simulate`` / ``Session.explain``:
    per-workload totals, per-memory-level and per-vertex time/energy
    breakdowns, and (from ``explain``) gradient-based bottleneck
    attribution — the elasticities DOpt already computes, ranked;
  * :class:`OptResult`     — ``Session.optimize``: improvement factor,
    convergence history, ranked technology importance, the optimized design
    as canonical ``.dhd`` text;
  * :class:`FrontierResult`— ``Session.frontier``: the constrained Pareto
    front with per-point metrics and serialized designs.

Everything is plain floats/strings/tuples (computed once, host-side), so
reports are hashable-free frozen dataclasses that ``json.dumps`` cleanly via
:meth:`to_json` and round-trip through logs, caches and RPC boundaries.
Designs serialize to ``.dhd`` text (:meth:`OptResult.to_dhd`,
:meth:`FrontierResult.to_dhd`) — the suite's interchange format.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass


def _to_json(obj, exclude: tuple[str, ...] = ()) -> str:
    d = {
        f.name: getattr(obj, f.name)
        for f in dataclasses.fields(obj)
        if f.name not in exclude
    }

    def default(x):
        if dataclasses.is_dataclass(x) and not isinstance(x, type):
            return dataclasses.asdict(x)
        return float(x)

    return json.dumps(d, default=default, indent=1)


# --------------------------------------------------------------------------- #
# simulate / explain
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Attribution:
    """One ranked bottleneck: d log(objective) / d log(parameter).

    Positive elasticity: shrinking the parameter improves the objective
    (it is a cost driver); negative: growing it helps (it is starved).
    """

    parameter: str  # e.g. "tech.mainMem.cell_read_latency", "arch.frequency"
    elasticity: float

    @property
    def action(self) -> str:
        return "reduce" if self.elasticity > 0 else "increase"


@dataclass(frozen=True)
class MemoryLevelReport:
    """Where a memory level's bytes, time and energy went."""

    level: str  # localMem | globalBuf | mainMem
    reads_bytes: float
    writes_bytes: float
    transfer_time_s: float  # demanded (no-overlap) transfer time
    dynamic_energy_j: float
    leakage_energy_j: float
    bw_utilization: float  # average utilization (globalBuf EMA input)


@dataclass(frozen=True)
class ComputeClassReport:
    """Per compute class: issued work and energy."""

    unit: str  # systolicArray | vector | macTree | fpu
    flops: float
    dynamic_energy_j: float
    leakage_energy_j: float


@dataclass(frozen=True)
class VertexReport:
    """One DFG vertex's share of the mapped execution."""

    name: str
    time_s: float
    energy_j: float
    time_share: float  # fraction of total runtime


@dataclass(frozen=True)
class WorkloadReport:
    """One workload's totals + breakdowns on the session's architecture."""

    label: str
    runtime_s: float
    energy_j: float
    power_w: float
    edp: float
    cycles: float
    energy_mem_j: float
    energy_comp_j: float
    energy_leak_j: float
    levels: tuple[MemoryLevelReport, ...]
    compute: tuple[ComputeClassReport, ...]
    vertices: tuple[VertexReport, ...]

    def top_vertices(self, k: int = 5) -> tuple[VertexReport, ...]:
        return tuple(sorted(self.vertices, key=lambda v: -v.time_s)[:k])


@dataclass(frozen=True)
class SimReport:
    """``Session.simulate``'s result: explainable, frozen, JSON-able.

    ``workloads`` carries one :class:`WorkloadReport` per member of the
    simulated :class:`repro.api.Workload`; the scalar conveniences
    (``runtime_s`` ...) read workload 0 for a single workload and the
    geometric mean across the set otherwise (matching the engines'
    mean-log reduction).  ``attribution`` is empty unless the report came
    from ``Session.explain``.
    """

    architecture: str  # architecture name
    objective: str  # the objective `attribution` differentiates ("" = none)
    area_mm2: float
    workloads: tuple[WorkloadReport, ...]
    attribution: tuple[Attribution, ...] = ()

    def _agg(self, field: str) -> float:
        vals = [getattr(w, field) for w in self.workloads]
        if len(vals) == 1:
            return vals[0]
        import math

        return math.exp(sum(math.log(max(v, 1e-300)) for v in vals) / len(vals))

    @property
    def runtime_s(self) -> float:
        return self._agg("runtime_s")

    @property
    def energy_j(self) -> float:
        return self._agg("energy_j")

    @property
    def power_w(self) -> float:
        return self._agg("power_w")

    @property
    def edp(self) -> float:
        return self._agg("edp")

    def bottlenecks(self, k: int = 5) -> tuple[Attribution, ...]:
        """Top-k parameters by |elasticity| (requires ``explain``)."""
        return self.attribution[:k]

    def to_json(self) -> str:
        return _to_json(self)

    def __str__(self) -> str:
        lines = [f"SimReport[{self.architecture}] area {self.area_mm2:.1f} mm^2"]
        for w in self.workloads:
            lines.append(
                f"  {w.label:24s} {w.runtime_s * 1e3:9.3f} ms  "
                f"{w.energy_j * 1e3:9.3f} mJ  edp {w.edp:.3e}"
            )
            for lv in w.levels:
                lines.append(
                    f"      {lv.level:10s} r/w {lv.reads_bytes / 1e6:8.1f}/"
                    f"{lv.writes_bytes / 1e6:8.1f} MB  "
                    f"dyn {lv.dynamic_energy_j * 1e3:8.3f} mJ"
                )
        for a in self.attribution[:5]:
            lines.append(f"  -> {a.action:8s} {a.parameter:44s} |e|={abs(a.elasticity):.3f}")
        return "\n".join(lines)


# --------------------------------------------------------------------------- #
# optimize
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class OptResult:
    """``Session.optimize``'s result: what changed, by how much, and why.

    ``improvement`` is the start/end objective factor (geometric-mean
    objective across the workload set, matching the engine's loss);
    ``importance`` ranks technology parameters by accumulated |elasticity|
    — the paper's Table-3 ordering; ``dhd`` is the optimized design as
    canonical text (``to_dhd``), parse-able back into an
    :class:`repro.api.Architecture`.
    """

    objective: str
    opt_over: str
    epochs: int
    improvement: float
    objective_history: tuple[float, ...]  # geomean objective per epoch
    importance: tuple[Attribution, ...]
    baseline: SimReport | None  # None when built with report=False
    optimized: SimReport | None
    dhd: str

    def to_dhd(self) -> str:
        return self.dhd

    def to_json(self) -> str:
        return _to_json(self)

    def __str__(self) -> str:
        top = " > ".join(a.parameter for a in self.importance[:3])
        return (
            f"OptResult[{self.objective}/{self.opt_over}] {self.epochs} epochs, "
            f"{self.improvement:.1f}x better; top levers: {top}"
        )


# --------------------------------------------------------------------------- #
# frontier
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class FrontierPoint:
    """One non-dominated design on the constrained frontier."""

    index: int
    seed: str  # .dhd library architecture the member descended from
    weights: tuple[float, ...]  # PARETO_METRICS objective mix
    time_s: float
    energy_j: float
    area_mm2: float
    power_w: float
    edp: float
    dhd: str  # the design, serialized


@dataclass(frozen=True)
class FrontierResult:
    """``Session.frontier``'s result: the feasible Pareto front.

    ``raw`` keeps the engine's :class:`repro.core.popsim.ParetoResult`
    (device pytrees, full population) for follow-up computation; it is
    excluded from ``to_json``.
    """

    metrics: tuple[str, ...]
    population: int
    epochs: int
    feasible: int
    hypervolume: float
    area_budget: float
    power_budget: float
    front: tuple[FrontierPoint, ...]
    raw: object = None

    def to_dhd(self) -> str:
        """All winning designs as one concatenated ``.dhd`` document."""
        return "\n\n".join(p.dhd for p in self.front)

    def to_json(self) -> str:
        return _to_json(self, exclude=("raw",))

    def __str__(self) -> str:
        lines = [
            f"FrontierResult: {len(self.front)}/{self.population} designs on the "
            f"{'/'.join(self.metrics)} front, hv {self.hypervolume:.2f}"
        ]
        for p in self.front:
            lines.append(
                f"  [{p.seed:10s}] {p.time_s * 1e3:8.2f} ms  {p.energy_j:7.3f} J  "
                f"{p.area_mm2:7.1f} mm^2  {p.power_w:6.1f} W"
            )
        return "\n".join(lines)
