import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
against the production mesh — 16x16=256 chips single-pod and 2x16x16=512
chips multi-pod — and record the compiled artifact's cost/memory analysis +
collective traffic for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

No arrays are ever allocated at model scale: parameters, optimizer states,
batches and KV caches all enter .lower() as ShapeDtypeStructs.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod both] [--out results/dryrun]
  python -m repro.launch.dryrun --popsim            # DRAGON's own DSE program
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_archs, cell_status, get_config
from repro.launch.hlo_costs import hlo_costs
from repro.launch.hlo_stats import collective_stats, while_trip_counts
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.specs import (
    abstract_batch,
    as_shardings,
    batch_specs,
    train_state_specs,
)
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig, abstract_train_state, make_train_step

# TPU v5e-flavoured target constants (per chip) — §Roofline
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link


def opt_cfg_for(cfg) -> AdamWConfig:
    # trillion-param MoE: int8 moments or optimizer state cannot fit HBM
    int8 = cfg.family == "moe" and cfg.moe.n_experts >= 64
    return AdamWConfig(int8_states=int8)


def _lower_cell(arch: str, shape_name: str, multi_pod: bool, parallelism: str = "tp",
                remat: str | None = None):
    from repro.models.sharding import parallelism as parallelism_ctx

    cfg = get_config(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    # decode at 500k with batch 1: shard the KV-cache sequence dim instead
    # of the unshardable batch dim
    n_batch_shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    seq_shard = shape.kind == "decode" and shape.global_batch < n_batch_shards

    ctx = parallelism_ctx(parallelism)
    with mesh, ctx:
        if shape.kind == "train":
            ocfg, tcfg = opt_cfg_for(cfg), TrainConfig()
            step = make_train_step(model, ocfg, tcfg, mesh=mesh)
            state_abs = abstract_train_state(model, ocfg, tcfg)
            batch_abs = abstract_batch(cfg, shape)
            sspec = train_state_specs(model, mesh, ocfg, tcfg)
            bspec = batch_specs(cfg, mesh, batch_abs)
            fn = jax.jit(
                step,
                in_shardings=(as_shardings(mesh, sspec), as_shardings(mesh, bspec)),
                out_shardings=(as_shardings(mesh, sspec), None),
                donate_argnums=(0,),
            )
            lowered = fn.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            pspec = model.specs(mesh)
            batch_abs = abstract_batch(cfg, shape)
            bspec = batch_specs(cfg, mesh, batch_abs)
            params_abs = model.abstract_params()
            args = [batch_abs["tokens"]]
            in_sh = [as_shardings(mesh, pspec), NamedSharding(mesh, bspec["tokens"])]
            if cfg.vision:
                args.append(batch_abs["vision"])
                in_sh.append(NamedSharding(mesh, bspec["vision"]))

            if cfg.vision:
                def fn(p, toks, vision):
                    return model.prefill(p, toks, max_len=shape.seq_len, vision=vision, mesh=mesh)
            else:
                def fn(p, toks):
                    return model.prefill(p, toks, max_len=shape.seq_len, mesh=mesh)

            lowered = jax.jit(fn, in_shardings=tuple(in_sh)).lower(params_abs, *args)
        else:  # decode
            B, M = shape.global_batch, shape.seq_len
            pspec = model.specs(mesh)
            cache_abs = model.cache_struct(B, M)
            cspec = model.cache_specs(mesh, B, M, seq_shard=seq_shard)
            tok_shape = (B, 1, cfg.audio.n_codebooks) if cfg.audio else (B, 1)
            toks_abs = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
            from repro.models.sharding import repair_spec

            tspec = repair_spec(
                P(_present(mesh, ("pod", "data")), *([None] * (len(tok_shape) - 1))),
                tok_shape, mesh,
            )

            def fn(p, toks, cache):
                return model.decode_step(p, toks, cache, mesh=mesh, seq_shard=seq_shard)

            lowered = jax.jit(
                fn,
                in_shardings=(
                    as_shardings(mesh, pspec),
                    NamedSharding(mesh, tspec),
                    as_shardings(mesh, cspec),
                ),
                donate_argnums=(2,),
            ).lower(model.abstract_params(), toks_abs, cache_abs)
    return lowered, mesh, model, shape


def _present(mesh, axes):
    got = tuple(a for a in axes if a in mesh.axis_names)
    return got if len(got) > 1 else (got[0] if got else None)


def run_cell(arch: str, shape_name: str, multi_pod: bool, collect_hlo: bool = True,
             parallelism: str = "tp") -> dict:
    t0 = time.time()
    lowered, mesh, model, shape = _lower_cell(arch, shape_name, multi_pod, parallelism)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ma = compiled.memory_analysis()
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh_chips(mesh),
        "kind": shape.kind,
        "parallelism": parallelism,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # XLA's own numbers (count while bodies ONCE — kept for reference)
        "xla_flops_per_device": float(ca.get("flops", -1.0)),
        "xla_bytes_per_device": float(ca.get("bytes accessed", -1.0)),
        "memory": {
            k: int(getattr(ma, k, -1))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            )
        },
    }
    if collect_hlo:
        txt = compiled.as_text()
        rec["collectives"] = collective_stats(txt)
        rec["scan_trip_counts"] = while_trip_counts(txt)[:32]
        costs = hlo_costs(txt)  # trip-count-weighted (launch/hlo_costs.py)
        rec["flops_per_device"] = costs["flops"]
        rec["bytes_per_device"] = costs["bytes"]
        rec["flops_by_op"] = costs["flops_by_op"]
        rec["bytes_by_op"] = costs["bytes_by_op"]
    # roofline terms (seconds) — per-device numerators over per-chip rates
    live = (
        rec["memory"]["argument_size_in_bytes"]
        + rec["memory"]["output_size_in_bytes"]
        - rec["memory"].get("alias_size_in_bytes", 0)
        + rec["memory"]["temp_size_in_bytes"]
    )
    rec["hbm_per_device_gb"] = round(live / 1e9, 3)
    rec["roofline"] = {
        "t_compute": rec["flops_per_device"] / PEAK_FLOPS,
        "t_memory": rec["bytes_per_device"] / HBM_BW,
        "t_collective": rec.get("collectives", {}).get("total_bytes", 0) / LINK_BW,
    }
    rec["roofline"]["bottleneck"] = max(rec["roofline"], key=lambda k: rec["roofline"][k])
    return rec


def run_popsim(multi_pod: bool) -> dict:
    """Lower DRAGON's own population-DSE step on the production mesh."""
    from repro.core.popsim import dse_in_shardings, init_population, make_dse_step
    from repro.workloads import get_workload

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_pop = 4096
    pop = jax.eval_shape(lambda k: init_population(k, n_pop), jax.ShapeDtypeStruct((2,), jnp.uint32))
    g = get_workload("bert_base")
    W = mesh.shape["model"]
    graphs = jax.eval_shape(
        lambda: jax.tree.map(lambda x: jnp.stack([x] * W), g)
    )
    step = make_dse_step()
    pop_s, g_s = dse_in_shardings(mesh, pop, graphs)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=(pop_s, g_s)).lower(pop, graphs)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    txt = compiled.as_text()
    return {
        "arch": "dragon-popsim-dse",
        "shape": f"pop{n_pop}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh_chips(mesh),
        "kind": "dse",
        "ok": True,
        "compile_s": round(time.time() - t0, 2),
        "flops_per_device": float(ca.get("flops", -1.0)),
        "bytes_per_device": float(ca.get("bytes accessed", -1.0)),
        "collectives": collective_stats(txt),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--popsim", action="store_true")
    ap.add_argument("--multipod", choices=("on", "off", "both"), default="off")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--resume", action="store_true", help="skip cells with existing JSON")
    ap.add_argument("--parallelism", choices=("tp", "dp", "auto"), default="tp",
                    help="model-axis policy; auto = launch.policy per cell")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multipod]

    if args.popsim:
        for mp in pods:
            rec = run_popsim(mp)
            fn = os.path.join(args.out, f"popsim__{rec['mesh']}.json")
            json.dump(rec, open(fn, "w"), indent=1)
            print(f"[dryrun] popsim {rec['mesh']}: OK compile={rec['compile_s']}s")
        return

    cells = []
    if args.all:
        for a in all_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape_name in cells:
        status = cell_status(get_config(arch), SHAPES[shape_name])
        for mp in pods:
            mesh_tag = "2x16x16" if mp else "16x16"
            fn = os.path.join(args.out, f"{arch}__{shape_name}__{mesh_tag}.json")
            if args.resume and os.path.exists(fn):
                print(f"[dryrun] skip existing {fn}")
                continue
            if status != "run":
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                       "ok": True, "skipped": status}
                json.dump(rec, open(fn, "w"), indent=1)
                print(f"[dryrun] {arch} x {shape_name} [{mesh_tag}]: SKIP ({status})")
                continue
            try:
                par = args.parallelism
                if par == "auto":
                    from repro.launch.policy import parallelism_for

                    par = parallelism_for(get_config(arch), SHAPES[shape_name])
                rec = run_cell(arch, shape_name, mp, parallelism=par)
                r = rec["roofline"]
                print(
                    f"[dryrun] {arch} x {shape_name} [{mesh_tag}]: OK "
                    f"compile={rec['compile_s']:.1f}s hbm/dev={rec['hbm_per_device_gb']}GB "
                    f"t_comp={r['t_compute']:.3e} t_mem={r['t_memory']:.3e} "
                    f"t_coll={r['t_collective']:.3e} -> {r['bottleneck']}"
                )
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[dryrun] {arch} x {shape_name} [{mesh_tag}]: FAIL {type(e).__name__}: {e}")
            json.dump(rec, open(fn, "w"), indent=1)


if __name__ == "__main__":
    main()
