"""Serving launcher: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
      --requests 8 --max-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.serving import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_local_mesh()
    with mesh:
        eng = Engine(model, params, slots=args.slots, max_len=args.max_len, mesh=mesh)
        rng = np.random.default_rng(0)
        t0 = time.time()
        for i in range(args.requests):
            shape = (args.prompt_len, cfg.audio.n_codebooks) if cfg.audio else (args.prompt_len,)
            eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, shape).astype(np.int32),
                               max_tokens=args.max_tokens, temperature=args.temperature, seed=i))
        done = eng.run()
    wall = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    ttfts = [r.t_first - r.t_submit for r in done]
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks/wall:.1f} tok/s); mean TTFT {np.mean(ttfts)*1e3:.0f} ms")


if __name__ == "__main__":
    main()
