"""Production mesh construction.

make_production_mesh() never touches jax device state at import time — the
dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before any
jax import so the (2, 16, 16) multi-pod mesh (512 chips) and the (16, 16)
single-pod mesh (256 chips) can be built on the CPU host.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def mesh_chips(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
