from repro.launch.mesh import make_local_mesh, make_production_mesh, mesh_chips  # noqa: F401
