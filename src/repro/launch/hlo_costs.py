"""Trip-count-aware HLO cost analysis (FLOPs + HBM bytes, per-op breakdown).

``compiled.cost_analysis()`` counts every while (lax.scan) body ONCE — a
61-layer scan is undercounted 61x, making it useless for the roofline. This
walker parses the post-SPMD HLO text into computations, builds a symbol
table (instruction/parameter -> shape), and folds costs bottom-up:

  * dot:     2 * prod(out) * prod(contracting dims of lhs)
  * fusion:  callee's internal FLOPs; bytes = callee params + fusion output
             (one kernel: reads inputs, writes outputs — internal traffic
             stays in registers/VMEM)
  * while:   body cost x trip count (from known_trip_count or the condition
             computation's comparison constant)
  * element-wise / reduce / DUS / slice / collective: prod-of-shape flops
    and operand+output bytes per the table in _op_cost

Outputs: dict(flops, bytes, flops_by_op, bytes_by_op) — per device, since
the SPMD module is the per-device program. Used by launch/dryrun.py and
benchmarks/bench_roofline.py; the per-op breakdown is the profile the §Perf
hillclimb reads.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"  # tuple types carry {layouts}
    r"([\w\-]+)\((.*)$"
)
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count=\{"?n"?[:=]"?(\d+)"?\}')
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "compare",
    "select", "clamp", "and", "or", "xor", "not", "cosine", "sine",
    "logistic", "sign", "floor", "ceil", "round-nearest-even",
    "round-nearest-afz", "remainder", "atan2", "expm1", "log1p", "cbrt",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "exponential-minus-one", "is-finite",
}
_ZERO_COST = {
    "parameter", "constant", "iota", "bitcast", "reshape", "tuple",
    "get-tuple-element", "after-all", "partition-id", "replica-id",
    "rng-bit-generator", "rng", "bitcast-convert", "opt-barrier",
    "custom-call", "infeed", "outfeed", "domain",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _type_bytes_elems(t: str) -> tuple[int, int]:
    """(total bytes, total elements) of a type string (handles tuples)."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(t):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES.get(dt, 4)
    return total_b, total_e


def _shape_dims(t: str) -> list[int]:
    m = _SHAPE_RE.search(t)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _split_params(s: str) -> list[tuple[str, str]]:
    """'p1: f32[..], p2: (f32[..], s32[])' -> [(name, type), ...]"""
    out, depth, cur = [], 0, ""
    for ch in s:
        if ch == "(" or ch == "{" or ch == "[":
            depth += 1
        elif ch == ")" or ch == "}" or ch == "]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        out.append(cur)
    parsed = []
    for item in out:
        if ":" in item:
            name, t = item.split(":", 1)
            parsed.append((name.strip().lstrip("%"), t.strip()))
    return parsed


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, dict] = {}
        self.entry: str | None = None
        self._eff_param_cache: dict[str, float] = {}
        cur = None
        for line in text.splitlines():
            s = line.rstrip()
            st = s.strip()
            if st.endswith("{") and ("->" in st or st.startswith("ENTRY")):
                m = _HDR_RE.match(st)
                if m:
                    name = m.group(2)
                    cur = {"lines": [], "params": dict(_split_params(m.group(3))), "fusion_body": False}
                    self.comps[name] = cur
                    if m.group(1):
                        self.entry = name
                continue
            if st == "}" or st.startswith("} "):
                cur = None
                continue
            if cur is not None and st:
                cur["lines"].append(st)
        # mark fusion bodies (callees of fusion instructions)
        for c in self.comps.values():
            for ln in c["lines"]:
                if " fusion(" in ln:
                    for callee in _CALLS_RE.findall(ln):
                        if callee in self.comps:
                            self.comps[callee]["fusion_body"] = True

    # ------------------------------------------------------------------ #
    def _symtab(self, comp: dict) -> dict:
        tab = dict(comp["params"])
        for ln in comp["lines"]:
            m = _INSTR_RE.match(ln)
            if m:
                tab[m.group(1)] = m.group(2)
        return tab

    def _trip(self, cond_name: str, line: str) -> int:
        m = _TRIP_RE.search(line)
        if m:
            return int(m.group(1))
        consts = []
        for ln in self.comps.get(cond_name, {}).get("lines", []):
            consts += [int(x) for x in _CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    # ------------------------------------------------------------------ #
    def _effective_param_bytes(self, callee: str) -> float:
        """Σ over callee params of min(full size, sliced access size)."""
        if callee in self._eff_param_cache:
            return self._eff_param_cache[callee]
        comp = self.comps.get(callee)
        if comp is None:
            return 0.0
        full = {p: _type_bytes_elems(t)[0] for p, t in comp["params"].items()}
        sliced: dict[str, float] = {}
        other_use: set = set()
        for ln in comp["lines"]:
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            _n, otype, op, rest = m.groups()
            ops_ = _OPERAND_RE.findall(rest.split("), ")[0] + ")")
            if op in ("dynamic-slice", "gather", "slice") and ops_ and ops_[0] in full:
                ob = _type_bytes_elems(otype)[0]
                sliced[ops_[0]] = sliced.get(ops_[0], 0.0) + ob
                for o in ops_[1:]:
                    if o in full:
                        other_use.add(o)
            else:
                for o in ops_:
                    if o in full:
                        other_use.add(o)
        total = 0.0
        for p, fb in full.items():
            if p in sliced and p not in other_use:
                total += min(fb, sliced[p])
            else:
                total += fb
        self._eff_param_cache[callee] = total
        return total

    def cost(self) -> dict:
        memo: dict[str, tuple] = {}

        def resolve(name: str, stack=()) -> tuple[dict, dict]:
            if name in memo:
                return memo[name]
            if name not in self.comps or name in stack:
                return {}, {}
            comp = self.comps[name]
            tab = self._symtab(comp)
            flops: dict = defaultdict(float)
            bytes_: dict = defaultdict(float)
            in_fusion = comp["fusion_body"]

            for ln in comp["lines"]:
                m = _INSTR_RE.match(ln)
                if not m:
                    continue
                _iname, otype, op, rest = m.groups()
                ob, oe = _type_bytes_elems(otype)

                if op == "while":
                    wm = _WHILE_ATTR_RE.search(ln)
                    if wm:
                        trip = self._trip(wm.group(1), ln)
                        bf, bb = resolve(wm.group(2), stack + (name,))
                        for k, v in bf.items():
                            flops[k] += v * trip
                        for k, v in bb.items():
                            bytes_[k] += v * trip
                    continue
                if op == "fusion":
                    for callee in _CALLS_RE.findall(ln):
                        cf, _cb = resolve(callee, stack + (name,))
                        for k, v in cf.items():
                            flops[k] += v
                        # bytes: fusion kernel reads callee params, writes
                        # out. A param consumed ONLY through dynamic-slice /
                        # gather reads just the slice (charging the full
                        # array would bill a scan's whole stacked input at
                        # every step — 100x overcounts attention pair scans)
                        bytes_["fusion"] += self._effective_param_bytes(callee) + ob
                    continue
                if op in ("call", "conditional", "async-start", "custom-call"):
                    for callee in _CALLS_RE.findall(ln):
                        cf, cb = resolve(callee, stack + (name,))
                        for k, v in cf.items():
                            flops[k] += v
                        for k, v in cb.items():
                            bytes_[k] += v
                    continue
                if op == "dot":
                    operands = _OPERAND_RE.findall(rest.split("), ")[0] + ")")
                    k = 1
                    cd = _CDIMS_RE.search(ln)
                    if cd and operands:
                        lhs_t = tab.get(operands[0], "")
                        dims = _shape_dims(lhs_t)
                        for di in cd.group(1).split(","):
                            if di and int(di) < len(dims):
                                k *= dims[int(di)]
                    flops["dot"] += 2.0 * oe * k
                    if not in_fusion:
                        opb = sum(_type_bytes_elems(tab.get(o, ""))[0] for o in operands[:2])
                        bytes_["dot"] += opb + ob
                    continue
                if op in ("reduce", "reduce-window"):
                    operands = _OPERAND_RE.findall(rest)
                    ib = _type_bytes_elems(tab.get(operands[0], ""))[0] if operands else ob
                    ie = _type_bytes_elems(tab.get(operands[0], ""))[1] if operands else oe
                    flops["reduce"] += ie
                    if not in_fusion:
                        bytes_["reduce"] += ib + ob
                    continue
                if op in _COLLECTIVES:
                    if not in_fusion:
                        bytes_["collective"] += 2.0 * ob
                    continue
                if op in _ELEMENTWISE:
                    flops["elementwise"] += oe
                    if not in_fusion:
                        n_ops = max(len(_OPERAND_RE.findall(rest)), 1)
                        bytes_["elementwise"] += (n_ops + 1.0) * ob
                    continue
                if op in ("convert", "copy", "transpose", "reverse", "copy-start"):
                    if not in_fusion:
                        ops_ = _OPERAND_RE.findall(rest)
                        ib = _type_bytes_elems(tab.get(ops_[0], ""))[0] if ops_ else ob
                        bytes_["layout"] += ib + ob
                    continue
                if op in ("dynamic-update-slice",):
                    ops_ = _OPERAND_RE.findall(rest)
                    ub = _type_bytes_elems(tab.get(ops_[1], ""))[0] if len(ops_) > 1 else 0
                    if not in_fusion:
                        bytes_["slice"] += 2.0 * ub
                    continue
                if op in ("dynamic-slice", "slice", "gather", "scatter", "concatenate", "pad", "sort", "select-and-scatter"):
                    if not in_fusion:
                        bytes_["slice"] += 2.0 * ob
                    continue
                if op == "broadcast":
                    if not in_fusion:
                        bytes_["layout"] += ob
                    continue
                # _ZERO_COST and anything else: free

            out = (dict(flops), dict(bytes_))
            memo[name] = out
            return out

        f, b = resolve(self.entry) if self.entry else ({}, {})
        return {
            "flops": float(sum(f.values())),
            "bytes": float(sum(b.values())),
            "flops_by_op": {k: float(v) for k, v in f.items()},
            "bytes_by_op": {k: float(v) for k, v in b.items()},
        }


def hlo_costs(text: str) -> dict:
    return HloModule(text).cost()
