"""Per-(arch, shape) parallelism policy — the §Perf hillclimb outcome.

"tp"  — model axis = tensor/expert parallel (attention heads, ffn, experts,
        vocab).  Required for: MoE (expert parallelism), decode (batch too
        small to feed 256-way DP), and anything whose optimizer state
        doesn't fit without TP.
"dp"  — model axis folds into data parallelism + ZeRO-3 parameter sharding.
        Wins for dense/SSM/hybrid TRAIN at 1M-token global batch: per-layer
        TP activation all-gathers (~1 TB/dev/step on granite) collapse to
        ZeRO-3's ~50 GB/dev/step of bf16 parameter gathers
        (EXPERIMENTS.md §Perf, hillclimb 1).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def parallelism_for(cfg: ModelConfig, shape: ShapeConfig, chips: int = 256) -> str:
    if cfg.family == "moe":
        return "tp"  # expert parallelism lives on the model axis
    if shape.kind != "train":
        return "tp"  # decode/prefill batches can't feed 256-way DP
    if shape.global_batch % chips != 0:
        return "tp"
    return "dp"
