"""Training launcher.

On a real cluster each host runs this under its TPU runtime with
jax.distributed auto-initialized; here it drives the same Trainer on
whatever devices exist. XLA latency-hiding flags below are the TPU
production set (overlap the DP all-reduce with backward compute).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os

# latency-hiding scheduler: overlap collectives with compute (TPU target;
# harmless on CPU). Must be set before jax import.
_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)
os.environ.setdefault("LIBTPU_INIT_ARGS", _FLAGS)

import jax

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.model import build_model
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import TrainConfig, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None, help="override global batch")
    ap.add_argument("--seq", type=int, default=None, help="override seq len")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--int8-opt", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (needs 256 devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shape = SHAPES.get(args.shape) or ShapeConfig(args.shape, args.seq or 512, args.batch or 8, "train")

    mesh = make_production_mesh() if args.production_mesh else make_local_mesh()
    opt = AdamWConfig(lr=args.lr, schedule=warmup_cosine(args.warmup, args.steps),
                      int8_states=args.int8_opt)
    tcfg = TrainConfig(microbatches=args.microbatches, compress_grads=args.compress_grads)
    rcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, batch_override=args.batch,
                         seq_override=args.seq)
    with mesh:
        trainer = Trainer(model, shape, opt, tcfg, rcfg, mesh=mesh)
        out = trainer.run()
    print(f"[train] {args.arch}: {len(out['losses'])} steps, "
          f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}, "
          f"{out['wall']:.1f}s, {len(out['stragglers'])} stragglers flagged")


if __name__ == "__main__":
    main()
