"""Post-SPMD HLO statistics: collective-traffic accounting for the roofline.

collective_bytes is NOT in compiled.cost_analysis(); we parse the per-device
optimized HLO (compiled.as_text()) computation by computation:

  * every all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute contributes per-chip *link bytes* using the standard
    ring-algorithm factors (an all-reduce of N bytes over a group of g moves
    2N(g-1)/g per chip, etc.);
  * collectives inside scan bodies are weighted by the loop TRIP COUNT,
    recovered from the while condition's comparison constant (the CPU
    backend emits no known_trip_count annotation) — without this a 61-layer
    scan would undercount its gradient all-reduces 61-fold;
  * fusion/call sub-computations are folded into their callers; the entry
    computation's total is the per-device number the §Roofline collective
    term consumes.

Sizes are per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count=\{"?n"?[:=]"?(\d+)"?\}')


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _link_bytes(kind: str, out_bytes: int, g: int) -> float:
    """Per-chip bytes over ICI links (ring implementations)."""
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)  # collective-permute


def _split_computations(text: str) -> tuple[dict, str]:
    """(name -> instruction lines, entry computation name)."""
    comps: dict = {}
    cur, name, entry = None, None, ""
    for line in text.splitlines():
        s = line.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY") or s.startswith("%")):
            hdr = s.split("(")[0].strip()
            name = hdr.replace("ENTRY", "").strip().lstrip("%").strip()
            cur = []
            comps[name] = cur
            if s.startswith("ENTRY"):
                entry = name
        elif s == "}" or s.startswith("} "):
            cur = None
        elif cur is not None:
            cur.append(s)
    return comps, entry


def collective_stats(hlo_text: str) -> dict:
    comps, entry = _split_computations(hlo_text)

    def cond_trip(cond_name: str) -> int:
        """Trip count from the while condition's comparison constant."""
        consts = []
        for line in comps.get(cond_name, []):
            consts += [int(x) for x in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    memo: dict = {}

    def resolve(name: str, stack=()) -> dict:
        if name in memo:
            return memo[name]
        if name in stack:  # recursion guard
            return defaultdict(float)
        acc: dict = defaultdict(float)
        counts: dict = defaultdict(float)
        for line in comps.get(name, []):
            mcoll = _COLL_RE.search(line)
            if mcoll and mcoll.group(3) != "-done":
                out_shape, kind = mcoll.group(1), mcoll.group(2)
                size = sum(_shape_bytes(dt, d) for dt, d in _SHAPE_RE.findall(out_shape))
                lb = _link_bytes(kind, size, _group_size(line))
                acc[kind] += lb
                # dtype split: the CPU pipeline upcasts bf16 dot operands to
                # f32 and hoists the convert before collectives; the
                # "@f32"/"@lp" split lets the roofline report a TPU-adjusted
                # collective term (f32 traffic would be bf16 on TPU)
                dts = {dt for dt, _ in _SHAPE_RE.findall(out_shape)}
                bucket = "@f32" if dts & {"f32", "f64"} else "@lp"
                acc[bucket] += lb
                counts[kind] += 1
                continue
            mwhile = _WHILE_RE.search(line)
            if mwhile:
                cond, body = mwhile.group(1), mwhile.group(2)
                mt = _TRIP_RE.search(line)
                trip = int(mt.group(1)) if mt else cond_trip(cond)
                sub = resolve(body, stack + (name,))
                for k, v in sub.items():
                    if k.startswith("#"):
                        counts[k[1:]] += v * trip
                    else:
                        acc[k] += v * trip
                continue
            for callee in _CALL_RE.findall(line):
                sub = resolve(callee, stack + (name,))
                for k, v in sub.items():
                    if k.startswith("#"):
                        counts[k[1:]] += v
                    else:
                        acc[k] += v
        out = dict(acc)
        out.update({f"#{k}": v for k, v in counts.items()})
        memo[name] = out
        return out

    totals = resolve(entry) if entry else {}
    bytes_by_kind = {k: int(v) for k, v in totals.items()
                     if not k.startswith("#") and not k.startswith("@")}
    counts = {k[1:]: int(v) for k, v in totals.items() if k.startswith("#")}
    f32_bytes = int(totals.get("@f32", 0))
    lp_bytes = int(totals.get("@lp", 0))
    return {
        "bytes_by_kind": bytes_by_kind,
        "counts": counts,
        "total_bytes": int(sum(bytes_by_kind.values())),
        "f32_bytes": f32_bytes,
        "lp_bytes": lp_bytes,
        # what the same program moves on a TPU pipeline that keeps bf16
        # operands native (f32 collectives halve)
        "tpu_adjusted_bytes": int(f32_bytes / 2 + lp_bytes),
    }


def while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort trip counts of all whiles (diagnostic)."""
    comps, _ = _split_computations(hlo_text)
    trips = []
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                consts = []
                for cl in comps.get(m.group(1), []):
                    consts += [int(x) for x in _CONST_RE.findall(cl)]
                trips.append(max(consts) if consts else -1)
    return trips
