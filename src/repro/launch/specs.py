"""Sharding-spec trees for every lowered program (train / prefill / decode).

Everything is derived from the ParamDef trees — one source of truth — so the
dry-run's in_shardings always structurally match the abstract inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import defs as D
from repro.models.model import Model
from repro.models.sharding import batch_spec, logical_to_spec, repair_spec
from repro.optim.adamw import AdamWConfig, Q8, q8_scale_shape
from repro.train.train_step import TrainConfig


def moment_specs(model: Model, mesh: Mesh, opt_cfg: AdamWConfig, fsdp_axes):
    """Spec tree for one Adam moment (m or v), mirroring the param specs.
    Q8 leaves get (codes=param_spec, scale=param_spec[:-1] + (None,))."""
    ax = mesh.axis_names

    def one(d: D.ParamDef):
        spec = repair_spec(logical_to_spec(d.axes, ax, fsdp_axes), d.shape, mesh)
        if not opt_cfg.int8_states:
            return spec
        entries = list(spec) + [None] * (len(d.shape) - len(spec))
        sshape = q8_scale_shape(d.shape)
        scale_spec = repair_spec(P(*entries[:-1], None), sshape, mesh) if len(d.shape) else P(None)
        return Q8(codes=spec, scale=scale_spec)

    return jax.tree.map(one, model.param_defs(), is_leaf=D.is_def)


def train_state_specs(model: Model, mesh: Mesh, opt_cfg: AdamWConfig, tcfg: TrainConfig):
    fsdp = model.fsdp_axes()
    pspecs = model.specs(mesh, fsdp)
    mom = moment_specs(model, mesh, opt_cfg, fsdp)
    out = {
        "params": pspecs,
        "opt": {"m": mom, "v": mom, "step": P()},
        "step": P(),
    }
    if tcfg.compress_grads:
        out["ef_err"] = pspecs
    return out


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_abs: dict | None = None) -> dict:
    tok_dims = 2 if cfg.audio else 1  # [B, S(, ncb)]
    out = {
        "tokens": batch_spec(mesh, tok_dims),
        "labels": batch_spec(mesh, tok_dims),
    }
    if cfg.vision:
        out["vision"] = batch_spec(mesh, 2)
    if batch_abs is not None:
        out = {k: repair_spec(out[k], batch_abs[k].shape, mesh) for k in out}
    return out


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig, seq: int | None = None, batch: int | None = None) -> dict:
    B = batch or shape.global_batch
    S = seq or shape.seq_len
    tshape = (B, S, cfg.audio.n_codebooks) if cfg.audio else (B, S)
    out = {
        "tokens": jax.ShapeDtypeStruct(tshape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(tshape, jnp.int32),
    }
    if cfg.vision:
        out["vision"] = jax.ShapeDtypeStruct(
            (B, cfg.vision.n_patches, cfg.vision.d_vision), jnp.float32
        )
    return out


def as_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
