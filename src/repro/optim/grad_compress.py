"""Error-feedback int8 gradient compression for the DP all-reduce.

Under plain pjit the DP gradient psum is inserted by the GSPMD partitioner
and cannot be intercepted, so the compressed path is an *explicit* SPMD-mapped
reduction (run the body under kernels/runtime.spmd_map): per-DP-shard
gradients are int8-quantized (block scales), summed
with jax.lax.psum on the quantized-then-dequantized values, and the
quantization residual is carried in an error-feedback buffer that is added
to the next step's gradients — the classic EF-SGD construction, which keeps
convergence within noise of the uncompressed baseline (test_optim.py).

Bandwidth: int8 codes + fp32 scale / 256 block = ~1.016 bytes/element vs 4
(fp32 grads) or 2 (bf16): a 2–4x DP all-reduce reduction.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import Q8, q8_dequantize, q8_quantize


def compress_decompress(g: jax.Array, err: jax.Array):
    """Quantize (g + err) to int8 blocks; return (dequantized, new_err)."""
    target = g.astype(jnp.float32) + err
    q = q8_quantize(target)
    deq = q8_dequantize(q)
    return deq.astype(g.dtype), target - deq


def ef_compress_tree(grads, err_tree):
    """Apply error-feedback compression leaf-wise. Returns (grads', err')."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    outs = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
    )


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, axis_name: str, err_tree):
    """SPMD-map body helper: EF-compress local grads, psum, return mean."""
    cg, err = ef_compress_tree(grads, err_tree)
    summed = jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), cg)
    return summed, err
