"""LR schedules (multiplier form: step -> factor in [0, 1])."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup: int, total: int, min_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return f


def constant():
    return lambda step: jnp.float32(1.0)


def inverse_sqrt(warmup: int):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        return jnp.minimum(step / jnp.maximum(warmup, 1), jnp.sqrt(warmup / jnp.maximum(step, 1)))

    return f
