"""AdamW from scratch, with optional int8 block-quantized moment states.

The int8 path is what lets the 1T-param kimi-k2 optimizer state fit HBM
(2 bytes/param of moments instead of 8): each moment tensor is stored as
int8 codes + one fp32 scale per 256-element block along the flattened last
axis.  Quantization error is absorbed by an error-feedback residual folded
into the next update (so long-run drift is bounded; see
tests/test_optim.py for the convergence-parity property test).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


# --------------------------------------------------------------------------- #
# int8 block quantization
# --------------------------------------------------------------------------- #


class Q8(NamedTuple):
    codes: jax.Array  # int8, original param shape
    scale: jax.Array  # fp32, shape[:-1] + (n_blocks,) — blocks along LAST axis

    @property
    def shape(self):
        return self.codes.shape


def _pad_to_block(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def q8_scale_shape(shape: tuple) -> tuple:
    """Blocks run along the last axis so the scale tensor inherits the
    param's leading dims (and therefore its sharding)."""
    if not shape:
        return (1,)
    return tuple(shape[:-1]) + (_pad_to_block(shape[-1]) // BLOCK,)


def q8_quantize(x: jax.Array, nonlinear: bool = False) -> Q8:
    """Blockwise absmax int8. ``nonlinear`` uses a quadratic code map
    (value = sign(c) * (|c|/127)^2 * absmax) — ~100x finer resolution near
    zero, required for Adam moment tensors whose within-block dynamic range
    is huge (the bitsandbytes dynamic-map insight)."""
    shape = x.shape
    if not shape:
        x = x.reshape(1)
        shape = (1,)
    n = shape[-1]
    padded = _pad_to_block(n)
    xf = x.astype(jnp.float32)
    xp = jnp.pad(xf, [(0, 0)] * (len(shape) - 1) + [(0, padded - n)])
    xb = xp.reshape(shape[:-1] + (padded // BLOCK, BLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1)  # [..., nb] absmax
    norm = xb / jnp.maximum(scale[..., None], 1e-30)  # in [-1, 1]
    if nonlinear:
        mag = jnp.sqrt(jnp.abs(norm))
    else:
        mag = jnp.abs(norm)
    codes = (jnp.sign(norm) * jnp.clip(jnp.round(127.0 * mag), 0, 127)).astype(jnp.int8)
    codes = codes.reshape(shape[:-1] + (padded,))[..., :n]
    return Q8(codes=codes.reshape(x.shape), scale=scale)


def q8_dequantize(q: Q8, nonlinear: bool = False) -> jax.Array:
    shape = q.codes.shape
    if not shape:
        shape = (1,)
    n = shape[-1]
    padded = _pad_to_block(n)
    cf = q.codes.astype(jnp.float32).reshape(shape)
    cp = jnp.pad(cf, [(0, 0)] * (len(shape) - 1) + [(0, padded - n)])
    cb = cp.reshape(shape[:-1] + (padded // BLOCK, BLOCK))
    mag = jnp.abs(cb) / 127.0
    if nonlinear:
        mag = mag * mag
    out = jnp.sign(cb) * mag * q.scale[..., None]
    return out.reshape(shape[:-1] + (padded,))[..., :n].reshape(q.codes.shape)


# --------------------------------------------------------------------------- #
# AdamW
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    int8_states: bool = False
    schedule: Optional[Any] = None  # callable step -> lr multiplier


def init_opt_state(params, cfg: AdamWConfig):
    def zeros_like_state(p):
        if cfg.int8_states:
            return Q8(
                codes=jnp.zeros(p.shape, jnp.int8),
                scale=jnp.zeros(q8_scale_shape(p.shape), jnp.float32),
            )
        return jnp.zeros(p.shape, jnp.float32)

    is_q8 = lambda x: isinstance(x, Q8)
    return {
        "m": jax.tree.map(zeros_like_state, params),
        "v": jax.tree.map(zeros_like_state, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)

    is_q8 = lambda x: isinstance(x, Q8)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = q8_dequantize(m, nonlinear=True) if isinstance(m, Q8) else m
        vf = q8_dequantize(v, nonlinear=True) if isinstance(v, Q8) else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        mh = mf / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = vf / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        m_new = q8_quantize(mf, nonlinear=True) if isinstance(m, Q8) else mf
        v_new = q8_quantize(vf, nonlinear=True) if isinstance(v, Q8) else vf
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_q8)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_q8)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.float32(lr)}
