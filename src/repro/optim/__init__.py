from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    Q8,
    adamw_update,
    global_norm,
    init_opt_state,
    q8_dequantize,
    q8_quantize,
)
from repro.optim.grad_compress import (  # noqa: F401
    compressed_psum,
    ef_compress_tree,
    init_error_buffer,
)
from repro.optim.schedule import constant, inverse_sqrt, warmup_cosine  # noqa: F401
