from repro.train.train_step import (  # noqa: F401
    TrainConfig,
    abstract_train_state,
    init_train_state,
    make_train_step,
)
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
