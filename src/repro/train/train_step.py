"""pjit train step: loss -> grad -> AdamW, with microbatch gradient
accumulation and optional error-feedback int8 gradient compression.

``make_train_step`` returns a function (state, batch) -> (state, metrics)
suitable for jax.jit with donated state.  Gradient accumulation runs as a
lax.scan over microbatches; with accumulation the DP all-reduce of
microbatch i overlaps the compute of microbatch i+1 under XLA's
latency-hiding scheduler (enabled via flags in launch/train.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.grad_compress import ef_compress_tree, init_error_buffer


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    compress_grads: bool = False


def init_train_state(model: Model, key, opt_cfg: AdamWConfig, tcfg: TrainConfig = TrainConfig()):
    params = model.init(key)
    state = {"params": params, "opt": init_opt_state(params, opt_cfg), "step": jnp.zeros((), jnp.int32)}
    if tcfg.compress_grads:
        state["ef_err"] = init_error_buffer(params)
    return state


def abstract_train_state(model: Model, opt_cfg: AdamWConfig, tcfg: TrainConfig = TrainConfig()):
    """ShapeDtypeStruct train state — dry-run path, no allocation."""
    params = model.abstract_params()

    def build():
        p = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params)
        st = {"params": p, "opt": init_opt_state(p, opt_cfg), "step": jnp.zeros((), jnp.int32)}
        if tcfg.compress_grads:
            st["ef_err"] = init_error_buffer(p)
        return st

    return jax.eval_shape(build)


def make_train_step(model: Model, opt_cfg: AdamWConfig, tcfg: TrainConfig = TrainConfig(), mesh=None):
    def loss_fn(params, batch):
        return model.loss(params, batch, mesh=mesh)

    def train_step(state, batch):
        params = state["params"]
        mb = tcfg.microbatches
        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            def slice_mb(x, i):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])[i]

            def mb_body(acc, i):
                sub = jax.tree.map(lambda x: slice_mb(x, i), batch)
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, sub)
                acc = jax.tree.map(jnp.add, acc, {"g": g, "l": l, "m": m})
                return acc, None

            zero = jax.eval_shape(lambda p, b: jax.value_and_grad(loss_fn, has_aux=True)(p, b),
                                  params, jax.tree.map(lambda x: jax.ShapeDtypeStruct((x.shape[0] // mb,) + x.shape[1:], x.dtype), batch))
            acc0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                {"g": zero[1], "l": zero[0][0], "m": zero[0][1]})
            acc, _ = jax.lax.scan(mb_body, acc0, jnp.arange(mb))
            grads = jax.tree.map(lambda x: x / mb, acc["g"])
            loss = acc["l"] / mb
            metrics = jax.tree.map(lambda x: x / mb, acc["m"])

        if tcfg.compress_grads:
            grads, new_err = ef_compress_tree(grads, state["ef_err"])

        params, opt, opt_metrics = adamw_update(params, grads, state["opt"], opt_cfg)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if tcfg.compress_grads:
            new_state["ef_err"] = new_err
        metrics = {**metrics, **opt_metrics, "total_loss": loss}
        return new_state, metrics

    return train_step
