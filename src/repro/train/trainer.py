"""The training driver: jit'd train step + data prefetch + checkpointing +
straggler monitoring + crash/restart recovery in one loop.

``Trainer.run`` is what examples/train_tiny.py and launch/train.py call; the
fault-tolerance loop (restore from the last atomic checkpoint after a
SimulatedFailure / crash) is exercised in tests/test_fault_tolerance.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, Prefetcher, make_batch
from repro.ft import FailureInjector, SimulatedFailure, StragglerMonitor
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    log_every: int = 10
    batch_override: Optional[int] = None
    seq_override: Optional[int] = None
    max_restarts: int = 3


class Trainer:
    def __init__(self, model: Model, shape, opt_cfg: AdamWConfig,
                 tcfg: TrainConfig = TrainConfig(), rcfg: TrainerConfig = TrainerConfig(),
                 dcfg: DataConfig = DataConfig(), mesh=None,
                 injector: Optional[FailureInjector] = None,
                 log_fn: Callable[[str], None] = print):
        self.model, self.shape = model, shape
        self.opt_cfg, self.tcfg, self.rcfg, self.dcfg = opt_cfg, tcfg, rcfg, dcfg
        self.mesh = mesh
        self.injector = injector
        self.log = log_fn
        self.monitor = StragglerMonitor()
        self.ckpt = Checkpointer(rcfg.ckpt_dir, keep=rcfg.ckpt_keep) if rcfg.ckpt_dir else None
        self.step_fn = jax.jit(make_train_step(model, opt_cfg, tcfg, mesh=mesh), donate_argnums=(0,))
        self.history: list[dict] = []

    # -------------------------------------------------------------- state --
    def fresh_state(self, seed: int = 0):
        return init_train_state(self.model, jax.random.PRNGKey(seed), self.opt_cfg, self.tcfg)

    def _restore_or_fresh(self):
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            like = jax.eval_shape(self.fresh_state)
            state, extra = self.ckpt.restore(None, like)
            start = int(extra.get("data_step", state["step"]))
            self.log(f"[trainer] restored checkpoint at step {start}")
            return state, start
        return self.fresh_state(), 0

    # ---------------------------------------------------------------- run --
    def run(self) -> dict:
        restarts = 0
        while True:
            try:
                return self._run_once()
            except SimulatedFailure as e:
                restarts += 1
                self.log(f"[trainer] {e}; restart {restarts}/{self.rcfg.max_restarts}")
                if restarts > self.rcfg.max_restarts:
                    raise

    def _run_once(self) -> dict:
        state, start = self._restore_or_fresh()
        r = self.rcfg
        losses = []
        t_total0 = time.time()
        for step in range(start, r.steps):
            batch = make_batch(self.model.cfg, self.shape, step, self.dcfg,
                               batch_override=r.batch_override, seq_override=r.seq_override)
            t0 = time.time()
            if self.injector is not None:
                self.injector.maybe_fail(step)  # inside the timed region:
                # a simulated slow device shows up in the step wall time
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["total_loss"])
            dt = time.time() - t0
            straggler = self.monitor.record(step, dt)
            losses.append(loss)
            self.history.append({"step": step, "loss": loss, "dt": dt, "straggler": straggler})
            if straggler:
                self.log(f"[trainer] step {step} straggler: {dt:.3f}s vs ewma {self.monitor.ewma:.3f}s")
            if r.log_every and step % r.log_every == 0:
                self.log(f"[trainer] step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)"
                         f" grad_norm {float(metrics['grad_norm']):.3f}")
            if self.ckpt is not None and (step + 1) % r.ckpt_every == 0:
                self.ckpt.save(step + 1, state, extra={"data_step": step + 1})
        if self.ckpt is not None:
            self.ckpt.save(r.steps, state, extra={"data_step": r.steps})
            self.ckpt.wait()
        return {
            "state": state,
            "losses": losses,
            "wall": time.time() - t_total0,
            "stragglers": list(self.monitor.flagged),
        }
