"""GPipe-style pipeline parallelism via an explicit SPMD map + collective_permute.

Why it exists here: §Perf hillclimb 2 concluded that 1T-class MoE training
is ZeRO-3 *weight-gather bound* — every step re-gathers 2 TB of expert
weights because they cannot reside per chip. Pipeline parallelism is the
classic fix: each stage HOLDS its layers' weights resident and only
activations cross stage boundaries.

Design (the standard JAX "pipeline as a collective matmul" construction):

  * the mesh gains a "stage" axis; layer stacks [L, ...] are sharded over it
    (L/S layers resident per stage — no weight motion, ever);
  * inside the SPMD-mapped body (runtime.spmd_map), each device runs the GPipe
    schedule over M microbatches as a fori-loop of (S + M - 1) ticks: compute
    the resident layers on the current microbatch, then ppermute the
    activations to the next stage;
  * bubbles: first (S-1) ticks of the pipe are fill; efficiency M/(M+S-1);
  * the backward pass is jax.grad THROUGH the SPMD map (ppermute transposes
    to the reverse permutation automatically), giving the 1F1B-equivalent
    traffic without hand-writing the backward schedule.

This module implements the pipeline for a stack of homogeneous layer
functions (the dense/MoE block signature used by models/transformer.py);
``pipeline_loss`` is the drop-in train-loss for a config with
pipeline_stages > 1. Validated numerically against the sequential model on
a 4-device CPU mesh in tests/test_pipeline.py.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import runtime


def gpipe(
    layer_fn: Callable,  # (layer_params, x) -> x
    n_stages: int,
    n_microbatches: int,
    stage_axis: str = "stage",
):
    """Build a pipelined apply: (stacked_params [L,...], x [M*mb, ...]) -> y.

    Returned fn must run INSIDE runtime.spmd_map with ``stacked_params`` sharded
    P(stage_axis, ...) on the layer dim and ``x`` replicated per stage
    (microbatches enter at stage 0).
    """

    def apply(params_local, x):  # params_local: [L/S, ...]; x: [M, mb, ...]
        stage = jax.lax.axis_index(stage_axis)
        M = x.shape[0]
        ticks = n_stages + M - 1
        mb_shape = x.shape[1:]

        def run_stage(carry_in):
            # apply this stage's resident layers sequentially
            def body(h, lp):
                return layer_fn(lp, h), None

            out, _ = jax.lax.scan(body, carry_in, params_local)
            return out

        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(t, state):
            buf, outs = state
            # stage 0 ingests microbatch t (if any); others use the ppermuted
            # activation from the previous tick
            mb_idx = jnp.clip(t, 0, M - 1)
            inject = jax.lax.dynamic_index_in_dim(x, mb_idx, 0, keepdims=False)
            h_in = jnp.where(stage == 0, inject, buf)
            h_out = run_stage(h_in)
            # the LAST stage emits a finished microbatch at ticks >= S-1
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            emit = (t >= n_stages - 1) & (stage == n_stages - 1)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, h_out, out_idx, 0),
                lambda o: o,
                outs,
            )
            # rotate activations to the next stage
            buf = jax.lax.ppermute(h_out, stage_axis, perm)
            return buf, outs

        buf0 = jnp.zeros(mb_shape, x.dtype)
        outs0 = jnp.zeros((M,) + mb_shape, x.dtype)
        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf0, outs0))
        # every stage holds `outs`; only the last stage's copy is real. Make
        # it consistent everywhere (cheap: one broadcast from last stage).
        outs = jax.lax.ppermute(
            outs, stage_axis, [((n_stages - 1 + i) % n_stages, i) for i in range(n_stages)]
        ) if n_stages > 1 else outs
        # after rotation by (S-1), stage 0 holds the real outs; rebroadcast
        outs = jax.lax.all_gather(outs, stage_axis, axis=0, tiled=False)[0]
        return outs

    return apply


def pipeline_apply(
    mesh: Mesh,
    layer_fn: Callable,
    stacked_params,  # [L, ...] pytree
    x,  # [B, ...] activations
    *,
    n_microbatches: int,
    stage_axis: str = "stage",
):
    """SPMD-map wrapper: shards layers over the stage axis, microbatches the
    batch dim, runs the GPipe schedule, returns [B, ...]."""
    n_stages = mesh.shape[stage_axis]
    B = x.shape[0]
    assert B % n_microbatches == 0, (B, n_microbatches)
    mb = B // n_microbatches
    xm = x.reshape((n_microbatches, mb) + x.shape[1:])

    apply = gpipe(layer_fn, n_stages, n_microbatches, stage_axis)

    fn = runtime.spmd_map(
        apply,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),  # layers sharded; microbatches replicated
        out_specs=P(),
        check=False,
    )
    y = fn(stacked_params, xm)
    return y.reshape((B,) + x.shape[1:])
