"""The DRAGON front door: one typed façade over DGen, DSim and DOpt.

The suite's engines are free functions over raw pytrees — right for
composing JAX programs, wrong as a public surface: every caller re-implements
the same specialize → stack → simulate → optimize plumbing and pays compile
time on every query.  This module is the served API instead:

    from repro import Session, Architecture, Workload

    sess = Session(Architecture("edge"))            # .dhd text, library name,
    rep = sess.simulate(Workload("bert_base"))      #   or raw pytrees
    print(rep)                                      # explainable SimReport
    opt = sess.optimize("bert_base", objective="edp", steps=40)
    front = sess.frontier(["lstm", "bert_base"], population=12)

Three types:

  * :class:`Workload` — a validated workload set.  Wraps one Graph, a list,
    or workload names; stacks them (``Graph.stack``) with the vertex axis
    padded to a shape *bucket* (next power of two, min 32) so different
    workload sets of similar size land on the same compiled program.
    Padding is exact — the mapper prices no-op vertices at zero.
  * :class:`Architecture` — a validated design point: ``.dhd`` text, a
    library name, a ``CompiledArch``, or raw ``(tech, arch, spec)`` pytrees
    — one constructor, ``CompiledArch`` underneath, ``to_dhd()`` back out.
  * :class:`Session` — owns the compiled-program cache and routes
    ``simulate()`` / ``optimize()`` / ``frontier()`` / ``explain()`` to the
    dsim / dopt / popsim / pareto engines, returning the frozen result
    objects from :mod:`repro.core.report`.

Cache-key semantics (the serving contract)
------------------------------------------

Programs are keyed by ``(kind, ArchSpec, MapperCfg, shape bucket,
objective signature)``:

  * **ArchSpec / MapperCfg** are static configuration — they change the
    traced program, so they key it;
  * **shape bucket** is ``(n_workloads, padded_vertex_count)`` from
    :attr:`Workload.bucket` — any workload set in the same bucket replays
    the same executable;
  * **objective signature** is the objective *name* only.  Objective
    weights, budgets and penalty weights are *traced* arguments (PR 4), so
    a changed mix reuses the program; technology/architecture parameter
    values are traced too, so a changed design point never retraces.

Repeated calls — the serving pattern — therefore never retrace and never
recompile; :attr:`Session.stats` reports programs/hits/misses/traces, and
the trace counts are asserted (not assumed) via
:mod:`repro.core.instrument`.

The same keys address the *persistent* executable cache:
``Session(cache_dir=...)`` loads serialized executables written by
:meth:`Session.preheat` (AOT ``jax.jit(...).lower().compile()``), so a
restarted process answers its first query with zero traces and replies
bit-identical to a fresh compile — see :mod:`repro.serving.aotcache` for
the digest/versioning/quarantine story and ``docs/api.md`` for the
operator view.

The engine layer (``repro.core.simulate`` / ``optimize`` / ``pareto_dse``
...) keeps working as-is for one more release: it is the numerical oracle
the façade is tested identical against.  New code — and everything under
``examples/``, ``benchmarks/``, ``tools/`` (lint-enforced by
``tools/check_api_surface.py``) — should use the façade.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import threading
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dgen as _dgen
from repro.core import dopt as _dopt
from repro.core import instrument
from repro.core import popsim as _popsim
from repro.core.dhdl import CompiledArch, load_arch, parse_arch, serialize_arch
from repro.core.dopt import from_log, tech_param_names, to_log
from repro.core.dsim import (
    PARETO_METRICS,
    PerfEstimate,
    simulate_breakdown,
    simulate_stacked,
    stacked_log_objective,
)
from repro.core.graph import Graph
from repro.core.mapper import MapperCfg
from repro.core.params import COMP_CLS, MEM_CLS, ArchParams, ArchSpec, TechParams
from repro.core.report import (
    Attribution,
    ComputeClassReport,
    FrontierPoint,
    FrontierResult,
    MemoryLevelReport,
    OptResult,
    SimReport,
    VertexReport,
    WorkloadReport,
)
from repro.workloads import get_workload

__all__ = [
    "Workload",
    "Architecture",
    "Session",
    "CacheStats",
    # result objects (re-exported from core.report)
    "SimReport",
    "OptResult",
    "FrontierResult",
    "Attribution",
    # engine types call sites legitimately need alongside the façade
    "Graph",
    "MapperCfg",
    "ArchParams",
    "ArchSpec",
    "TechParams",
    "PerfEstimate",
    "PARETO_METRICS",
    "get_workload",
]

_MIN_BUCKET = 32  # below this the mapper's auto dispatch flips impls; also
# keeps tiny-workload buckets from fragmenting the program cache

_MIN_REQUEST_BUCKET = 2  # batched dispatches pad the request axis to pow2;
# below 2 the sequential program is already the right shape


def _bucket_vertices(v: int) -> int:
    """Vertex-axis bucket: next power of two, at least ``_MIN_BUCKET``."""
    return max(_MIN_BUCKET, 1 << (max(v, 1) - 1).bit_length())


def _bucket_requests(n: int) -> int:
    """Request-axis bucket for batched dispatches: next power of two, at
    least ``_MIN_REQUEST_BUCKET`` — same convention as the vertex axis, so
    warm batches of similar size replay one compiled program."""
    return max(_MIN_REQUEST_BUCKET, 1 << (max(n, 1) - 1).bit_length())


def _dhd_ident(name: str) -> str:
    """Sanitize a display name into a ``.dhd`` identifier, so every
    Architecture serializes to parseable text."""
    import re

    ident = re.sub(r"[^A-Za-z0-9_]", "_", name) or "anonymous"
    return ident if ident[0].isalpha() or ident[0] == "_" else f"_{ident}"


def _check_finite_positive(tree, what: str) -> None:
    for leaf in jax.tree.leaves(tree):
        a = np.asarray(leaf)
        if not np.all(np.isfinite(a)):
            raise ValueError(f"{what} contains non-finite values")
        if np.any(a <= 0):
            raise ValueError(f"{what} contains non-positive values (parameters are positive)")


# --------------------------------------------------------------------------- #
# Workload
# --------------------------------------------------------------------------- #


class Workload:
    """A validated, shape-bucketed workload set.

    ``source`` may be a workload name (resolved via
    ``repro.workloads.get_workload``), a :class:`Graph`, another
    ``Workload``, or a list mixing names and Graphs.  The set stacks into
    one ``[W, V_bucket, ...]`` Graph (:attr:`stacked`) with vertex padding
    to the shape bucket and the static per-vertex names stripped, so any
    same-bucket set is *structurally identical* to jit — that is what lets
    a :class:`Session` serve different workloads from one compiled program.

    Construct once and reuse in hot loops: stacking is host work.
    """

    def __init__(self, source, *, labels: tuple[str, ...] | None = None):
        graphs, auto_labels = self._resolve(source)
        if not graphs:
            raise ValueError("Workload needs at least one graph")
        for lbl, g in zip(auto_labels, graphs):
            if not isinstance(g, Graph):
                raise TypeError(f"workload {lbl!r} is not a Graph (got {type(g).__name__})")
            if g.n_vertices < 1:
                raise ValueError(f"workload {lbl!r} has no vertices")
            if g.n_comp.ndim != 2:
                raise ValueError(
                    f"workload {lbl!r} is already stacked ([W,V,...]); pass its member graphs"
                )
            for field in ("n_comp", "n_read", "n_write", "n_alloc"):
                a = np.asarray(getattr(g, field))
                if not np.all(np.isfinite(a)) or np.any(a < 0):
                    raise ValueError(f"workload {lbl!r}.{field} must be finite and >= 0")
        self.graphs: tuple[Graph, ...] = tuple(graphs)
        self.labels: tuple[str, ...] = tuple(labels) if labels is not None else tuple(auto_labels)
        if len(self.labels) != len(self.graphs):
            raise ValueError(f"{len(self.labels)} labels for {len(self.graphs)} graphs")
        vmax = max(g.n_vertices for g in self.graphs)
        self._bucket = (len(self.graphs), _bucket_vertices(vmax))
        self._stacked: Graph | None = None

    @staticmethod
    def _resolve(source) -> tuple[list[Graph], list[str]]:
        if isinstance(source, Workload):
            return list(source.graphs), list(source.labels)
        if isinstance(source, (str, Graph)):
            source = [source]
        graphs, labels = [], []
        for i, item in enumerate(source):
            if isinstance(item, str):
                graphs.append(get_workload(item))
                labels.append(item)
            elif isinstance(item, Graph):
                graphs.append(item)
                labels.append(f"workload{i}")
            else:
                raise TypeError(f"cannot build a Workload from {type(item).__name__}")
        return graphs, labels

    @property
    def bucket(self) -> tuple[int, int]:
        """``(n_workloads, padded_vertex_count)`` — the cache-key shape."""
        return self._bucket

    @property
    def n_workloads(self) -> int:
        return len(self.graphs)

    @property
    def stacked(self) -> Graph:
        """The bucket-padded ``[W, V_bucket, ...]`` stack, names stripped."""
        if self._stacked is None:
            _, vb = self._bucket
            gs = Graph.stack([g.pad_to(vb) for g in self.graphs])
            self._stacked = dataclasses.replace(gs, names=())
        return self._stacked

    def __repr__(self) -> str:
        w, v = self._bucket
        return f"Workload({list(self.labels)!r}, bucket=[{w}, {v}])"


# --------------------------------------------------------------------------- #
# Architecture
# --------------------------------------------------------------------------- #


class Architecture:
    """A validated design point — one constructor for every spelling.

    ``Architecture("edge")`` loads the named ``.dhd`` library design;
    ``Architecture("arch mine inherits edge { ... }")`` parses text (any
    source containing ``{`` is treated as text); ``Architecture(ca)`` wraps
    an existing :class:`CompiledArch`; ``Architecture(tech=..., arch=...,
    spec=...)`` builds one from raw pytrees (defaults fill the gaps).
    ``to_dhd()`` serializes back to canonical text — the suite's
    interchange format (parse → serialize → parse is the identity).  Names
    are sanitized to ``.dhd`` identifiers (``[A-Za-z_][A-Za-z0-9_]*``) so
    every Architecture's text form is guaranteed parseable.
    """

    def __init__(
        self,
        source: "str | CompiledArch | Architecture | None" = None,
        *,
        tech: TechParams | None = None,
        arch: ArchParams | None = None,
        spec: ArchSpec | None = None,
        name: str | None = None,
    ):
        if isinstance(source, Architecture):
            ca = source._ca
        elif isinstance(source, CompiledArch):
            ca = source
        elif isinstance(source, str):
            ca = parse_arch(source) if "{" in source else load_arch(source)
        elif source is None:
            ca = CompiledArch(
                name=name or "custom",
                spec=spec if spec is not None else ArchSpec(),
                arch=arch if arch is not None else ArchParams.default(),
                tech=tech if tech is not None else TechParams.default(),
            )
        else:
            raise TypeError(f"cannot build an Architecture from {type(source).__name__}")
        if source is not None and (tech is not None or arch is not None or spec is not None):
            ca = CompiledArch(
                name=name or ca.name,
                spec=spec if spec is not None else ca.spec,
                arch=arch if arch is not None else ca.arch,
                tech=tech if tech is not None else ca.tech,
            )
        elif name is not None and name != ca.name:
            ca = CompiledArch(name=name, spec=ca.spec, arch=ca.arch, tech=ca.tech)
        ident = _dhd_ident(ca.name)
        if ident != ca.name:
            ca = CompiledArch(name=ident, spec=ca.spec, arch=ca.arch, tech=ca.tech)
        _check_finite_positive(ca.tech, f"Architecture {ca.name!r} tech params")
        _check_finite_positive(ca.arch, f"Architecture {ca.name!r} arch params")
        self._ca = ca

    @property
    def name(self) -> str:
        return self._ca.name

    @property
    def spec(self) -> ArchSpec:
        return self._ca.spec

    @property
    def arch(self) -> ArchParams:
        return self._ca.arch

    @property
    def tech(self) -> TechParams:
        return self._ca.tech

    @property
    def compiled(self) -> CompiledArch:
        return self._ca

    def to_dhd(self) -> str:
        """Canonical ``.dhd`` text of this design (round-trips bit-exactly)."""
        return serialize_arch(name=self.name, spec=self.spec, arch=self.arch, tech=self.tech)

    def peaks(self) -> dict:
        """Machine peaks of this design point — the roofline axes.

        Evaluates the hardware model (DGen ``specialize``) and returns
        ``peak_flops`` (FLOP/s summed over enabled compute classes at the
        timing-feasible clock), ``mem_bw`` (bytes/s per memory level, keyed
        by :data:`MEM_CLS` name) and ``frequency`` (Hz).  Host floats — this
        is reporting surface, not a traced program.
        """
        chw = _dgen.specialize(self.tech, self.arch, self.spec)
        freq = float(np.asarray(chw.frequency))
        bw = np.asarray(chw.mem_bw)
        return {
            "peak_flops": float(np.sum(np.asarray(chw.flops_per_cycle))) * freq,
            "mem_bw": {lvl: float(bw[i]) for i, lvl in enumerate(MEM_CLS)},
            "frequency": freq,
        }

    def __repr__(self) -> str:
        return f"Architecture({self.name!r})"


# --------------------------------------------------------------------------- #
# Session
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class CacheStats:
    """Program-cache bookkeeping: ``traces`` counts actual compilations of
    this session's programs (via the trace-side-effect probe); ``hits`` /
    ``misses`` count cache-key lookups."""

    programs: int
    hits: int
    misses: int
    traces: int


_ARCH_PARAM_NAMES: list[str] | None = None


def _arch_param_names() -> list[str]:
    # memoized: building ArchParams.default() materializes device arrays,
    # ~15 of them — at ~1 ms a pop that was most of a warm explain() call
    global _ARCH_PARAM_NAMES
    if _ARCH_PARAM_NAMES is None:
        default = ArchParams.default()
        names = []
        for f in dataclasses.fields(ArchParams):
            n = np.asarray(getattr(default, f.name)).size
            if n == 1:
                names.append(f.name)
            else:
                names.extend(f"{cls}.{f.name}" for cls in MEM_CLS[:n])
        _ARCH_PARAM_NAMES = names
    return _ARCH_PARAM_NAMES


def _flatten(tree) -> np.ndarray:
    return np.concatenate([np.atleast_1d(np.asarray(x)) for x in jax.tree.leaves(tree)])


class Session:
    """The suite front door: simulate / optimize / frontier / explain
    against one architecture, with compiled programs cached across calls.

    ``architecture`` accepts anything :class:`Architecture` accepts (and
    defaults to the library ``base`` design); per-call ``architecture=``
    overrides never invalidate the cache — parameter values are traced
    arguments, only a changed :class:`ArchSpec` keys a new program.

    ``programs`` shares a compiled-program cache between sessions: pass
    another session's :attr:`programs` (or a plain dict) and every program
    one session compiles is warm for the others — the multi-tenant serving
    arrangement, where N tenants must not mean N copies of every
    executable.  Hit/miss/trace *stats* stay per-session (a shared program
    counts as a hit for the session that finds it and traces only under
    the session that built it).

    ``cache_dir`` makes the cache *persistent*: executables built by
    :meth:`preheat` are serialized to disk
    (:class:`repro.serving.aotcache.AotCache`), and construction loads
    every entry matching this runtime back into :attr:`programs` — a
    restarted process serves its first query with zero traces
    (:attr:`disk_loaded` reports how many programs arrived that way).
    """

    _ids = itertools.count()

    def __init__(self, architecture="base", *, mcfg: MapperCfg = MapperCfg(),
                 programs: dict | None = None, cache_dir=None):
        self.architecture = Architecture(architecture)
        self.mcfg = mcfg
        self._tag = f"api.session{next(Session._ids)}"
        # key -> compiled callable; shared across sessions when passed in
        self._programs: dict = programs if programs is not None else {}
        self._engine_keys: set = set()  # engine-routed configs seen (bookkeeping)
        self._hits = 0
        self._misses = 0
        self._workload_memo: dict[str, Workload] = {}
        self._arch_memo: dict[str, Architecture] = {}
        # the pooled serving tier dispatches chunks from worker threads that
        # share one session; cache lookups and build bookkeeping stay atomic
        self._plock = threading.RLock()
        self._aot = None
        self.disk_loaded = 0  # programs rehydrated from cache_dir at construction
        if cache_dir is not None:
            # deferred: the serving package (and its fault taxonomy) only
            # loads for sessions that opt into persistence
            from repro.serving.aotcache import AotCache

            self._aot = AotCache(cache_dir)
            for key, fn in self._aot.load_all().items():
                if key not in self._programs:
                    self._programs[key] = fn
                    self.disk_loaded += 1

    @property
    def programs(self) -> dict:
        """The compiled-program cache — pass to another ``Session`` to share."""
        return self._programs

    # ------------------------------------------------------------- helpers --
    def _arch(self, architecture) -> Architecture:
        if architecture is None:
            return self.architecture
        if isinstance(architecture, Architecture):
            return architecture
        if isinstance(architecture, str):
            # memoized like workloads: re-parsing a .dhd and materializing
            # its params costs ~ms — far more than a warm dispatch
            a = self._arch_memo.get(architecture)
            if a is None:
                a = self._arch_memo[architecture] = Architecture(architecture)
            return a
        return Architecture(architecture)

    def _workload(self, workload) -> Workload:
        if isinstance(workload, Workload):
            return workload
        if isinstance(workload, str):
            if workload not in self._workload_memo:
                self._workload_memo[workload] = Workload(workload)
            return self._workload_memo[workload]
        return Workload(workload)

    def _program(self, key: tuple, build):
        """The compiled-program cache: ``key`` -> jitted callable.

        Misses consult the persistent cache first (an entry another worker
        preheated after this session started is still a disk hit); only a
        full miss pays ``build()`` — a jit wrapper that traces on first
        call.  Thread-safe: concurrent pool workers racing the same key get
        one build and consistent hit/miss counts.
        """
        with self._plock:
            fn = self._programs.get(key)
            if fn is None and self._aot is not None:
                fn = self._aot.get(key)
                if fn is not None:
                    self._programs[key] = fn
            if fn is None:
                self._misses += 1
                fn = self._programs[key] = build()
            else:
                self._hits += 1
            return fn

    def _engine_call(self, key: tuple) -> None:
        """Bookkeeping for calls whose program lives in the *engine's* jit
        cache (optimize/frontier): hit/miss counts key recurrence; their
        retraces show up in the engine's global probe tags
        (``dopt._dopt_step`` / ``popsim._member_step``), not in
        ``stats.traces``."""
        if key in self._engine_keys:
            self._hits += 1
        else:
            self._misses += 1
            self._engine_keys.add(key)

    @property
    def stats(self) -> CacheStats:
        # trailing "." so session1 never sums session10's counters
        return CacheStats(
            programs=len(self._programs),
            hits=self._hits,
            misses=self._misses,
            traces=instrument.trace_count(prefix=f"{self._tag}."),
        )

    # ------------------------------------------------------------ programs --
    # Each served program kind is declared as a *spec* — ``(cache key,
    # build)`` where ``build()`` returns the jit wrapper — so the lazy
    # first-call path (``_program``) and the AOT path (``preheat``, which
    # wants ``build().lower(...).compile()`` instead) share one definition.

    def _perf_spec(self, bucket, spec: ArchSpec, mcfg: MapperCfg):
        """jit(simulate_stacked) — byte-identical to the engine call it wraps."""
        tag = f"{self._tag}.simulate"

        def build():
            def fn(tech, arch, gstack):
                instrument.count_trace(tag)
                return simulate_stacked(tech, arch, gstack, spec, mcfg)

            return jax.jit(fn)

        return ("simulate", spec, mcfg, bucket), build

    def _perf_program(self, bucket, spec: ArchSpec, mcfg: MapperCfg):
        return self._program(*self._perf_spec(bucket, spec, mcfg))

    def _report_spec(self, bucket, spec: ArchSpec, mcfg: MapperCfg):
        """One program for the whole report: batched PerfEstimate + the
        per-vertex / per-level breakdown extras (simulate_breakdown computes
        both in one pass, so reports cost one compile and one dispatch)."""
        tag = f"{self._tag}.report"

        def build():
            def fn(tech, arch, gstack):
                instrument.count_trace(tag)
                return jax.vmap(
                    lambda g: simulate_breakdown(tech, arch, g, spec, mcfg)
                )(gstack)

            return jax.jit(fn)

        return ("report", spec, mcfg, bucket), build

    def _report_program(self, bucket, spec: ArchSpec, mcfg: MapperCfg):
        return self._program(*self._report_spec(bucket, spec, mcfg))

    def _explain_spec(self, bucket, spec: ArchSpec, mcfg: MapperCfg, objective: str):
        """Elasticities d log(objective) / d log(param) for tech AND arch."""
        tag = f"{self._tag}.explain"

        def build():
            def fn(tech, arch, gstack):
                instrument.count_trace(tag)

                def loss(tz, az):
                    val, _ = stacked_log_objective(
                        from_log(tz), from_log(az), gstack, objective, spec=spec, mcfg=mcfg
                    )
                    return val

                return jax.grad(loss, argnums=(0, 1))(to_log(tech), to_log(arch))

            return jax.jit(fn)

        return ("explain", spec, mcfg, bucket, objective), build

    def _explain_program(self, bucket, spec: ArchSpec, mcfg: MapperCfg, objective: str):
        return self._program(*self._explain_spec(bucket, spec, mcfg, objective))

    # ----------------------------------------------------- batched programs --
    def _batched_report_spec(self, nb: int, bucket, spec: ArchSpec, mcfg: MapperCfg):
        """The report program with a leading *request* axis: one dispatch
        answers ``nb`` same-bucket queries, each with its own (tech, arch,
        gstack).  Keyed by the request bucket too, so warm batches of
        similar size never retrace."""
        tag = f"{self._tag}.report_batched"

        def build():
            def one(tech, arch, gstack):
                return jax.vmap(
                    lambda g: simulate_breakdown(tech, arch, g, spec, mcfg)
                )(gstack)

            def fn(techs, archs, gstacks):
                instrument.count_trace(tag)
                return jax.vmap(one)(techs, archs, gstacks)

            return jax.jit(fn)

        return ("report_batched", spec, mcfg, bucket, nb), build

    def _batched_report_program(self, nb: int, bucket, spec: ArchSpec, mcfg: MapperCfg):
        return self._program(*self._batched_report_spec(nb, bucket, spec, mcfg))

    def _batched_explain_spec(
        self, nb: int, bucket, spec: ArchSpec, mcfg: MapperCfg, objective: str
    ):
        """Elasticities with a leading request axis (vmapped grad)."""
        tag = f"{self._tag}.explain_batched"

        def build():
            def one(tech, arch, gstack):
                def loss(tz, az):
                    val, _ = stacked_log_objective(
                        from_log(tz), from_log(az), gstack, objective, spec=spec, mcfg=mcfg
                    )
                    return val

                return jax.grad(loss, argnums=(0, 1))(to_log(tech), to_log(arch))

            def fn(techs, archs, gstacks):
                instrument.count_trace(tag)
                return jax.vmap(one)(techs, archs, gstacks)

            return jax.jit(fn)

        return ("explain_batched", spec, mcfg, bucket, objective, nb), build

    def _batched_explain_program(
        self, nb: int, bucket, spec: ArchSpec, mcfg: MapperCfg, objective: str
    ):
        return self._program(
            *self._batched_explain_spec(nb, bucket, spec, mcfg, objective)
        )

    # ------------------------------------------------------------- preheat --
    def _bucket_stack(self, item) -> tuple[tuple[int, int], Graph]:
        """Resolve a preheat target into ``(bucket, example stack)``.

        Accepts anything :class:`Workload` accepts *or* a bare
        ``(n_workloads, vertex_count)`` bucket tuple, for which a zero-filled
        stack of that shape is synthesized — compilation depends on array
        shapes/dtypes only, so the dummy program serves real same-bucket
        workloads bit-identically.
        """
        if (
            isinstance(item, tuple)
            and len(item) == 2
            and all(isinstance(x, (int, np.integer)) for x in item)
        ):
            w, vb = int(item[0]), _bucket_vertices(int(item[1]))
            stack = Graph(
                n_comp=jnp.zeros((w, vb, len(COMP_CLS)), jnp.float32),
                n_read=jnp.zeros((w, vb, len(MEM_CLS)), jnp.float32),
                n_write=jnp.zeros((w, vb, len(MEM_CLS)), jnp.float32),
                n_alloc=jnp.zeros((w, vb, len(MEM_CLS)), jnp.float32),
                dims=jnp.zeros((w, vb, 3), jnp.float32),
                op_kind=jnp.zeros((w, vb), jnp.int32),
                edges=jnp.zeros((w, 0, 2), jnp.int32),
                names=(),
            )
            return (w, vb), stack
        wl = self._workload(item)
        return wl.bucket, wl.stacked

    def _preheat_one(self, key, build, args) -> tuple[bool, bool]:
        """Ensure one program is compiled (AOT) and persisted.

        Returns ``(built, persisted)``.  An existing in-memory or on-disk
        program is reused; otherwise the program is built ahead of time via
        ``build().lower(*args).compile()`` — the same trace a first call
        would pay, paid now, yielding a serializable executable.
        """
        fn = self._programs.get(key)
        if fn is None and self._aot is not None:
            fn = self._aot.get(key)
            if fn is not None:
                self._programs[key] = fn
        built = False
        if fn is None:
            self._misses += 1
            fn = self._programs[key] = build().lower(*args).compile()
            built = True
        else:
            self._hits += 1
        persisted = False
        if self._aot is not None and not self._aot.has(key):
            target = fn
            if not isinstance(fn, jax.stages.Compiled):
                # snapshot path: the program was first compiled lazily (a
                # jit wrapper, not serializable) — AOT-compile an equivalent
                # executable for the disk entry; the in-memory one stays
                target = build().lower(*args).compile()
            persisted = self._aot.put(key, target)
        return built, persisted

    def preheat(
        self,
        workloads,
        *,
        objectives: tuple[str, ...] = ("edp",),
        kinds: tuple[str, ...] = ("simulate", "explain"),
        request_buckets: tuple[int, ...] = (),
        architecture=None,
    ) -> dict:
        """Compile the declared working set ahead of time — no first-call
        trace latency, and (with ``cache_dir``) no recompiles after restart.

        ``workloads`` is one item or a list: anything :meth:`simulate`
        accepts, or bare ``(n_workloads, vertex_count)`` bucket tuples when
        the real graphs don't exist yet (shapes are all compilation needs).
        ``kinds`` selects program families — ``"simulate"`` (the report
        program behind :meth:`simulate`), ``"explain"`` (adds the gradient
        program per objective), ``"perf"`` (the raw :meth:`perf` program).
        ``request_buckets`` additionally builds the batched-dispatch
        variants at those pinned request axes (pass the serving layer's
        ``request_bucket`` — ``DesignService.warmup`` does).

        Programs land in :attr:`programs` as AOT executables and, when the
        session has a ``cache_dir``, are serialized to disk.  Returns a
        summary dict: ``programs`` touched, ``built`` (compiled now),
        ``reused`` (already warm), ``persisted`` (new disk entries),
        ``seconds``.
        """
        a = self._arch(architecture)
        spec, mcfg = a.spec, self.mcfg
        if isinstance(workloads, (str, Graph, Workload)) or (
            isinstance(workloads, tuple)
            and len(workloads) == 2
            and all(isinstance(x, (int, np.integer)) for x in workloads)
        ):
            workloads = [workloads]
        kinds = tuple(kinds)
        unknown = set(kinds) - {"perf", "simulate", "explain"}
        if unknown:
            raise ValueError(
                f"preheat kinds {sorted(unknown)} not in ('perf', 'simulate', 'explain')"
            )
        t0 = time.perf_counter()
        built = reused = persisted = 0
        seen: set = set()
        for item in workloads:
            bucket, gstack = self._bucket_stack(item)
            if bucket in seen:
                continue
            seen.add(bucket)
            args = (a.tech, a.arch, gstack)
            jobs = []
            if "perf" in kinds:
                jobs.append((self._perf_spec(bucket, spec, mcfg), args))
            if "simulate" in kinds or "explain" in kinds:
                jobs.append((self._report_spec(bucket, spec, mcfg), args))
            if "explain" in kinds:
                for obj in objectives:
                    jobs.append((self._explain_spec(bucket, spec, mcfg, obj), args))
            for nb in request_buckets:
                nb = int(nb)
                bargs = jax.tree.map(lambda x: jnp.stack([x] * nb), args)
                if "simulate" in kinds or "explain" in kinds:
                    jobs.append((self._batched_report_spec(nb, bucket, spec, mcfg), bargs))
                if "explain" in kinds:
                    for obj in objectives:
                        jobs.append(
                            (self._batched_explain_spec(nb, bucket, spec, mcfg, obj), bargs)
                        )
            for (key, build), eargs in jobs:
                was_built, was_persisted = self._preheat_one(key, build, eargs)
                built += was_built
                reused += not was_built
                persisted += was_persisted
        return dict(
            programs=built + reused,
            built=built,
            reused=reused,
            persisted=persisted,
            seconds=round(time.perf_counter() - t0, 3),
        )

    def _assemble_batch(self, workloads, architectures, request_bucket=None):
        """Validate + stack a request batch: every item must share the
        session's spec and one shape bucket (that is what makes the stacks
        structurally identical under one program).  Returns
        ``(ws, archs, nb, stacked-pytrees)`` with the request axis padded to
        the pow2 bucket by repeating lane 0 (padding lanes are computed and
        discarded — same convention as vertex padding, minus the zero
        pricing, because discarding is exact).

        ``request_bucket`` pins the padded request axis instead of the
        auto pow2 bucket.  XLA specializes reduction order to array shape,
        so two *different* request buckets can differ in the last ulp;
        serving pins one bucket across sequential and coalesced dispatches
        precisely so replies are bit-identical however queries were
        batched."""
        ws = [self._workload(w) for w in workloads]
        if not ws:
            raise ValueError("batched call needs at least one workload")
        if architectures is None:
            archs = [self.architecture] * len(ws)
        else:
            archs = [self._arch(a) for a in architectures]
        if len(archs) != len(ws):
            raise ValueError(f"{len(archs)} architectures for {len(ws)} workloads")
        bucket, spec = ws[0].bucket, archs[0].spec
        for w in ws[1:]:
            if w.bucket != bucket:
                raise ValueError(
                    f"batched call mixes shape buckets {bucket} and {w.bucket}; "
                    "coalesce same-bucket queries only"
                )
        for a in archs[1:]:
            if a.spec != spec:
                raise ValueError("batched call mixes ArchSpecs; split by spec")
        if request_bucket is None:
            nb = _bucket_requests(len(ws))
        else:
            nb = int(request_bucket)
            if nb < len(ws):
                raise ValueError(
                    f"request_bucket={nb} smaller than the batch ({len(ws)} queries)"
                )
        pad = nb - len(ws)
        techs = jax.tree.map(
            lambda *xs: jnp.stack(xs + (xs[0],) * pad), *[a.tech for a in archs]
        )
        arch_ps = jax.tree.map(
            lambda *xs: jnp.stack(xs + (xs[0],) * pad), *[a.arch for a in archs]
        )
        gstacks = jax.tree.map(
            lambda *xs: jnp.stack(xs + (xs[0],) * pad), *[w.stacked for w in ws]
        )
        return ws, archs, nb, (techs, arch_ps, gstacks)

    def simulate_batch(
        self, workloads, *, architectures=None, request_bucket=None
    ) -> list[SimReport]:
        """Answer N same-bucket simulate queries in ONE vmapped dispatch.

        ``workloads`` is a list of anything :meth:`simulate` accepts;
        ``architectures`` (optional, same length) gives each request its own
        design point.  Every workload must share one shape bucket and every
        architecture the session's ``ArchSpec``.  Reports are bit-identical
        across batch compositions at one ``request_bucket`` — pinned by
        test — the batch only amortizes dispatch overhead across requests.
        """
        ws, archs, nb, stacked = self._assemble_batch(
            workloads, architectures, request_bucket
        )
        return self._simulate_batch_assembled(ws, archs, nb, stacked)

    def _simulate_batch_assembled(self, ws, archs, nb, stacked) -> list[SimReport]:
        techs, arch_ps, gstacks = stacked
        prog = self._batched_report_program(nb, ws[0].bucket, archs[0].spec, self.mcfg)
        perfs, extras = prog(techs, arch_ps, gstacks)
        return self._reports_from_batch(ws, archs, perfs, extras)

    def _reports_from_batch(self, ws, archs, perfs, extras) -> list[SimReport]:
        """Finish a batched report dispatch: slice the ``[nb]``-leading
        program outputs back into per-lane :class:`SimReport`\\ s.  Shared by
        :meth:`simulate_batch` and the serving pool's staging-buffer
        dispatcher, so both paths build reports from identical bits."""
        # one device->host sync for the whole batch, then numpy views per lane
        perfs = jax.tree.map(np.asarray, perfs)
        extras = {k: np.asarray(v) for k, v in extras.items()}
        return [
            self._build_report(
                archs[i],
                ws[i],
                jax.tree.map(lambda x: x[i], perfs),
                {k: v[i] for k, v in extras.items()},
            )
            for i in range(len(ws))
        ]

    def explain_batch(
        self, workloads, *, objective: str = "edp", architectures=None,
        request_bucket=None,
    ) -> list[SimReport]:
        """Batched :meth:`explain`: one vmapped report dispatch + one
        vmapped gradient dispatch answer N same-bucket explain queries.
        Reports (attribution included) are bit-identical across batch
        compositions at one ``request_bucket``."""
        ws, archs, nb, stacked = self._assemble_batch(
            workloads, architectures, request_bucket
        )
        techs, arch_ps, gstacks = stacked
        reports = self._simulate_batch_assembled(ws, archs, nb, stacked)
        prog = self._batched_explain_program(
            nb, ws[0].bucket, archs[0].spec, self.mcfg, objective
        )
        g_techs, g_archs = prog(techs, arch_ps, gstacks)
        return self._attribute_batch(reports, g_techs, g_archs, objective)

    def _attribute_batch(self, reports, g_techs, g_archs, objective) -> list[SimReport]:
        """Finish a batched explain dispatch: rank the ``[nb]``-leading
        gradient outputs into per-lane attributions.  Shared by
        :meth:`explain_batch` and the serving pool's staging-buffer
        dispatcher."""
        g_techs = jax.tree.map(np.asarray, g_techs)
        g_archs = jax.tree.map(np.asarray, g_archs)
        names = [f"tech.{n}" for n in tech_param_names()] + [
            f"arch.{n}" for n in _arch_param_names()
        ]
        out = []
        for i, rep in enumerate(reports):
            elast = np.concatenate([
                _flatten(jax.tree.map(lambda x: x[i], g_techs)),
                _flatten(jax.tree.map(lambda x: x[i], g_archs)),
            ])
            ranked = sorted(zip(names, elast.tolist()), key=lambda kv: -abs(kv[1]))
            attribution = tuple(
                Attribution(parameter=n, elasticity=float(v)) for n, v in ranked
            )
            out.append(
                dataclasses.replace(rep, objective=objective, attribution=attribution)
            )
        return out

    # ------------------------------------------------------------ simulate --
    def perf(self, workload, *, architecture=None) -> PerfEstimate:
        """Raw batched :class:`PerfEstimate` (device arrays, leading [W]
        axis) from the cached program — the zero-overhead serving path; use
        :meth:`simulate` for the explainable report."""
        w, a = self._workload(workload), self._arch(architecture)
        prog = self._perf_program(w.bucket, a.spec, self.mcfg)
        return prog(a.tech, a.arch, w.stacked)

    def simulate(self, workload, *, architecture=None) -> SimReport:
        """Simulate the workload set; returns a :class:`SimReport` with
        per-workload totals and per-memory-level / per-vertex breakdowns."""
        w, a = self._workload(workload), self._arch(architecture)
        perfs, extras = self._report_program(w.bucket, a.spec, self.mcfg)(
            a.tech, a.arch, w.stacked
        )
        return self._build_report(a, w, perfs, extras)

    def explain(self, workload, *, objective: str = "edp", architecture=None) -> SimReport:
        """:meth:`simulate` + gradient-based bottleneck attribution: every
        technology and architecture parameter ranked by its elasticity
        d log(objective) / d log(parameter) — DOpt's Table-3 signal, served
        as an explanation instead of a descent direction."""
        w, a = self._workload(workload), self._arch(architecture)
        rep = self.simulate(w, architecture=a)
        g_tech, g_arch = self._explain_program(w.bucket, a.spec, self.mcfg, objective)(
            a.tech, a.arch, w.stacked
        )
        names = [f"tech.{n}" for n in tech_param_names()] + [
            f"arch.{n}" for n in _arch_param_names()
        ]
        elast = np.concatenate([_flatten(g_tech), _flatten(g_arch)])
        ranked = sorted(zip(names, elast.tolist()), key=lambda kv: -abs(kv[1]))
        attribution = tuple(Attribution(parameter=n, elasticity=float(v)) for n, v in ranked)
        return dataclasses.replace(rep, objective=objective, attribution=attribution)

    # ------------------------------------------------------------ optimize --
    def optimize(
        self,
        workload,
        *,
        objective: str = "edp",
        steps: int = 200,
        lr: float = 0.05,
        opt_over: str = "both",
        architecture=None,
        report: bool = True,
        **engine_kw,
    ) -> OptResult:
        """Gradient-descend the design for this workload set (DOpt).

        Routes to ``repro.core.optimize`` with the session's bucketed stack,
        so repeated calls with same-bucket workloads reuse the engine's
        fused-chunk program (the mix/budget arguments are traced — see
        module docstring).  ``engine_kw`` forwards the engine's knobs
        (``fused``, ``chunk``, ``target_factor``, ``objective_weights``,
        ``area_budget``, ``power_budget``, ``penalty_weight``, ...).

        ``report=False`` skips the baseline/optimized :class:`SimReport`
        pair (those fields come back ``None``) — the lean serving/benchmark
        mode where only the descent itself should be on the clock.
        """
        w, a = self._workload(workload), self._arch(architecture)
        mcfg = engine_kw.pop("mcfg", self.mcfg)
        # everything static to the engine's fused-chunk program belongs in
        # the key: steps/target_factor/chunk set the scan length, and
        # fused/area_constraint are static argnames of _fused_chunk
        self._engine_call(
            ("optimize", a.spec, mcfg, w.bucket, objective, opt_over, steps,
             engine_kw.get("fused", True), engine_kw.get("chunk"),
             engine_kw.get("target_factor"), engine_kw.get("area_constraint"))
        )
        res = _dopt.optimize(
            w.stacked,
            tech=a.tech,
            arch=a.arch,
            spec=a.spec,
            objective=objective,
            opt_over=opt_over,
            steps=steps,
            lr=lr,
            mcfg=mcfg,
            **engine_kw,
        )
        opt_arch = Architecture(
            None, name=f"{a.name}_opt", tech=res.tech, arch=res.arch, spec=a.spec
        )
        hist = tuple(float(math.exp(v)) for v in res.history["objective"])
        improvement = hist[0] / max(hist[-1], 1e-300) if hist else 1.0
        return OptResult(
            objective=objective,
            opt_over=opt_over,
            epochs=len(hist),
            improvement=improvement,
            objective_history=hist,
            importance=tuple(
                Attribution(parameter=f"tech.{n}", elasticity=v) for n, v in res.importance
            ),
            baseline=self.simulate(w, architecture=a) if report else None,
            optimized=self.simulate(w, architecture=opt_arch) if report else None,
            dhd=opt_arch.to_dhd(),
        )

    def tech_targets(self, workload, *, goal_factor: float = 100.0, **engine_kw) -> dict:
        """Technology targets for a ``goal_factor``x objective improvement
        (paper §8.3) — thin passthrough to ``repro.core.dopt.derive_tech_targets``
        on the session's bucketed stack."""
        w = self._workload(workload)
        return _dopt.derive_tech_targets(w.stacked, goal_factor=goal_factor, **engine_kw)

    # ------------------------------------------------------------ frontier --
    def frontier(
        self,
        workload,
        *,
        seeds: tuple[str, ...] = ("base", "edge", "datacenter"),
        population: int = 24,
        steps: int = 24,
        lr: float = 0.1,
        metrics: tuple[str, ...] = ("time", "energy", "area"),
        area_budget: float | None = None,
        power_budget: float | None = None,
        **engine_kw,
    ) -> FrontierResult:
        """Population-scale constrained multi-objective DSE: the feasible
        latency/energy/area Pareto front for this workload set (popsim).

        Seeds descend from the named ``.dhd`` library designs (the session
        architecture does not constrain the population).  ``engine_kw``
        forwards ``repro.core.pareto_dse``'s knobs (``penalty_weight``,
        ``sigma``, ``mesh``, ``key``, ``hv_box``, ...).
        """
        w = self._workload(workload)
        mcfg = engine_kw.pop("mcfg", self.mcfg)
        self._engine_call(
            ("frontier", mcfg, w.bucket, tuple(metrics), tuple(seeds),
             population, steps, engine_kw.get("chunk"), engine_kw.get("opt_over", "both"))
        )
        res = _popsim.pareto_dse(
            w.stacked,
            seeds=seeds,
            population=population,
            steps=steps,
            lr=lr,
            metrics=metrics,
            area_budget=area_budget,
            power_budget=power_budget,
            mcfg=mcfg,
            **engine_kw,
        )
        front = tuple(
            FrontierPoint(
                index=int(win["index"]),
                seed=win["seed"],
                weights=tuple(win["weights"][m] for m in PARETO_METRICS),
                time_s=win["time_s"],
                energy_j=win["energy_j"],
                area_mm2=win["area_mm2"],
                power_w=win["power_w"],
                edp=win["edp"],
                dhd=win["dhd"],
            )
            for win in res.winners
        )
        return FrontierResult(
            metrics=tuple(metrics),
            population=population,
            epochs=steps,
            feasible=int(res.feasible.sum()),
            hypervolume=float(res.hypervolume),
            area_budget=float("inf") if area_budget is None else float(area_budget),
            power_budget=float("inf") if power_budget is None else float(power_budget),
            front=front,
            raw=res,
        )

    # --------------------------------------------------------- introspection --
    def trace_programs(self, workload, *, objective: str = "edp", architecture=None) -> dict:
        """Abstractly lower the four served program kinds to jaxprs.

        Returns ``{"simulate": ..., "explain": ..., "optimize": ...,
        "frontier": ...}`` — each a ``ClosedJaxpr`` from ``jax.make_jaxpr``
        over *the same engine functions the session compiles and serves*
        (``simulate_stacked``; the explain gradient; one DOpt epoch, i.e.
        the body the fused chunk scans; the vmapped popsim member step over
        a 2-member population).  Nothing is compiled or executed — this is
        the static program view ``tools/dragonlint`` Pass B inspects for
        transfers, dtype promotions, folded constants and seam-unsafe
        primitives.

        Tracing is a real trace: the engines' retrace probes
        (``dopt._dopt_step`` / ``popsim._member_step``) each bump once per
        call.  Benchmarks gate on *deltas* of those counters, so calling
        this between measurements is safe; don't call it inside one.
        """
        w, a = self._workload(workload), self._arch(architecture)
        spec, mcfg = a.spec, self.mcfg
        gstack = w.stacked
        out: dict = {}

        def sim(tech, arch, g):
            return simulate_stacked(tech, arch, g, spec, mcfg)

        out["simulate"] = jax.make_jaxpr(sim)(a.tech, a.arch, gstack)

        def expl(tech, arch, g):
            def loss(tz, az):
                val, _ = stacked_log_objective(
                    from_log(tz), from_log(az), g, objective, spec=spec, mcfg=mcfg
                )
                return val

            return jax.grad(loss, argnums=(0, 1))(to_log(tech), to_log(arch))

        out["explain"] = jax.make_jaxpr(expl)(a.tech, a.arch, gstack)

        # one DOpt epoch with the exact state/mix layout optimize() scans
        # (opt_over="both": no type logits, placeholder ystate)
        tech_z, arch_z = to_log(a.tech), to_log(a.arch)
        state = (
            tech_z, arch_z, None,
            _dopt.adam_init(tech_z), _dopt.adam_init(arch_z),
            _dopt.adam_init(jnp.zeros(1)),
            _dopt.guard_init(),
        )
        mix = (
            jnp.zeros(len(PARETO_METRICS)), jnp.float32(jnp.inf),
            jnp.float32(jnp.inf), jnp.float32(1.0),
        )

        def opt(st, g, lr, mx, flt):
            return _dopt._dopt_step(st, g, lr, mx, flt, spec, objective, None, "both", mcfg)

        out["optimize"] = jax.make_jaxpr(opt)(state, gstack, jnp.float32(0.05), mix, jnp.float32(0.0))

        # the population chunk's member axis, minimally populated (P=2)
        pop = 2
        ptz = jax.tree.map(lambda x: jnp.stack([x] * pop), tech_z)
        paz = jax.tree.map(lambda x: jnp.stack([x] * pop), arch_z)
        tstate = jax.vmap(_dopt.adam_init)(ptz)
        astate = jax.vmap(_dopt.adam_init)(paz)
        weights = jnp.zeros((pop, len(PARETO_METRICS)))
        budgets = jnp.full((pop,), jnp.inf)

        def front(tz, az, ts, as_, wts, ab, pb, g, lr, pw):
            def member(tz1, az1, ts1, as1, w1, ab1, pb1):
                return _popsim._member_step(
                    tz1, az1, ts1, as1, w1, ab1, pb1, g, lr, pw, spec, mcfg, "both"
                )

            return jax.vmap(member)(tz, az, ts, as_, wts, ab, pb)

        out["frontier"] = jax.make_jaxpr(front)(
            ptz, paz, tstate, astate, weights, budgets, budgets,
            gstack, jnp.float32(0.1), jnp.float32(1.0),
        )
        return out

    # -------------------------------------------------------------- report --
    def _build_report(self, a: Architecture, w: Workload, perfs, extras) -> SimReport:
        state = perfs.state
        reads = np.asarray(state.reads)
        writes = np.asarray(state.writes)
        comp_ops = np.asarray(state.comp_ops)
        bw_util = np.asarray(state.bw_util)
        ex = {k: np.asarray(v) for k, v in extras.items()}
        runtime = np.asarray(perfs.runtime)
        # one host sync per field, outside the per-workload loop
        energy = np.asarray(perfs.energy)
        power = np.asarray(perfs.power)
        edp = np.asarray(perfs.edp)
        cycles = np.asarray(perfs.cycles)
        energy_mem = np.asarray(perfs.energy_mem)
        energy_comp = np.asarray(perfs.energy_comp)
        energy_leak = np.asarray(perfs.energy_leak)
        area = np.asarray(perfs.area)
        workloads = []
        for i, (lbl, g) in enumerate(zip(w.labels, w.graphs)):
            v = g.n_vertices
            time_v = ex["time_v"][i, :v]
            energy_v = ex["energy_v"][i, :v]
            rt = float(runtime[i])
            levels = tuple(
                MemoryLevelReport(
                    level=lvl,
                    reads_bytes=float(reads[i, li]),
                    writes_bytes=float(writes[i, li]),
                    transfer_time_s=float(ex["t_level"][i, li]),
                    dynamic_energy_j=float(ex["e_level_dyn"][i, li]),
                    leakage_energy_j=float(ex["e_level_leak"][i, li]),
                    bw_utilization=float(bw_util[i, li]),
                )
                for li, lvl in enumerate(MEM_CLS)
            )
            compute = tuple(
                ComputeClassReport(
                    unit=unit,
                    flops=float(comp_ops[i, ci]),
                    dynamic_energy_j=float(ex["e_comp_dyn"][i, ci]),
                    leakage_energy_j=float(ex["e_comp_leak"][i, ci]),
                )
                for ci, unit in enumerate(COMP_CLS)
            )
            vertices = tuple(
                VertexReport(
                    name=str(g.names[vi]) if vi < len(g.names) else f"v{vi}",
                    time_s=float(time_v[vi]),
                    energy_j=float(energy_v[vi]),
                    time_share=float(time_v[vi] / max(rt, 1e-300)),
                )
                for vi in range(v)
            )
            workloads.append(
                WorkloadReport(
                    label=lbl,
                    runtime_s=rt,
                    energy_j=float(energy[i]),
                    power_w=float(power[i]),
                    edp=float(edp[i]),
                    cycles=float(cycles[i]),
                    energy_mem_j=float(energy_mem[i]),
                    energy_comp_j=float(energy_comp[i]),
                    energy_leak_j=float(energy_leak[i]),
                    levels=levels,
                    compute=compute,
                    vertices=vertices,
                )
            )
        return SimReport(
            architecture=a.name,
            objective="",
            area_mm2=float(area[0]),
            workloads=tuple(workloads),
        )
