"""Resilience layer for DSE-as-a-service (docs/serving.md).

A serving engine answering design queries for a fleet must degrade
gracefully: one malformed ``.dhd``, one NaN-diverging descent or one slow
cold compile must cost *one structured error reply*, never a crashed or
stalled batch.  This module is the policy layer :class:`DesignService`
(serving/engine.py) runs every query through:

  * a **typed fault taxonomy** — :class:`ClientError` /
    :class:`TransientFault` / :class:`DeadlineExceeded` /
    :class:`NumericFault` (plus :class:`CircuitOpen` for the degraded
    fast-fail path), each carrying a stable ``code`` and a ``retryable``
    bit, serialized into replies as :class:`FaultInfo`;
  * **bounded retry** (:class:`RetryPolicy`) — exponential backoff with
    *deterministic* jitter (hash-derived from ``(token, attempt)``, so a
    replay of the same query stream backs off identically);
  * **per-query wall-clock deadlines** (:class:`DeadlineConfig`) — separate
    cold-compile and warm budgets, because the trace probe shows a cold
    (spec, bucket, objective) costs ~0.7-1.1 s of trace+compile while the
    warm path is sub-millisecond (results/bench/sim_speed.json,
    api_cache.json);
  * a **per-key circuit breaker** (:class:`CircuitBreaker`) — keyed by
    ``(kind, bucket)``, trips after repeated consecutive failures and
    fast-fails further queries with a structured ``circuit-open`` reply
    until a cooldown expires, so a poisoned program shape cannot cascade
    into every lane of a batch.

Everything takes injectable ``clock``/``sleep`` callables so tests and the
chaos harness (serving/chaos.py) can drive time deterministically.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

# --------------------------------------------------------------------------- #
# fault taxonomy
# --------------------------------------------------------------------------- #


class ServingFault(Exception):
    """Base of the typed serving faults.  ``code`` is the stable wire
    identifier (what replies and stats key on); ``retryable`` is the retry
    loop's decision bit."""

    code: str = "fault"
    retryable: bool = False


class ClientError(ServingFault):
    """The query itself is bad (unparseable ``.dhd``, non-finite graph
    tensors, empty workload set, unknown kind, invalid engine knobs).
    Never retried — the same input fails the same way — and never counted
    against the circuit breaker: the server is healthy."""

    code = "client-error"
    retryable = False


class TransientFault(ServingFault):
    """A fault expected to clear on retry: an injected/infra exception, a
    failed compile, a flaky dependency.  Retried under the deadline."""

    code = "transient"
    retryable = True


class DeadlineExceeded(ServingFault):
    """The per-query wall-clock budget is gone (the answer arrived late, or
    the remaining budget cannot cover another backoff+attempt).  Not
    retryable by definition."""

    code = "deadline-exceeded"
    retryable = False


class NumericFault(ServingFault):
    """The engine produced a non-finite answer (NaN/inf leaked through a
    descent or a simulation).  Retryable once — transient numeric
    corruption (e.g. injected) clears; a deterministic divergence exhausts
    its attempts and degrades to a structured error reply."""

    code = "numeric"
    retryable = True


class CircuitOpen(ServingFault):
    """Degraded fast-fail: the breaker for this (kind, bucket) is open."""

    code = "circuit-open"
    retryable = False


@dataclass(frozen=True)
class FaultInfo:
    """The structured error a reply carries when ``ok=False`` — JSON-able,
    stable codes, enough to route/alert on without parsing messages."""

    code: str
    message: str
    attempts: int
    retryable: bool

    def to_json(self) -> dict:
        return dict(code=self.code, message=self.message,
                    attempts=self.attempts, retryable=self.retryable)


def classify_exception(exc: BaseException) -> ServingFault:
    """Map a foreign exception onto the taxonomy: engine argument errors are
    the client's (``ValueError``/``TypeError``/``KeyError`` → ClientError),
    numeric traps are NumericFault, anything else is assumed transient (the
    retry loop will prove or disprove that)."""
    if isinstance(exc, ServingFault):
        return exc
    if isinstance(exc, FloatingPointError):
        return NumericFault(f"{type(exc).__name__}: {exc}")
    if isinstance(exc, (ValueError, TypeError, KeyError)):
        return ClientError(f"{type(exc).__name__}: {exc}")
    return TransientFault(f"{type(exc).__name__}: {exc}")


# --------------------------------------------------------------------------- #
# bounded retry with deterministic jitter
# --------------------------------------------------------------------------- #


def _unit_hash(token: int, attempt: int, salt: int = 0) -> float:
    """Deterministic uniform in [0, 1) from ``(token, attempt, salt)`` —
    NumPy's SeedSequence is a stable, platform-independent hash, so jitter
    (and the chaos schedule built on the same primitive) replays exactly."""
    ss = np.random.SeedSequence([token & 0xFFFFFFFF, attempt & 0xFFFFFFFF, salt & 0xFFFFFFFF])
    return float(np.random.default_rng(ss).random())


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry: at most ``max_attempts`` total tries, exponential
    backoff ``base_s * multiplier**retry`` capped at ``max_backoff_s``,
    shrunk by a deterministic jitter fraction so replayed streams neither
    thundering-herd nor diverge between runs."""

    max_attempts: int = 4
    base_s: float = 0.01
    multiplier: float = 2.0
    max_backoff_s: float = 0.25
    jitter: float = 0.5  # backoff is scaled into [1 - jitter, 1] deterministically

    def backoff_s(self, retry: int, token: int = 0) -> float:
        raw = min(self.base_s * self.multiplier ** retry, self.max_backoff_s)
        return raw * (1.0 - self.jitter * _unit_hash(token, retry, salt=7))


# --------------------------------------------------------------------------- #
# per-query deadlines (cold-compile vs warm budgets)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class DeadlineConfig:
    """Wall-clock budgets per query.  ``cold_s`` covers the first query of a
    (kind, spec, bucket, objective) shape — which pays trace+compile, ~1 s
    on the recorded trajectory — ``warm_s`` covers the cached steady state.
    ``optimize_scale`` multiplies both for optimize/frontier queries, whose
    useful work is a whole descent rather than one dispatch."""

    warm_s: float = 2.0
    cold_s: float = 30.0
    optimize_scale: float = 4.0

    def budget_s(self, cold: bool, kind: str = "simulate") -> float:
        base = self.cold_s if cold else self.warm_s
        return base * (self.optimize_scale if kind in ("optimize", "frontier") else 1.0)


# --------------------------------------------------------------------------- #
# per-(kind, bucket) circuit breaker
# --------------------------------------------------------------------------- #


@dataclass
class _BreakerState:
    failures: int = 0  # consecutive server-side failures
    opened_at: float | None = None
    trips: int = 0
    rejected: int = 0


class CircuitBreaker:
    """Consecutive-failure breaker, one independent state per key.

    Closed → ``failure_threshold`` consecutive failures → open (fast-fail)
    → after ``cooldown_s`` one probe query is let through (half-open) →
    success closes the breaker, failure re-opens it with a fresh cooldown.
    Single-threaded by design, matching the service's serve loop."""

    def __init__(self, failure_threshold: int = 4, cooldown_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._states: dict = {}

    def _state(self, key) -> _BreakerState:
        return self._states.setdefault(key, _BreakerState())

    def allow(self, key) -> bool:
        st = self._state(key)
        if st.opened_at is not None and (self._clock() - st.opened_at) < self.cooldown_s:
            st.rejected += 1
            return False
        return True  # closed, or open past cooldown: the half-open probe

    def record(self, key, ok: bool) -> None:
        st = self._state(key)
        if ok:
            st.failures = 0
            st.opened_at = None
        else:
            st.failures += 1
            if st.failures >= self.failure_threshold or st.opened_at is not None:
                if st.opened_at is None:
                    st.trips += 1
                st.opened_at = self._clock()

    def snapshot(self) -> dict:
        """Per-key breaker state for stats: open?, consecutive failures,
        lifetime trips and fast-fail rejections."""
        now = self._clock()
        return {
            key: dict(
                open=st.opened_at is not None and (now - st.opened_at) < self.cooldown_s,
                failures=st.failures, trips=st.trips, rejected=st.rejected,
            )
            for key, st in self._states.items()
        }


# --------------------------------------------------------------------------- #
# result validation: non-finite containment at the reply boundary
# --------------------------------------------------------------------------- #


def nonfinite_in(result: Any) -> str | None:
    """Name of the first non-finite headline field of a result object, or
    None when the reply is clean.  This is the serving-side containment
    net: the engines already roll back non-finite descent steps (dopt) and
    mark diverging members infeasible (popsim), so anything caught here is
    either injected chaos or a genuinely new numeric escape — both become
    a typed :class:`NumericFault`, never a NaN shipped to a client.

    Budget fields are deliberately not checked: ``inf`` is the valid
    spelling of "no budget"."""
    from repro.core.report import FrontierResult, OptResult, SimReport

    if isinstance(result, SimReport):
        if not math.isfinite(result.area_mm2):
            return "area_mm2"
        for wl in result.workloads:
            for f in ("runtime_s", "energy_j", "power_w", "edp"):
                if not math.isfinite(getattr(wl, f)):
                    return f"{wl.label}.{f}"
        return None
    if isinstance(result, OptResult):
        if not math.isfinite(result.improvement):
            return "improvement"
        for i, v in enumerate(result.objective_history):
            if not math.isfinite(v):
                return f"objective_history[{i}]"
        for sub, nm in ((result.baseline, "baseline"), (result.optimized, "optimized")):
            if sub is not None:
                hit = nonfinite_in(sub)
                if hit:
                    return f"{nm}.{hit}"
        return None
    if isinstance(result, FrontierResult):
        if not math.isfinite(result.hypervolume):
            return "hypervolume"
        for p in result.front:
            for f in ("time_s", "energy_j", "area_mm2", "power_w", "edp"):
                if not math.isfinite(getattr(p, f)):
                    return f"front[{p.index}].{f}"
        return None
    return None


# --------------------------------------------------------------------------- #
# the guarded call: retry x deadline x validation, one outcome
# --------------------------------------------------------------------------- #


@dataclass
class GuardedOutcome:
    """What one guarded call produced: either ``result`` (fault is None) or
    a terminal :class:`FaultInfo`.  ``attempts`` counts tries made."""

    result: Any = None
    fault: FaultInfo | None = None
    attempts: int = 0
    wall_s: float = 0.0
    deadline_s: float = float("inf")

    @property
    def ok(self) -> bool:
        return self.fault is None

    @property
    def retries(self) -> int:
        return max(0, self.attempts - 1)


def run_guarded(
    fn: Callable[[int], Any],
    *,
    policy: RetryPolicy,
    deadline_s: float,
    token: int = 0,
    clock: Callable[[], float] = time.monotonic,
    sleep: Callable[[float], None] = time.sleep,
    validate: Callable[[Any], str | None] = nonfinite_in,
    classify: Callable[[BaseException], ServingFault] = classify_exception,
) -> GuardedOutcome:
    """Run ``fn(attempt)`` under the full guard stack.

    Per attempt: call, validate the result (non-finite headline fields
    raise :class:`NumericFault`), then check the wall clock — an answer
    that lands past ``deadline_s`` is a :class:`DeadlineExceeded` outcome,
    not a success.  Faults are classified; retryable ones retry with
    deterministic backoff, but only while the remaining budget covers the
    pause (a retry that cannot finish in budget degrades to
    ``deadline-exceeded`` immediately instead of burning the sleep).
    Never raises: every path returns a :class:`GuardedOutcome`.
    """
    t0 = clock()
    attempt = 0
    fault: ServingFault = TransientFault("no attempt made")
    while attempt < policy.max_attempts:
        try:
            result = fn(attempt)
            hit = validate(result) if validate is not None else None
            if hit is not None:
                raise NumericFault(f"non-finite result field {hit!r}")
            wall = clock() - t0
            if wall > deadline_s:
                raise DeadlineExceeded(
                    f"answered after {wall:.3f}s > {deadline_s:.3f}s budget"
                )
            return GuardedOutcome(result=result, attempts=attempt + 1,
                                  wall_s=wall, deadline_s=deadline_s)
        except BaseException as e:  # noqa: B036 — classified, never swallowed
            if isinstance(e, (KeyboardInterrupt, SystemExit)):
                raise
            fault = classify(e)
        attempt += 1
        if not fault.retryable or attempt >= policy.max_attempts:
            break
        pause = policy.backoff_s(attempt - 1, token)
        if (clock() - t0) + pause >= deadline_s:
            fault = DeadlineExceeded(
                f"budget exhausted after {attempt} attempt(s): remaining "
                f"{max(0.0, deadline_s - (clock() - t0)):.3f}s < backoff {pause:.3f}s"
            )
            break
        sleep(pause)
    return GuardedOutcome(
        fault=FaultInfo(code=fault.code, message=str(fault),
                        attempts=attempt, retryable=fault.retryable),
        attempts=attempt, wall_s=clock() - t0, deadline_s=deadline_s,
    )
