"""Seeded, deterministic chaos harness for the design service.

Resilience claims are only as good as the faults they were tested under, so
the fault source must be *replayable*: :class:`ChaosInjector` derives every
injection decision from ``SeedSequence([seed, qid])`` — a stable hash that
does not depend on arrival order, retry interleaving, or wall clock.  The
same seed therefore produces the identical fault schedule on every run and
every platform, which is what lets the bench/CI gate assert exact
availability numbers (bench_serving.py ``--chaos``) and lets tests diff two
runs bit-for-bit.

Fault repertoire (per query, mutually composable):

  * **transient exception** — the attempt raises
    :class:`~repro.serving.resilience.TransientFault` before the engine runs;
  * **compile failure** — same raise, labelled as a failed trace/compile
    (the service still observes it pre-result, like a real XLA abort);
  * **latency spike** — the first attempt sleeps ``latency_s`` before the
    engine runs, stressing deadlines and the straggler monitor;
  * **NaN poisoning** — the attempt's *result* has a headline field replaced
    with NaN (``SimReport.area_mm2`` / ``OptResult.improvement`` /
    ``FrontierResult.hypervolume``), exercising the service's non-finite
    containment and retry instead of the engines' own in-jit guards;
  * **cache corruption** — the attempt raises
    :class:`~repro.serving.aotcache.CacheCorruption` before the engine runs,
    modelling a torn/bit-flipped persistent AOT entry discovered at
    program-load time (the real reader quarantines the file and falls back
    to a fresh compile — transient by construction, so retry clears it);
  * **worker kill** — not injected by :meth:`ChaosInjector.call` at all:
    the multi-process coordinator (``repro.serving.pool``) reads
    ``plan(qid).worker_kill`` and SIGKILLs the worker process a marked
    query was assigned to, once per qid, exercising crash detection and
    in-flight requeue.  In-process services ignore the flag.

Faults fire on the *leading* attempts of a query only (bounded depth), so a
retry policy with enough attempts always clears transient-class chaos —
this is the property the CI chaos probe hard-gates at availability == 1.0.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import Counter
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.serving.aotcache import CacheCorruption
from repro.serving.resilience import TransientFault

_NAN = float("nan")


@dataclass(frozen=True)
class ChaosConfig:
    """Per-fault marginal probabilities (independent draws per query) and
    shape knobs.  ``depth`` is how many leading attempts each drawn fault
    consumes — keep ``depth * (number of fault classes) < max_attempts`` if
    availability must stay 1.0 under retry."""

    seed: int = 0
    p_transient: float = 0.0
    p_compile_fail: float = 0.0
    p_latency: float = 0.0
    p_nan: float = 0.0
    latency_s: float = 0.05
    depth: int = 1
    p_cache_corrupt: float = 0.0
    p_worker_kill: float = 0.0


@dataclass(frozen=True)
class FaultPlan:
    """The chaos verdict for one query: how many leading attempts raise a
    transient, then a compile failure, then a corrupt-cache-entry fault,
    then how many return a NaN-poisoned result; ``latency`` delays the
    first attempt."""

    qid: int
    transient: int
    compile_fail: int
    nan: int
    latency: bool
    cache_corrupt: int = 0
    # coordinator-enacted (process death), not an attempt fault: the query
    # is re-enqueued and re-served whole, so it does not affect clean /
    # min_attempts — a killed-and-requeued query still answers bit-identically
    worker_kill: bool = False

    @property
    def clean(self) -> bool:
        return not (
            self.transient or self.compile_fail or self.cache_corrupt
            or self.nan or self.latency
        )

    @property
    def min_attempts(self) -> int:
        """Attempts a retrying client needs to get a clean answer."""
        return self.transient + self.compile_fail + self.cache_corrupt + self.nan + 1

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def poison(result: Any) -> Any:
    """Return ``result`` with one headline metric NaN'd (frozen dataclasses
    are rebuilt via ``dataclasses.replace``); non-report objects pass
    through untouched."""
    from repro.core.report import FrontierResult, OptResult, SimReport

    if isinstance(result, SimReport):
        return dataclasses.replace(result, area_mm2=_NAN)
    if isinstance(result, OptResult):
        return dataclasses.replace(result, improvement=_NAN)
    if isinstance(result, FrontierResult):
        return dataclasses.replace(result, hypervolume=_NAN)
    return result


class ChaosInjector:
    """Wraps a query handler with the seeded fault schedule.

    The service calls :meth:`call` once per attempt; everything the injector
    does is a pure function of ``(config.seed, qid, attempt)`` plus the
    handler's own (deterministic) result, so two services configured with
    the same seed observe the same chaos regardless of timing.
    """

    def __init__(self, config: ChaosConfig, *, sleep: Callable[[float], None] = time.sleep):
        self.config = config
        self.sleep = sleep
        self.injected: Counter = Counter()
        # the pooled service runs attempts from several threads; the ledger
        # (not the schedule, which is pure) needs the lock
        self._lock = threading.Lock()

    # ----------------------------------------------------------- schedule --
    def plan(self, qid: int) -> FaultPlan:
        c = self.config
        # new fault classes always draw LAST: PCG64 generates uniforms
        # sequentially, so draws 0-3 are identical to the historical 4-draw
        # schedule and draw 4 to the 5-draw one — adding a fault class
        # never reshuffles existing seeded schedules (cache_corrupt joined
        # at index 4, worker_kill at index 5)
        u = np.random.default_rng(
            np.random.SeedSequence([c.seed & 0xFFFFFFFF, qid & 0xFFFFFFFF])
        ).random(6)
        d = c.depth
        return FaultPlan(
            qid=qid,
            transient=d * int(u[0] < c.p_transient),
            compile_fail=d * int(u[1] < c.p_compile_fail),
            nan=d * int(u[2] < c.p_nan),
            latency=bool(u[3] < c.p_latency),
            cache_corrupt=d * int(u[4] < c.p_cache_corrupt),
            worker_kill=bool(u[5] < c.p_worker_kill),
        )

    def schedule(self, qids) -> list[FaultPlan]:
        """The full fault schedule for a batch — what determinism tests and
        the bench's bit-identity check compare against."""
        return [self.plan(q) for q in qids]

    # --------------------------------------------------------------- inject --
    def call(self, handler: Callable[[], Any], *, qid: int, attempt: int) -> Any:
        """Run one attempt of ``handler`` under the query's fault plan."""
        p = self.plan(qid)
        if p.latency and attempt == 0:
            self._count("latency")
            self.sleep(self.config.latency_s)
        if attempt < p.transient:
            self._count("transient")
            raise TransientFault(f"chaos: injected transient fault (q{qid} attempt {attempt})")
        if attempt - p.transient < p.compile_fail:
            self._count("compile_fail")
            raise TransientFault(f"chaos: injected compile failure (q{qid} attempt {attempt})")
        if attempt - p.transient - p.compile_fail < p.cache_corrupt:
            # pre-engine, like the real thing: a torn entry surfaces at
            # program-load time, before any dispatch
            self._count("cache_corrupt")
            raise CacheCorruption(
                f"chaos: injected corrupt cache entry (q{qid} attempt {attempt})"
            )
        result = handler()
        if attempt - p.transient - p.compile_fail - p.cache_corrupt < p.nan:
            bad = poison(result)
            if bad is not result:
                self._count("nan")
                return bad
            # nothing poisonable in this result type: no injection recorded
        return result

    def _count(self, fault: str, n: int = 1) -> None:
        with self._lock:
            self.injected[fault] += n

    # ----------------------------------------------------------------- info --
    def summary(self) -> dict:
        return dict(self.injected)
