"""Length-prefixed frame protocol for coordinator <-> worker links.

The multi-process serving tier (:mod:`repro.serving.pool` /
:mod:`repro.serving.worker`) talks over a private Unix-domain socket the
coordinator creates, one connection per worker process it spawned.  The
wire format is deliberately tiny:

    ``MAGIC (4 bytes) | length (u32, big-endian) | body``

where ``body = pickle((tag, payload))``.  Frames carry whole chunks of
queries / replies, so per-frame overhead amortizes across the request
bucket (a 16-query chunk of ``SimReport`` replies pickles to ~20 KB in
~0.3 ms — noise next to the dispatch it answers).

Tags (direction):

| tag        | dir  | payload |
|------------|------|---------|
| ``hello``  | w->c | ``{"worker": id, "pid": pid}`` — first frame after connect |
| ``cfg``    | c->w | service construction dict (policy/retry/deadlines/chaos/cache_dir/...) |
| ``ready``  | w->c | ``{"worker": id, "disk_loaded": n}`` — service built + warmed, taking traffic |
| ``chunk``  | c->w | ``(chunk_id, [DesignQuery, ...])`` |
| ``replies``| w->c | ``(chunk_id, [DesignReply, ...], ServiceStats)`` — stats piggyback on every reply frame so the coordinator's fleet view survives a later crash |
| ``hb``     | w->c | worker id — liveness beacon from a daemon thread |
| ``shutdown``| c->w| None — drain and exit |
| ``bye``    | w->c | final ``ServiceStats`` |

Pickle is safe here because the channel is *private by construction*: the
socket lives in a coordinator-owned temp directory (mode 0700) and both
ends are processes the coordinator spawned — never a network listener,
never untrusted peers.  :exc:`ProtocolError` covers the failure modes a
crashing peer can produce (EOF mid-frame, bad magic, absurd length), so
the coordinator can classify any framing problem as worker death.
"""
from __future__ import annotations

import pickle
import struct
from typing import Any, Tuple

MAGIC = b"DGN1"
_HEADER = struct.Struct(">4sI")

#: hard ceiling on one frame's body — a length prefix beyond this is a
#: corrupt/foreign stream, not a real chunk (the largest legitimate frame,
#: a full request bucket of explain replies, is well under 1 MB)
MAX_FRAME = 64 * 1024 * 1024


class ProtocolError(RuntimeError):
    """Framing violation: truncated stream, bad magic, oversized length.
    The coordinator treats any of these as death of the peer."""


def encode_frame(tag: str, payload: Any) -> bytes:
    """One wire frame.  Split from :func:`send_frame` so a sender can fail
    on an unpicklable payload *before* writing anything — a half-written
    frame would corrupt the stream for every later message."""
    body = pickle.dumps((tag, payload), protocol=pickle.HIGHEST_PROTOCOL)
    if len(body) > MAX_FRAME:
        raise ProtocolError(f"frame {tag!r} is {len(body)} bytes (max {MAX_FRAME})")
    return _HEADER.pack(MAGIC, len(body)) + body


def send_frame(sock, tag: str, payload: Any) -> None:
    sock.sendall(encode_frame(tag, payload))


def _recv_exact(sock, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        part = sock.recv(n - got)
        if not part:
            raise ProtocolError(f"peer closed mid-frame ({got}/{n} bytes)")
        chunks.append(part)
        got += len(part)
    return b"".join(chunks)


def recv_frame(sock) -> Tuple[str, Any]:
    """Read one complete frame; blocks until it arrives.  Raises
    :exc:`ProtocolError` on EOF / framing violations (a clean EOF *between*
    frames raises too — callers treat it as the peer leaving)."""
    magic, length = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if magic != MAGIC:
        raise ProtocolError(f"bad frame magic {magic!r}")
    if length > MAX_FRAME:
        raise ProtocolError(f"frame length {length} exceeds {MAX_FRAME}")
    tag, payload = pickle.loads(_recv_exact(sock, length))
    return tag, payload
