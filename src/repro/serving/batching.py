"""Cross-request batching mechanics: intake queue, flush policy, coalescing.

The PR 5 bucket convention makes same-``(kind, spec, bucket, objective)``
query stacks *structurally identical*, so one compiled program with a
leading request axis can answer a whole group in one vmapped dispatch.
This module owns the plumbing around that fact:

* :class:`FlushPolicy` — when a queued batch is dispatched (size or age);
* :class:`IntakeQueue` — the arrival-ordered queue with an injectable
  clock, so tests drive flush timing deterministically;
* :func:`plan_chunks` — group admitted queries by batch key into dispatch
  chunks (arrival order preserved, chunk size capped);
* :func:`make_chunk_handlers` — per-lane handlers over ONE lazily
  memoized coalesced dispatch, shaped so the existing resilience stack
  (retry / deadline / chaos injection) wraps each query unchanged.

The lazy memo is the contract that keeps PR 7's guarantees intact: the
coalesced dispatch runs inside the *first* lane's guarded attempt (so the
cold-compile deadline applies to the query that pays it), later lanes read
their slice for free, and a chaos fault injected into one lane never
touches the memo — retries of that lane return its clean slice.

:class:`repro.serving.BatchingDesignService` composes these with the
``DesignService`` guard stack.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

#: query kinds that may share a coalesced dispatch (pure, stateless
#: evaluations; optimize/frontier carry per-query engine knobs and loops)
BATCHABLE_KINDS = ("simulate", "explain")


@dataclass(frozen=True)
class FlushPolicy:
    """When does a queued batch flush?

    * immediately once ``max_batch`` queries wait (size trigger);
    * once the *oldest* queued query is ``max_delay_s`` old and at least
      ``min_batch`` queries wait (deadline trigger — bounds the latency a
      query can pay for the privilege of being coalesced).

    ``max_batch`` doubles as the service's pinned request bucket: every
    dispatch pads its request axis to it, so one compiled program serves
    every batch size and replies are bit-identical however queries were
    coalesced.
    """

    max_batch: int = 8
    max_delay_s: float = 0.002
    min_batch: int = 1

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if not 1 <= self.min_batch <= self.max_batch:
            raise ValueError(
                f"min_batch must be in [1, max_batch], got {self.min_batch}"
            )
        if self.max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {self.max_delay_s}")


class IntakeQueue:
    """Arrival-ordered intake queue with enqueue timestamps.

    The clock is injectable so tests (and the deterministic bench) can
    drive the age-based flush trigger without sleeping.  Push/drain are
    lock-guarded: the pooled service pushes from caller threads while its
    dispatcher thread drains.
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._lock = threading.Lock()
        self._items: list = []  # (t_enqueue, query)

    def __len__(self) -> int:
        return len(self._items)

    def push(self, query: Any) -> None:
        with self._lock:
            self._items.append((self._clock(), query))

    def oldest_age(self) -> float:
        with self._lock:
            if not self._items:
                return 0.0
            return self._clock() - self._items[0][0]

    def due(self, policy: FlushPolicy) -> bool:
        n = len(self._items)
        if n == 0:
            return False
        if n >= policy.max_batch:
            return True
        return n >= policy.min_batch and self.oldest_age() >= policy.max_delay_s

    def drain(self) -> list:
        """Pop everything, in arrival order, as ``(t_enqueue, query)``."""
        with self._lock:
            items, self._items = self._items, []
        return items


def batch_key(adm) -> Optional[tuple]:
    """The coalescing key for an admitted query — queries sharing it are
    answerable by one request-axis program — or None if the kind cannot
    batch.  Tenant is deliberately absent: parameter values are traced
    data and programs are shared, so cross-tenant coalescing is exact."""
    q = adm.q
    if q.kind not in BATCHABLE_KINDS:
        return None
    objective = q.objective if q.kind == "explain" else None
    return (q.kind, adm.arch.spec, adm.w.bucket, objective)


def plan_chunks(admitted: list, max_batch: int) -> list:
    """Group ``(idx, adm)`` pairs into dispatch chunks.

    Same-key queries share a chunk (capped at ``max_batch``, overflow
    starts a fresh chunk); unbatchable queries become singleton chunks.
    Chunk order follows each chunk's first arrival, and members keep
    arrival order inside the chunk — the scatter back to per-query replies
    is by the original ``idx``, so reply order never depends on grouping.
    """
    chunks: list = []
    open_chunk: dict = {}  # key -> index into chunks of the unfilled chunk
    for idx, adm in admitted:
        key = batch_key(adm)
        if key is None:
            chunks.append([(idx, adm)])
            continue
        at = open_chunk.get(key)
        if at is None or len(chunks[at]) >= max_batch:
            open_chunk[key] = len(chunks)
            chunks.append([(idx, adm)])
        else:
            chunks[at].append((idx, adm))
    return chunks


def make_chunk_handlers(chunk: list, dispatch: Callable[[list], list]) -> dict:
    """Per-lane handlers over one lazily memoized coalesced dispatch.

    ``dispatch(adms)`` must return one result per admitted query, in order.
    It runs at most once per *successful* attempt-chain: the first lane
    whose guarded attempt reaches its handler pays the dispatch (and any
    cold compile — its deadline is the cold one precisely because the
    warmth ledger said so); every other lane reads its memoized slice.
    If the dispatch itself raises, the memo stays empty and the next
    attempt — same lane's retry, or the next lane — tries again, so a
    transient dispatch fault degrades exactly like a sequential one.
    Chaos NaN-poisoning copies (``dataclasses.replace``) the returned
    slice, never the memo, so one lane's injected fault cannot leak into a
    batchmate's reply.
    """
    memo: dict = {}
    adms = [adm for _, adm in chunk]

    def lane(i: int) -> Callable[[], Any]:
        def handler():
            if "results" not in memo:
                memo["results"] = dispatch(adms)
            return memo["results"][i]

        return handler

    return {idx: lane(i) for i, (idx, _) in enumerate(chunk)}
