"""Persistent AOT executable cache — compiled programs that survive restart.

Warm façade calls run at ~0.4 ms but every cold ``(kind, spec, bucket,
objective)`` pays ~0.7–1.1 s of trace+compile; a fleet worker restarting
under traffic eats that per program (ROADMAP open item 2).  This module is
the on-disk half of the fix: :class:`AotCache` persists executables that
``Session.preheat`` built via ``jax.jit(...).lower().compile()``, and a
restarted ``Session(cache_dir=...)`` loads them back so its first query
dispatches a deserialized executable — zero traces, bit-identical replies
(the artifact *is* the bytes the fresh compile produced).

Keying
------

Entries are addressed by :func:`cache_key_digest`: a SHA-256 over

  * a cache **schema version** (bump it to invalidate every entry on a
    format change),
  * the **runtime fingerprint** (jax + jaxlib versions and the backend,
    from ``repro.kernels.runtime.executable_fingerprint`` — an upgraded
    runtime misses cleanly instead of deserializing a stale executable),
  * a **canonical text encoding** of the existing Session program-cache
    key — ``(kind, ArchSpec, MapperCfg, bucket[, objective][, request
    bucket])`` — encoded field-by-field (:func:`canonical_key_text`), never
    via Python ``hash()`` (which is salted per process).

Robustness
----------

Reads never raise.  A truncated / bit-flipped / zero-length entry fails
the checksum (or unpickling) and is **quarantined** — renamed to
``*.quarantined`` so it can never be read as a cache entry again, while
the bytes stay on disk for post-mortem — and the caller falls back to a
fresh compile.  A schema or fingerprint mismatch is a *clean miss*: the
entry is left in place (it belongs to another runtime).  Writes are
atomic (temp file + rename) so a crashed writer can never publish a torn
entry.  :class:`CacheCorruption` subclasses ``TransientFault`` — the
chaos harness injects it (``ChaosConfig.p_cache_corrupt``) to prove the
retry loop clears it.

Entries carry pickled executables; a cache directory is trusted local
state (like ``__pycache__``), not an interchange format — don't load
cache directories from untrusted sources.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import tempfile

from repro.kernels import runtime
from repro.serving.resilience import TransientFault

__all__ = [
    "AotCache",
    "CacheCorruption",
    "SCHEMA_VERSION",
    "cache_key_digest",
    "canonical_key_text",
]

SCHEMA_VERSION = 1

_MAGIC = b"DRGNAOT\x01"
_SUFFIX = ".aotx"
_QUARANTINE = ".quarantined"
_CHECKSUM_BYTES = 32  # sha256 of the body, stored right after the magic


class CacheCorruption(TransientFault):
    """A persisted executable failed its checksum or deserialization.

    Transient by construction: the reader quarantines the bad file and
    falls back to a fresh compile, so a retry serves from a clean slate.
    The wire code stays ``"transient"`` — no new alert class for fleets.
    """


# --------------------------------------------------------------------------- #
# key canonicalization + digest
# --------------------------------------------------------------------------- #


def canonical_key_text(key) -> str:
    """Deterministic text encoding of a Session program-cache key.

    Frozen dataclasses (``ArchSpec``, ``MapperCfg``) encode as
    ``ClassName(field=value, ...)`` over their declared fields, scalars by
    ``repr`` — every component lands in the text, so any single-field
    perturbation changes the digest, and equal keys encode equally in any
    process (property-tested in ``tests/test_aot_cache.py``).
    """
    if dataclasses.is_dataclass(key) and not isinstance(key, type):
        inner = ",".join(
            f"{f.name}={canonical_key_text(getattr(key, f.name))}"
            for f in dataclasses.fields(key)
        )
        return f"{type(key).__qualname__}({inner})"
    if isinstance(key, (tuple, list)):
        return "(" + ",".join(canonical_key_text(x) for x in key) + ")"
    if key is None or isinstance(key, (bool, int, float, str)):
        return repr(key)
    raise TypeError(
        f"cache key contains an unsupported component {type(key).__name__}: {key!r}"
    )


def cache_key_digest(key, *, schema: int | None = None, fingerprint: str | None = None) -> str:
    """SHA-256 hex digest addressing one persisted executable.

    Covers the schema version and the runtime fingerprint in addition to
    the key itself, so format changes and jax/jaxlib/backend upgrades both
    invalidate by *missing*, never by deserializing the wrong artifact.
    """
    if schema is None:
        schema = SCHEMA_VERSION
    if fingerprint is None:
        fingerprint = runtime.executable_fingerprint()
    text = f"dragon-aot|v{schema}|{fingerprint}|{canonical_key_text(key)}"
    return hashlib.sha256(text.encode()).hexdigest()


# --------------------------------------------------------------------------- #
# the cache
# --------------------------------------------------------------------------- #


class AotCache:
    """One directory of serialized executables, one file per program key.

    File layout: ``dragon-<digest32>.aotx`` = magic + sha256(body) + body,
    where body pickles ``{schema, fingerprint, key, blob}`` and ``blob`` is
    ``runtime.serialize_compiled`` output.  All read paths return misses
    instead of raising; corrupt files are quarantined via :meth:`_quarantine`.
    """

    def __init__(self, path):
        self.path = str(path)
        os.makedirs(self.path, exist_ok=True)
        self.loaded = 0  # entries successfully deserialized
        self.written = 0  # entries persisted by this process
        self.rejected = 0  # clean misses: schema/fingerprint from another runtime
        self.quarantined = 0  # corrupt files renamed out of the namespace

    # -------------------------------------------------------------- naming --
    def _file(self, key) -> str:
        return os.path.join(self.path, f"dragon-{cache_key_digest(key)[:32]}{_SUFFIX}")

    def entries(self) -> list[str]:
        """Cache-entry file names currently in the directory (sorted)."""
        return sorted(n for n in os.listdir(self.path) if n.endswith(_SUFFIX))

    def has(self, key) -> bool:
        return os.path.exists(self._file(key))

    def stats(self) -> dict:
        return dict(
            entries=len(self.entries()),
            loaded=self.loaded,
            written=self.written,
            rejected=self.rejected,
            quarantined=self.quarantined,
        )

    # ------------------------------------------------------------- writing --
    def put(self, key, compiled) -> bool:
        """Persist one executable; returns True iff a new entry was written.

        Skips keys already on disk and programs that cannot be serialized
        (plain jit wrappers, seam-less jax) — persisting is best-effort,
        serving never depends on it.
        """
        path = self._file(key)
        if os.path.exists(path):
            return False
        blob = runtime.serialize_compiled(compiled)
        if blob is None:
            return False
        body = pickle.dumps(
            dict(
                schema=SCHEMA_VERSION,
                fingerprint=runtime.executable_fingerprint(),
                key=key,
                blob=blob,
            ),
            protocol=pickle.HIGHEST_PROTOCOL,
        )
        # multi-writer safe: N worker processes racing the same digest each
        # write a private tmp (mkstemp randomizes the name; the pid suffix
        # additionally namespaces writers, and makes a stray tmp attributable
        # post-mortem) and publish via atomic rename — last rename wins with
        # byte-identical content, readers never observe a torn file
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=f".{os.getpid()}.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(_MAGIC + hashlib.sha256(body).digest() + body)
            os.replace(tmp, path)  # atomic publish: readers see whole files only
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.written += 1
        return True

    # ------------------------------------------------------------- reading --
    def get(self, key):
        """The loaded executable for ``key``, or None (miss / rejected /
        quarantined).  Never raises."""
        path = self._file(key)
        if not os.path.exists(path):
            return None
        record = self._read_record(path)
        if record is None:
            return None
        if record["key"] != key:
            # digest collision or a tampered record: impossible by
            # construction, so treat as corruption
            self._quarantine(path)
            return None
        return self._load(record, path)

    def load_all(self) -> dict:
        """Every valid entry, as ``{session cache key: loaded executable}`` —
        the restart path: feed straight into ``Session(programs=...)``."""
        out: dict = {}
        for name in self.entries():
            path = os.path.join(self.path, name)
            record = self._read_record(path)
            if record is None:
                continue
            fn = self._load(record, path)
            if fn is not None:
                out[record["key"]] = fn
        return out

    def _read_record(self, path: str) -> dict | None:
        """Read + verify one entry file.  None on any failure: corruption is
        quarantined, foreign schema/fingerprint is a clean miss."""
        try:
            with open(path, "rb") as f:
                payload = f.read()
            header = len(_MAGIC) + _CHECKSUM_BYTES
            if len(payload) < header or not payload.startswith(_MAGIC):
                raise CacheCorruption(f"bad header: {os.path.basename(path)}")
            body = payload[header:]
            if hashlib.sha256(body).digest() != payload[len(_MAGIC):header]:
                raise CacheCorruption(f"checksum mismatch: {os.path.basename(path)}")
            record = pickle.loads(body)
            if not isinstance(record, dict) or "key" not in record or "blob" not in record:
                raise CacheCorruption(f"malformed record: {os.path.basename(path)}")
        except Exception:
            self._quarantine(path)
            return None
        if (
            record.get("schema") != SCHEMA_VERSION
            or record.get("fingerprint") != runtime.executable_fingerprint()
        ):
            self.rejected += 1
            return None
        return record

    def _load(self, record: dict, path: str):
        """Deserialize a verified record; quarantine on executable rejection
        (checksum passed but the runtime refused the artifact)."""
        try:
            fn = runtime.deserialize_compiled(record["blob"])
        except Exception:
            self._quarantine(path)
            return None
        self.loaded += 1
        return fn

    def _quarantine(self, path: str) -> None:
        """Rename, never delete: the bytes stay for post-mortem and can
        never be read as a cache entry again."""
        dst = path + _QUARANTINE
        n = 0
        while os.path.exists(dst):
            n += 1
            dst = f"{path}{_QUARANTINE}.{n}"
        try:
            os.replace(path, dst)
        except OSError:
            return  # already quarantined/removed by a concurrent reader
        self.quarantined += 1
