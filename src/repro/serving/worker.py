"""Worker-process entry point for multi-process design serving.

``python -m repro.serving.worker --socket <path> --id <n>`` connects back
to the coordinator (:class:`repro.serving.pool.MultiProcessDesignService`),
receives its construction config over the frame protocol, builds a
:class:`~repro.serving.pool.StagedBatchingService` over
``Session(cache_dir=...)`` against the *shared* AOT cache directory, and
then drains query chunks until told to shut down.  A preheated cache means
the service here rehydrates every program from disk — the worker answers
its first query with zero traces, bit-identical to the parent's sequential
replies (the executables are literally the same bytes).

Liveness: a daemon thread beacons ``hb`` every ``heartbeat_s``.  If a
beacon (or any send) fails, the coordinator is gone and the worker exits
immediately — orphaned workers must never outlive their pool.  The
coordinator symmetrically treats heartbeat silence, socket EOF and process
exit as worker death and requeues whatever this worker never answered.

Workers are *spawned* (``subprocess``), never forked: JAX's runtime is
initialized at import and forking it deadlocks (see the ``fork-unsafe``
lint rule).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import socket
import sys
import threading

from repro.serving import protocol


def _strip_raw(reply):
    """Drop device-array payloads (``FrontierResult.raw``) before pickling
    a reply onto the wire — jax arrays don't unpickle across processes and
    the raw population is a debugging artifact, not part of the reply
    contract."""
    result = reply.result
    if result is not None and hasattr(result, "raw") and result.raw is not None:
        reply = dataclasses.replace(reply, result=dataclasses.replace(result, raw=None))
    return reply


def _error_replies(svc, queries, exc):
    """Structured per-query failures when a whole chunk's replies could not
    be encoded (e.g. an unpicklable result object)."""
    return [svc._last_ditch(q, exc) for q in queries]


def serve_forever(sock_path: str, worker_id: int) -> int:
    from repro.serving.chaos import ChaosInjector
    from repro.serving.pool import StagedBatchingService

    conn = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    conn.connect(sock_path)
    send_lock = threading.Lock()  # heartbeat thread and reply frames interleave

    def send(tag, payload):
        frame = protocol.encode_frame(tag, payload)
        with send_lock:
            conn.sendall(frame)

    send("hello", {"worker": worker_id, "pid": os.getpid()})
    tag, cfg = protocol.recv_frame(conn)
    if tag != "cfg":
        raise protocol.ProtocolError(f"expected cfg, got {tag!r}")

    chaos = ChaosInjector(cfg["chaos"]) if cfg.get("chaos") is not None else None
    svc = StagedBatchingService(
        cfg["architecture"],
        policy=cfg["policy"],
        retry=cfg["retry"],
        deadlines=cfg["deadlines"],
        chaos=chaos,
        request_bucket=cfg["request_bucket"],
        cache_dir=cfg["cache_dir"],
    )
    if cfg.get("warm"):
        svc.warmup(
            cfg["warm"],
            objectives=tuple(cfg.get("objectives") or ("edp",)),
            kinds=tuple(cfg.get("kinds") or ("simulate", "explain")),
        )
    send("ready", {"worker": worker_id, "disk_loaded": svc.session.disk_loaded})

    stop = threading.Event()

    def beacon():
        while not stop.wait(cfg["heartbeat_s"]):
            try:
                send("hb", worker_id)
            except OSError:
                os._exit(1)  # coordinator is gone; don't linger

    threading.Thread(target=beacon, name="dragon-hb", daemon=True).start()

    while True:
        try:
            tag, payload = protocol.recv_frame(conn)
        except (OSError, protocol.ProtocolError):
            return 1  # coordinator died mid-stream
        if tag == "shutdown":
            stop.set()
            try:
                send("bye", svc.stats)
            except OSError:
                return 1  # coordinator gone; stats snapshot already piggybacked
            return 0
        if tag != "chunk":
            continue  # unknown frame: skip, stay alive
        cid, queries = payload
        replies = [_strip_raw(r) for r in svc.serve(queries)]
        try:
            frame = protocol.encode_frame("replies", (cid, replies, svc.stats))
        except Exception as e:  # unpicklable result: degrade per-query
            replies = _error_replies(svc, queries, e)
            frame = protocol.encode_frame("replies", (cid, replies, svc.stats))
        try:
            with send_lock:
                conn.sendall(frame)
        except OSError:
            return 1  # coordinator died mid-reply


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="DRAGON design-serving worker process")
    ap.add_argument("--socket", required=True, help="coordinator's unix socket path")
    ap.add_argument("--id", type=int, required=True, help="worker id assigned by the coordinator")
    args = ap.parse_args(argv)
    return serve_forever(args.socket, args.id)


if __name__ == "__main__":
    sys.exit(main())
