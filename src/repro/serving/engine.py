"""Serving engines: continuous token batching + DRAGON design queries.

**Token engine** (:class:`Engine`) — two jit'd programs (the same ones the
dry-run lowers):
  * prefill(params, tokens)            -> last-token logits + per-slot cache
  * decode_step(params, tokens, cache) -> next-token logits + updated cache

The engine multiplexes requests onto ``slots`` decode lanes: a free slot is
prefilled with an incoming prompt (cache rows for that slot are swapped in),
then joins the batched decode step; finished sequences (eos / max_tokens)
free their slot.  Per-slot cache lengths make ragged decoding exact.

Sampling: greedy or temperature, seeded per request (deterministic replay).

**Design service** (:class:`DesignService`) — the same serving pattern for
hardware-simulation queries: many simulate/explain/optimize requests
answered against ONE compiled model, via the :class:`repro.api.Session`
façade and its compiled-program cache.  Replies record wall time and
whether the query compiled anything, so a fleet operator can see the
cold/warm split that the cache-key semantics (docs/api.md) guarantee.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.resilience import (
    CircuitBreaker,
    CircuitOpen,
    ClientError,
    DeadlineConfig,
    DeadlineExceeded,
    FaultInfo,
    RetryPolicy,
    classify_exception,
    run_guarded,
)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] or [S, ncb]
    max_tokens: int = 32
    temperature: float = 0.0
    eos: Optional[int] = None
    seed: int = 0
    # filled by the engine
    generated: list = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


_MIN_PROMPT_BUCKET = 8


def _bucket_prompt(s: int) -> int:
    """Prompt-length bucket: next power of two, at least
    ``_MIN_PROMPT_BUCKET`` — a handful of compiled prefill programs instead
    of one per distinct prompt length."""
    return max(_MIN_PROMPT_BUCKET, 1 << (max(s, 1) - 1).bit_length())


class Engine:
    def __init__(self, model: Model, params, *, slots: int = 4, max_len: int = 512, mesh=None):
        self.model, self.params = model, params
        self.slots, self.max_len = slots, max_len
        self.mesh = mesh
        cfg = model.cfg
        # prompt bucketing is exact only for causal kv-cache families: the
        # true length is traced data (head slice + cache["len"]), so decode's
        # length-masked attention never reads a padded position.  Recurrent
        # (ssm/hybrid) prefill folds every position into the state — those
        # keep exact-length prefill and pay one trace per distinct length.
        self._bucket_prompts = model.cache_dims()["kind"] in ("kv", "kv+x")
        self._prefill = jax.jit(
            lambda p, t, n, v=None: model.prefill(
                p, t, max_len=max_len, vision=v, mesh=mesh, length=n
            )
        )
        self._prefill_exact = jax.jit(
            lambda p, t, v=None: model.prefill(p, t, max_len=max_len, vision=v, mesh=mesh)
        )
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, mesh=mesh), donate_argnums=(2,)
        )

        # slot admission as ONE compiled program (slot index is traced data):
        # donation updates the big cache buffers in place instead of copying
        # the whole slots-times-larger cache per admit
        def write(cache, src, slot):
            def wr(dst, s):
                if dst.ndim == 1:  # len
                    return dst.at[slot].set(s[0])
                # batch dim position differs per leaf kind: [L, B, ...] vs [B]
                return dst.at[:, slot].set(s[:, 0])

            return jax.tree.map(wr, cache, src)

        self._write = jax.jit(write, donate_argnums=(0,))
        self.cache = model.init_cache(slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._next_tok = np.zeros(
            (slots, 1, cfg.audio.n_codebooks) if cfg.audio else (slots, 1), np.int32
        )
        self._active_any = False

    # ------------------------------------------------------------ intake --
    def submit(self, req: Request):
        req.t_submit = time.time()
        self.queue.append(req)

    # ------------------------------------------------------- cache plumb --
    def _write_slot(self, slot: int, src_cache, src_b: int = 0):
        """Copy one request's prefill cache (batch 1) into slot ``slot``."""
        del src_b  # prefill serves batch 1; kept for call-site compatibility
        self.cache = self._write(self.cache, src_cache, slot)

    # --------------------------------------------------------------- step --
    def step(self):
        """One engine iteration: admit + prefill new requests, then one
        batched decode step for all active slots."""
        cfg = self.model.cfg
        # admit
        for slot in range(self.slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                prompt = np.asarray(req.prompt)
                vis = None
                if cfg.vision:
                    vis = jnp.zeros((1, cfg.vision.n_patches, cfg.vision.d_vision), jnp.float32)
                if self._bucket_prompts:
                    s = prompt.shape[0]
                    sb = min(self.max_len, _bucket_prompt(s))
                    if sb > s:
                        pad = ((0, sb - s),) + ((0, 0),) * (prompt.ndim - 1)
                        prompt = np.pad(prompt, pad)
                    logits, cache1 = self._prefill(self.params, jnp.asarray(prompt)[None], s, vis)
                else:
                    logits, cache1 = self._prefill_exact(self.params, jnp.asarray(prompt)[None], vis)
                self._write_slot(slot, cache1)
                tok = self._sample(req, np.asarray(logits)[0])
                req.t_first = time.time()
                req.generated.append(tok)
                self._next_tok[slot] = np.asarray(tok).reshape(self._next_tok[slot].shape)
                self.slot_req[slot] = req
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return False
        # batched decode (inactive slots decode garbage into their own lane)
        logits, self.cache = self._decode(self.params, jnp.asarray(self._next_tok), self.cache)
        logits = np.asarray(logits)
        for slot in active:
            req = self.slot_req[slot]
            tok = self._sample(req, logits[slot])
            req.generated.append(tok)
            self._next_tok[slot] = np.asarray(tok).reshape(self._next_tok[slot].shape)
            done = len(req.generated) >= req.max_tokens or (
                req.eos is not None and np.all(np.asarray(tok) == req.eos)
            )
            if done:
                req.done = True
                req.t_done = time.time()
                self.finished.append(req)
                self.slot_req[slot] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # ------------------------------------------------------------ sample --
    def _sample(self, req: Request, logits: np.ndarray):
        """logits: [V] or [ncb, V]."""
        if req.temperature <= 0.0:
            return logits.argmax(-1).astype(np.int32)
        # fold (rid, position) into the stream: integer *addition* made
        # adjacent seeds share one gumbel stream at an offset
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(req.seed), req.rid),
            len(req.generated),
        )
        g = np.asarray(jax.random.gumbel(key, logits.shape))
        return (logits / req.temperature + g).argmax(-1).astype(np.int32)


# --------------------------------------------------------------------------- #
# DRAGON design queries as a service (DSE-as-a-service, via the façade)
# --------------------------------------------------------------------------- #


@dataclass
class DesignQuery:
    """One design question: simulate / explain / optimize a workload set
    against an architecture, or sweep the Pareto ``frontier``.  ``workload``
    and ``architecture`` accept anything :class:`repro.api.Workload` /
    :class:`repro.api.Architecture` accept (names, ``.dhd`` text, graphs,
    pytrees); ``architecture=None`` uses the service default.  ``params``
    forwards engine knobs (``steps``, ``lr``, ``opt_over``, ...);
    ``deadline_s`` overrides the service's cold/warm budget for this query."""

    qid: int
    kind: str  # "simulate" | "explain" | "optimize" | "frontier"
    workload: Any
    architecture: Any = None
    objective: str = "edp"
    params: dict = field(default_factory=dict)
    deadline_s: Optional[float] = None
    tenant: Optional[str] = None  # None = the service's default session


@dataclass
class DesignReply:
    """Every submitted query gets exactly one reply — success or a typed,
    structured failure (docs/serving.md §reply contract).  ``ok=True``:
    ``result`` holds the report and ``error`` is None.  ``ok=False``:
    ``result`` is None and ``error`` carries the
    :class:`~repro.serving.resilience.FaultInfo` (stable ``code``, human
    message, attempts made, whether the fault class is retryable)."""

    qid: int
    kind: str
    wall_s: float  # total time in the service, retries and backoff included
    compiled: bool  # did answering require tracing a new program?
    result: Any  # SimReport | OptResult | FrontierResult, or None on error
    ok: bool = True
    error: Optional[FaultInfo] = None
    attempts: int = 1
    deadline_s: float = float("inf")  # the budget this query was held to
    straggler: bool = False  # flagged by the latency monitor (warm path only)
    batched: bool = False  # answered from a coalesced cross-request dispatch
    batch_size: int = 1  # queries sharing that dispatch (1 = sequential)


@dataclass(frozen=True)
class ServiceStats:
    """Cache counters (same fields :class:`repro.api.CacheStats` exposes,
    so existing consumers keep working) + the serving-health ledger."""

    programs: int
    hits: int
    misses: int
    traces: int
    queries: int
    ok: int
    retries: int  # extra attempts beyond the first, summed over queries
    deadline_misses: int
    degraded: int  # fast-failed by an open circuit breaker
    errors: dict  # fault code -> count
    stragglers: tuple  # (qid, wall_s) pairs flagged by the latency monitor
    breakers: dict  # (kind, bucket) -> breaker state snapshot
    batches: int = 0  # coalesced dispatches flushed (batching service only)
    batched_queries: int = 0  # queries answered from a coalesced dispatch
    tenants: int = 1  # sessions sharing this service's program cache

    @property
    def availability(self) -> float:
        """Fraction of queries answered ok within their deadline."""
        return self.ok / self.queries if self.queries else 1.0

    def merge(self, other: "ServiceStats") -> "ServiceStats":
        """Lossless aggregation of two workers' ledgers (the coordinator's
        fleet view).  Query counters, cache lookups and error codes sum;
        stragglers concatenate; breaker lanes merge key-wise (a lane is open
        fleet-wide if any worker's is; trips/rejections sum).  ``programs``
        and ``tenants`` sum *resident* executables/sessions — right for
        worker processes with private caches, an overcount when services
        share one programs dict (each reports the same residency).

        Partition-invariance — per-worker stats summed over any split of a
        query stream equal the sequential run's ledger — holds because every
        per-query outcome (chaos schedule, retry jitter, deadline class) is
        keyed on the query, never on worker identity or completion order;
        ``tests/test_serving_pool.py`` pins it as a property test.
        """
        errors = dict(self.errors)
        for code, n in other.errors.items():
            errors[code] = errors.get(code, 0) + n
        breakers = {k: dict(v) for k, v in self.breakers.items()}
        for key, st in other.breakers.items():
            if key in breakers:
                mine = breakers[key]
                breakers[key] = dict(
                    open=bool(mine["open"] or st["open"]),
                    failures=mine["failures"] + st["failures"],
                    trips=mine["trips"] + st["trips"],
                    rejected=mine["rejected"] + st["rejected"],
                )
            else:
                breakers[key] = dict(st)
        return ServiceStats(
            programs=self.programs + other.programs,
            hits=self.hits + other.hits, misses=self.misses + other.misses,
            traces=self.traces + other.traces,
            queries=self.queries + other.queries, ok=self.ok + other.ok,
            retries=self.retries + other.retries,
            deadline_misses=self.deadline_misses + other.deadline_misses,
            degraded=self.degraded + other.degraded,
            errors=errors, stragglers=self.stragglers + other.stragglers,
            breakers=breakers,
            batches=self.batches + other.batches,
            batched_queries=self.batched_queries + other.batched_queries,
            tenants=self.tenants + other.tenants,
        )

    def __add__(self, other: "ServiceStats") -> "ServiceStats":
        return self.merge(other)


@dataclass
class _Admitted:
    """A query that cleared intake: resolved inputs + the guard parameters
    :meth:`DesignService._complete` needs.  The seam between sequential
    answering and the batching layer's coalesced dispatch."""

    q: DesignQuery
    t0: float
    w: Any  # resolved Workload
    arch: Any  # resolved Architecture
    sess: Any  # the tenant's Session
    bkey: tuple  # circuit-breaker lane (kind, bucket)
    shape: tuple  # warmth key (kind, spec, bucket, objective)
    deadline: float


class DesignService:
    """Answer many design queries against one compiled model, fault-contained.

    The hardware-simulation twin of the token :class:`Engine`: a
    :class:`repro.api.Session` owns the compiled-program cache, so the
    steady state — repeated queries over same-bucket workloads — replays
    cached executables and the service runs as fast as the hardware allows.
    This is the seam async batching / multi-tenant serving / remote workers
    plug into.

    Every query runs through the resilience stack (docs/serving.md):

    * **isolation** — :meth:`submit` never raises; a batch always completes
      with one :class:`DesignReply` per query;
    * **intake quarantine** — unparseable ``.dhd``, non-finite graph
      tensors, empty workload sets and unknown kinds become structured
      ``client-error`` replies before any engine runs;
    * **deadlines** — per-query wall budgets, cold-compile vs warm
      (:class:`DeadlineConfig`), predicted from whether this
      (kind, spec, bucket, objective) shape has been served before;
    * **bounded retry** — transient/numeric faults retry with deterministic
      backoff while budget remains (:class:`RetryPolicy`);
    * **non-finite containment** — results with NaN/inf headline fields are
      typed ``numeric`` faults, never shipped;
    * **circuit breaker** — repeated failures on one (kind, bucket) trip to
      fast-fail replies until a cooldown (:class:`CircuitBreaker`);
    * **latency tracking** — per-query wall times feed a
      :class:`repro.ft.straggler.StragglerMonitor`; cold compiles re-prime
      its EWMA (their cost is expected), warm outliers are flagged on the
      reply and in :attr:`stats`.

    ``chaos`` accepts a :class:`repro.serving.chaos.ChaosInjector` — the
    seeded fault harness the bench/CI probe drives.  ``clock``/``sleep``
    are injectable for deterministic tests.
    """

    _KINDS = ("simulate", "explain", "optimize", "frontier")

    def __init__(self, architecture="base", *, retry: Optional[RetryPolicy] = None,
                 deadlines: Optional[DeadlineConfig] = None,
                 breaker: Optional[CircuitBreaker] = None, chaos=None,
                 monitor=None, clock=time.monotonic, sleep=time.sleep,
                 request_bucket: int = 8, **session_kw):
        from repro.api import Session
        from repro.ft.straggler import StragglerMonitor

        self.session = Session(architecture, **session_kw)
        self._default_architecture = architecture
        self._session_kw = dict(session_kw)
        self._session_kw.pop("programs", None)
        # tenants share the default session's programs dict, which already
        # holds everything cache_dir rehydrated — reloading per tenant would
        # only burn construction time
        self._session_kw.pop("cache_dir", None)
        # every serving dispatch — sequential or coalesced — pads its request
        # axis to this one bucket, so ONE compiled program serves every batch
        # size and replies are bit-identical however queries were batched
        # (XLA specializes reduction order to shape; two request buckets can
        # differ in the last ulp)
        self.request_bucket = int(request_bucket)
        # tenant name -> Session; all share self.session's compiled programs,
        # each keeps its own stats/workload memos (per-tenant isolation)
        self._tenants: dict = {}
        self.retry = retry or RetryPolicy()
        self.deadlines = deadlines or DeadlineConfig()
        self.breaker = breaker or CircuitBreaker(clock=clock)
        self.chaos = chaos
        self.monitor = monitor or StragglerMonitor()
        self._clock = clock
        self._sleep = sleep
        # guards shared mutable state (ledger, breaker, monitor, warmth) when
        # the pooled service completes queries from several threads; the
        # engine dispatch itself runs OUTSIDE this lock so chunks overlap
        self._mutex = threading.RLock()
        self._warm: set = set()  # (kind, spec, bucket, objective) shapes served
        self.replies: list[DesignReply] = []
        self._queries = 0
        self._ok = 0
        self._retries = 0
        self._deadline_misses = 0
        self._degraded = 0
        self._errors: dict = {}
        self._batches = 0
        self._batched_queries = 0

    # ------------------------------------------------------------ tenants --
    def _session_for(self, tenant: Optional[str]):
        """The tenant's own :class:`~repro.api.Session` over the shared
        compiled-program cache — a program any tenant compiles is warm for
        every other, but stats and memos never leak across tenants."""
        if tenant is None:
            return self.session
        with self._mutex:
            sess = self._tenants.get(tenant)
            if sess is None:
                from repro.api import Session

                sess = self._tenants[tenant] = Session(
                    self._default_architecture,
                    programs=self.session.programs,
                    **self._session_kw,
                )
            return sess

    def _sessions(self):
        return [self.session, *self._tenants.values()]

    # ------------------------------------------------------------- warmup --
    def warmup(self, workloads, *, objectives: tuple[str, ...] = ("edp",),
               kinds: tuple[str, ...] = ("simulate", "explain")) -> dict:
        """Preheat the service's declared working set at startup.

        Builds (AOT) the exact batched programs :meth:`submit` dispatches —
        pinned to this service's ``request_bucket`` — plus the sequential
        variants, and persists them when the service was constructed with
        ``cache_dir=...``.  A worker that calls ``warmup`` before taking
        traffic serves every declared shape with zero traces and the *warm*
        deadline from its first query; a restarted worker gets the same
        guarantee from the disk entries alone.  Returns the
        :meth:`repro.api.Session.preheat` summary dict.
        """
        return self.session.preheat(
            workloads, objectives=objectives, kinds=kinds,
            request_buckets=(self.request_bucket,),
        )

    def _preheated(self, kind: str, spec, bucket, objective: str) -> bool:
        """Disk/AOT warmth: True when every program ``kind`` dispatches for
        this shape is already in the shared cache, so the first serve pays
        dispatch only.  optimize/frontier run in the engines' own jit caches
        — preheat can't see those, so they are never disk-warm."""
        programs = self.session.programs
        mcfg = self.session.mcfg
        rb = self.request_bucket
        if kind == "simulate":
            return ("report_batched", spec, mcfg, bucket, rb) in programs
        if kind == "explain":
            return (
                ("report_batched", spec, mcfg, bucket, rb) in programs
                and ("explain_batched", spec, mcfg, bucket, objective, rb) in programs
            )
        return False

    # ------------------------------------------------------------- intake --
    def submit(self, q: DesignQuery) -> DesignReply:
        """Answer one query.  Never raises: every failure mode — bad input,
        engine exception, non-finite result, blown deadline, open breaker —
        degrades to a structured ``ok=False`` reply."""
        try:
            reply = self._answer(q)
        except Exception as e:
            reply = self._last_ditch(q, e)
        self._account(reply)
        self.replies.append(reply)
        return reply

    def serve(self, queries: list[DesignQuery]) -> list[DesignReply]:
        """Answer a batch.  Per-query isolation means the batch always
        completes: len(replies) == len(queries), in order, no exceptions."""
        return [self.submit(q) for q in queries]

    # ------------------------------------------------------------- answer --
    def _answer(self, q: DesignQuery) -> DesignReply:
        adm = self._prepare(q)
        if isinstance(adm, DesignReply):
            return adm
        return self._complete(adm)

    def _prepare(self, q: DesignQuery):
        """Intake: validate, resolve, consult the breaker and predict the
        deadline.  Returns a refusal :class:`DesignReply`, or an
        :class:`_Admitted` record ready for :meth:`_complete` — the batching
        layer runs intake for a whole flush before any engine work, so a
        poison query is quarantined before it can join a batch."""
        t0 = self._clock()
        if q.kind not in self._KINDS:
            return self._refuse(q, t0, ClientError(
                f"unknown DesignQuery.kind {q.kind!r} (expected one of {list(self._KINDS)})"
            ))
        # intake quarantine: resolve + validate inputs before any engine work
        # (Workload/Architecture reject non-finite tensors, empty sets and
        # malformed .dhd at construction)
        sess = self._session_for(q.tenant)
        try:
            w = sess._workload(q.workload)
            arch = sess._arch(q.architecture)
        except Exception as e:
            return self._refuse(q, t0, ClientError(
                f"poison query quarantined at intake: {type(e).__name__}: {e}"
            ))
        bkey = (q.kind, w.bucket)
        if not self.breaker.allow(bkey):
            return self._refuse(q, t0, CircuitOpen(
                f"circuit open for kind={q.kind!r} bucket={w.bucket} "
                f"(cooldown {self.breaker.cooldown_s:.1f}s)"
            ))
        shape = (q.kind, arch.spec, w.bucket, q.objective)
        # a shape is warm if it was served before (the PR 8 ledger) OR if
        # its programs were preheated / rehydrated from the persistent
        # cache — a restarted worker must predict warm deadlines from its
        # first query, not after re-learning every shape the hard way
        cold = shape not in self._warm and not self._preheated(
            q.kind, arch.spec, w.bucket, q.objective
        )
        deadline = q.deadline_s if q.deadline_s is not None else \
            self.deadlines.budget_s(cold, q.kind)
        return _Admitted(q=q, t0=t0, w=w, arch=arch, sess=sess, bkey=bkey,
                         shape=shape, deadline=deadline)

    def _complete(self, adm: "_Admitted", handler: Optional[Callable[[], Any]] = None,
                  *, batched: bool = False, batch_size: int = 1) -> DesignReply:
        """Run one admitted query through the guard stack.  ``handler``
        overrides the sequential engine call — the batching layer passes a
        closure that reads this query's lane of a coalesced dispatch."""
        q = adm.q
        if handler is None:
            handler = self._handler(q, adm.w, adm.arch, adm.sess)
        if self.chaos is not None:
            chaos, qid = self.chaos, q.qid

            def fn(attempt):
                return chaos.call(handler, qid=qid, attempt=attempt)
        else:
            def fn(attempt):
                return handler()
        traces0 = self._traces()
        out = run_guarded(fn, policy=self.retry, deadline_s=adm.deadline, token=q.qid,
                          clock=self._clock, sleep=self._sleep)
        compiled = self._traces() > traces0
        with self._mutex:
            if out.ok or compiled:
                # warm = the program is cached.  A query that failed before
                # anything compiled leaves the shape cold — the next query of
                # that shape still faces the full trace+compile and must get
                # the cold deadline, not the warm one.
                self._warm.add(adm.shape)
            # client errors don't indict the server; everything else votes
            if out.ok or out.fault.code != ClientError.code:
                self.breaker.record(adm.bkey, out.ok)
            straggler = False
            if out.ok:
                if compiled:
                    # a cold compile is *expected* to be slow: reset the
                    # latency baseline instead of polluting the EWMA /
                    # flagging it
                    self.monitor.reprime(out.wall_s)
                else:
                    straggler = bool(self.monitor.record(q.qid, out.wall_s))
        return DesignReply(
            qid=q.qid, kind=q.kind, wall_s=self._clock() - adm.t0, compiled=compiled,
            result=out.result, ok=out.ok, error=out.fault,
            attempts=max(out.attempts, 1), deadline_s=adm.deadline,
            straggler=straggler, batched=batched, batch_size=batch_size,
        )

    def _handler(self, q: DesignQuery, w, arch, sess) -> Callable[[], Any]:
        rb = self.request_bucket
        return {
            "simulate": lambda: sess.simulate_batch(
                [w], architectures=[arch], request_bucket=rb
            )[0],
            "explain": lambda: sess.explain_batch(
                [w], objective=q.objective, architectures=[arch], request_bucket=rb
            )[0],
            "optimize": lambda: sess.optimize(
                w, objective=q.objective, architecture=arch, **q.params
            ),
            "frontier": lambda: sess.frontier(w, **q.params),
        }[q.kind]

    def _refuse(self, q: DesignQuery, t0: float, fault) -> DesignReply:
        """A structured no-attempt reply (quarantine / open breaker)."""
        return DesignReply(
            qid=q.qid, kind=q.kind, wall_s=self._clock() - t0, compiled=False,
            result=None, ok=False,
            error=FaultInfo(code=fault.code, message=str(fault), attempts=0,
                            retryable=fault.retryable),
            attempts=0, deadline_s=0.0,
        )

    # ----------------------------------------------------------- plumbing --
    def _account(self, r: DesignReply) -> None:
        with self._mutex:
            self._queries += 1
            self._retries += max(0, r.attempts - 1)
            if r.ok:
                self._ok += 1
                return
            code = r.error.code if r.error else "fault"
            self._errors[code] = self._errors.get(code, 0) + 1
            if code == DeadlineExceeded.code:
                self._deadline_misses += 1
            elif code == CircuitOpen.code:
                self._degraded += 1

    def _last_ditch(self, q, e: Exception) -> DesignReply:
        """Isolation of last resort: a bug in the guard stack itself must
        still cost only this one query."""
        fault = classify_exception(e)
        return DesignReply(
            qid=getattr(q, "qid", -1), kind=getattr(q, "kind", "?"),
            wall_s=0.0, compiled=False, result=None, ok=False,
            error=FaultInfo(code=fault.code, message=str(fault),
                            attempts=1, retryable=fault.retryable),
            attempts=1, deadline_s=0.0,
        )

    def _traces(self) -> int:
        """Traces attributable to this service: every tenant Session's
        programs plus the shared engine steps.  Scoped (not the global
        counter) so a concurrent service compiling its own programs doesn't
        mislabel this one's warm queries as cold; only the engine tags are
        shared."""
        from repro.core import instrument

        return sum(s.stats.traces for s in self._sessions()) + instrument.trace_count(
            "dopt._dopt_step"
        ) + instrument.trace_count("popsim._member_step")

    @property
    def stats(self) -> ServiceStats:
        per = [s.stats for s in self._sessions()]
        return ServiceStats(
            programs=per[0].programs,  # the cache is shared: one count
            hits=sum(s.hits for s in per), misses=sum(s.misses for s in per),
            traces=sum(s.traces for s in per),
            queries=self._queries, ok=self._ok, retries=self._retries,
            deadline_misses=self._deadline_misses, degraded=self._degraded,
            errors=dict(self._errors), stragglers=tuple(self.monitor.flagged),
            breakers=self.breaker.snapshot(),
            batches=self._batches, batched_queries=self._batched_queries,
            tenants=len(self._sessions()),
        )


class BatchingDesignService(DesignService):
    """:class:`DesignService` with cross-request batching (ROADMAP item 1).

    Queries enter an intake queue; a :class:`~repro.serving.batching.FlushPolicy`
    flushes on batch size or queue age.  A flush runs intake quarantine for
    *every* query first (a poison query never joins a batch), groups the
    admitted simulate/explain queries by ``(kind, spec, bucket, objective)``,
    and answers each group with ONE vmapped dispatch over a request axis —
    the same compiled program, padded to ``policy.max_batch``, that the
    sequential path uses, so coalesced replies are bit-identical to serving
    the same queries one at a time (pinned by test).

    Every query still runs through the full PR 7 guard stack individually:
    the coalesced dispatch is lazily memoized inside the first lane's
    guarded attempt (see :func:`~repro.serving.batching.make_chunk_handlers`),
    so retries, deadlines, chaos injection, breaker votes and non-finite
    containment all stay per-query — one bad query in a batch costs only
    that query.

    ``optimize``/``frontier`` queries pass through the flush as singleton
    chunks on the sequential path (their useful work is a whole descent;
    there is nothing to coalesce).
    """

    #: smallest batchable chunk routed through :meth:`_dispatch_chunk`;
    #: below it the sequential handler runs.  The staged pool subclass
    #: lowers this to 1 — its dispatcher is faster than the sequential
    #: assembly path even for a single lane.
    _coalesce_min = 2

    def __init__(self, architecture="base", *, policy=None, **kw):
        from repro.serving.batching import FlushPolicy, IntakeQueue

        self.policy = policy or FlushPolicy()
        # the flush cap doubles as the pinned request bucket: sequential and
        # coalesced dispatches share one program => bit-identical replies
        kw.setdefault("request_bucket", self.policy.max_batch)
        super().__init__(architecture, **kw)
        self._queue = IntakeQueue(clock=self._clock)

    # ------------------------------------------------------------- intake --
    def enqueue(self, q: DesignQuery) -> list[DesignReply]:
        """Queue one query; flush if the policy says a batch is due.
        Returns the replies flushed *now* (often empty — they arrive with a
        later flush).  Never raises."""
        self._queue.push(q)
        return self.pump()

    def pump(self) -> list[DesignReply]:
        """Flush if due (size or queue-age trigger); else no-op."""
        if self._queue.due(self.policy):
            return self.flush()
        return []

    def submit(self, q: DesignQuery) -> DesignReply:
        """Answer one query immediately (a flush of one — same program,
        same reply bits as arriving in a full batch)."""
        return self.serve([q])[0]

    def serve(self, queries: list[DesignQuery]) -> list[DesignReply]:
        """Answer a batch through the coalescing path.  Per-query isolation
        holds: len(replies) == len(queries), in order, no exceptions."""
        if len(self._queue):  # earlier enqueue()d strays answer separately
            self.flush()
        for q in queries:
            self._queue.push(q)
        return self.flush()

    # -------------------------------------------------------------- flush --
    def flush(self) -> list[DesignReply]:
        """Drain the queue and answer everything, coalescing same-shape
        queries into one dispatch per chunk.  Replies come back in arrival
        order; accounting matches :meth:`DesignService.submit` exactly."""
        from repro.serving.batching import batch_key, make_chunk_handlers, plan_chunks

        items = self._queue.drain()
        if not items:
            return []
        replies: list = [None] * len(items)
        admitted: list = []
        for i, (t_enq, q) in enumerate(items):
            try:
                prep = self._prepare(q)
            except Exception as e:
                prep = self._last_ditch(q, e)
            if isinstance(prep, DesignReply):
                replies[i] = prep
            else:
                prep.t0 = t_enq  # wall time includes the queue wait
                admitted.append((i, prep))
        handler_of: dict = {}
        size_of: dict = {}
        for chunk in plan_chunks(admitted, self.policy.max_batch):
            if len(chunk) < self._coalesce_min or batch_key(chunk[0][1]) is None:
                continue  # nothing to coalesce; sequential handler
            handler_of.update(make_chunk_handlers(chunk, self._dispatch_chunk))
            for idx, _ in chunk:
                size_of[idx] = len(chunk)
            if len(chunk) > 1:  # a size-1 staged dispatch is not a coalesce
                self._batches += 1
                self._batched_queries += len(chunk)
        for i, adm in admitted:
            try:
                replies[i] = self._complete(
                    adm, handler_of.get(i),
                    batched=size_of.get(i, 1) > 1, batch_size=size_of.get(i, 1),
                )
            except Exception as e:
                replies[i] = self._last_ditch(adm.q, e)
        for r in replies:
            self._account(r)
            self.replies.append(r)
        return replies

    def _dispatch_chunk(self, adms: list) -> list:
        """ONE vmapped dispatch answering a whole same-key chunk.  Runs on
        the default session (programs are shared across tenants, parameter
        values are traced data — per-lane results match each tenant's own
        sequential dispatch bit for bit)."""
        kind = adms[0].q.kind
        ws = [a.w for a in adms]
        archs = [a.arch for a in adms]
        if kind == "simulate":
            return self.session.simulate_batch(
                ws, architectures=archs, request_bucket=self.request_bucket
            )
        return self.session.explain_batch(
            ws, objective=adms[0].q.objective, architectures=archs,
            request_bucket=self.request_bucket,
        )
