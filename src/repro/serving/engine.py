"""Serving engines: continuous token batching + DRAGON design queries.

**Token engine** (:class:`Engine`) — two jit'd programs (the same ones the
dry-run lowers):
  * prefill(params, tokens)            -> last-token logits + per-slot cache
  * decode_step(params, tokens, cache) -> next-token logits + updated cache

The engine multiplexes requests onto ``slots`` decode lanes: a free slot is
prefilled with an incoming prompt (cache rows for that slot are swapped in),
then joins the batched decode step; finished sequences (eos / max_tokens)
free their slot.  Per-slot cache lengths make ragged decoding exact.

Sampling: greedy or temperature, seeded per request (deterministic replay).

**Design service** (:class:`DesignService`) — the same serving pattern for
hardware-simulation queries: many simulate/explain/optimize requests
answered against ONE compiled model, via the :class:`repro.api.Session`
façade and its compiled-program cache.  Replies record wall time and
whether the query compiled anything, so a fleet operator can see the
cold/warm split that the cache-key semantics (docs/api.md) guarantee.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] or [S, ncb]
    max_tokens: int = 32
    temperature: float = 0.0
    eos: Optional[int] = None
    seed: int = 0
    # filled by the engine
    generated: list = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, model: Model, params, *, slots: int = 4, max_len: int = 512, mesh=None):
        self.model, self.params = model, params
        self.slots, self.max_len = slots, max_len
        self.mesh = mesh
        cfg = model.cfg
        self._prefill = jax.jit(
            lambda p, t, v=None: model.prefill(p, t, max_len=max_len, vision=v, mesh=mesh)
        )
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, mesh=mesh), donate_argnums=(2,)
        )
        self.cache = model.init_cache(slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._next_tok = np.zeros(
            (slots, 1, cfg.audio.n_codebooks) if cfg.audio else (slots, 1), np.int32
        )
        self._active_any = False

    # ------------------------------------------------------------ intake --
    def submit(self, req: Request):
        req.t_submit = time.time()
        self.queue.append(req)

    # ------------------------------------------------------- cache plumb --
    def _write_slot(self, slot: int, src_cache, src_b: int = 0):
        """Copy one request's prefill cache (batch 1) into slot ``slot``."""
        def wr(dst, src):
            if dst.ndim == 1:  # len
                return dst.at[slot].set(src[src_b])
            # batch dim position differs per leaf kind: [L, B, ...] vs [B]
            return dst.at[:, slot].set(src[:, src_b])

        self.cache = jax.tree.map(wr, self.cache, src_cache)

    # --------------------------------------------------------------- step --
    def step(self):
        """One engine iteration: admit + prefill new requests, then one
        batched decode step for all active slots."""
        cfg = self.model.cfg
        # admit
        for slot in range(self.slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray(req.prompt)[None]
                vis = None
                if cfg.vision:
                    vis = jnp.zeros((1, cfg.vision.n_patches, cfg.vision.d_vision), jnp.float32)
                logits, cache1 = self._prefill(self.params, toks, vis)
                self._write_slot(slot, cache1)
                tok = self._sample(req, np.asarray(logits)[0])
                req.t_first = time.time()
                req.generated.append(tok)
                self._next_tok[slot] = np.asarray(tok).reshape(self._next_tok[slot].shape)
                self.slot_req[slot] = req
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return False
        # batched decode (inactive slots decode garbage into their own lane)
        logits, self.cache = self._decode(self.params, jnp.asarray(self._next_tok), self.cache)
        logits = np.asarray(logits)
        for slot in active:
            req = self.slot_req[slot]
            tok = self._sample(req, logits[slot])
            req.generated.append(tok)
            self._next_tok[slot] = np.asarray(tok).reshape(self._next_tok[slot].shape)
            done = len(req.generated) >= req.max_tokens or (
                req.eos is not None and np.all(np.asarray(tok) == req.eos)
            )
            if done:
                req.done = True
                req.t_done = time.time()
                self.finished.append(req)
                self.slot_req[slot] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # ------------------------------------------------------------ sample --
    def _sample(self, req: Request, logits: np.ndarray):
        """logits: [V] or [ncb, V]."""
        if req.temperature <= 0.0:
            return logits.argmax(-1).astype(np.int32)
        key = jax.random.PRNGKey(req.seed + len(req.generated))
        g = np.asarray(jax.random.gumbel(key, logits.shape))
        return (logits / req.temperature + g).argmax(-1).astype(np.int32)


# --------------------------------------------------------------------------- #
# DRAGON design queries as a service (DSE-as-a-service, via the façade)
# --------------------------------------------------------------------------- #


@dataclass
class DesignQuery:
    """One design question: simulate / explain / optimize a workload set
    against an architecture.  ``workload`` and ``architecture`` accept
    anything :class:`repro.api.Workload` / :class:`repro.api.Architecture`
    accept (names, ``.dhd`` text, graphs, pytrees); ``architecture=None``
    uses the service default.  ``params`` forwards engine knobs
    (``steps``, ``lr``, ``opt_over``, ...)."""

    qid: int
    kind: str  # "simulate" | "explain" | "optimize"
    workload: Any
    architecture: Any = None
    objective: str = "edp"
    params: dict = field(default_factory=dict)


@dataclass
class DesignReply:
    qid: int
    kind: str
    wall_s: float
    compiled: bool  # did answering require tracing a new program?
    result: Any  # SimReport | OptResult (repro.core.report)


class DesignService:
    """Answer many design queries against one compiled model.

    The hardware-simulation twin of the token :class:`Engine`: a
    :class:`repro.api.Session` owns the compiled-program cache, so the
    steady state — repeated queries over same-bucket workloads — replays
    cached executables and the service runs as fast as the hardware allows.
    This is the seam async batching / multi-tenant serving / remote workers
    plug into.
    """

    def __init__(self, architecture="base", **session_kw):
        from repro.api import Session

        self.session = Session(architecture, **session_kw)
        self.replies: list[DesignReply] = []

    def submit(self, q: DesignQuery) -> DesignReply:
        handler = {
            "simulate": lambda: self.session.simulate(q.workload, architecture=q.architecture),
            "explain": lambda: self.session.explain(
                q.workload, objective=q.objective, architecture=q.architecture
            ),
            "optimize": lambda: self.session.optimize(
                q.workload, objective=q.objective, architecture=q.architecture, **q.params
            ),
        }.get(q.kind)
        if handler is None:
            raise ValueError(f"unknown DesignQuery.kind {q.kind!r}")
        traces0 = self._traces()
        t0 = time.perf_counter()
        result = handler()
        reply = DesignReply(
            qid=q.qid,
            kind=q.kind,
            wall_s=time.perf_counter() - t0,
            compiled=self._traces() > traces0,
            result=result,
        )
        self.replies.append(reply)
        return reply

    def _traces(self) -> int:
        """Traces attributable to this service: its own Session's programs
        plus the shared engine steps.  Scoped (not the global counter) so a
        concurrent service compiling its own programs doesn't mislabel this
        one's warm queries as cold; only the engine tags are shared."""
        from repro.core import instrument

        return self.session.stats.traces + instrument.trace_count(
            "dopt._dopt_step"
        ) + instrument.trace_count("popsim._member_step")

    def serve(self, queries: list[DesignQuery]) -> list[DesignReply]:
        return [self.submit(q) for q in queries]

    @property
    def stats(self):
        return self.session.stats
