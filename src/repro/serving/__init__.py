from repro.serving.aotcache import AotCache, CacheCorruption, cache_key_digest  # noqa: F401
from repro.serving.batching import FlushPolicy, IntakeQueue  # noqa: F401
from repro.serving.chaos import ChaosConfig, ChaosInjector, FaultPlan  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    BatchingDesignService,
    DesignQuery,
    DesignReply,
    DesignService,
    Engine,
    Request,
    ServiceStats,
)
from repro.serving.pool import (  # noqa: F401
    MultiProcessDesignService,
    PooledDesignService,
    StagedBatchingService,
)
from repro.serving.protocol import ProtocolError, recv_frame, send_frame  # noqa: F401
from repro.serving.resilience import (  # noqa: F401
    CircuitBreaker,
    CircuitOpen,
    ClientError,
    DeadlineConfig,
    DeadlineExceeded,
    FaultInfo,
    NumericFault,
    RetryPolicy,
    ServingFault,
    TransientFault,
    classify_exception,
    nonfinite_in,
    run_guarded,
)
