from repro.serving.engine import Engine, Request  # noqa: F401
