from repro.serving.engine import (  # noqa: F401
    DesignQuery,
    DesignReply,
    DesignService,
    Engine,
    Request,
)
