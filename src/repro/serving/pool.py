"""Async worker-pool serving: overlapped dispatch + multi-process workers.

The single-thread :class:`~repro.serving.engine.BatchingDesignService`
serializes host-side batch assembly, device dispatch and report
construction on one thread — on the mixed design load that is ~95% host
assembly (tree-stacking 16 lanes costs ~25 ms against a ~0.6 ms program
dispatch).  This module is the serving tier above it, in two layers:

* :class:`StagedBatchingService` — the same coalescing service with a
  **staging-buffer** chunk dispatcher: per-lane parameter leaves are
  memoized as numpy views once per (workload, architecture) and copied
  into preallocated ``(request_bucket, ...)`` staging buffers (~0.1 ms for
  16 lanes, ~250x the stacked path), then fed to the *identical* batched
  program the sequential path runs.  Same program + same pad convention
  (repeat lane 0) = bit-identical replies, by construction.

* :class:`PooledDesignService` — async intake: callers ``enqueue`` and a
  dispatcher thread pulls flushed chunks from the :class:`IntakeQueue`,
  hands each to a bounded thread pool, and completions scatter back by
  ticket.  Host assembly of one chunk overlaps the device dispatch and
  report construction of another; the PR 7 guard stack still wraps every
  query individually (``_complete`` bookkeeping is mutex-guarded, the
  engine call runs outside the lock).

* :class:`MultiProcessDesignService` — N worker *processes*, each a
  :class:`StagedBatchingService` over ``Session(cache_dir=...)`` against
  one shared :class:`~repro.serving.aotcache.AotCache` directory (PR 9's
  persistent executables make worker spin-up zero-compile).  The
  coordinator owns a private Unix socket (:mod:`repro.serving.protocol`),
  shards flushed chunks to the least-loaded live worker, tracks worker
  heartbeats, detects crashes (process exit, EOF, heartbeat silence) and
  **re-enqueues in-flight queries** of a dead worker; per-worker
  :class:`ServiceStats` piggyback on reply frames and aggregate losslessly
  via :meth:`ServiceStats.merge`.  ``ChaosConfig.p_worker_kill`` marks
  queries whose assigned worker the coordinator SIGKILLs (once per qid) —
  the injectable crash fault the bench gates on.

Workers are spawned with ``subprocess`` (``python -m repro.serving.worker``),
never ``fork``: a forked JAX runtime deadlocks on its internal thread pools
(the ``fork-unsafe`` lint rule pins this repo-wide).

Determinism under concurrency: chaos schedules, retry jitter and deadline
classes are all pure functions of the query (qid, retry index, shape) —
never of thread identity, worker count or completion order — so the same
seed replays the same per-query faults on 1 worker or 8, and per-worker
stats summed over any partition equal the sequential ledger
(``tests/test_serving_pool.py`` pins both).
"""
from __future__ import annotations

import itertools
import os
import selectors
import socket
import subprocess
import sys
import tempfile
import threading
import time
import weakref
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.serving import protocol
from repro.serving.batching import FlushPolicy, IntakeQueue, batch_key, make_chunk_handlers, plan_chunks
from repro.serving.chaos import ChaosConfig, ChaosInjector
from repro.serving.engine import (
    BatchingDesignService,
    DesignQuery,
    DesignReply,
    ServiceStats,
)
from repro.serving.resilience import FaultInfo, TransientFault

__all__ = [
    "StagedBatchingService",
    "PooledDesignService",
    "MultiProcessDesignService",
]


# --------------------------------------------------------------------------- #
# staging-buffer assembly
# --------------------------------------------------------------------------- #


class _StagedAssembler:
    """Fast host-side batch assembly for one session.

    ``Session._assemble_batch`` tree-stacks device arrays per call; this
    assembler instead memoizes each lane's flattened *numpy* leaves once
    per (architecture, workload) object and writes them into reusable
    ``(request_bucket, ...)`` staging buffers.  The output pytree has the
    exact structure and pad convention (lane 0 repeated) of the stacked
    path, and feeds the same compiled program — XLA converts host numpy
    identically to device stacking, so per-lane outputs are bit-identical
    (pinned by test).

    Buffers are thread-local: pool workers stage concurrently without
    copies racing.  Lane memos are weak-keyed so a transient Architecture
    (e.g. a one-off ``.dhd`` query) never pins memory or risks an id-reuse
    collision.
    """

    def __init__(self, request_bucket: int):
        self.nb = int(request_bucket)
        self._lock = threading.Lock()
        self._arch_np: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
        self._w_np: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
        self._tls = threading.local()

    def _arch_leaves(self, a) -> list:
        with self._lock:
            out = self._arch_np.get(a)
        if out is None:
            out = [np.asarray(x) for x in jax.tree.leaves((a.tech, a.arch))]
            with self._lock:
                self._arch_np[a] = out
        return out

    def _w_leaves(self, w) -> list:
        with self._lock:
            out = self._w_np.get(w)
        if out is None:
            out = [np.asarray(x) for x in jax.tree.leaves(w.stacked)]
            with self._lock:
                self._w_np[w] = out
        return out

    def stage(self, ws, archs):
        """``(techs, arch_ps, gstacks)`` staged to the request bucket —
        drop-in for the stacked pytrees ``Session._assemble_batch`` returns
        (callers validated same-spec / same-bucket already)."""
        lanes = [self._arch_leaves(a) + self._w_leaves(w) for w, a in zip(ws, archs)]
        key = (archs[0].spec, ws[0].bucket)
        cache = getattr(self._tls, "bufs", None)
        if cache is None:
            cache = self._tls.bufs = {}
        entry = cache.get(key)
        if entry is None:
            treedef = jax.tree.structure((archs[0].tech, archs[0].arch, ws[0].stacked))
            bufs = [np.empty((self.nb,) + lf.shape, lf.dtype) for lf in lanes[0]]
            entry = cache[key] = (treedef, bufs)
        treedef, bufs = entry
        n = len(lanes)
        for i in range(self.nb):
            lane = lanes[i] if i < n else lanes[0]  # pad = repeat lane 0
            for j, leaf in enumerate(lane):
                bufs[j][i] = leaf
        return jax.tree.unflatten(treedef, bufs)


class StagedBatchingService(BatchingDesignService):
    """:class:`BatchingDesignService` whose chunk dispatch assembles via
    :class:`_StagedAssembler` — bit-identical replies, ~10x the host
    throughput.  Also routes *singleton* batchable chunks through the
    staged dispatcher (``_coalesce_min = 1``): a lone simulate query costs
    one 0.1 ms staging pass instead of the sequential tree-stack.  This is
    the service a pool worker process runs."""

    _coalesce_min = 1

    def __init__(self, architecture="base", *, policy=None, **kw):
        super().__init__(architecture, policy=policy, **kw)
        self._assembler = _StagedAssembler(self.request_bucket)

    def _dispatch_chunk(self, adms: list) -> list:
        kind = adms[0].q.kind
        sess = self.session
        ws = [a.w for a in adms]
        archs = [a.arch for a in adms]
        bucket, spec = ws[0].bucket, archs[0].spec
        staged = self._assembler.stage(ws, archs)
        prog = sess._batched_report_program(self.request_bucket, bucket, spec, sess.mcfg)
        perfs, extras = prog(*staged)
        reports = sess._reports_from_batch(ws, archs, perfs, extras)
        if kind == "simulate":
            return reports
        objective = adms[0].q.objective
        eprog = sess._batched_explain_program(
            self.request_bucket, bucket, spec, sess.mcfg, objective
        )
        g_techs, g_archs = eprog(*staged)
        return sess._attribute_batch(reports, g_techs, g_archs, objective)


# --------------------------------------------------------------------------- #
# threaded pool: dispatcher thread + bounded worker pool
# --------------------------------------------------------------------------- #


class PooledDesignService(StagedBatchingService):
    """Async serving over one process: a dispatcher thread drains the
    intake queue per the flush policy and hands each planned chunk to a
    bounded thread pool, so one chunk's host assembly overlaps another's
    device dispatch and report construction.

    * :meth:`enqueue` is non-blocking and returns a **ticket**; replies
      scatter into an internal map as chunks complete.
    * :meth:`serve` keeps the synchronous contract — enqueue all, barrier
      on :meth:`join`, return replies in query order.
    * :meth:`join` forces a drain of sub-policy stragglers and blocks until
      every enqueued query has a reply.
    * Guard-stack semantics are unchanged: every query runs
      ``_complete`` individually (retry / deadline / chaos / breaker /
      non-finite checks), chunk-locally memoized exactly like the
      synchronous flush.  Bookkeeping races are closed by the service
      mutex; the engine call runs outside any lock.

    One caveat inherited from concurrency: ``DesignReply.compiled`` (and
    the straggler monitor's cold-reprime) keys on a service-wide trace
    counter, so with several chunks *compiling* simultaneously a query can
    be labelled compiled because its neighbor traced.  Preheated fleets —
    the deployment this tier exists for — compile nothing on the query
    path, where the label is exact.
    """

    def __init__(self, architecture="base", *, workers: int = 2, policy=None,
                 poll_s: Optional[float] = None, **kw):
        super().__init__(architecture, policy=policy, **kw)
        self.workers = max(1, int(workers))
        self._ticket = itertools.count()
        self._cond = threading.Condition()
        self._pending = 0
        self._results: dict[int, DesignReply] = {}
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._drain_now = False
        self._poll_s = poll_s if poll_s is not None else max(self.policy.max_delay_s, 0.001)
        self._exec = ThreadPoolExecutor(
            max_workers=self.workers, thread_name_prefix="dragon-pool"
        )
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="dragon-dispatcher", daemon=True
        )
        self._dispatcher.start()

    # ------------------------------------------------------------- intake --
    def enqueue(self, q: DesignQuery) -> int:
        """Queue one query, non-blocking; returns a ticket for
        :meth:`take`.  (The synchronous parent returns flushed replies
        here — the async tier never blocks intake on a flush.)"""
        if self._stop.is_set():
            raise RuntimeError("PooledDesignService is closed")
        ticket = next(self._ticket)
        with self._cond:
            self._pending += 1
        self._queue.push((ticket, q))
        if self._queue.due(self.policy):
            self._wake.set()
        return ticket

    def pump(self) -> list:
        return []  # the dispatcher thread owns flushing

    def submit(self, q: DesignQuery) -> DesignReply:
        return self.serve([q])[0]

    def serve(self, queries: list[DesignQuery]) -> list[DesignReply]:
        tickets = [self.enqueue(q) for q in queries]
        self.join()
        return [self.take(t) for t in tickets]

    def flush(self) -> list:
        """Force-drain; returns [] (replies arrive via tickets)."""
        self.join()
        return []

    # ------------------------------------------------------------ results --
    def take(self, ticket: int) -> Optional[DesignReply]:
        """Pop the reply for a ticket (None if not finished yet)."""
        with self._cond:
            return self._results.pop(ticket, None)

    def join(self, timeout: Optional[float] = None) -> bool:
        """Force a drain and block until every enqueued query has a reply.
        Returns False on timeout."""
        self._drain_now = True
        self._wake.set()
        with self._cond:
            return self._cond.wait_for(lambda: self._pending == 0, timeout=timeout)

    def close(self) -> None:
        """Drain, then stop the dispatcher and the worker pool."""
        if self._stop.is_set():
            return
        self.join()
        self._stop.set()
        self._wake.set()
        self._dispatcher.join(timeout=10)
        self._exec.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # --------------------------------------------------------- dispatcher --
    def _dispatch_loop(self) -> None:
        while True:
            self._wake.wait(self._poll_s)
            self._wake.clear()
            drain = self._drain_now
            self._drain_now = False
            if drain or self._queue.due(self.policy):
                items = self._queue.drain()
                if items:
                    self._process(items)
            if self._stop.is_set() and not len(self._queue):
                return

    def _process(self, items: list) -> None:
        """Intake + plan one drained batch, then fan chunks out to the
        pool.  Mirrors the synchronous ``flush`` accounting exactly."""
        admitted: list = []
        ticket_of: dict[int, int] = {}
        for i, (t_enq, (ticket, q)) in enumerate(items):
            ticket_of[i] = ticket
            try:
                prep = self._prepare(q)
            except Exception as e:
                prep = self._last_ditch(q, e)
            if isinstance(prep, DesignReply):
                self._finish(ticket, prep)
            else:
                prep.t0 = t_enq  # wall time includes the queue wait
                admitted.append((i, prep))
        for chunk in plan_chunks(admitted, self.policy.max_batch):
            handler_of: dict = {}
            if len(chunk) >= self._coalesce_min and batch_key(chunk[0][1]) is not None:
                handler_of = make_chunk_handlers(chunk, self._dispatch_chunk)
                if len(chunk) > 1:
                    with self._mutex:
                        self._batches += 1
                        self._batched_queries += len(chunk)
            try:
                self._exec.submit(self._run_chunk, chunk, handler_of, ticket_of)
            except RuntimeError:  # pool shut down mid-close: finish inline
                self._run_chunk(chunk, handler_of, ticket_of)

    def _run_chunk(self, chunk: list, handler_of: dict, ticket_of: dict) -> None:
        n = len(chunk)
        for i, adm in chunk:
            try:
                reply = self._complete(
                    adm, handler_of.get(i),
                    batched=n > 1 and i in handler_of,
                    batch_size=n if i in handler_of else 1,
                )
            except Exception as e:
                reply = self._last_ditch(adm.q, e)
            self._finish(ticket_of[i], reply)

    def _finish(self, ticket: int, reply: DesignReply) -> None:
        self._account(reply)
        with self._cond:
            self._results[ticket] = reply
            self.replies.append(reply)
            self._pending -= 1
            self._cond.notify_all()


# --------------------------------------------------------------------------- #
# multi-process coordinator
# --------------------------------------------------------------------------- #


@dataclass
class _Worker:
    """Coordinator-side state for one worker process."""

    wid: int
    proc: Optional[subprocess.Popen] = None
    conn: Optional[socket.socket] = None
    last_seen: float = 0.0
    ready: bool = False
    alive: bool = True
    inflight: dict = field(default_factory=dict)  # chunk id -> [(ticket, query)]
    stats: Optional[ServiceStats] = None


_EMPTY_STATS = ServiceStats(
    programs=0, hits=0, misses=0, traces=0, queries=0, ok=0, retries=0,
    deadline_misses=0, degraded=0, errors={}, stragglers=(), breakers={},
)


class MultiProcessDesignService:
    """N worker processes draining design queries from one coordinator.

    Each worker is a :class:`StagedBatchingService` over
    ``Session(cache_dir=...)`` against the **shared** AOT cache directory,
    so a preheated cache gives every worker zero-compile spin-up and
    bit-identical programs.  The coordinator is deliberately engine-free:
    it resolves queries only far enough to group them by batch key (a
    resolver ``Session`` that never dispatches), shards full chunks to the
    least-loaded live worker over the frame protocol, and scatters replies
    back by ticket.

    Fault containment extends the PR 7 stack across the process boundary:

    * **heartbeats** — workers beacon every ``heartbeat_s`` from a daemon
      thread; silence beyond ``worker_timeout_s`` marks the worker dead
      (hung processes count as dead, not just exited ones);
    * **crash detection** — process exit, socket EOF and framing errors
      all route to the same death path;
    * **requeue** — a dead worker's in-flight, unanswered queries re-enter
      the intake queue and are re-planned onto surviving workers; replies
      are deduplicated by ticket (first answer wins), so a worker killed
      *after* replying costs nothing;
    * **worker-kill chaos** — with ``chaos=ChaosConfig(p_worker_kill=...)``
      the coordinator SIGKILLs the assigned worker of each marked qid
      (once per qid, deterministically seeded like every other fault) and
      the requeue path must restore availability — the bench gate.

    ``stats`` merges the latest per-worker :class:`ServiceStats` (workers
    piggyback a snapshot on every reply frame, so even a crashed worker's
    ledger survives to its last answered chunk); ``pool_info`` carries the
    coordinator's own counters (kills, requeues, worker liveness).
    """

    def __init__(self, architecture: str = "base", *, workers: int = 2,
                 cache_dir=None, policy: Optional[FlushPolicy] = None,
                 retry=None, deadlines=None, chaos: Optional[ChaosConfig] = None,
                 request_bucket: Optional[int] = None,
                 heartbeat_s: float = 0.25, worker_timeout_s: float = 10.0,
                 ready_timeout_s: float = 600.0, max_inflight_chunks: int = 2,
                 warm: Optional[list] = None, objectives: tuple = ("edp",),
                 kinds: tuple = ("simulate", "explain"),
                 worker_cmd: Optional[list] = None):
        if cache_dir is None:
            raise ValueError(
                "multi-process serving requires cache_dir= (the shared AotCache "
                "directory workers rehydrate their programs from)"
            )
        if not isinstance(architecture, str):
            raise TypeError(
                "MultiProcessDesignService takes the architecture as a library "
                "name or .dhd text (it must cross a process boundary)"
            )
        self.architecture = architecture
        self.workers = max(1, int(workers))
        self.cache_dir = str(cache_dir)
        self.policy = policy or FlushPolicy()
        self.retry = retry
        self.deadlines = deadlines
        self.chaos_config = chaos
        self.request_bucket = int(request_bucket or self.policy.max_batch)
        self.heartbeat_s = float(heartbeat_s)
        self.worker_timeout_s = float(worker_timeout_s)
        self.ready_timeout_s = float(ready_timeout_s)
        self.max_inflight_chunks = max(1, int(max_inflight_chunks))
        self.warm = list(warm) if warm else None
        self.objectives = tuple(objectives)
        self.kinds = tuple(kinds)
        self.worker_cmd = list(worker_cmd) if worker_cmd else None
        # plan() only — the coordinator never injects attempt faults itself
        self._chaos_planner = ChaosInjector(chaos) if chaos is not None else None
        self.kills = 0
        self.requeues = 0
        self._killed: set[int] = set()
        self._queue = IntakeQueue()
        self._backlog: deque = deque()  # planned chunks awaiting a worker slot
        self._ticket = itertools.count()
        self._cid = itertools.count()
        self._cond = threading.Condition()
        self._pending = 0
        self._results: dict[int, DesignReply] = {}
        self._resolved: set[int] = set()
        self.replies: list[DesignReply] = []
        self._workers: dict[int, _Worker] = {}
        self._resolver = None  # lazy Session for batch-key grouping
        self._stop = threading.Event()
        self._drain_now = False
        self._started = False
        self._closed = False
        self._dir: Optional[str] = None
        self._loop_thread: Optional[threading.Thread] = None

    # -------------------------------------------------------------- start --
    def start(self) -> "MultiProcessDesignService":
        """Spawn workers, handshake, wait until all are warmed and taking
        traffic, then start the coordinator loop."""
        if self._started:
            return self
        import repro

        self._dir = tempfile.mkdtemp(prefix="dragon-pool-")
        sock_path = os.path.join(self._dir, "pool.sock")
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(sock_path)
        self._listener.listen(self.workers)
        self._listener.settimeout(self.ready_timeout_s)
        # the child must import repro the same way we did, wherever the
        # parent was launched from
        env = dict(os.environ)
        src_root = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        base_cmd = self.worker_cmd or [sys.executable, "-m", "repro.serving.worker"]
        for wid in range(self.workers):
            proc = subprocess.Popen(
                base_cmd + ["--socket", sock_path, "--id", str(wid)], env=env
            )
            self._workers[wid] = _Worker(wid=wid, proc=proc)
        cfg = dict(
            architecture=self.architecture, policy=self.policy,
            retry=self.retry, deadlines=self.deadlines,
            request_bucket=self.request_bucket, cache_dir=self.cache_dir,
            chaos=self.chaos_config, heartbeat_s=self.heartbeat_s,
            warm=self.warm, objectives=self.objectives, kinds=self.kinds,
        )
        for _ in range(self.workers):
            conn, _addr = self._listener.accept()
            conn.settimeout(self.ready_timeout_s)
            tag, payload = protocol.recv_frame(conn)
            if tag != "hello":
                raise protocol.ProtocolError(f"expected hello, got {tag!r}")
            w = self._workers[payload["worker"]]
            w.conn = conn
            w.last_seen = time.monotonic()
            protocol.send_frame(conn, "cfg", cfg)
        for w in self._workers.values():
            tag, payload = protocol.recv_frame(w.conn)
            while tag == "hb":  # beacons may precede readiness
                tag, payload = protocol.recv_frame(w.conn)
            if tag != "ready":
                raise protocol.ProtocolError(f"worker {w.wid}: expected ready, got {tag!r}")
            w.ready = True
            w.last_seen = time.monotonic()
            # liveness now rides on heartbeats; a blocking recv must not
            # stall the loop longer than one beacon interval
            w.conn.settimeout(self.worker_timeout_s)
        self._started = True
        self._loop_thread = threading.Thread(
            target=self._loop, name="dragon-coordinator", daemon=True
        )
        self._loop_thread.start()
        return self

    # ------------------------------------------------------------- intake --
    def enqueue(self, q: DesignQuery) -> int:
        if not self._started:
            self.start()
        if self._stop.is_set():
            raise RuntimeError("MultiProcessDesignService is closed")
        ticket = next(self._ticket)
        with self._cond:
            self._pending += 1
        self._queue.push((ticket, q))
        return ticket

    def serve(self, queries: list[DesignQuery]) -> list[DesignReply]:
        tickets = [self.enqueue(q) for q in queries]
        self.join()
        with self._cond:
            return [self._results.pop(t) for t in tickets]

    def take(self, ticket: int) -> Optional[DesignReply]:
        with self._cond:
            return self._results.pop(ticket, None)

    def join(self, timeout: Optional[float] = None) -> bool:
        self._drain_now = True
        with self._cond:
            return self._cond.wait_for(lambda: self._pending == 0, timeout=timeout)

    # ------------------------------------------------------------ results --
    @property
    def stats(self) -> ServiceStats:
        """The merged fleet ledger (latest snapshot per worker)."""
        per = [w.stats for w in self._workers.values() if w.stats is not None]
        if not per:
            return _EMPTY_STATS
        out = per[0]
        for s in per[1:]:
            out = out.merge(s)
        return out

    @property
    def pool_info(self) -> dict:
        """Coordinator-side counters: worker liveness, chaos kills, requeues."""
        return dict(
            workers=self.workers,
            alive=sum(1 for w in self._workers.values() if w.alive),
            ready=sum(1 for w in self._workers.values() if w.ready),
            kills=self.kills,
            requeues=self.requeues,
        )

    # ------------------------------------------------------------ shutdown --
    def close(self, timeout: float = 30.0) -> None:
        """Drain, stop the loop, collect final worker stats, reap."""
        if self._closed:
            return
        self._closed = True
        if self._started:
            self.join(timeout=timeout)
            self._stop.set()
            if self._loop_thread is not None:
                self._loop_thread.join(timeout=timeout)
            for w in self._workers.values():
                if not (w.alive and w.conn):
                    continue
                try:
                    protocol.send_frame(w.conn, "shutdown", None)
                    w.conn.settimeout(5.0)
                    tag, payload = protocol.recv_frame(w.conn)
                    while tag != "bye":
                        tag, payload = protocol.recv_frame(w.conn)
                    w.stats = payload
                except (OSError, protocol.ProtocolError):
                    pass  # worker left early; last piggybacked snapshot stands
            for w in self._workers.values():
                if w.conn is not None:
                    try:
                        w.conn.close()
                    except OSError:
                        pass
                if w.proc is not None:
                    try:
                        w.proc.wait(timeout=5)
                    except subprocess.TimeoutExpired:
                        w.proc.kill()
                        w.proc.wait(timeout=5)
            try:
                self._listener.close()
            except OSError:
                pass
        if self._dir is not None:
            import shutil

            shutil.rmtree(self._dir, ignore_errors=True)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.close()
        return False

    # ----------------------------------------------------------- the loop --
    def _loop(self) -> None:
        sel = selectors.DefaultSelector()
        for w in self._workers.values():
            if w.alive and w.conn is not None:
                sel.register(w.conn, selectors.EVENT_READ, w)
        poll_s = max(self.policy.max_delay_s, 0.002)
        try:
            while not self._stop.is_set():
                self._maybe_dispatch(sel)
                for key, _ev in sel.select(timeout=poll_s):
                    self._read_worker(key.data, sel)
                self._check_liveness(sel)
        finally:
            sel.close()

    def _maybe_dispatch(self, sel) -> None:
        drain = self._drain_now
        self._drain_now = False
        if drain or self._queue.due(self.policy):
            for chunk in self._plan(self._queue.drain()):
                self._backlog.append(chunk)
        self._pump(sel)

    def _pump(self, sel) -> None:
        """Backpressured assignment: at most ``max_inflight_chunks`` chunks
        outstanding per worker.  Blasting the whole backlog down the pipes
        deadlocks at scale — the coordinator blocks in ``sendall`` while
        every worker blocks sending a reply frame nobody is reading, the
        worker's heartbeat thread starves behind its send lock, and
        ``worker_timeout_s`` later the whole fleet reads as hung.  Bounding
        in-flight chunks keeps both socket directions shallow and caps how
        much a crashed worker can strand."""
        while self._backlog:
            live = [w for w in self._workers.values() if w.alive and w.ready]
            if live and min(len(w.inflight) for w in live) >= self.max_inflight_chunks:
                return  # every live worker saturated: resume on next reply
            self._assign(self._backlog.popleft(), sel)

    # ------------------------------------------------------------- planning --
    def _resolve_key(self, q: DesignQuery):
        """The batch key, via a resolver Session that never dispatches.
        Unresolvable queries group as singletons — the worker owns the
        actual quarantine (and emits the structured client-error reply)."""
        if q.kind not in ("simulate", "explain"):
            return None
        if self._resolver is None:
            from repro.api import Session

            self._resolver = Session(self.architecture)
        try:
            w = self._resolver._workload(q.workload)
            a = self._resolver._arch(q.architecture)
        except Exception:
            return None
        return (q.kind, a.spec, w.bucket, q.objective if q.kind == "explain" else None)

    def _plan(self, items: list) -> list:
        """Group drained ``(t, (ticket, q))`` items into same-key chunks
        capped at the request bucket — ``plan_chunks`` over wire queries
        instead of admitted records."""
        chunks: list = []
        open_chunk: dict = {}
        for _t, (ticket, q) in items:
            key = self._resolve_key(q)
            if key is None:
                chunks.append([(ticket, q)])
                continue
            at = open_chunk.get(key)
            if at is None or len(chunks[at]) >= self.request_bucket:
                open_chunk[key] = len(chunks)
                chunks.append([(ticket, q)])
            else:
                chunks[at].append((ticket, q))
        return chunks

    # ----------------------------------------------------------- assignment --
    def _assign(self, chunk: list, sel) -> None:
        live = [w for w in self._workers.values() if w.alive and w.ready]
        if not live:
            for ticket, q in chunk:
                self._finish(ticket, self._no_worker_reply(q))
            return
        w = min(live, key=lambda h: len(h.inflight))
        cid = next(self._cid)
        w.inflight[cid] = chunk
        kill = False
        if self._chaos_planner is not None and len(live) >= 2:
            # enact a planned kill only while a survivor remains: the fault
            # models one process crashing out of a fleet, not the fleet
            # evaporating (a marked qid on the last live worker is skipped
            # permanently — the plan stays deterministic, enactment is
            # capacity-bounded)
            for _ticket, q in chunk:
                if q.qid not in self._killed and self._chaos_planner.plan(q.qid).worker_kill:
                    self._killed.add(q.qid)  # at most one kill per qid
                    kill = True
        try:
            protocol.send_frame(w.conn, "chunk", (cid, [q for _, q in chunk]))
        except (OSError, protocol.ProtocolError):
            self._dead(w, sel)  # requeues this chunk with the rest
            return
        if kill and w.proc is not None:
            # the seeded crash fault: SIGKILL the worker this chunk just
            # landed on, then take the death path immediately — the chunk
            # (and anything else unanswered) requeues onto survivors
            self.kills += 1
            self._chaos_planner._count("worker_kill")
            try:
                w.proc.kill()
            except OSError:
                pass
            self._dead(w, sel)

    def _no_worker_reply(self, q: DesignQuery) -> DesignReply:
        fault = TransientFault("no live workers (all worker processes died)")
        return DesignReply(
            qid=q.qid, kind=q.kind, wall_s=0.0, compiled=False, result=None,
            ok=False, error=FaultInfo(code=fault.code, message=str(fault),
                                      attempts=0, retryable=True),
            attempts=0, deadline_s=0.0,
        )

    # -------------------------------------------------------------- events --
    def _read_worker(self, w: _Worker, sel) -> None:
        try:
            tag, payload = protocol.recv_frame(w.conn)
        except (OSError, protocol.ProtocolError):
            self._dead(w, sel)
            return
        w.last_seen = time.monotonic()
        if tag == "hb":
            return
        if tag == "replies":
            cid, replies, stats = payload
            w.stats = stats
            chunk = w.inflight.pop(cid, None)
            if chunk is None:
                return  # chunk was already requeued (kill/reply race)
            if len(replies) == len(chunk):
                pairs = list(zip((t for t, _ in chunk), replies))
            else:  # defensive: match by qid if the worker reordered
                by_qid = {q.qid: t for t, q in chunk}
                pairs = [(by_qid.get(r.qid), r) for r in replies]
            for ticket, reply in pairs:
                if ticket is None:
                    continue
                self._finish(ticket, reply)
            self._pump(sel)  # a slot freed: hand this worker its next chunk
        elif tag == "bye":
            w.stats = payload

    def _finish(self, ticket: int, reply: DesignReply) -> None:
        with self._cond:
            if ticket in self._resolved:
                return  # duplicate answer after a requeue race: first wins
            self._resolved.add(ticket)
            self._results[ticket] = reply
            self.replies.append(reply)
            self._pending -= 1
            self._cond.notify_all()

    def _dead(self, w: _Worker, sel) -> None:
        """One death path for every detection mode: unregister, reap, and
        re-enqueue whatever the worker never answered."""
        if not w.alive:
            return
        w.alive = False
        w.ready = False
        try:
            sel.unregister(w.conn)
        except (KeyError, ValueError, OSError):
            pass
        try:
            w.conn.close()
        except OSError:
            pass
        if w.proc is not None and w.proc.poll() is None:
            try:
                w.proc.kill()
            except OSError:
                pass
        for _cid, chunk in w.inflight.items():
            for ticket, q in chunk:
                with self._cond:
                    done = ticket in self._resolved
                if done:
                    continue
                self.requeues += 1
                self._queue.push((ticket, q))
        w.inflight.clear()
        self._drain_now = True

    def _check_liveness(self, sel) -> None:
        now = time.monotonic()
        for w in list(self._workers.values()):
            if not w.alive:
                continue
            if w.proc is not None and w.proc.poll() is not None:
                self._dead(w, sel)
            elif now - w.last_seen > self.worker_timeout_s:
                self._dead(w, sel)  # hung counts as dead
