"""Pallas TPU chunked SSD scan (Mamba2) — the hot loop of the zamba2 hybrid
and the long_500k cells.

The SSD (state-space dual) form splits the sequence into chunks of length L:
intra-chunk work is dense matmuls (MXU-friendly), and only a small [N, P]
state carries between chunks.  This kernel implements the exact chunked
recurrence:

  per head h, chunk c:
    dtA       = dt * A_h                      [L]
    cum       = cumsum(dtA)                   [L]
    Lmat[i,j] = exp(cum_i - cum_j) (i >= j)   [L, L]   (decay matrix)
    y_diag[i] = sum_j (C_i . B_j) Lmat[i,j] dt_j x_j      (intra-chunk)
    y_off[i]  = (C_i . state) exp(cum_i)                  (inter-chunk)
    state'    = exp(cum_last) state + B^T diag(exp(cum_last - cum) dt) x

Grid: (batch, heads, chunks) with the chunk axis sequential ("arbitrary") so
the state lives in VMEM scratch across chunk steps.  Blocks: x (L, P),
B/C (L, N), dt (L,) — with L=256, P=64, N=64 in bf16 that is ~100 KB VMEM.
fp32 accumulation throughout; cum/decay math in fp32.

The chunked form is algebraically exact, so the oracle (ref.ssd_reference —
a naive per-timestep lax.scan) must match to fp tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime


def _ssd_kernel(
    x_ref,  # [1, L, 1, P]
    dt_ref,  # [1, L, 1]
    a_ref,  # [1, 1]  A coefficient for this head (negative)
    b_ref,  # [1, L, N]
    c_ref,  # [1, L, N]
    y_ref,  # [1, L, 1, P] out
    state_out_ref,  # [1, 1, N, P] out (final state)
    state_ref,  # VMEM scratch [N, P] fp32
    *,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [L]
    A = a_ref[0, 0].astype(jnp.float32)  # scalar
    B = b_ref[0].astype(jnp.float32)  # [L, N]
    C = c_ref[0].astype(jnp.float32)  # [L, N]

    dtA = dt * A  # [L]
    cum = jnp.cumsum(dtA)  # [L]
    cum_last = cum[-1]

    # intra-chunk: scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, i >= j
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)  # [L, L]
    L = cum.shape[0]
    decay = jnp.exp(cum[:, None] - cum[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    scores = jnp.where(ii >= jj, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)  # [L, P]

    # inter-chunk: contribution of the carried state
    state = state_ref[...]  # [N, P]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update
    w = (jnp.exp(cum_last - cum) * dt)[:, None]  # [L, 1]
    state_new = jnp.exp(cum_last) * state + jax.lax.dot_general(
        B, x * w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [N, P]
    state_ref[...] = state_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        state_out_ref[0, 0] = state_new.astype(state_out_ref.dtype)


def ssd_chunk_scan(
    x: jax.Array,  # [Batch, S, H, P]
    dt: jax.Array,  # [Batch, S, H]   (softplus-activated, positive)
    A: jax.Array,  # [H]             (negative)
    B: jax.Array,  # [Batch, S, N]   (n_groups=1, shared across heads)
    C: jax.Array,  # [Batch, S, N]
    *,
    chunk: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [Batch,S,H,P], final_state [Batch,H,N,P])."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    chunk = runtime.clamp_block(chunk, S, name="chunk")
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks)
    y, state = runtime.dragon_pallas_call(
        kernel,
        grid=(Bt, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (0, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, N, P), jnp.float32),
        ],
        scratch_shapes=[runtime.vmem_scratch((N, P), jnp.float32)],
        interpret=interpret,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )(x, dt, A.reshape(1, H), B, C)
    return y, state
