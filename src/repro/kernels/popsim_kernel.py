"""Pallas TPU population-simulation kernel — DSim's hot loop (the paper's
~1000x speed claim) batched across DSE candidate populations.

One grid step evaluates a block of BP candidate designs against the whole
workload DFG: the graph's per-vertex stats stay resident in VMEM (one HBM
read per population block) and a fori_loop walks the vertices, accumulating
cycles + dynamic energy per candidate with the mapper's forward semantics
(tiling, max(t_comp, t_mem) critical path, prefetch/stream gating on the
bandwidth EMA).  Lanes = candidates, so all per-vertex arithmetic is
(BP,)-vectorized on the VPU.

Packed layouts (see ops.pack_chw / ops.pack_graph):
  chw   [P, 27]: freq, cap_gbuf, bw[3], rlat[3], wlat[3], re_pb[3], we_pb[3],
                 e_flop[4], rate[4] (FLOP/cycle), sys_x, sys_y
                 (= CHW_COLS = 27; column slices below are the ground truth)
  graph [V, 16]: n_comp[4], n_read[3], n_write[3], n_alloc_gbuf, main_alloc,
                 dims[3], pad  (= GRAPH_COLS = 16)
Output [P, 8]: cycles, e_dyn, t_comp, t_mem, t_exposed, tiles, pad, pad.

The pure-jnp oracle is ref.popsim_reference — identical math via lax.scan —
and tests sweep population/graph sizes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime

# chw packed column indices
FREQ, CAP_GBUF = 0, 1
BW = slice(2, 5)
RLAT = slice(5, 8)
WLAT = slice(8, 11)
RE_PB = slice(11, 14)
WE_PB = slice(14, 17)
E_FLOP = slice(17, 21)
RATE = slice(21, 25)
SYS_X, SYS_Y = 25, 26
CHW_COLS = 27

# graph packed column indices
G_COMP = slice(0, 4)
G_READ = slice(4, 7)
G_WRITE = slice(7, 10)
G_ALLOC_GBUF = 10
G_MAIN_PRESENT = 11
G_DIMS = slice(12, 15)
GRAPH_COLS = 16

# layout consistency: the column map must tile the declared widths exactly
assert RATE.stop == SYS_X and SYS_Y == CHW_COLS - 1, "chw column map out of sync"
assert G_DIMS.stop < GRAPH_COLS, "graph column map out of sync"

OUT_COLS = 8
_LOCAL, _GBUF, _MAIN = 0, 1, 2
_SYS = 0
HEADROOM = 0.9


def _popsim_kernel(graph_ref, chw_ref, out_ref, *, n_vertices: int):
    chw = chw_ref[...].astype(jnp.float32)  # [BP, CHW_COLS]
    freq = chw[:, FREQ]
    cap_gbuf = chw[:, CAP_GBUF] * HEADROOM
    bw = chw[:, BW]  # [BP, 3]
    rlat, wlat = chw[:, RLAT], chw[:, WLAT]
    re_pb, we_pb = chw[:, RE_PB], chw[:, WE_PB]
    e_flop, rate = chw[:, E_FLOP], chw[:, RATE]
    sys_x, sys_y = chw[:, SYS_X], chw[:, SYS_Y]

    bp = chw.shape[0]
    zeros = jnp.zeros((bp,), jnp.float32)

    def body(v, carry):
        cycles, e_dyn, t_comp_acc, t_mem_acc, t_exp_acc, tiles_acc, occupancy, bw_ema = carry
        g = graph_ref[v]  # [GRAPH_COLS]
        n_comp = g[G_COMP]  # [4]
        n_read, n_write = g[G_READ], g[G_WRITE]
        alloc_gbuf = g[G_ALLOC_GBUF]
        has_main = g[G_MAIN_PRESENT]
        M, N, K = g[G_DIMS][0], g[G_DIMS][1], g[G_DIMS][2]

        tiles = jnp.maximum(jnp.ceil(alloc_gbuf / cap_gbuf), 1.0)  # [BP]

        # systolic wave model (same calibrated form as mapper.py)
        m_t = jnp.maximum(M / tiles, 1.0)
        waves = jnp.ceil(m_t / sys_x) * jnp.ceil(jnp.maximum(N, 1.0) / sys_y)
        cyc_sys_tile = waves * (jnp.ceil(jnp.maximum(K, 1.0)) + sys_x + sys_y)
        ops_sys_tile = n_comp[_SYS] / tiles
        cyc_sys_tile = jnp.maximum(
            cyc_sys_tile, ops_sys_tile / jnp.maximum(rate[:, _SYS], 1e-9)
        )
        t_sys = jnp.where(ops_sys_tile > 0, tiles * cyc_sys_tile / freq, 0.0)
        eff = jnp.maximum(rate, 1e-9) * freq[:, None]  # FLOP/s
        t_other = jnp.max((n_comp[None, :] / eff).at[:, _SYS].set(0.0), axis=-1)
        t_comp = jnp.maximum(t_other, t_sys)  # [BP]

        t_lvl = (n_read + n_write)[None, :] / bw * 1.04  # bank-conflict mean
        t_tile_lat = tiles[:, None] * (rlat + wlat)
        t_onchip = jnp.maximum(t_lvl[:, _GBUF] + t_tile_lat[:, _GBUF], t_lvl[:, _LOCAL])
        t_main = t_lvl[:, _MAIN] + t_tile_lat[:, _MAIN] * has_main

        can_prefetch = ((occupancy + alloc_gbuf / tiles) < cap_gbuf).astype(jnp.float32) * (
            bw_ema < HEADROOM
        ).astype(jnp.float32)
        can_stream = (bw_ema < HEADROOM).astype(jnp.float32)
        hide = jnp.maximum(can_prefetch, can_stream)

        t_core = jnp.maximum(t_comp, t_onchip)
        t_exposed = jnp.maximum(t_main - hide * t_core, 0.0)
        # integer-cycle quantization per tile; no-op (padding) vertices are
        # free and excluded from diagnostics (matches mapper.py)
        active = (
            jnp.sum(n_comp) + jnp.sum(n_read) + jnp.sum(n_write) + alloc_gbuf + has_main
        ) > 0
        t_vertex = tiles * jnp.ceil((t_core + t_exposed) * freq / tiles) / freq * active

        # EMA of the *demanded* (no-overlap) utilization — matches mapper.py's
        # carry-free recurrence, not the post-gating realized time
        t_full = tiles * jnp.ceil((t_core + t_main) * freq / tiles) / freq
        used_bw = jnp.where(
            t_full > 0,
            (n_read[_GBUF] + n_write[_GBUF]) / jnp.maximum(t_full, 1e-30) / bw[:, _GBUF],
            0.0,
        )
        bw_ema = 0.8 * bw_ema + 0.2 * jnp.clip(used_bw, 0.0, 2.0)
        occupancy = jnp.minimum(0.5 * occupancy + alloc_gbuf, cap_gbuf / HEADROOM)

        e_v = jnp.sum(n_read[None, :] * re_pb + n_write[None, :] * we_pb, -1) + jnp.sum(
            n_comp[None, :] * e_flop, -1
        )
        return (
            cycles + t_vertex * freq,
            e_dyn + e_v,
            t_comp_acc + t_comp,
            t_mem_acc + t_onchip * active,
            t_exp_acc + t_exposed,
            tiles_acc + tiles * active,
            occupancy,
            bw_ema,
        )

    init = (zeros,) * 8
    cycles, e_dyn, t_c, t_m, t_e, tiles, _, _ = jax.lax.fori_loop(0, n_vertices, body, init)
    out = jnp.stack([cycles, e_dyn, t_c, t_m, t_e, tiles, zeros, zeros], axis=-1)
    out_ref[...] = out.astype(out_ref.dtype)


def popsim(
    graph_packed: jax.Array,  # [V, GRAPH_COLS] fp32
    chw_packed: jax.Array,  # [P, CHW_COLS] fp32
    *,
    block_pop: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate P candidate designs against one DFG.  Returns [P, OUT_COLS]."""
    V = graph_packed.shape[0]
    P = chw_packed.shape[0]
    block_pop = runtime.clamp_block(block_pop, P, name="block_pop")

    kernel = functools.partial(_popsim_kernel, n_vertices=V)
    return runtime.dragon_pallas_call(
        kernel,
        grid=(P // block_pop,),
        in_specs=[
            pl.BlockSpec((V, GRAPH_COLS), lambda p: (0, 0)),  # graph resident
            pl.BlockSpec((block_pop, CHW_COLS), lambda p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((block_pop, OUT_COLS), lambda p: (p, 0)),
        out_shape=jax.ShapeDtypeStruct((P, OUT_COLS), jnp.float32),
        interpret=interpret,
        dimension_semantics=("parallel",),
    )(graph_packed, chw_packed)
