"""Pallas TPU selective-scan kernel (Mamba1) — falcon-mamba's hot spot.

The jnp chunked scan (models/mamba.selective_scan) materializes the
[B, chunk, C, N] decay/update tensors in HBM every chunk — ~60 s of HBM
time per train step for falcon-mamba-7b (§Roofline). This kernel keeps the
SSM state [block_c, N] resident in VMEM scratch and streams u/dt/B/C
chunk-by-chunk, so HBM traffic drops to the O(S·C) inputs/outputs — the
mamba-style "hardware-aware" scan, TPU edition.

Grid: (batch, channel_blocks, seq_chunks); the seq axis is sequential
("arbitrary") so the state scratch carries across chunks. Inside a chunk a
fori_loop steps time; every op is [block_c, N]-shaped (VPU lanes on N,
sublanes on channels).

Validated against the exact per-step recurrence in tests/test_kernels.py.

This module also hosts :func:`affine_scan` — the first-order affine prefix
``s_i = decay * s_{i-1} + b_i`` the DSim mapper's bandwidth-EMA carry
dispatches through when ``MapperCfg.scan_impl == "pallas"``.  The forward
runs as a Pallas kernel (state resident in VMEM scratch, sequential grid
over chunks, through the ``runtime.dragon_pallas_call`` seam); the backward
is the closed-form reversed scan (``custom_vjp``), so the mapper stays
fully differentiable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime


def _scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref,
                 *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0].astype(jnp.float32)    # [chunk, bc]
    dt = dt_ref[0].astype(jnp.float32)  # [chunk, bc]
    A = a_ref[...].astype(jnp.float32)  # [bc, N]
    Bm = b_ref[0].astype(jnp.float32)   # [chunk, N]
    Cm = c_ref[0].astype(jnp.float32)   # [chunk, N]
    D = d_ref[...].astype(jnp.float32)  # [1, bc]

    def step(t, carry):
        state, ys = carry  # [bc, N], [chunk, bc]
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)  # [1, bc]
        u_t = jax.lax.dynamic_slice_in_dim(u, t, 1, 0)
        b_t = jax.lax.dynamic_slice_in_dim(Bm, t, 1, 0)  # [1, N]
        c_t = jax.lax.dynamic_slice_in_dim(Cm, t, 1, 0)
        decay = jnp.exp(dt_t.T * A)  # [bc, N]
        state = decay * state + (dt_t * u_t).T * b_t  # [bc, N]
        y_t = jnp.sum(state * c_t, axis=1) + (u_t * D)[0]  # [bc]
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y_t[None], t, 0)
        return state, ys

    state, ys = jax.lax.fori_loop(
        0, chunk, step, (state_ref[...], jnp.zeros_like(u))
    )
    state_ref[...] = state
    y_ref[0] = ys.astype(y_ref.dtype)


def selective_scan_pallas(
    u: jax.Array,   # [B, S, C]
    dt: jax.Array,  # [B, S, C] (post softplus)
    A: jax.Array,   # [C, N] (negative)
    Bm: jax.Array,  # [B, S, N]
    Cm: jax.Array,  # [B, S, N]
    D: jax.Array,   # [C]
    *,
    chunk: int = 64,
    block_c: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    B, S, C = u.shape
    N = A.shape[1]
    chunk = runtime.clamp_block(chunk, S, name="chunk")
    block_c = runtime.clamp_block(block_c, C, name="block_c")
    n_chunks = S // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    return runtime.dragon_pallas_call(
        kernel,
        grid=(B, C // block_c, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_c), lambda b, c, s: (b, s, c)),  # u
            pl.BlockSpec((1, chunk, block_c), lambda b, c, s: (b, s, c)),  # dt
            pl.BlockSpec((block_c, N), lambda b, c, s: (c, 0)),            # A
            pl.BlockSpec((1, chunk, N), lambda b, c, s: (b, s, 0)),        # B
            pl.BlockSpec((1, chunk, N), lambda b, c, s: (b, s, 0)),        # C
            pl.BlockSpec((1, block_c), lambda b, c, s: (0, c)),            # D
        ],
        out_specs=pl.BlockSpec((1, chunk, block_c), lambda b, c, s: (b, s, c)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), u.dtype),
        scratch_shapes=[runtime.vmem_scratch((block_c, N), jnp.float32)],
        interpret=interpret,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )(u, dt, A, Bm, Cm, D.reshape(1, C))


# --------------------------------------------------------------------------- #
# first-order affine prefix scan (the mapper's bw-EMA carry)
# --------------------------------------------------------------------------- #


def _affine_scan_kernel(b_ref, s_ref, state_ref, *, chunk: int, decay: float):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    b = b_ref[...].astype(jnp.float32)  # [1, chunk]

    def step(t, carry):
        state, out = carry  # [1, 1], [1, chunk]
        b_t = jax.lax.dynamic_slice(b, (0, t), (1, 1))
        state = decay * state + b_t
        out = jax.lax.dynamic_update_slice(out, state, (0, t))
        return state, out

    state, out = jax.lax.fori_loop(0, chunk, step, (state_ref[...], jnp.zeros_like(b)))
    state_ref[...] = state
    s_ref[...] = out.astype(s_ref.dtype)


def _affine_scan_pallas(decay: float, add: jax.Array, *, chunk: int = 512,
                        interpret: bool | None = None) -> jax.Array:
    """Inclusive prefix of ``s' = decay*s + b`` (s0 = 0) as a Pallas kernel.

    The running state lives in a [1, 1] VMEM scratch that carries across the
    sequential chunk grid; trailing padding (b = 0) only touches dropped
    outputs, never the prefix of real elements."""
    (v,) = add.shape
    chunk = min(chunk, max(v, 1))
    vp = -(-v // chunk) * chunk
    b = jnp.pad(add, (0, vp - v)).reshape(1, vp)
    kernel = functools.partial(_affine_scan_kernel, chunk=chunk, decay=float(decay))
    out = runtime.dragon_pallas_call(
        kernel,
        grid=(vp // chunk,),
        in_specs=[pl.BlockSpec((1, chunk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, vp), add.dtype),
        scratch_shapes=[runtime.vmem_scratch((1, 1), jnp.float32)],
        interpret=interpret,
        dimension_semantics=("arbitrary",),
    )(b)
    return out[0, :v]


def _affine_prefix(decay: float, add: jax.Array) -> jax.Array:
    """The backward workhorse: core.mapper's associative inclusive prefix.

    Imported lazily (mapper itself lazily imports :func:`affine_scan` for
    its pallas dispatch, so neither module needs the other at import time);
    one definition of the recurrence keeps the VJP in lockstep with the
    forward semantics."""
    from repro.core.mapper import affine_prefix_assoc

    return affine_prefix_assoc(decay, add)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def affine_scan(decay: float, add: jax.Array) -> jax.Array:
    """Differentiable Pallas-backed inclusive prefix of ``s' = decay*s + b``.

    ``s_i = sum_{j<=i} decay^(i-j) b_j``; the VJP is the reversed scan
    ``db_k = sum_{i>=k} decay^(i-k) g_i`` — another affine prefix, so no
    residuals beyond the cotangent are needed.
    """
    return _affine_scan_pallas(decay, add)


def _affine_scan_fwd(decay, add):
    return _affine_scan_pallas(decay, add), None


def _affine_scan_bwd(decay, _res, g):
    return (jnp.flip(_affine_prefix(decay, jnp.flip(g))),)


affine_scan.defvjp(_affine_scan_fwd, _affine_scan_bwd)
