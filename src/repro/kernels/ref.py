"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
must match in tests/test_kernels.py shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import popsim_kernel as pk


def reference_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Naive full-materialization attention with GQA, fp32 softmax."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_reference(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]
    A: jax.Array,  # [H]
    Bm: jax.Array,  # [B, S, N]
    C: jax.Array,  # [B, S, N]
) -> tuple[jax.Array, jax.Array]:
    """Exact per-timestep SSM recurrence (the definition SSD reformulates):

      state_t = exp(dt_t A_h) state_{t-1} + dt_t * (B_t outer x_t)
      y_t     = C_t . state_t
    """
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf, Af = Bm.astype(jnp.float32), C.astype(jnp.float32), A.astype(jnp.float32)

    def step(state, inp):  # state [B, H, N, P]
        x_t, dt_t, B_t, C_t = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dt_t * Af[None, :])  # [B, H]
        upd = dt_t[..., None, None] * (B_t[:, None, :, None] * x_t[:, :, None, :])
        state = decay[..., None, None] * state + upd
        y_t = jnp.einsum("bn,bhnp->bhp", C_t, state)
        return state, y_t

    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    state0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),  # [S, B, H, P]
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def popsim_reference(graph_packed: jax.Array, chw_packed: jax.Array) -> jax.Array:
    """lax.scan-over-vertices oracle with the popsim kernel's exact math,
    vmapped over candidates.  Returns [P, OUT_COLS]."""

    def one(chw):  # chw: [CHW_COLS]
        freq = chw[pk.FREQ]
        cap_gbuf = chw[pk.CAP_GBUF] * pk.HEADROOM
        bw, rlat, wlat = chw[pk.BW], chw[pk.RLAT], chw[pk.WLAT]
        re_pb, we_pb = chw[pk.RE_PB], chw[pk.WE_PB]
        e_flop, rate = chw[pk.E_FLOP], chw[pk.RATE]
        sys_x, sys_y = chw[pk.SYS_X], chw[pk.SYS_Y]

        def step(carry, g):
            occupancy, bw_ema = carry
            n_comp, n_read, n_write = g[pk.G_COMP], g[pk.G_READ], g[pk.G_WRITE]
            alloc_gbuf, has_main = g[pk.G_ALLOC_GBUF], g[pk.G_MAIN_PRESENT]
            M, N = g[pk.G_DIMS][0], g[pk.G_DIMS][1]

            tiles = jnp.maximum(jnp.ceil(alloc_gbuf / cap_gbuf), 1.0)
            m_t = jnp.maximum(M / tiles, 1.0)
            K = g[pk.G_DIMS][2]
            waves = jnp.ceil(m_t / sys_x) * jnp.ceil(jnp.maximum(N, 1.0) / sys_y)
            cyc_sys_tile = waves * (jnp.ceil(jnp.maximum(K, 1.0)) + sys_x + sys_y)
            ops_sys_tile = n_comp[pk._SYS] / tiles
            cyc_sys_tile = jnp.maximum(
                cyc_sys_tile, ops_sys_tile / jnp.maximum(rate[pk._SYS], 1e-9)
            )
            t_sys = jnp.where(ops_sys_tile > 0, tiles * cyc_sys_tile / freq, 0.0)
            eff = jnp.maximum(rate, 1e-9) * freq
            t_comp = jnp.maximum(jnp.max((n_comp / eff).at[pk._SYS].set(0.0)), t_sys)

            t_lvl = (n_read + n_write) / bw * 1.04
            t_tile_lat = tiles * (rlat + wlat)
            t_onchip = jnp.maximum(t_lvl[pk._GBUF] + t_tile_lat[pk._GBUF], t_lvl[pk._LOCAL])
            t_main = t_lvl[pk._MAIN] + t_tile_lat[pk._MAIN] * has_main

            can_pf = ((occupancy + alloc_gbuf / tiles) < cap_gbuf).astype(jnp.float32) * (
                bw_ema < pk.HEADROOM
            ).astype(jnp.float32)
            can_st = (bw_ema < pk.HEADROOM).astype(jnp.float32)
            hide = jnp.maximum(can_pf, can_st)

            t_core = jnp.maximum(t_comp, t_onchip)
            t_exposed = jnp.maximum(t_main - hide * t_core, 0.0)
            # integer-cycle quantization per tile; no-op (padding) vertices
            # are free and excluded from diagnostics (matches mapper.py)
            active = (
                jnp.sum(n_comp) + jnp.sum(n_read) + jnp.sum(n_write) + alloc_gbuf + has_main
            ) > 0
            t_vertex = tiles * jnp.ceil((t_core + t_exposed) * freq / tiles) / freq * active

            # demanded-utilization EMA input (matches mapper.py / popsim_kernel)
            t_full = tiles * jnp.ceil((t_core + t_main) * freq / tiles) / freq
            used_bw = jnp.where(
                t_full > 0,
                (n_read[pk._GBUF] + n_write[pk._GBUF]) / jnp.maximum(t_full, 1e-30) / bw[pk._GBUF],
                0.0,
            )
            bw_ema = 0.8 * bw_ema + 0.2 * jnp.clip(used_bw, 0.0, 2.0)
            occupancy = jnp.minimum(0.5 * occupancy + alloc_gbuf, cap_gbuf / pk.HEADROOM)

            e_v = jnp.sum(n_read * re_pb + n_write * we_pb) + jnp.sum(n_comp * e_flop)
            out = jnp.stack(
                [t_vertex * freq, e_v, t_comp, t_onchip * active, t_exposed, tiles * active, 0.0, 0.0]
            )
            return (occupancy, bw_ema), out

        _, outs = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), graph_packed)
        return jnp.sum(outs, axis=0)

    return jax.vmap(one)(chw_packed)
