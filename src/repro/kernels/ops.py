"""Jit'd public wrappers around the Pallas kernels + packing helpers.

``interpret`` defaults to auto (resolved by kernels/runtime.py): Pallas
kernel bodies execute in Python on CPU (this container) and compile to
Mosaic on real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dgen import ConcreteHW
from repro.core.graph import Graph
from repro.kernels import popsim_kernel as pk
from repro.kernels import runtime
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.ssd import ssd_chunk_scan as _ssd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512, interpret=None):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x, dt, A, B, C, *, chunk=256, interpret=None):
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_c", "interpret"))
def selective_scan(u, dt, A, B, C, D, *, chunk=64, block_c=512, interpret=None):
    from repro.kernels.sscan import selective_scan_pallas

    return selective_scan_pallas(u, dt, A, B, C, D, chunk=chunk,
                                 block_c=block_c, interpret=interpret)


# --------------------------------------------------------------------------- #
# popsim packing
# --------------------------------------------------------------------------- #


def pack_chw(chw: ConcreteHW) -> jax.Array:
    """Pack a ConcreteHW (or a vmapped population of them, leading dim P)
    into the popsim kernel layout [P, CHW_COLS]."""

    def pack_one(c: ConcreteHW) -> jax.Array:
        parts = [
            jnp.atleast_1d(c.frequency),
            jnp.atleast_1d(c.capacity[pk._GBUF]),
            c.mem_bw,
            c.read_latency,
            c.write_latency,
            c.read_energy_pb,
            c.write_energy_pb,
            c.energy_per_flop,
            c.flops_per_cycle,
            jnp.atleast_1d(c.sys_x),
            jnp.atleast_1d(c.sys_y),
        ]
        return jnp.concatenate(parts).astype(jnp.float32)

    packed = pack_one(chw)[None, :] if jnp.ndim(chw.frequency) == 0 else jax.vmap(pack_one)(chw)
    assert packed.shape[-1] == pk.CHW_COLS, (packed.shape, pk.CHW_COLS)
    return packed


def pack_graph(g: Graph) -> jax.Array:
    """Pack a Graph into the popsim kernel layout [V, GRAPH_COLS]."""
    V = g.n_vertices
    out = jnp.zeros((V, pk.GRAPH_COLS), jnp.float32)
    out = out.at[:, pk.G_COMP].set(g.n_comp)
    out = out.at[:, pk.G_READ].set(g.n_read)
    out = out.at[:, pk.G_WRITE].set(g.n_write)
    out = out.at[:, pk.G_ALLOC_GBUF].set(g.n_alloc[:, 1])
    out = out.at[:, pk.G_MAIN_PRESENT].set((g.n_alloc[:, 2] > 0).astype(jnp.float32))
    out = out.at[:, pk.G_DIMS].set(g.dims)
    assert out.shape[-1] == pk.GRAPH_COLS, (out.shape, pk.GRAPH_COLS)
    return out


@functools.partial(jax.jit, static_argnames=("block_pop", "interpret"))
def popsim(graph_packed, chw_packed, *, block_pop=128, interpret=None):
    bp = runtime.gcd_block(block_pop, chw_packed.shape[0])
    return pk.popsim(graph_packed, chw_packed, block_pop=bp, interpret=interpret)
