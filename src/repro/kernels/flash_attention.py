"""Pallas TPU flash attention (GQA, causal) — the compute hot-spot of 8/10
assigned architectures (train + 32k prefill cells).

Design (TPU-native, DESIGN.md §6):
  * grid = (batch, q_heads, Sq/block_q, Skv/block_k); the kv dimension is the
    innermost, sequentially-iterated ("arbitrary") axis, so the online-softmax
    carries (m, l, acc) live in VMEM scratch across kv steps — the canonical
    MaxText/Pallas accumulation pattern.
  * BlockSpecs keep one (block_q, head_dim) Q tile and one (block_k, head_dim)
    K/V tile in VMEM per step: with the default 512x512 bf16 blocks and
    head_dim 128 that is ~0.8 MB of operand VMEM, MXU-aligned (multiples of
    (16,128) for bf16).
  * GQA by index mapping: kv block index = q_head // group_size — no K/V
    replication in HBM.
  * causal masking by global block offset; fully-masked kv blocks are skipped
    via jnp.where on the accumulation (XLA hoists the comparison; on TPU the
    block is still fetched — the §Perf log covers the block-skip variant).
  * accumulation in fp32 regardless of input dtype.

Validated against ref.reference_attention in interpret mode (CPU) across
shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # VMEM scratch carries
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_steps: int,
    off: int = 0,  # Skv - Sq: suffix-causal (query i sees keys <= i + off)
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)  # [bk, d]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos + off >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]  # [bq, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)  # [bq, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # [bq, bk]
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == kv_steps - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,  # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    block_q = runtime.clamp_block(block_q, Sq, name="block_q")
    block_k = runtime.clamp_block(block_k, Skv, name="block_k")
    scale = scale if scale is not None else D ** -0.5
    q_steps, kv_steps = Sq // block_q, Skv // block_k

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        kv_steps=kv_steps,
        off=Skv - Sq,
    )
    return runtime.dragon_pallas_call(
        kernel,
        grid=(B, Hq, q_steps, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            runtime.vmem_scratch((block_q, 1), jnp.float32),  # m: running row max
            runtime.vmem_scratch((block_q, 1), jnp.float32),  # l: running row sum
            runtime.vmem_scratch((block_q, D), jnp.float32),  # acc
        ],
        interpret=interpret,
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
    )(q, k, v)
