"""Version-adaptive JAX/Pallas runtime layer — the ONE place that touches
version-fragile JAX API spellings.

JAX has renamed or moved every API the DSim kernels depend on at least once:

  * ``pltpu.TPUCompilerParams`` (<= 0.4.x)  ->  ``pltpu.CompilerParams``
  * ``jax.experimental.shard_map.shard_map`` ->  ``jax.shard_map``
  * ``shard_map(..., check_rep=)``           ->  ``shard_map(..., check_vma=)``

Every kernel and every explicit-SPMD call site routes through this module so
the rest of the codebase never spells a version-specific name:

  * :func:`tpu_compiler_params` — construct TPU compiler params under either
    class name (returns ``None`` when no TPU Pallas backend is available).
  * :func:`resolve_shard_map` — return the shard-map entry point under either
    spelling (``None`` if the installed JAX has neither).
  * :func:`spmd_map` — the call-site wrapper around :func:`resolve_shard_map`
    that also adapts the replication-check keyword across versions.
  * :func:`dragon_pallas_call` — the single ``pl.pallas_call`` wrapper:
    backend detection, interpret-mode auto-fallback on non-TPU backends,
    compiler-params construction, and scratch plumbing.
  * :func:`clamp_block` / :func:`gcd_block` — centralized block-size clamping.
  * :func:`vmem_scratch` — VMEM scratch allocation without importing pltpu.
  * :func:`serialize_compiled` / :func:`deserialize_compiled` /
    :func:`executable_fingerprint` — the executable (de)serialization seam
    (``jax.experimental.serialize_executable`` on 0.4.x) behind the
    persistent AOT cache; the fingerprint names the jax/jaxlib/backend an
    artifact is valid under.

Resolution is performed at call time (never cached) so tests can monkeypatch
either spelling in and out, and so a process that upgrades its backend
mid-life (e.g. ``jax.config`` platform switches) stays correct.
"""
from __future__ import annotations

import inspect
import math
import warnings
from typing import Any, Callable, Sequence

import jax
from jax.experimental import pallas as pl

try:  # pltpu imports cleanly on CPU-only installs; gate it anyway.
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - exercised via monkeypatch in tests
    pltpu = None


# --------------------------------------------------------------------------- #
# backend detection
# --------------------------------------------------------------------------- #


def auto_interpret() -> bool:
    """True when Pallas kernels must run in interpret mode (non-TPU backend).

    Pallas TPU kernels compile through Mosaic only on a real TPU backend; on
    CPU/GPU the kernel bodies execute in the Pallas interpreter instead.
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` convention: None means auto."""
    return auto_interpret() if interpret is None else bool(interpret)


# --------------------------------------------------------------------------- #
# compiler params (TPUCompilerParams <-> CompilerParams)
# --------------------------------------------------------------------------- #


def _compiler_params_cls():
    if pltpu is None:
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    return None


def tpu_compiler_params(**kw) -> Any | None:
    """Build TPU compiler params under whichever class the installed JAX has.

    Returns ``None`` (caller omits the argument) when neither spelling exists,
    so kernels degrade gracefully on installs without a TPU Pallas backend.
    Keywords the resolved class does not accept are dropped with the same
    graceful intent — e.g. ``serial_iteration_hints`` on old versions.
    """
    cls = _compiler_params_cls()
    if cls is None:
        return None
    try:
        accepted = inspect.signature(cls).parameters
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        return cls(**kw)
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in accepted.values()):
        return cls(**kw)
    return cls(**{k: v for k, v in kw.items() if k in accepted})


# --------------------------------------------------------------------------- #
# shard-map resolution
# --------------------------------------------------------------------------- #


def resolve_shard_map() -> Callable | None:
    """Return the shard-map entry point under either spelling.

    Prefers the stable ``jax.shard_map`` (>= 0.5); falls back to
    ``jax.experimental.shard_map.shard_map`` (0.4.x). ``None`` if neither
    exists.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    try:
        from jax.experimental.shard_map import shard_map as legacy_fn
    except ImportError:
        return None
    return legacy_fn


def spmd_map(fn: Callable, *, mesh, in_specs, out_specs, check: bool = True) -> Callable:
    """Version-adaptive shard-map wrapper — the only sanctioned call site API.

    ``check`` maps onto whichever replication-check keyword the resolved
    entry point accepts (``check_vma`` on new JAX, ``check_rep`` on 0.4.x).
    """
    sm = resolve_shard_map()
    if sm is None:
        raise RuntimeError(
            "No shard-map implementation found in the installed JAX; "
            "need jax.shard_map or jax.experimental.shard_map.shard_map."
        )
    kw: dict[str, Any] = {}
    try:
        params = inspect.signature(sm).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtins etc.
        params = {}
    for name in ("check_vma", "check_rep"):
        if name in params:
            kw[name] = check
            break
    else:
        if not check:
            # A third keyword rename (or an uninspectable wrapper) must be
            # visible, not silent: without the kwarg, shard-map runs with its
            # default check ENABLED at call sites that asked to disable it.
            warnings.warn(
                "spmd_map: resolved shard-map accepts neither check_vma nor "
                "check_rep; check=False could not be forwarded — update "
                "repro.kernels.runtime for this JAX version.",
                RuntimeWarning,
                stacklevel=2,
            )
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# --------------------------------------------------------------------------- #
# block-size clamping
# --------------------------------------------------------------------------- #


def clamp_block(block: int, size: int, *, name: str = "block") -> int:
    """Clamp a block size to the dimension extent; the result must tile it."""
    b = min(int(block), int(size))
    if b <= 0 or size % b != 0:
        raise ValueError(f"{name}={block} cannot tile extent {size} (clamped to {b})")
    return b


def gcd_block(block: int, size: int) -> int:
    """Largest divisor of ``size`` that is <= gcd(block, size) — always tiles."""
    return max(int(math.gcd(int(block), int(size))), 1)


# --------------------------------------------------------------------------- #
# scratch + the pallas_call seam
# --------------------------------------------------------------------------- #


def vmem_scratch(shape: Sequence[int], dtype) -> Any:
    """A VMEM scratch allocation, without the caller importing pltpu.

    Unlike compiler params (which degrade to "omit the argument"), scratch
    has no pltpu-free spelling — even interpret mode rejects a plain
    ShapeDtypeStruct — so an install without the TPU Pallas module gets a
    hard, descriptive error rather than silent misbehavior.
    """
    if pltpu is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu is unavailable in this install; "
            "scratch-using kernels need it even in interpret mode (there is "
            "no portable scratch spelling)."
        )
    return pltpu.VMEM(tuple(shape), dtype)


def executable_fingerprint() -> str:
    """The runtime identity a serialized executable is only valid under.

    Compiled artifacts are specific to the jax/jaxlib pair that lowered
    them and the backend they were compiled for; the persistent AOT cache
    (:mod:`repro.serving.aotcache`) folds this string into every cache-key
    digest so an upgraded runtime misses cleanly instead of deserializing
    a stale executable.
    """
    import jaxlib

    return f"jax={jax.__version__}|jaxlib={jaxlib.__version__}|backend={jax.default_backend()}"


def _serialize_executable_module():
    """The executable (de)serialization seam of the installed JAX, or None.

    jax 0.4.x ships it as ``jax.experimental.serialize_executable``
    (``serialize`` / ``deserialize_and_load``); post-0.5 exports may move
    it — adapt here, nowhere else.
    """
    try:
        from jax.experimental import serialize_executable as se
    except ImportError:  # pragma: no cover - exercised on future jax
        return None
    if not (hasattr(se, "serialize") and hasattr(se, "deserialize_and_load")):
        return None  # pragma: no cover - exercised on future jax
    return se


def serialize_compiled(compiled) -> bytes | None:
    """Serialize a ``jax.stages.Compiled`` into one portable byte string.

    Returns ``None`` when the installed JAX has no serialization seam, when
    ``compiled`` is not an AOT-compiled stage (plain ``jax.jit`` wrappers
    cannot be snapshotted), or when the backend refuses — callers treat
    ``None`` as "this program cannot be persisted", never as an error.
    """
    se = _serialize_executable_module()
    if se is None:
        return None
    import pickle

    try:
        payload, in_tree, out_tree = se.serialize(compiled)
    except Exception:
        return None
    return pickle.dumps((payload, in_tree, out_tree), protocol=pickle.HIGHEST_PROTOCOL)


def deserialize_compiled(data: bytes):
    """Rehydrate :func:`serialize_compiled` output into a loaded executable.

    Raises on malformed bytes or a missing seam — the cache layer catches,
    quarantines the source file, and falls back to a fresh compile.
    """
    se = _serialize_executable_module()
    if se is None:
        raise RuntimeError(
            "installed JAX has no executable-serialization seam "
            "(jax.experimental.serialize_executable); cannot load AOT cache entries"
        )
    import pickle

    payload, in_tree, out_tree = pickle.loads(data)
    return se.deserialize_and_load(payload, in_tree, out_tree)


def dragon_pallas_call(
    kernel: Callable,
    *,
    grid,
    in_specs,
    out_specs,
    out_shape,
    scratch_shapes: Sequence[Any] | None = None,
    dimension_semantics: Sequence[str] | None = None,
    interpret: bool | None = None,
    **compiler_kw,
) -> Callable:
    """The single ``pl.pallas_call`` wrapper all DSim kernels go through.

    * ``interpret=None`` auto-falls back to interpret mode off-TPU
      (:func:`auto_interpret`), matching the kernels' CPU test path.
    * ``dimension_semantics`` (plus any extra ``compiler_kw``) is turned into
      compiler params via :func:`tpu_compiler_params`; when the installed JAX
      exposes no compiler-params class the argument is omitted entirely.
    """
    interpret = resolve_interpret(interpret)
    kwargs: dict[str, Any] = dict(
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    if scratch_shapes:
        kwargs["scratch_shapes"] = list(scratch_shapes)
    if dimension_semantics is not None:
        compiler_kw = dict(compiler_kw, dimension_semantics=tuple(dimension_semantics))
    if compiler_kw:
        params = tpu_compiler_params(**compiler_kw)
        if params is not None:
            kwargs["compiler_params"] = params
    return pl.pallas_call(kernel, **kwargs)
