"""Pallas TPU kernels for the perf-critical compute layers.

flash_attention — train/prefill attention (8/10 archs' hot spot)
ssd             — Mamba2 chunked SSD scan (hybrid + long-context cells)
popsim_kernel   — DSim population evaluation (the paper's speed claim)

Each kernel ships with a pure-jnp oracle in ref.py; ops.py holds the jit'd
public wrappers (interpret=True on CPU, Mosaic on TPU).

The runtime seam (kernels/runtime.py)
-------------------------------------
JAX renames/moves the APIs these kernels depend on across versions (TPU
compiler-params class name, the shard-map entry point and its keyword
names). ``runtime.py`` is the ONE module allowed to spell those names;
everything else goes through its version-adaptive wrappers:

  * ``runtime.dragon_pallas_call(...)`` instead of a direct pallas_call —
    centralizes backend detection, interpret-mode auto-fallback on non-TPU
    backends, block clamping helpers and compiler-params construction;
  * ``runtime.spmd_map(...)`` instead of any direct shard-map spelling;
  * ``runtime.vmem_scratch(...)`` instead of importing the TPU pallas module.

New kernels MUST route through these wrappers — ``tools/check_kernel_seam.py``
(run in CI) fails the build if a version-fragile spelling appears outside
``kernels/runtime.py``.
"""
from repro.kernels import runtime  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    flash_attention,
    pack_chw,
    pack_graph,
    popsim,
    selective_scan,
    ssd_chunk_scan,
)
