"""Pallas TPU kernels for the perf-critical compute layers.

flash_attention — train/prefill attention (8/10 archs' hot spot)
ssd             — Mamba2 chunked SSD scan (hybrid + long-context cells)
popsim_kernel   — DSim population evaluation (the paper's speed claim)

Each kernel ships with a pure-jnp oracle in ref.py; ops.py holds the jit'd
public wrappers (interpret=True on CPU, Mosaic on TPU).
"""
from repro.kernels.ops import (  # noqa: F401
    flash_attention,
    pack_chw,
    pack_graph,
    popsim,
    selective_scan,
    ssd_chunk_scan,
)
