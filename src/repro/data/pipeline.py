"""Deterministic, resumable, sharded synthetic-token data pipeline.

Every batch is a pure function of (seed, step, arch config, shape) — so a
restore-from-checkpoint resumes the exact stream (the checkpoint stores the
step cursor), and every host/process generates only its slice.  A background
prefetch thread keeps ``depth`` batches ahead of the consumer.

The synthetic stream is a mixture of Zipf-distributed tokens with injected
periodic structure (so models actually *learn* — loss decreases — in the
end-to-end examples, unlike uniform noise).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2
    period: int = 17  # injected structure: x[t] depends on x[t-period]
    copy_prob: float = 0.7


def _token_block(rng: np.random.Generator, n: int, vocab: int, dcfg: DataConfig) -> np.ndarray:
    """1-D structured token stream of length n."""
    zipf = rng.zipf(dcfg.zipf_a, size=n).astype(np.int64)
    toks = (zipf - 1) % vocab
    p = dcfg.period
    copy = rng.random(n) < dcfg.copy_prob
    for t in range(p, n):
        if copy[t]:
            toks[t] = toks[t - p]
    return toks.astype(np.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int, dcfg: DataConfig = DataConfig(),
               batch_override: Optional[int] = None, seq_override: Optional[int] = None) -> dict:
    """Batch for one step: dict(tokens, labels[, vision]) as numpy arrays."""
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    rng = np.random.default_rng(np.random.SeedSequence([dcfg.seed, step]))
    ncb = cfg.audio.n_codebooks if cfg.audio else 1
    flat = _token_block(rng, B * (S + 1) * ncb, cfg.vocab_size, dcfg)
    toks = flat.reshape(B, S + 1, ncb) if cfg.audio else flat.reshape(B, S + 1)
    batch = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
    }
    if cfg.vision:
        batch["vision"] = rng.standard_normal(
            (B, cfg.vision.n_patches, cfg.vision.d_vision), dtype=np.float32
        )
    return batch


class Prefetcher:
    """Background-thread prefetch of ``make_batch`` outputs, resumable."""

    def __init__(self, cfg, shape, start_step: int = 0, depth: int = 2,
                 dcfg: DataConfig = DataConfig(), device_put=None, **kw):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._device_put = device_put

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = make_batch(cfg, shape, step, dcfg, **kw)
                if self._device_put is not None:
                    b = self._device_put(b)
                try:
                    self._q.put((step, b), timeout=1.0)
                except queue.Full:
                    if self._stop.is_set():
                        return
                    continue
                step += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                return self._q.get(timeout=1.0)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration

    def close(self):
        self._stop.set()
