"""Fault-tolerant checkpointing: atomic, async, sharding-aware, elastic.

Protocol (crash-consistent):
  1. write all leaf arrays + manifest into  <dir>/step_N.tmp/
  2. fsync, then os.replace -> <dir>/step_N     (atomic on POSIX)
  3. prune to the newest ``keep`` checkpoints.
A crash mid-write leaves only a .tmp dir, which restore ignores and the next
save overwrites — no torn checkpoints.

Async mode snapshots device arrays to host (blocking only on the copy),
then does file I/O on a background thread so training continues.

Elastic restore: arrays are stored UNSHARDED (gathered); ``restore``
device_puts them under *whatever shardings the new mesh provides*, so a
512-chip checkpoint restores onto 256 chips (or 1 CPU) unchanged.

Leaves are addressed by their jax.tree_util key-path string; int8-quantized
optimizer states (Q8 NamedTuples) are ordinary pytree nodes and round-trip
transparently.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), v) for kp, v in flat]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, state, extra: Optional[dict] = None):
        """Snapshot to host, then write (async by default)."""
        self.wait()  # one in-flight save at a time
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        extra = dict(extra or {})

        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_state, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_state, extra: dict):
        try:
            tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(final):
                return  # already checkpointed (deterministic content)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "extra": extra, "leaves": []}
            for i, (path, val) in enumerate(_leaf_paths(host_state)):
                fn = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fn), val)
                manifest["leaves"].append({"path": path, "file": fn})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
            self._prune()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _prune(self):
        done = sorted(d for d in os.listdir(self.dir) if d.startswith("step_") and not d.endswith(".tmp"))
        for d in done[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def latest_step(self) -> Optional[int]:
        done = sorted(d for d in os.listdir(self.dir) if d.startswith("step_") and not d.endswith(".tmp"))
        return int(done[-1].split("_")[1]) if done else None

    def restore(self, step: Optional[int], like, shardings=None) -> tuple[Any, dict]:
        """Rebuild the state pytree. ``like`` provides the tree structure
        (abstract or concrete); ``shardings`` (same structure, optional)
        places each leaf — this is the elastic re-shard path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {l["path"]: l["file"] for l in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (
            [None] * len(flat) if shardings is None else jax.tree.leaves(shardings)
        )
        vals = []
        for (kp, leaf_like), shard in zip(flat, shard_flat):
            path = jax.tree_util.keystr(kp)
            arr = np.load(os.path.join(d, by_path[path]))
            if hasattr(leaf_like, "dtype"):
                arr = arr.astype(leaf_like.dtype)
            vals.append(jax.device_put(arr, shard) if shard is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, vals), manifest["extra"]
