"""Config system: model architecture configs + input-shape configs + registry.

Every assigned architecture is a frozen dataclass instance registered under its
arch id; shapes are the 4 assigned LM shape cells.  Frozen/hashable so configs
can be closed over by jitted functions as static data.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# --------------------------------------------------------------------------- #
# Sub-configs
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    router_dtype: str = "float32"
    # capacity factor used for sizing dense one-hot dispatch (GSPMD-friendly)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    version: int  # 1 = Mamba1 (selective scan), 2 = Mamba2 (SSD)
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64  # mamba2 only: SSD head dim
    chunk: int = 256  # mamba2 SSD chunk length
    dt_rank: int = 0  # mamba1: rank of dt projection; 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class VisionConfig:
    cross_attn_every: int  # a cross-attn layer every k-th layer
    n_patches: int = 1601  # precomputed patch embeddings (frontend stub)
    d_vision: int = 1280


@dataclass(frozen=True)
class AudioConfig:
    n_codebooks: int = 4  # EnCodec codebooks; embeddings summed (frontend stub)


@dataclass(frozen=True)
class HybridConfig:
    attn_every: int  # shared attention block applied after every k SSM layers
    shared_attn_mlp_ff: int = 8192


# --------------------------------------------------------------------------- #
# Model config
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"  # swiglu | gelu | relu2
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    vision: Optional[VisionConfig] = None
    audio: Optional[AudioConfig] = None
    hybrid: Optional[HybridConfig] = None
    # runtime knobs (overridable per launch)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # weight storage; "bfloat16" for 1T-scale
    remat: str = "full"  # full | dots | none
    fsdp: bool = False  # ZeRO-3 style param sharding over the data axis
    use_flash: bool = True  # use the Pallas flash-attention kernel path
    source: str = ""  # provenance note

    # ---------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def dt_rank(self) -> int:
        if not self.ssm:
            return 0
        return self.ssm.dt_rank or -(-self.d_model // 16)

    def attention_free(self) -> bool:
        return self.family == "ssm"

    def subquadratic(self) -> bool:
        """Can this arch serve a 500k context without a dense KV cache?"""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------ param count
    def param_count(self) -> int:
        """Exact parameter count of the JAX implementation (see models/)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        total = V * d  # token embedding
        if not self.tie_embeddings:
            total += V * d  # lm head
        if self.audio:
            total += (self.audio.n_codebooks - 1) * V * d  # extra codebook emb
            total += (self.audio.n_codebooks - 1) * V * d  # extra heads
        if self.vision:
            total += self.vision.d_vision * d  # patch-embedding projection
        per_layer = self._per_layer_params()
        total += per_layer
        total += d  # final norm
        return total

    def _per_layer_params(self) -> int:
        d, ff = self.d_model, self.d_ff
        hd = self.hd
        n_attn = 0
        attn_layer = (
            d * (self.n_heads * hd)  # Wq
            + 2 * d * (self.n_kv_heads * hd)  # Wk, Wv
            + (self.n_heads * hd) * d  # Wo
            + (2 * d)  # norms (pre-attn + pre-mlp)
        )
        if self.qkv_bias:
            attn_layer += self.n_heads * hd + 2 * self.n_kv_heads * hd
        if self.family in ("dense", "vlm", "audio", "moe"):
            n_attn = self.n_layers
        mlp = {
            "swiglu": 3 * d * ff,
            "gelu": 2 * d * ff,
            "relu2": 2 * d * ff,
        }[self.mlp_type]
        total = 0
        if self.family in ("dense", "vlm", "audio"):
            total = self.n_layers * (attn_layer + mlp)
            if self.vision:
                n_cross = self.n_layers // self.vision.cross_attn_every
                # cross layers reuse the attn+mlp shape (already counted in
                # n_layers) and add their tanh gates (attn + mlp, scalars)
                total += n_cross * 2
        elif self.family == "moe":
            e = self.moe
            expert = 3 * d * e.d_ff_expert  # swiglu experts
            total = self.n_layers * (
                attn_layer + e.n_experts * expert + d * e.n_experts  # router
            )
        elif self.family == "ssm":
            di, s = self.d_inner, self.ssm
            layer = (
                d * 2 * di  # in_proj (x, z)
                + di * s.d_conv + di  # depthwise conv + bias
                + di * (self.dt_rank + 2 * s.d_state)  # x -> (dt, B, C)
                + self.dt_rank * di + di  # dt_proj + dt_bias
                + di * s.d_state  # A_log
                + di  # D
                + di * d  # out_proj
                + d  # norm
            )
            total = self.n_layers * layer
        elif self.family == "hybrid":
            # Mamba2 with n_groups=1 (B, C shared across heads — the zamba2/
            # mamba2 default), matching models/ssm_models.mamba2_defs
            di, s = self.d_inner, self.ssm
            nh = di // s.head_dim
            N = s.d_state
            m2_layer = (
                d * (2 * di + 2 * N + nh)  # in_proj: x, z, B, C, dt
                + (di + 2 * N) * s.d_conv + (di + 2 * N)  # conv over x,B,C + bias
                + nh  # A_log
                + nh  # dt_bias
                + nh  # D
                + di  # gated norm
                + di * d  # out_proj
                + d  # norm
            )
            total = self.n_layers * m2_layer
            # one SHARED attention block (concat input 2d; out proj to d)
            h = self.hybrid
            shared = (
                (2 * d) * (self.n_heads * self.hd)  # wq
                + 2 * (2 * d) * (self.n_kv_heads * self.hd)  # wk, wv
                + (self.n_heads * self.hd) * d  # wo
                + 3 * d * h.shared_attn_mlp_ff  # swiglu mlp
                + (2 * d) + d  # ln1 (2d) + ln2 (d)
            )
            total += shared
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.family != "moe":
            return self.param_count()
        e = self.moe
        d = self.d_model
        expert = 3 * d * e.d_ff_expert
        inactive = self.n_layers * (e.n_experts - e.top_k) * expert
        return self.param_count() - inactive

    # -------------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            remat="none",
            fsdp=False,
            use_flash=False,
        )
        if self.family == "hybrid":
            kw["n_kv_heads"] = 4  # MHA in zamba2
        if self.moe:
            kw["moe"] = replace(self.moe, n_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64)
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=8, head_dim=16, chunk=16, dt_rank=8)
        if self.vision:
            kw["vision"] = replace(self.vision, cross_attn_every=2, n_patches=16, d_vision=32)
        if self.audio:
            kw["audio"] = replace(self.audio, n_codebooks=2)
        if self.hybrid:
            kw["hybrid"] = replace(self.hybrid, attn_every=2, shared_attn_mlp_ff=128)
        return replace(self, **kw)


# --------------------------------------------------------------------------- #
# Shape cells
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'run' or a 'skip:<reason>' marker for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.subquadratic():
        return "skip:full-attention arch; 500k decode needs sub-quadratic attention (DESIGN.md)"
    return "run"


def all_cells() -> list[tuple[str, str, str]]:
    """[(arch, shape, status)] for the full 40-cell grid."""
    out = []
    for a in all_archs():
        cfg = get_config(a)
        for s in SHAPES.values():
            out.append((a, s.name, cell_status(cfg, s)))
    return out
