"""llama-3.2-vision-11b — text backbone with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256.  Every 5th layer is a cross-attention
layer attending to precomputed vision-patch embeddings (the vision tower is
a STUB frontend per the assignment: input_specs() provides patch embeddings
of shape [batch, 1601, 1280]).
"""
from repro.configs.base import ModelConfig, VisionConfig, register

CONFIG = register(
    ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        vision=VisionConfig(cross_attn_every=5, n_patches=1601, d_vision=1280),
        fsdp=True,
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    )
)
