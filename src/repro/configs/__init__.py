"""Architecture config registry — import side-effect registers all archs."""
from repro.configs.base import (  # noqa: F401
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    all_archs,
    all_cells,
    cell_status,
    get_config,
    register,
)

# one module per assigned architecture (+ the paper's own workload configs live
# in repro.workloads)
from repro.configs import (  # noqa: F401
    falcon_mamba_7b,
    granite_3_8b,
    kimi_k2_1t_a32b,
    llama4_scout_17b_a16e,
    llama_3_2_vision_11b,
    minitron_8b,
    musicgen_large,
    phi4_mini_3_8b,
    qwen2_5_32b,
    zamba2_1_2b,
)

ALL_ARCH_IDS = [
    "musicgen-large",
    "minitron-8b",
    "qwen2.5-32b",
    "granite-3-8b",
    "phi4-mini-3.8b",
    "kimi-k2-1t-a32b",
    "llama4-scout-17b-a16e",
    "falcon-mamba-7b",
    "llama-3.2-vision-11b",
    "zamba2-1.2b",
]
