"""zamba2-1.2b — hybrid: Mamba2 backbone + one SHARED attention block.

[arXiv:2411.15242; hf]  38L d_model=2048 32H (GQA kv=32 == MHA) d_ff=8192
vocab=32000, ssm_state=64.  38 Mamba2 (SSD) layers; a single shared-weight
attention+MLP block is applied after every 6 SSM layers on
concat(hidden, residual_stream_input) (2*d_model -> d_model projections).
Sub-quadratic backbone: runs the long_500k cell (the shared block's KV cache
at 500k is the documented cost; see DESIGN.md).
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
        hybrid=HybridConfig(attn_every=6, shared_attn_mlp_ff=8192),
        fsdp=True,
        source="arXiv:2411.15242; hf",
    )
)
