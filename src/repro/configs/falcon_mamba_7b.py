"""falcon-mamba-7b — attention-free Mamba1 SSM.

[arXiv:2410.05355; unverified]  64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16, expand=2 (d_inner=8192), d_conv=4, dt_rank=256.
Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=65024,
        ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2),
        fsdp=True,
        source="arXiv:2410.05355; unverified",
    )
)
