"""minitron-8b — width/depth-pruned Nemotron-4.

[arXiv:2407.14679; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000.  Nemotron family uses squared-ReLU (non-gated) MLP.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=256000,
        mlp_type="relu2",
        fsdp=True,
        source="arXiv:2407.14679; hf",
    )
)
