"""llama4-scout-17b-a16e — MoE with 16 experts, top-1 (switch-style routing).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192),
        fsdp=True,
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    )
)
