"""musicgen-large — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (GQA kv=32 == MHA) d_ff=8192
vocab=2048.  The EnCodec frontend is a STUB: the backbone consumes codebook
token ids directly (4 codebooks, embeddings summed; 4 parallel LM heads).
MusicGen uses a standard (non-gated) GELU MLP.
"""
from repro.configs.base import AudioConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        mlp_type="gelu",
        audio=AudioConfig(n_codebooks=4),
        fsdp=True,
        source="arXiv:2306.05284; hf",
    )
)
