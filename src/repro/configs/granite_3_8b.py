"""granite-3-8b — IBM Granite 3.0 dense GQA transformer.

[hf:ibm-granite/granite-3.0-2b-base family; hf]  40L d_model=4096 32H
(GQA kv=8) d_ff=12800 vocab=49155, SwiGLU.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12800,
        vocab_size=49155,
        fsdp=True,
        source="hf:ibm-granite/granite-3.0-2b-base; hf",
    )
)
