"""kimi-k2-1t-a32b — trillion-parameter MoE (384 experts, top-8).

[arXiv:2501.kimi2; unverified — paper-table spec]  61L d_model=7168 64H
(GQA kv=8) d_ff=2048 (per expert) vocab=163840, MoE 384e top-8.
head_dim 112 (= 7168/64).  ~1.04T total params, ~31B active.
Requires: expert parallelism over the model axis, FSDP over data, 8-bit
optimizer states (see train/).
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=163840,
        moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048),
        fsdp=True,
        param_dtype="bfloat16",  # 1T fp32 weights cannot fit 512 chips
        source="arXiv:2501.kimi2; unverified",
    )
)
