# tools/ is a package so `python -m tools.dragonlint` works from the repo root.
