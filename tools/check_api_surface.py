#!/usr/bin/env python
"""Lint: examples/benchmarks/tools must consume the façade, not the engines.

The public surface is ``repro.api`` (Session / Architecture / Workload) and
the report objects; the engine layer (``repro.core.dsim`` / ``dopt`` /
``popsim`` / ``mapper`` / ``dgen`` / ``refsim``, ``repro.kernels``) is the
numerical oracle underneath and stays importable — but user-facing code in
this repo must not quietly bypass the front door, or the façade stops being
the surface every scaling PR can rely on.  This script fails (exit 1) when
a scanned file imports an engine module or an engine entry point:

  * ``import repro.core.dsim`` / ``from repro.core.dopt import ...`` — the
    engine modules themselves (and ``repro.kernels``);
  * ``from repro.core import simulate, optimize, ...`` — engine functions
    via the old aggregate surface.

Escape hatch: a line tagged ``# engine-oracle`` is allowed — it declares a
deliberate baseline/accuracy comparison *against* the façade path (e.g.
bench_sim_speed's refsim accuracy oracle, bench_pareto's engine-vs-
sequential throughput comparison).  Tags are counted and listed so new ones
are visible in review.

Usage: python tools/check_api_surface.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

SCAN_DIRS = ("examples", "benchmarks", "tools")

ENGINE_MODULES = re.compile(
    r"repro\.core\.(dsim|dopt|popsim|mapper|dgen|refsim)\b|repro\.kernels\b"
)
ENGINE_NAMES = (
    # engine modules pulled as aliases (`from repro.core import dsim`)
    "dsim",
    "dopt",
    "popsim",
    "mapper",
    "dgen",
    "refsim",
    "kernels",
    # engine entry points
    "simulate",
    "simulate_chw",
    "simulate_stacked",
    "simulate_jit",
    "simulate_breakdown",
    "stacked_log_objective",
    "stacked_log_metrics",
    "mixed_log_objective",
    "optimize",
    "derive_tech_targets",
    "pareto_dse",
    "population_chunk",
    "seed_population",
    "sample_objective_mixes",
    "init_population_state",
    "specialize",
    "map_workload",
    "map_workload_scan",
)
FROM_CORE = re.compile(r"^\s*from\s+repro\.core\s+import\s+(.+)$")
ORACLE_TAG = "# engine-oracle"


def check(root: Path) -> int:
    violations, tagged = [], []
    for d in SCAN_DIRS:
        for path in sorted((root / d).rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel == "tools/check_api_surface.py":
                continue  # this file spells the forbidden patterns in its docs
            lines = path.read_text().splitlines()
            i = 0
            while i < len(lines):
                lineno, line = i + 1, lines[i]
                i += 1
                # fold a parenthesized `from X import (...)` statement into
                # one logical line so wrapped imports can't slip through
                stmt = line
                if re.match(r"^\s*from\s+\S+\s+import\s*\(", line) and ")" not in line:
                    while i < len(lines) and ")" not in lines[i]:
                        stmt += " " + lines[i]
                        i += 1
                    if i < len(lines):
                        stmt += " " + lines[i]
                        i += 1
                hit = None
                if ENGINE_MODULES.search(stmt) and ("import" in stmt or "from" in stmt):
                    hit = "engine module"
                else:
                    m = FROM_CORE.match(stmt)
                    if m:
                        names = {
                            n.strip().split(" as ")[0]
                            for n in m.group(1).replace("(", " ").replace(")", " ").split(",")
                        }
                        bad = names & set(ENGINE_NAMES)
                        if bad:
                            hit = f"engine entry point {sorted(bad)}"
                if hit is None:
                    continue
                if ORACLE_TAG in stmt:
                    tagged.append(f"{rel}:{lineno}: {line.strip()}")
                else:
                    violations.append(f"{rel}:{lineno}: [{hit}] {line.strip()}")
    if tagged:
        print(f"declared engine-oracle imports ({len(tagged)} — baselines, allowed):")
        print("\n".join(f"  {t}" for t in tagged))
    if violations:
        print("API-surface violations (use repro.api / repro instead, or tag a")
        print(f"deliberate oracle comparison with '{ORACLE_TAG}'):")
        print("\n".join(violations))
        return 1
    print(f"api surface clean: {'/'.join(SCAN_DIRS)} consume the façade")
    return 0


if __name__ == "__main__":
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parent.parent
    sys.exit(check(root))
