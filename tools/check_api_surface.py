#!/usr/bin/env python
"""API-surface lint — thin shim over ``tools/dragonlint`` (CI-enforced).

The rule now lives in the dragonlint registry as ``api-surface`` (with the
``stale-oracle-tag`` companion; rationale and examples in docs/lint.md);
this entry point is kept so existing habits and docs keep working.  Prefer
``python -m tools.dragonlint --pass a --rules api-surface,stale-oracle-tag``.
"""
from __future__ import annotations

import os
import sys
from pathlib import Path

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from tools.dragonlint import render, run_pass_a  # noqa: E402
from tools.dragonlint.rules_ast import (  # noqa: E402,F401  (legacy re-exports)
    ENGINE_MODULES,
    ENGINE_NAMES,
    FROM_CORE,
    ORACLE_TAG,
)


def check(root: Path) -> int:
    findings = run_pass_a(root=Path(root).resolve(),
                          rules=["api-surface", "stale-oracle-tag"])
    print(render(findings, "api surface"))
    return 1 if findings else 0


if __name__ == "__main__":
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parent.parent
    sys.exit(check(root))
