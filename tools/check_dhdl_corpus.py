#!/usr/bin/env python
"""Golden-corpus check for ``.dhd`` — thin shim over ``tools/dragonlint``.

The check now lives in the dragonlint registry as the repo-scope
``dhdl-corpus`` rule (:mod:`tools.dragonlint.corpus`); this entry point —
and the ``check_valid_corpus`` / ``check_invalid_corpus`` functions
``tests/test_dhdl.py`` loads by path — are kept so existing habits keep
working.  Prefer ``python -m tools.dragonlint --pass a --rules dhdl-corpus``.

Usage: PYTHONPATH=src python tools/check_dhdl_corpus.py
Exit code 0 = corpus green; 1 = drift (details on stdout).
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from tools.dragonlint.corpus import (  # noqa: E402,F401  (legacy re-exports)
    check_invalid_corpus,
    check_valid_corpus,
)


def main() -> int:
    print("== valid corpus (architecture library) ==")
    failures = check_valid_corpus()
    print("== invalid corpus (expected errors) ==")
    failures += check_invalid_corpus()
    if failures:
        print("\nDHDL CORPUS DRIFT:")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print("dhdl corpus OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
