#!/usr/bin/env python
"""Golden-corpus check for the .dhd description language (CI-enforced).

Guards the grammar against silent drift from two directions:

1. VALID corpus — every `.dhd` in the architecture library
   (src/repro/configs/arch/) must parse, compile to finite positive
   pytrees, specialize to a finite ConcreteHW, and round-trip bit-exactly
   through the canonical serializer.

2. INVALID corpus — every `.dhd` under tests/data/dhdl_invalid/ must FAIL
   to compile, and the DhdlError message must contain the snippet declared
   in the file's first line (``# expect-error: <snippet>``).  A file that
   suddenly parses, or errors with a different message, is grammar drift.

Usage: PYTHONPATH=src python tools/check_dhdl_corpus.py
Exit code 0 = corpus green; 1 = drift (details on stdout).
"""
from __future__ import annotations

import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

INVALID_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "data", "dhdl_invalid")
_EXPECT_RE = re.compile(r"#\s*expect-error:\s*(.+)")


def check_valid_corpus() -> list[str]:
    import jax

    from repro.core import dhdl

    failures = []
    env = dhdl.load_library(refresh=True)
    if len(env) < 6:
        failures.append(f"library has only {len(env)} architectures; expected >= 6")
    for name in sorted(env):
        try:
            ca = dhdl.compile_arch(env[name], env)
            chw = ca.specialize()
            for leaf in jax.tree.leaves((ca.arch, ca.tech, chw)):
                a = np.asarray(leaf)
                if not np.all(np.isfinite(a)):
                    failures.append(f"{name}: non-finite values in compiled pytrees")
                    break
            text = dhdl.serialize_arch(ca)
            ca2 = dhdl.parse_arch(text, env={})
            exact = ca2.spec == ca.spec and all(
                bool(np.array_equal(np.asarray(x), np.asarray(y)))
                for x, y in zip(
                    jax.tree.leaves((ca.arch, ca.tech)), jax.tree.leaves((ca2.arch, ca2.tech))
                )
            )
            if not exact:
                failures.append(f"{name}: serializer round-trip is not bit-exact")
            elif dhdl.serialize_arch(ca2) != text:
                failures.append(f"{name}: canonical serialization is not a fixed point")
            else:
                print(f"  ok   {name}")
        except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
            failures.append(f"{name}: failed to compile: {e}")
    return failures


def check_invalid_corpus() -> list[str]:
    from repro.core import dhdl

    failures = []
    files = sorted(f for f in os.listdir(INVALID_DIR) if f.endswith(".dhd"))
    if not files:
        return [f"no invalid-corpus files found under {INVALID_DIR}"]
    for fn in files:
        src = open(os.path.join(INVALID_DIR, fn)).read()
        m = _EXPECT_RE.search(src)
        if not m:
            failures.append(f"{fn}: missing '# expect-error: <snippet>' directive")
            continue
        snippet = m.group(1).strip()
        try:
            dhdl.parse_arch(src, filename=fn, env={})
        except dhdl.DhdlError as e:
            if snippet in str(e):
                print(f"  ok   {fn} ({snippet!r})")
            else:
                failures.append(
                    f"{fn}: error message drifted.\n  expected snippet: {snippet!r}\n  got: {e}"
                )
        except Exception as e:  # noqa: BLE001 - a non-DhdlError is itself drift
            failures.append(
                f"{fn}: raised {type(e).__name__} instead of a located DhdlError: {e}"
            )
        else:
            failures.append(f"{fn}: expected a DhdlError containing {snippet!r}, but it compiled")
    return failures


def main() -> int:
    print("== valid corpus (architecture library) ==")
    failures = check_valid_corpus()
    print("== invalid corpus (expected errors) ==")
    failures += check_invalid_corpus()
    if failures:
        print("\nDHDL CORPUS DRIFT:")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print("dhdl corpus OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
