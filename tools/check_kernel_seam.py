#!/usr/bin/env python
"""Kernel-seam lint — thin shim over ``tools/dragonlint`` (CI-enforced).

The rule now lives in the dragonlint registry as ``kernel-seam`` (rationale
and examples in docs/lint.md); this entry point is kept so existing habits
and docs (``python tools/check_kernel_seam.py``) keep working.  Prefer
``python -m tools.dragonlint --pass a --rules kernel-seam``.
"""
from __future__ import annotations

import os
import sys
from pathlib import Path

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from tools.dragonlint import render, run_pass_a  # noqa: E402
from tools.dragonlint.rules_ast import (  # noqa: E402,F401  (legacy re-exports)
    KERNEL_SEAM_ALLOWED as ALLOWED,
    KERNEL_SEAM_PATTERN as PATTERN,
)


def check(src_dir: Path) -> int:
    findings = run_pass_a(root=Path(src_dir).resolve().parent, rules=["kernel-seam"])
    print(render(findings, "kernel seam"))
    return 1 if findings else 0


if __name__ == "__main__":
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parent.parent / "src"
    sys.exit(check(root))
