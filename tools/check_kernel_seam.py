#!/usr/bin/env python
"""Lint: version-fragile JAX spellings must stay inside kernels/runtime.py.

The runtime seam (src/repro/kernels/runtime.py) is the only module allowed
to reference TPU compiler-params classes or the shard-map entry points by
name — everything else must go through runtime.dragon_pallas_call /
runtime.spmd_map / runtime.tpu_compiler_params. This script fails (exit 1)
when a version-fragile spelling appears anywhere else under src/, so a new
kernel cannot silently reintroduce a fragile call site:

  * ``CompilerParams`` / ``shard_map`` — the renamed APIs themselves;
  * ``pltpu`` / ``pallas import tpu`` — kernels must use
    ``runtime.vmem_scratch`` instead of importing the TPU pallas module;
  * ``pl.pallas_call`` — kernels must use ``runtime.dragon_pallas_call``
    (interpret auto-fallback + compiler-params construction).

Usage: python tools/check_kernel_seam.py [src_dir]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

PATTERN = re.compile(
    r"CompilerParams|shard_map|\bpltpu\b|pallas\s+import\s+tpu|pl\.pallas_call"
)
ALLOWED = ("kernels/runtime.py",)


def check(src_dir: Path) -> int:
    violations = []
    for path in sorted(src_dir.rglob("*.py")):
        rel = path.as_posix()
        if rel.endswith(ALLOWED):
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if PATTERN.search(line):
                violations.append(f"{rel}:{lineno}: {line.strip()}")
    if violations:
        print("kernel-seam violations (route through repro.kernels.runtime):")
        print("\n".join(violations))
        return 1
    print(f"kernel seam clean: no version-fragile spellings outside {ALLOWED[0]}")
    return 0


if __name__ == "__main__":
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parent.parent / "src"
    sys.exit(check(root))
