"""The ``dhdl-corpus`` repo-scope rule: golden-corpus check for ``.dhd``.

Same two directions the legacy ``tools/check_dhdl_corpus.py`` enforced (that
script is now a shim over this rule):

1. VALID corpus — every ``.dhd`` in the architecture library compiles to
   finite pytrees, specializes to a finite ConcreteHW, and round-trips
   bit-exactly through the canonical serializer (which is also a fixed
   point).
2. INVALID corpus — every ``.dhd`` under ``tests/data/dhdl_invalid/`` must
   fail with a :class:`DhdlError` whose message contains the snippet the
   file declares via ``# expect-error: <snippet>``.

``repro.core.dhdl`` is the description-language front end, not an engine
module — the api-surface rule deliberately leaves it callable from tools.
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import Iterator

from tools.dragonlint.engine import Finding, rule

_EXPECT_RE = re.compile(r"#\s*expect-error:\s*(.+)")


def check_valid_corpus() -> list[str]:
    """Compile + round-trip every library architecture; return failure strings."""
    import jax
    import numpy as np

    from repro.core import dhdl

    failures = []
    env = dhdl.load_library(refresh=True)
    if len(env) < 6:
        failures.append(f"library has only {len(env)} architectures; expected >= 6")
    for name in sorted(env):
        try:
            ca = dhdl.compile_arch(env[name], env)
            chw = ca.specialize()
            for leaf in jax.tree.leaves((ca.arch, ca.tech, chw)):
                a = np.asarray(leaf)
                if not np.all(np.isfinite(a)):
                    failures.append(f"{name}: non-finite values in compiled pytrees")
                    break
            text = dhdl.serialize_arch(ca)
            ca2 = dhdl.parse_arch(text, env={})
            exact = ca2.spec == ca.spec and all(
                bool(np.array_equal(np.asarray(x), np.asarray(y)))
                for x, y in zip(
                    jax.tree.leaves((ca.arch, ca.tech)), jax.tree.leaves((ca2.arch, ca2.tech))
                )
            )
            if not exact:
                failures.append(f"{name}: serializer round-trip is not bit-exact")
            elif dhdl.serialize_arch(ca2) != text:
                failures.append(f"{name}: canonical serialization is not a fixed point")
        except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
            failures.append(f"{name}: failed to compile: {e}")
    return failures


def check_invalid_corpus(invalid_dir: Path | None = None) -> list[str]:
    """Every invalid-corpus file must fail with its declared error snippet."""
    from repro.core import dhdl

    from tools.dragonlint.engine import REPO_ROOT

    invalid_dir = invalid_dir or REPO_ROOT / "tests" / "data" / "dhdl_invalid"
    failures = []
    files = sorted(p for p in invalid_dir.glob("*.dhd"))
    if not files:
        return [f"no invalid-corpus files found under {invalid_dir}"]
    for path in files:
        src = path.read_text()
        fn = path.name
        m = _EXPECT_RE.search(src)
        if not m:
            failures.append(f"{fn}: missing '# expect-error: <snippet>' directive")
            continue
        snippet = m.group(1).strip()
        try:
            dhdl.parse_arch(src, filename=fn, env={})
        except dhdl.DhdlError as e:
            if snippet not in str(e):
                failures.append(
                    f"{fn}: error message drifted.\n  expected snippet: {snippet!r}\n  got: {e}"
                )
        except Exception as e:  # noqa: BLE001 - a non-DhdlError is itself drift
            failures.append(
                f"{fn}: raised {type(e).__name__} instead of a located DhdlError: {e}"
            )
        else:
            failures.append(f"{fn}: expected a DhdlError containing {snippet!r}, but it compiled")
    return failures


@rule(
    "dhdl-corpus",
    doc="the .dhd architecture library must compile and round-trip bit-exactly; "
        "the invalid corpus must keep failing with its pinned error snippets",
    scope="repo",
)
def dhdl_corpus(root: Path) -> Iterator[Finding]:
    for msg in check_valid_corpus():
        yield Finding("dhdl-corpus", "src/repro/configs/arch", 0, msg)
    for msg in check_invalid_corpus(root / "tests" / "data" / "dhdl_invalid"):
        yield Finding("dhdl-corpus", "tests/data/dhdl_invalid", 0, msg)
