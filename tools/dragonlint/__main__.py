"""CLI: ``python -m tools.dragonlint [--pass a|b|all] [--files ...]``.

Exit 0 = clean, 1 = findings (details on stdout).  The full run writes
``results/analysis/dragonlint.json`` next to the bench results; ``--files``
(the pre-commit mode) runs Pass A file rules on just the named files and
writes nothing.

Needs ``PYTHONPATH=src`` (or an installed ``repro``) for Pass B and the
dhdl-corpus rule; pure AST runs (``--pass a --files ...``) work without it.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

# allow `python tools/dragonlint` and pre-commit hooks that bypass -m
_ROOT = Path(__file__).resolve().parent.parent.parent
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))
_SRC = _ROOT / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from tools.dragonlint import engine  # noqa: E402
from tools.dragonlint.engine import render, run_pass_a, write_report  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.dragonlint",
        description="DRAGON static analysis: AST rules (Pass A) + jaxpr hazard pass (Pass B)",
    )
    ap.add_argument("--pass", dest="which", choices=("a", "b", "all"), default="all",
                    help="which pass to run (default: all)")
    ap.add_argument("--files", nargs="*", default=None,
                    help="run Pass A file rules on just these files (pre-commit mode; "
                         "skips repo-scope rules, Pass B and the JSON report)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all registered)")
    ap.add_argument("--workload", default=None,
                    help="Pass B workload bucket (default: bert_base)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help=f"report path (default: {engine.ANALYSIS_PATH})")
    args = ap.parse_args(argv)

    rules = [r.strip() for r in args.rules.split(",")] if args.rules else None
    if rules:
        unknown = [r for r in rules if r not in engine.RULES]
        if unknown:
            print(f"unknown rule(s): {unknown}; registered: {sorted(engine.RULES)}")
            return 2

    rc = 0
    pass_a: list = []
    pass_b: dict | None = None

    if args.which in ("a", "all"):
        pass_a = run_pass_a(files=args.files, rules=rules)
        print(render(pass_a, "pass A (AST rules)"))
        rc |= bool(pass_a)

    if args.which in ("b", "all") and args.files is None:
        from tools.dragonlint.rules_jaxpr import DEFAULT_WORKLOAD, run_pass_b

        pass_b = run_pass_b(workload=args.workload or DEFAULT_WORKLOAD)
        n = len(pass_b["findings"])
        print(f"pass B (jaxpr hazards): {pass_b['programs_lowered']} programs lowered "
              f"({len(pass_b['architectures'])} archs x {len(pass_b['kinds'])} kinds), "
              f"{n} finding(s)")
        for f in pass_b["findings"]:
            print(f"{f['path']}: [{f['rule']}] {f['message']}")
        rc |= n > 0

    if args.files is None:
        out = write_report(engine.REPO_ROOT, pass_a, pass_b, args.json_path)
        print(f"report: {out.relative_to(engine.REPO_ROOT)}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
