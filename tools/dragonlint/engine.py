"""dragonlint engine: rule registry, suppressions, drivers, reports.

The engine is deliberately small: a rule is a named checker registered in
:data:`RULES` with a scope — ``file`` rules get ``(rel, text, tree)`` for
every Python file under their declared ``scan`` prefixes, ``repo`` rules get
the repo root once.  Rules yield :class:`Finding`s; the engine filters them
through ``# dragonlint: disable=<rule>`` suppressions (same line, or a
comment-only line directly above) and renders the human report plus the
machine-readable ``results/analysis/dragonlint.json``.

Pass A (AST / line rules) lives in :mod:`tools.dragonlint.rules_ast` and
:mod:`tools.dragonlint.corpus`; Pass B (the jaxpr hazard pass over every
``Session`` program kind x the ``.dhd`` architecture library) lives in
:mod:`tools.dragonlint.rules_jaxpr`.  ``python -m tools.dragonlint`` runs
both; see :mod:`tools.dragonlint.__main__` for the CLI.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Iterable

REPO_ROOT = Path(__file__).resolve().parent.parent.parent

# directories never scanned, wherever a rule points
_SKIP_PARTS = {"__pycache__", ".git", ".ruff_cache", "results", "node_modules"}


@dataclass(frozen=True)
class Finding:
    """One lint hit.  ``path`` is repo-relative; jaxpr findings use the
    pseudo-path ``<jaxpr:{arch}/{kind}>`` with line 0."""

    rule: str
    path: str
    line: int
    message: str
    snippet: str = ""

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: [{self.rule}] {self.message}"
        if self.snippet:
            out += f"\n      {self.snippet}"
        return out

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class Rule:
    name: str
    doc: str  # one-line rationale (docs/lint.md holds the full catalog)
    scope: str  # "file" | "repo"
    scan: tuple[str, ...]  # repo-relative path prefixes (file scope)
    exclude: tuple[str, ...]  # repo-relative paths skipped (self-referential docs)
    check: Callable


RULES: dict[str, Rule] = {}


def rule(name: str, *, doc: str, scan: tuple[str, ...] = (), exclude: tuple[str, ...] = (),
         scope: str = "file"):
    """Register a checker.  File-scope checkers take ``(rel, text, tree)``
    and yield Findings; repo-scope checkers take the repo root ``Path``."""

    def deco(fn):
        if name in RULES:
            raise ValueError(f"duplicate dragonlint rule {name!r}")
        if scope == "file" and not scan:
            raise ValueError(f"file rule {name!r} needs scan prefixes")
        RULES[name] = Rule(name=name, doc=" ".join(doc.split()), scope=scope,
                           scan=tuple(scan), exclude=tuple(exclude), check=fn)
        return fn

    return deco


# --------------------------------------------------------------------------- #
# suppressions
# --------------------------------------------------------------------------- #

# the marker may follow a justification in the same comment:
#   # host static by contract -- dragonlint: disable=host-sync
_DISABLE_RE = re.compile(r"#.*?dragonlint:\s*disable=([A-Za-z0-9_,\- ]+)")


def suppressions(text: str) -> dict[int, set[str]]:
    """``# dragonlint: disable=<rule>[,<rule>...]`` markers, resolved to the
    line they guard: the marker's own line when it trails code, the *next*
    line when the marker is a comment-only line (the justification-comment
    style the repo uses)."""
    sup: dict[int, set[str]] = {}
    for i, line in enumerate(text.splitlines(), 1):
        m = _DISABLE_RE.search(line)
        if not m:
            continue
        names = {n.strip() for n in m.group(1).split(",") if n.strip()}
        target = i if line.split("#", 1)[0].strip() else i + 1
        sup.setdefault(target, set()).update(names)
    return sup


def _suppressed(f: Finding, sup: dict[int, set[str]]) -> bool:
    names = sup.get(f.line, set())
    return f.rule in names or "all" in names


# --------------------------------------------------------------------------- #
# drivers
# --------------------------------------------------------------------------- #


def file_rules() -> list[Rule]:
    return [r for r in RULES.values() if r.scope == "file"]


def repo_rules() -> list[Rule]:
    return [r for r in RULES.values() if r.scope == "repo"]


def _applies(r: Rule, rel: str) -> bool:
    if rel in r.exclude:
        return False
    return any(rel == s or rel.startswith(s) for s in r.scan)


def lint_source(rel: str, text: str, rules: Iterable[Rule] | None = None) -> list[Finding]:
    """Run every applicable file rule over one source text (the unit the
    fixture tests and the ``--files`` pre-commit mode are built on)."""
    rules = list(rules) if rules is not None else file_rules()
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding("parse-error", rel, e.lineno or 0, f"syntax error: {e.msg}")]
    sup = suppressions(text)
    out = []
    for r in rules:
        if not _applies(r, rel):
            continue
        out.extend(f for f in r.check(rel, text, tree) if not _suppressed(f, sup))
    return out


def _iter_py_files(root: Path, prefixes: set[str]):
    seen = set()
    for prefix in sorted(prefixes):
        base = root / prefix
        candidates = [base] if base.is_file() else sorted(base.rglob("*.py")) if base.is_dir() else []
        for path in candidates:
            rel = path.relative_to(root).as_posix()
            if rel in seen or _SKIP_PARTS.intersection(path.parts):
                continue
            seen.add(rel)
            yield path, rel


def run_pass_a(root: Path = REPO_ROOT, files: list[str] | None = None,
               rules: Iterable[str] | None = None) -> list[Finding]:
    """Pass A: file rules over the repo (or just ``files``), plus repo-scope
    rules (corpus checks) when running the full tree."""
    selected = [RULES[n] for n in rules] if rules is not None else list(RULES.values())
    frules = [r for r in selected if r.scope == "file"]
    findings: list[Finding] = []
    if files is not None:
        for f in files:
            path = Path(f)
            rel = path.resolve().relative_to(root.resolve()).as_posix() if path.is_absolute() \
                else path.as_posix()
            if not (root / rel).exists():
                continue
            findings.extend(lint_source(rel, (root / rel).read_text(), frules))
        return findings
    prefixes = {s for r in frules for s in r.scan}
    for path, rel in _iter_py_files(root, prefixes):
        findings.extend(lint_source(rel, path.read_text(), frules))
    for r in selected:
        if r.scope == "repo":
            findings.extend(r.check(root))
    return findings


# --------------------------------------------------------------------------- #
# reports
# --------------------------------------------------------------------------- #

ANALYSIS_PATH = "results/analysis/dragonlint.json"


def write_report(root: Path, pass_a: list[Finding], pass_b: dict | None,
                 path: str | None = None) -> Path:
    out = root / (path or ANALYSIS_PATH)
    out.parent.mkdir(parents=True, exist_ok=True)
    n_b = len(pass_b["findings"]) if pass_b else 0
    payload = {
        "version": 1,
        "rules": {n: {"scope": r.scope, "doc": r.doc} for n, r in sorted(RULES.items())},
        "pass_a": {"findings": [f.to_json() for f in pass_a]},
        "pass_b": pass_b,
        "ok": not pass_a and n_b == 0,
    }
    out.write_text(json.dumps(payload, indent=1, default=str) + "\n")
    return out


def render(findings: list[Finding], header: str) -> str:
    if not findings:
        return f"{header}: clean"
    lines = [f"{header}: {len(findings)} finding(s)"]
    lines += [f.format() for f in findings]
    return "\n".join(lines)
