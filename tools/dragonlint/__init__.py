"""dragonlint: DRAGON's static-analysis suite.

Pass A — AST/line rules over the source tree (the serving contract plus the
three absorbed legacy checkers).  Pass B — the jaxpr hazard pass over every
served ``Session`` program kind x the ``.dhd`` architecture library.

Run ``python -m tools.dragonlint`` from the repo root (docs/lint.md is the
rule catalog).  Importing this package registers every rule.
"""
from tools.dragonlint import corpus, rules_ast  # noqa: F401  (rule registration)
from tools.dragonlint.engine import (  # noqa: F401
    ANALYSIS_PATH,
    REPO_ROOT,
    RULES,
    Finding,
    Rule,
    lint_source,
    render,
    run_pass_a,
    write_report,
)
from tools.dragonlint.rules_jaxpr import run_pass_b  # noqa: F401
