"""Pass A rules: the serving contract, checked from the AST.

Two families:

* the three absorbed legacy checkers — ``kernel-seam`` (version-fragile JAX
  spellings stay inside ``kernels/runtime.py``), ``api-surface``
  (examples/benchmarks/tools consume the façade) and the repo-scope
  ``dhdl-corpus`` (:mod:`tools.dragonlint.corpus`);

* the serving-contract rules — hazards that silently destroy the zero-
  retrace / no-host-sync guarantees ``bench_api`` gates dynamically:
  ``host-sync``, ``scan-donate``, ``retrace-hazard``, ``stray-debug``,
  ``float64-promotion``, ``stale-oracle-tag``.

The contract rules need to know what code runs *under trace*: a host sync in
a benchmark driver is normal, the same call inside a jitted body blocks the
dispatch pipeline on every step.  :func:`traced_functions` computes a static
approximation — a function is traced if it is decorated with / passed to a
JAX tracing entry point (``jax.jit``, ``vmap``, ``grad``, ``lax.scan``,
``runtime.spmd_map``, ``dragon_pallas_call``, ...), calls the repo's own
trace probe (``instrument.count_trace``), is defined inside a traced
function, or is called by name from one (module-local fixpoint).  Cross-
module tracing is intentionally out of scope for Pass A — Pass B covers it
by lowering the real served programs to jaxprs.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator

from tools.dragonlint.engine import Finding, rule

# --------------------------------------------------------------------------- #
# shared AST helpers
# --------------------------------------------------------------------------- #


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    par: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            par[child] = node
    return par


_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _enclosing_func(par: dict, node: ast.AST):
    n = par.get(node)
    while n is not None and not isinstance(n, _FUNCS):
        n = par.get(n)
    return n


def _scope_chain(par: dict, node: ast.AST) -> list:
    chain, n = [], _enclosing_func(par, node)
    while n is not None:
        chain.append(n)
        n = _enclosing_func(par, n)
    return chain


# entry points whose function-valued arguments (or decorated functions) run
# under trace
TRACING_CALLS = {
    "jax.jit", "jit",
    "jax.vmap", "vmap", "jax.pmap", "pmap",
    "jax.grad", "grad", "jax.value_and_grad", "value_and_grad",
    "jax.jacfwd", "jax.jacrev", "jax.hessian",
    "jax.vjp", "jax.jvp", "jax.linearize",
    "jax.lax.scan", "lax.scan",
    "jax.lax.associative_scan", "lax.associative_scan",
    "jax.lax.cond", "lax.cond",
    "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.map", "lax.map",
    "jax.checkpoint", "jax.remat",
    "jax.eval_shape", "jax.make_jaxpr",
    "jax.custom_vjp", "jax.custom_jvp",
    "runtime.spmd_map", "spmd_map",
    "runtime.dragon_pallas_call", "dragon_pallas_call", "pl.pallas_call",
}
_PARTIAL = {"partial", "functools.partial"}
_TRACE_MARKER = {"instrument.count_trace", "count_trace"}


def _tracing_name(node: ast.AST) -> bool:
    """Is this expression a tracing entry point — either the name itself or
    ``partial(<tracing entry>, ...)``?"""
    d = _dotted(node)
    if d in TRACING_CALLS:
        return True
    if isinstance(node, ast.Call) and _dotted(node.func) in _PARTIAL and node.args:
        return _dotted(node.args[0]) in TRACING_CALLS
    return False


def _local_defs(tree: ast.AST) -> dict[str, list[ast.AST]]:
    defs: dict[str, list[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
    return defs


def _resolve(name: str, site: ast.AST, par: dict, defs: dict) -> ast.AST | None:
    """Module-local name resolution: nearest definition whose scope encloses
    (or equals module scope for) the use site."""
    candidates = defs.get(name, [])
    if not candidates:
        return None
    site_chain = _scope_chain(par, site)
    best, best_depth = None, -1
    for cand in candidates:
        cand_scope = _enclosing_func(par, cand)
        if cand_scope is None:
            depth = 0
        elif cand_scope in site_chain:
            depth = 1 + site_chain.index(cand_scope)
        else:
            continue
        if depth > best_depth:
            best, best_depth = cand, depth
    return best


def traced_functions(tree: ast.AST, par: dict) -> set:
    """The set of function nodes whose bodies run under a JAX trace (static
    approximation; see module docstring)."""
    defs = _local_defs(tree)
    traced: set = set()

    def mark(fn):
        if fn is not None and isinstance(fn, _FUNCS) and fn not in traced:
            traced.add(fn)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_tracing_name(d) or (isinstance(d, ast.Call) and _tracing_name(d.func))
                   for d in node.decorator_list):
                mark(node)
        if isinstance(node, ast.Call):
            if _dotted(node.func) in _TRACE_MARKER:
                mark(_enclosing_func(par, node))
            if _tracing_name(node.func):
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Lambda):
                        mark(arg)
                    elif isinstance(arg, ast.Name):
                        mark(_resolve(arg.id, node, par, defs))

    # fixpoint: nesting + module-local calls from traced regions
    changed = True
    while changed:
        changed = False
        for node in ast.walk(tree):
            enc = _enclosing_func(par, node)
            in_traced = enc in traced or any(s in traced for s in _scope_chain(par, node))
            if not in_traced:
                continue
            new = None
            if isinstance(node, _FUNCS) and node not in traced:
                new = node
            elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                cand = _resolve(node.func.id, node, par, defs)
                if cand is not None and cand not in traced:
                    new = cand
            if new is not None:
                traced.add(new)
                changed = True
    return traced


def _in_traced(node: ast.AST, par: dict, traced: set) -> bool:
    return any(s in traced for s in _scope_chain(par, node))


def _line(text: str, lineno: int) -> str:
    lines = text.splitlines()
    return lines[lineno - 1].strip() if 0 < lineno <= len(lines) else ""


# --------------------------------------------------------------------------- #
# absorbed rule: kernel-seam
# --------------------------------------------------------------------------- #

KERNEL_SEAM_PATTERN = re.compile(
    r"CompilerParams|shard_map|\bpltpu\b|pallas\s+import\s+tpu|pl\.pallas_call"
    r"|serialize_executable|deserialize_and_load"
)
KERNEL_SEAM_ALLOWED = ("kernels/runtime.py",)


@rule(
    "kernel-seam",
    doc="version-fragile JAX spellings (pallas_call / shard_map / TPU compiler "
        "params / executable serialization) must stay inside kernels/runtime.py",
    scan=("src/",),
)
def kernel_seam(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    if rel.endswith(KERNEL_SEAM_ALLOWED):
        return
    for lineno, line in enumerate(text.splitlines(), 1):
        if KERNEL_SEAM_PATTERN.search(line):
            yield Finding("kernel-seam", rel, lineno,
                          "version-fragile spelling outside the runtime seam — route "
                          "through repro.kernels.runtime", line.strip())


# --------------------------------------------------------------------------- #
# absorbed rule: api-surface (+ the stale-oracle-tag companion)
# --------------------------------------------------------------------------- #

ENGINE_MODULES = re.compile(
    r"repro\.core\.(dsim|dopt|popsim|mapper|dgen|refsim)\b|repro\.kernels\b"
)
ENGINE_NAMES = (
    "dsim", "dopt", "popsim", "mapper", "dgen", "refsim", "kernels",
    "simulate", "simulate_chw", "simulate_stacked", "simulate_jit",
    "simulate_breakdown", "stacked_log_objective", "stacked_log_metrics",
    "mixed_log_objective", "optimize", "derive_tech_targets", "pareto_dse",
    "population_chunk", "seed_population", "sample_objective_mixes",
    "init_population_state", "specialize", "map_workload", "map_workload_scan",
)
FROM_CORE = re.compile(r"^\s*from\s+repro\.core\s+import\s+(.+)$")
ORACLE_TAG = "# engine-oracle"

_SURFACE_SCAN = ("examples/", "benchmarks/", "tools/")
# these files spell the forbidden patterns in their own docs/rule bodies
_SURFACE_EXCLUDE = (
    "tools/check_api_surface.py",
    "tools/dragonlint/rules_ast.py",
)


def _logical_stmts(text: str) -> Iterator[tuple[int, str, str]]:
    """(lineno, first_line, folded_stmt): parenthesized ``from X import
    (...)`` statements folded into one logical line so wrapped imports can't
    slip through."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        lineno, line = i + 1, lines[i]
        i += 1
        stmt = line
        if re.match(r"^\s*from\s+\S+\s+import\s*\(", line) and ")" not in line:
            while i < len(lines) and ")" not in lines[i]:
                stmt += " " + lines[i]
                i += 1
            if i < len(lines):
                stmt += " " + lines[i]
                i += 1
        yield lineno, line, stmt


def _engine_import_hit(stmt: str) -> str | None:
    if ENGINE_MODULES.search(stmt) and ("import" in stmt or "from" in stmt):
        return "engine module"
    m = FROM_CORE.match(stmt)
    if m:
        names = {
            n.strip().split(" as ")[0]
            for n in m.group(1).replace("(", " ").replace(")", " ").split(",")
        }
        bad = names & set(ENGINE_NAMES)
        if bad:
            return f"engine entry point {sorted(bad)}"
    return None


@rule(
    "api-surface",
    doc="examples/benchmarks/tools must consume the repro.api façade; deliberate "
        "engine baselines carry an '# engine-oracle' tag",
    scan=_SURFACE_SCAN,
    exclude=_SURFACE_EXCLUDE,
)
def api_surface(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    for lineno, line, stmt in _logical_stmts(text):
        hit = _engine_import_hit(stmt)
        if hit and ORACLE_TAG not in stmt:
            yield Finding("api-surface", rel, lineno,
                          f"[{hit}] use repro.api / repro instead, or tag a deliberate "
                          f"oracle comparison with {ORACLE_TAG!r}", line.strip())


@rule(
    "stale-oracle-tag",
    doc="an '# engine-oracle' tag on a line that no longer imports an engine "
        "module is a stale escape hatch — remove it",
    scan=_SURFACE_SCAN,
    exclude=_SURFACE_EXCLUDE,
)
def stale_oracle_tag(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    for lineno, line, stmt in _logical_stmts(text):
        if not re.match(r"^\s*(from|import)\s", stmt):
            continue  # prose mentions of the tag (docstrings) are not tags
        if ORACLE_TAG in stmt and _engine_import_hit(stmt) is None:
            yield Finding("stale-oracle-tag", rel, lineno,
                          "stale '# engine-oracle' tag: the line imports no engine "
                          "module/entry point — drop the tag", line.strip())


# --------------------------------------------------------------------------- #
# serving-contract rule: host-sync
# --------------------------------------------------------------------------- #

_HOST_SYNC_CALLS = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get", "float", "int", "bool",
}
_HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_HOST_CONTAINERS = (ast.List, ast.Tuple, ast.ListComp, ast.GeneratorExp,
                    ast.SetComp, ast.DictComp, ast.Constant)
_HOST_SCALAR_ANNOS = {"float", "int", "bool", "str"}


def _host_scalar_param(node: ast.AST, arg: ast.AST, par: dict) -> bool:
    """Is ``arg`` a Name bound to an enclosing parameter annotated with a
    host scalar type (``decay: float``)?  Casting those is host arithmetic
    on static config, not a device sync."""
    if not isinstance(arg, ast.Name):
        return False
    for fn in _scope_chain(par, node):
        if isinstance(fn, ast.Lambda):
            continue
        a = fn.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.arg == arg.id:
                return (isinstance(p.annotation, ast.Name)
                        and p.annotation.id in _HOST_SCALAR_ANNOS)
    return False


@rule(
    "host-sync",
    doc="host-synchronizing calls (float()/.item()/np.asarray/jax.device_get) on "
        "traced values inside jit regions stall the dispatch pipeline every step",
    scan=("src/repro/",),
)
def host_sync(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    par = _parents(tree)
    traced = traced_functions(tree, par)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not _in_traced(node, par, traced):
            continue
        d = _dotted(node.func)
        hit = None
        if d in _HOST_SYNC_CALLS:
            arg0 = node.args[0] if node.args else None
            # casting a literal or a host-scalar-annotated parameter is host
            # arithmetic on static config, not a device sync
            if d in ("float", "int", "bool") and (
                arg0 is None or isinstance(arg0, ast.Constant)
                or _host_scalar_param(node, arg0, par)
            ):
                continue
            # np.array over a host container (list/tuple/comprehension) is
            # trace-time table building, not a device readback
            if isinstance(arg0, _HOST_CONTAINERS):
                continue
            hit = f"{d}()"
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr in _HOST_SYNC_METHODS and not node.args):
            hit = f".{node.func.attr}()"
        if hit:
            yield Finding("host-sync", rel, node.lineno,
                          f"{hit} inside a traced region forces a device->host sync "
                          "(or fails under jit) — keep values on device or hoist to "
                          "the driver", _line(text, node.lineno))


# --------------------------------------------------------------------------- #
# serving-contract rule: scan-donate
# --------------------------------------------------------------------------- #


def _contains_scan(fn: ast.AST, par: dict, defs: dict) -> bool:
    """Does this function (or a module-local callee) run a lax.scan?"""
    seen: set = set()
    stack = [fn]
    while stack:
        cur = stack.pop()
        if cur in seen or cur is None:
            continue
        seen.add(cur)
        for node in ast.walk(cur):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d in ("jax.lax.scan", "lax.scan"):
                    return True
                if isinstance(node.func, ast.Name):
                    stack.append(_resolve(node.func.id, node, par, defs))
    return False


def _jit_sites(tree: ast.AST, par: dict, defs: dict):
    """Yield ``(report_node, wrapped_fn_node_or_None, jit_kwargs)`` for every
    ``jax.jit`` application: decorator (bare, call, or partial) and direct
    ``jax.jit(fn, ...)`` calls."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _dotted(dec) in ("jax.jit", "jit"):
                    yield dec, node, {}
                elif isinstance(dec, ast.Call):
                    f = _dotted(dec.func)
                    if f in ("jax.jit", "jit"):
                        yield dec, node, {kw.arg: kw.value for kw in dec.keywords}
                    elif f in _PARTIAL and dec.args and _dotted(dec.args[0]) in ("jax.jit", "jit"):
                        yield dec, node, {kw.arg: kw.value for kw in dec.keywords}
        elif isinstance(node, ast.Call) and _dotted(node.func) in ("jax.jit", "jit"):
            wrapped = None
            if node.args:
                a0 = node.args[0]
                if isinstance(a0, ast.Lambda):
                    wrapped = a0
                elif isinstance(a0, ast.Name):
                    wrapped = _resolve(a0.id, node, par, defs)
            yield node, wrapped, {kw.arg: kw.value for kw in node.keywords}


@rule(
    "scan-donate",
    doc="a jitted program that advances carried state through lax.scan must "
        "donate that state (donate_argnums/donate_argnames) or every dispatch "
        "copies it",
    scan=("src/repro/",),
)
def scan_donate(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    par = _parents(tree)
    defs = _local_defs(tree)
    for site, wrapped, kw in _jit_sites(tree, par, defs):
        if wrapped is None or not _contains_scan(wrapped, par, defs):
            continue
        if "donate_argnums" not in kw and "donate_argnames" not in kw:
            name = getattr(wrapped, "name", "<lambda>")
            yield Finding("scan-donate", rel, site.lineno,
                          f"jit of {name!r} runs a lax.scan over carried state but "
                          "donates nothing — pass donate_argnums/donate_argnames so "
                          "the state buffers are reused in place",
                          _line(text, site.lineno))


# --------------------------------------------------------------------------- #
# serving-contract rule: retrace-hazard
# --------------------------------------------------------------------------- #


def _static_names(kw: dict) -> set[str]:
    names: set[str] = set()
    v = kw.get("static_argnames")
    if isinstance(v, ast.Constant) and isinstance(v.value, str):
        names.add(v.value)
    elif isinstance(v, (ast.Tuple, ast.List)):
        names.update(e.value for e in v.elts
                     if isinstance(e, ast.Constant) and isinstance(e.value, str))
    return names


def _float_param(fn: ast.AST, name: str) -> bool:
    """Does parameter ``name`` default to a float literal or carry a bare
    ``float`` annotation?  (Both make the value part of the jit cache key —
    every distinct float compiles a fresh program.)"""
    if isinstance(fn, ast.Lambda):
        return False
    args = fn.args
    pos = args.posonlyargs + args.args
    pairs = list(zip(pos[len(pos) - len(args.defaults):], args.defaults))
    pairs += [(a, d) for a, d in zip(args.kwonlyargs, args.kw_defaults) if d is not None]
    for a, d in pairs:
        if a.arg == name and isinstance(d, ast.Constant) and isinstance(d.value, float):
            return True
    for a in pos + args.kwonlyargs:
        if a.arg == name and isinstance(a.annotation, ast.Name) and a.annotation.id == "float":
            return True
    return False


@rule(
    "retrace-hazard",
    doc="a float-valued static jit argument retraces on every distinct value — "
        "make it a traced argument (or part of the Session cache key if it is "
        "genuinely structural)",
    scan=("src/repro/",),
)
def retrace_hazard(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    par = _parents(tree)
    defs = _local_defs(tree)
    for site, wrapped, kw in _jit_sites(tree, par, defs):
        if wrapped is None:
            continue
        for name in sorted(_static_names(kw)):
            if _float_param(wrapped, name):
                yield Finding("retrace-hazard", rel, site.lineno,
                              f"static jit argument {name!r} of "
                              f"{getattr(wrapped, 'name', '<lambda>')!r} is float-"
                              "valued — every distinct value compiles a new program; "
                              "pass it traced instead", _line(text, site.lineno))


# --------------------------------------------------------------------------- #
# serving-contract rule: stray-debug
# --------------------------------------------------------------------------- #


@rule(
    "stray-debug",
    doc="jax.debug.* / breakpoint() in engine modules (and print() under trace) "
        "insert host callbacks into served programs",
    scan=("src/repro/",),
)
def stray_debug(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    par = _parents(tree)
    traced = traced_functions(tree, par)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func)
        if d and d.startswith(("jax.debug.", "debug.print", "debug.breakpoint")):
            yield Finding("stray-debug", rel, node.lineno,
                          f"{d} in library code lowers to a host callback — remove "
                          "before serving", _line(text, node.lineno))
        elif d == "breakpoint":
            yield Finding("stray-debug", rel, node.lineno,
                          "breakpoint() left in library code", _line(text, node.lineno))
        elif d == "print" and _in_traced(node, par, traced):
            yield Finding("stray-debug", rel, node.lineno,
                          "print() inside a traced region runs at trace time only "
                          "(or becomes a host callback) — use the driver loop or "
                          "jax.debug deliberately", _line(text, node.lineno))


# --------------------------------------------------------------------------- #
# serving-contract rule: swallowed-fault
# --------------------------------------------------------------------------- #

_BROAD_EXC = {"Exception", "BaseException", "builtins.Exception", "builtins.BaseException"}


def _only_pass(body: list) -> bool:
    """True when a handler body does nothing: ``pass`` / ``...`` / a bare
    docstring — no logging, no typed re-packaging, no re-raise."""
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            continue
        return False
    return True


@rule(
    "swallowed-fault",
    doc="bare 'except:' and 'except Exception: pass' silently swallow faults — "
        "the resilience layer needs every failure typed, logged, or re-raised",
    scan=("src/",),
)
def swallowed_fault(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            yield Finding("swallowed-fault", rel, node.lineno,
                          "bare 'except:' catches everything (KeyboardInterrupt "
                          "included) and hides the fault class — catch a typed "
                          "exception or classify via repro.serving.resilience",
                          _line(text, node.lineno))
            continue
        types = [node.type] if not isinstance(node.type, ast.Tuple) else list(node.type.elts)
        broad = any(_dotted(t) in _BROAD_EXC for t in types)
        if broad and _only_pass(node.body):
            yield Finding("swallowed-fault", rel, node.lineno,
                          "'except Exception: pass' swallows the fault with no "
                          "trace — type it, log it, re-raise, or degrade to a "
                          "structured error reply", _line(text, node.lineno))


# --------------------------------------------------------------------------- #
# serving-contract rule: float64-promotion
# --------------------------------------------------------------------------- #

_F64 = {"np.float64", "numpy.float64", "jnp.float64", "jax.numpy.float64"}


@rule(
    "float64-promotion",
    doc="float64 spellings inside traced regions double memory traffic and fall "
        "off the fast path (the suite is float32 end-to-end)",
    scan=("src/repro/",),
)
def float64_promotion(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    par = _parents(tree)
    traced = traced_functions(tree, par)
    for node in ast.walk(tree):
        if not _in_traced(node, par, traced):
            continue
        if isinstance(node, (ast.Attribute, ast.Name)) and _dotted(node) in _F64:
            yield Finding("float64-promotion", rel, node.lineno,
                          "float64 dtype inside a traced region — the serving "
                          "contract is float32 end-to-end", _line(text, node.lineno))
        elif isinstance(node, ast.Call):
            # x.astype(float) / jnp.asarray(x, dtype=float): weak float64
            args = list(node.args) + [kw.value for kw in node.keywords
                                      if kw.arg in ("dtype", None)]
            if (isinstance(node.func, ast.Attribute) and node.func.attr == "astype") or (
                _dotted(node.func) in ("jnp.asarray", "jnp.array")
            ):
                for a in args:
                    if isinstance(a, ast.Name) and a.id == "float":
                        yield Finding("float64-promotion", rel, node.lineno,
                                      "bare `float` dtype promotes to float64 under "
                                      "x64 — spell jnp.float32",
                                      _line(text, node.lineno))


# --------------------------------------------------------------------------- #
# serving-contract rule: fork-unsafe
# --------------------------------------------------------------------------- #

_FORK_CALLS = {"os.fork", "os.forkpty"}
_MP_FACTORIES = {"multiprocessing.Process", "multiprocessing.Pool",
                 "mp.Process", "mp.Pool"}
_CTX_CALLS = {"get_context", "set_start_method"}


@rule(
    "fork-unsafe",
    doc="os.fork / fork-start multiprocessing deadlock an imported JAX runtime "
        "(its internal thread pools don't survive fork) — spawn worker "
        "processes via subprocess or an explicit 'spawn' context",
    scan=("src/",),
)
def fork_unsafe(rel: str, text: str, tree: ast.AST) -> Iterator[Finding]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name in _FORK_CALLS:
            yield Finding("fork-unsafe", rel, node.lineno,
                          f"{name}() forks the process — a forked JAX runtime "
                          "deadlocks on its thread pools; spawn a fresh "
                          "interpreter (subprocess / 'spawn' context) instead",
                          _line(text, node.lineno))
        elif name in _MP_FACTORIES:
            # bare Process()/Pool() default to fork on Linux; a spawn-context
            # handle (ctx.Process where ctx = get_context("spawn")) resolves
            # to a different dotted name and passes
            yield Finding("fork-unsafe", rel, node.lineno,
                          f"{name}(...) uses the platform default start method "
                          "(fork on Linux) — JAX is already initialized here; "
                          "use subprocess or get_context('spawn')",
                          _line(text, node.lineno))
        elif (
            name is not None
            and name.split(".")[-1] in _CTX_CALLS
            and node.args
            and isinstance(node.args[0], ast.Constant)
            and node.args[0].value == "fork"
        ):
            yield Finding("fork-unsafe", rel, node.lineno,
                          "explicit 'fork' start method — a forked JAX runtime "
                          "deadlocks; request 'spawn'",
                          _line(text, node.lineno))
