"""Pass B: the jaxpr hazard pass over every served program kind.

Pass A reasons about source text; this pass reasons about the *programs*.
``Session.trace_programs`` abstractly lowers (``jax.make_jaxpr`` — no
compile, no execution) the four served program kinds — simulate / explain /
optimize / frontier — and this module walks the closed jaxprs (recursing
into scan/cond/pjit sub-jaxprs) looking for hazards no AST rule can see:

* ``jaxpr-callback``  — host-callback primitives (``jax.debug``/
  ``pure_callback``/``io_callback``) embedded in a served program: every
  dispatch round-trips to Python.
* ``jaxpr-transfer``  — explicit ``device_put`` inside the program: a
  value that should have entered as a traced argument is being shipped
  mid-program.
* ``jaxpr-float64``   — a float64 intermediate: the suite's serving
  contract is float32 end-to-end; a single promoted op doubles traffic
  downstream of it.
* ``jaxpr-const``     — a large array folded into the program as a
  constant.  Constants are baked into the executable; a big one is almost
  always a traced-argument candidate that leaked into the trace (and it
  bloats the AOT cache ROADMAP item 2 wants to ship).
* ``jaxpr-seam``      — primitives that cannot lower through the
  ``kernels/runtime.py`` seam (decompositions backed by per-backend custom
  calls, e.g. linear-algebra factorizations).

The sweep covers the full 7-architecture ``.dhd`` library x all 4 kinds
over one representative workload bucket; ``run_pass_b`` returns the
machine-readable dict embedded in ``results/analysis/dragonlint.json``.
"""
from __future__ import annotations

from pathlib import Path

from tools.dragonlint.engine import REPO_ROOT, Finding

KINDS = ("simulate", "explain", "optimize", "frontier")
DEFAULT_WORKLOAD = "bert_base"

# host-callback primitive names (jax 0.4.x spellings)
CALLBACK_PRIMS = {"debug_callback", "pure_callback", "io_callback", "callback", "outside_call"}
# mid-program host<->device / placement transfers.  jnp.asarray over tiny
# static config (spec masks) lowers to an ALIAS-semantics device_put of a
# constant — free at dispatch, constant-folded by XLA — so the rule only
# fires on placements bigger than this.
TRANSFER_PRIMS = {"device_put", "copy"}
TRANSFER_ELEMS_LIMIT = 1024
# backed by per-backend custom calls the kernels/runtime.py seam can't carry
SEAM_UNSAFE_PRIMS = {
    "eig", "eigh", "svd", "lu", "qr", "cholesky", "triangular_solve",
    "custom_linear_solve", "tridiagonal", "tridiagonal_solve", "schur",
    "approx_top_k", "fft",
}
# a constant this large folded into the executable is a traced-arg leak
CONST_ELEMS_LIMIT = 4096


def iter_eqns(jaxpr):
    """Depth-first over every equation, recursing into sub-jaxprs carried in
    eqn params (scan/while/cond bodies, pjit/custom_vjp calls, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_eqns(sub)


def _sub_jaxprs(value):
    from jax.extend import core as jex_core

    vals = value if isinstance(value, (list, tuple)) else [value]
    for v in vals:
        if isinstance(v, jex_core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jex_core.Jaxpr):
            yield v


def _is_float64(aval) -> bool:
    import numpy as np

    dt = getattr(aval, "dtype", None)
    return dt is not None and dt == np.dtype("float64")


def hazards_in(closed, label: str) -> list[Finding]:
    """All jaxpr hazards in one ClosedJaxpr; ``label`` becomes the finding's
    pseudo-path ``<jaxpr:arch/kind>``."""
    import numpy as np

    findings: list[Finding] = []
    path = f"<jaxpr:{label}>"

    for const in closed.consts:
        a = np.asarray(const)
        if a.size > CONST_ELEMS_LIMIT:
            findings.append(Finding(
                "jaxpr-const", path, 0,
                f"array of shape {a.shape} ({a.size} elems, {a.dtype}) folded into "
                "the program as a constant — pass it as a traced argument",
            ))
        if _is_float64(a):
            findings.append(Finding(
                "jaxpr-float64", path, 0,
                f"float64 constant of shape {a.shape} baked into the program",
            ))

    seen: set[tuple[str, str]] = set()
    for eqn in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        hit = None
        if name in CALLBACK_PRIMS:
            hit = ("jaxpr-callback",
                   f"host-callback primitive {name!r} in a served program — every "
                   "dispatch round-trips to Python")
        elif name in TRANSFER_PRIMS:
            sizes = [getattr(getattr(v, "aval", None), "size", 0) for v in eqn.invars]
            if max(sizes, default=0) > TRANSFER_ELEMS_LIMIT:
                hit = ("jaxpr-transfer",
                       f"mid-program transfer primitive {name!r} over "
                       f"{max(sizes)} elements — the value belongs in the "
                       "program's traced arguments")
        elif name in SEAM_UNSAFE_PRIMS:
            hit = ("jaxpr-seam",
                   f"primitive {name!r} lowers via per-backend custom calls and "
                   "cannot pass the kernels/runtime.py seam")
        if hit and (hit[0], name) not in seen:
            seen.add((hit[0], name))
            findings.append(Finding(hit[0], path, 0, hit[1]))
        for var in eqn.outvars:
            if _is_float64(getattr(var, "aval", None)) and ("jaxpr-float64", name) not in seen:
                seen.add(("jaxpr-float64", name))
                findings.append(Finding(
                    "jaxpr-float64", path, 0,
                    f"primitive {name!r} produces a float64 intermediate — the "
                    "serving contract is float32 end-to-end",
                ))
    return findings


def run_pass_b(root: Path = REPO_ROOT, workload: str = DEFAULT_WORKLOAD,
               objective: str = "edp") -> dict:
    """Lower simulate/explain/optimize/frontier for every library
    architecture and inspect the jaxprs.  Returns the Pass B report dict
    (``findings`` non-empty => fail)."""
    from repro.api import Architecture, Session, Workload
    from repro.core.dhdl import load_library

    archs = sorted(load_library(refresh=True))
    w = Workload(workload)
    findings: list[Finding] = []
    coverage: list[list[str]] = []
    for arch_name in archs:
        sess = Session(Architecture(arch_name))
        progs = sess.trace_programs(w, objective=objective)
        missing = [k for k in KINDS if k not in progs]
        if missing:
            findings.append(Finding(
                "jaxpr-coverage", f"<jaxpr:{arch_name}>", 0,
                f"trace_programs returned no program for kinds {missing}",
            ))
        for kind in KINDS:
            if kind not in progs:
                continue
            findings.extend(hazards_in(progs[kind], f"{arch_name}/{kind}"))
            coverage.append([arch_name, kind])
    return {
        "workload": workload,
        "bucket": list(w.bucket),
        "objective": objective,
        "architectures": archs,
        "kinds": list(KINDS),
        "coverage": coverage,
        "programs_lowered": len(coverage),
        "findings": [f.to_json() for f in findings],
    }
