"""Render EXPERIMENTS.md tables from results/dryrun/*.json."""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
from repro.configs import SHAPES, get_config
from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["musicgen-large", "minitron-8b", "qwen2.5-32b", "granite-3-8b",
              "phi4-mini-3.8b", "kimi-k2-1t-a32b", "llama4-scout-17b-a16e",
              "falcon-mamba-7b", "llama-3.2-vision-11b", "zamba2-1.2b"]


def model_flops_per_device(arch, shape_name, chips):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len / chips
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len / chips
    return 2.0 * n * shape.global_batch / chips


def load(d="results/dryrun_base"):
    recs = {}
    for fn in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(fn))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_table(recs, mesh="16x16"):
    print(f"\n### Dry-run table ({mesh}; compile+lower wall, per-device HBM)\n")
    print("| arch | shape | status | compile s | HBM/dev GB | collective GB/dev/step |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                print(f"| {a} | {s} | MISSING | | | |")
            elif r.get("skipped"):
                print(f"| {a} | {s} | skip (full-attn @500k) | — | — | — |")
            else:
                coll = r.get("collectives", {}).get("total_bytes", 0) / 1e9
                print(f"| {a} | {s} | ok | {r['compile_s']} | {r['hbm_per_device_gb']} | {coll:.1f} |")


def roofline_table(recs, mesh="16x16"):
    print(f"\n### Roofline table ({mesh})\n")
    print("| arch | shape | t_comp s | t_mem s | t_coll s | bound | 6ND/HLO | MFU-bound |")
    print("|---|---|---|---|---|---|---|---|")
    rows = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if not r or r.get("skipped") or "flops_per_device" not in r:
                continue
            tc = r["flops_per_device"] / PEAK_FLOPS
            tm = r["bytes_per_device"] / HBM_BW
            tl = r.get("collectives", {}).get("total_bytes", 0) / LINK_BW
            step = max(tc, tm, tl)
            bound = {tc: "compute", tm: "memory", tl: "collective"}[step]
            mf = model_flops_per_device(a, s, r["chips"])
            useful = mf / max(r["flops_per_device"], 1)
            mfu = mf / PEAK_FLOPS / step
            rows.append((a, s, tc, tm, tl, bound, useful, mfu))
            print(f"| {a} | {s} | {tc:.3e} | {tm:.3e} | {tl:.3e} | {bound} "
                  f"| {useful:.2f} | {mfu:.4f} |")
    return rows


if __name__ == "__main__":
    recs = load()
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    dryrun_table(recs, mesh)
    rows = roofline_table(recs, mesh)
    print("\nworst MFU-bound cells:")
    for a, s, tc, tm, tl, bound, useful, mfu in sorted(rows, key=lambda r: r[-1])[:6]:
        print(f"  {a} x {s}: mfu_bound={mfu:.5f} bound={bound}")
    print("most collective-bound cells:")
    for a, s, tc, tm, tl, bound, useful, mfu in sorted(rows, key=lambda r: -(r[4]/max(max(r[2],r[3]),1e-12)))[:6]:
        print(f"  {a} x {s}: t_coll/t_rest={tl/max(max(tc,tm),1e-12):.2f}")
