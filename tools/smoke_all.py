"""Dev tool: run a reduced-config forward+loss+prefill+decode for all archs,
smoke the façade (Session simulate/explain/optimize + warm-cache check),
then the examples' Pareto-DSE path (optimize_hw.pareto_frontier) at toy
scale.  ``--skip-dse`` runs the model matrix only."""
import importlib.util
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")
from repro.configs import all_archs, get_config
from repro.models import build_model


def smoke_session():
    """The front door end-to-end: every Session method returns a sane,
    explainable report and the warm path never retraces."""
    from repro import Session

    sess = Session("edge")
    rep = sess.simulate("lstm")
    assert rep.workloads[0].runtime_s > 0 and rep.area_mm2 > 0
    assert abs(sum(v.time_s for v in rep.workloads[0].vertices) - rep.runtime_s) < 1e-4 * rep.runtime_s
    exp = sess.explain("lstm")
    assert exp.attribution and exp.bottlenecks(1)[0].parameter
    opt = sess.optimize("lstm", steps=8, lr=0.05)
    assert opt.improvement > 1.0, f"optimize made the design worse: {opt.improvement}"
    assert opt.to_dhd().startswith("arch ")
    t0 = sess.stats.traces
    sess.simulate("merge_sort")  # same shape bucket: must be warm
    assert sess.stats.traces == t0, "warm same-bucket simulate retraced"
    print(f"session smoke: {sess.stats.programs} programs, "
          f"{sess.stats.traces} traces, warm path clean  OK")


def smoke_pareto_example():
    """Exercise examples/optimize_hw.py's frontier path on a tiny workload:
    population DSE must produce a non-empty, feasible, serialized front."""
    path = os.path.join(os.path.dirname(__file__), "..", "examples", "optimize_hw.py")
    spec = importlib.util.spec_from_file_location("optimize_hw", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    from repro.workloads import get_workload

    res = mod.pareto_frontier(get_workload("lstm"), population=6, steps=3)
    assert res.front.size >= 1, "empty Pareto front"
    assert res.feasible[res.front].all(), "front member violates budget"
    assert all(w["dhd"].startswith("arch ") for w in res.winners)
    print(f"pareto example: front {res.front.size}/6, hv {res.hypervolume:.2f}  OK")


def batch_for(cfg, B=2, S=16):
    key = jax.random.PRNGKey(0)
    shape = (B, S, cfg.audio.n_codebooks) if cfg.audio else (B, S)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.vision:
        batch["vision"] = jax.random.normal(key, (B, cfg.vision.n_patches, cfg.vision.d_vision))
    return batch


def main():
    for arch in all_archs():
        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(1))
        batch = batch_for(cfg)
        loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
        assert jnp.isfinite(loss), (arch, loss)
        # prefill + 2 decode steps
        logits, cache = m.prefill(
            params, batch["tokens"], max_len=32, vision=batch.get("vision")
        )
        assert jnp.isfinite(logits).all(), arch
        tok = jnp.argmax(logits, -1).reshape(2, 1, -1)[:, :, 0] if cfg.audio else jnp.argmax(logits, -1)[:, None]
        if cfg.audio:
            tok = jnp.broadcast_to(tok[..., None], (2, 1, cfg.audio.n_codebooks))
        for _ in range(2):
            logits2, cache = m.decode_step(params, tok, cache)
            assert jnp.isfinite(logits2).all(), arch
        print(f"{arch:28s} loss={float(loss):.4f}  params={m.param_count():,}  OK")
    if "--skip-dse" not in sys.argv:
        smoke_session()
        smoke_pareto_example()


if __name__ == "__main__":
    main()
