"""Worker pool & multi-process serving: frame protocol, staged-assembly
bit-identity, pooled dispatch, lossless stats aggregation, chaos determinism
under concurrency, worker crash/kill requeue, and AOT cache multi-writer
contention (docs/serving.md §worker pool)."""
import json
import os
import pickle
import socket
import subprocess
import sys
import tempfile
import textwrap

import pytest

from repro.serving import (
    BatchingDesignService,
    ChaosConfig,
    ChaosInjector,
    DesignQuery,
    DesignService,
    FlushPolicy,
    MultiProcessDesignService,
    PooledDesignService,
    ServiceStats,
    StagedBatchingService,
)
from repro.serving import protocol

POLICY = FlushPolicy(max_batch=8, max_delay_s=0.001)

#: one compiled-program cache for every in-process service in this file —
#: parameter values are traced data, so sharing is exact and saves compiles
_SHARED: dict = {}


def _mk(cls=BatchingDesignService, **kw):
    kw.setdefault("programs", _SHARED)
    return cls("base", policy=POLICY, **kw)


def _queries(n, workloads=("lstm", "gcn")):
    archs = [None, "edge", "datacenter", "mobile"]
    return [
        DesignQuery(qid=i, kind="simulate" if i % 2 == 0 else "explain",
                    workload=workloads[(i // 2) % len(workloads)],
                    architecture=archs[(i // 2) % 4])
        for i in range(n)
    ]


def _fingerprints(replies):
    return [json.dumps(r.result.to_json(), sort_keys=True) for r in replies]


# --------------------------------------------------------------------------- #
# frame protocol
# --------------------------------------------------------------------------- #


class TestProtocol:
    def test_roundtrip_over_socketpair(self):
        a, b = socket.socketpair()
        try:
            protocol.send_frame(a, "chunk", (7, ["q0", "q1"]))
            protocol.send_frame(a, "hb", 3)
            assert protocol.recv_frame(b) == ("chunk", (7, ["q0", "q1"]))
            assert protocol.recv_frame(b) == ("hb", 3)
        finally:
            a.close(), b.close()

    def test_eof_mid_frame_raises(self):
        a, b = socket.socketpair()
        try:
            frame = protocol.encode_frame("chunk", list(range(100)))
            a.sendall(frame[: len(frame) // 2])
            a.close()
            with pytest.raises(protocol.ProtocolError, match="mid-frame"):
                protocol.recv_frame(b)
        finally:
            b.close()

    def test_clean_eof_between_frames_raises(self):
        a, b = socket.socketpair()
        a.close()
        try:
            with pytest.raises(protocol.ProtocolError):
                protocol.recv_frame(b)
        finally:
            b.close()

    def test_bad_magic_rejected(self):
        a, b = socket.socketpair()
        try:
            a.sendall(b"XXXX" + (0).to_bytes(4, "big"))
            with pytest.raises(protocol.ProtocolError, match="magic"):
                protocol.recv_frame(b)
        finally:
            a.close(), b.close()

    def test_absurd_length_rejected(self):
        a, b = socket.socketpair()
        try:
            a.sendall(protocol.MAGIC + (protocol.MAX_FRAME + 1).to_bytes(4, "big"))
            with pytest.raises(protocol.ProtocolError, match="exceeds"):
                protocol.recv_frame(b)
        finally:
            a.close(), b.close()

    def test_unpicklable_payload_fails_before_any_bytes_hit_the_wire(self):
        a, b = socket.socketpair()
        try:
            with pytest.raises(Exception):
                protocol.send_frame(a, "replies", lambda: None)
            b.settimeout(0.05)
            with pytest.raises(socket.timeout):
                b.recv(1)  # stream is still clean: nothing was written
        finally:
            a.close(), b.close()


# --------------------------------------------------------------------------- #
# staged assembly: bit-identity with the sequential tree-stack path
# --------------------------------------------------------------------------- #


class TestStagedAssembly:
    @pytest.fixture(scope="class")
    def baseline(self):
        svc = _mk()
        qs = _queries(16)
        return qs, _fingerprints(svc.serve(qs))

    def test_staged_replies_bit_identical_to_sequential(self, baseline):
        qs, want = baseline
        got = _fingerprints(_mk(StagedBatchingService).serve(qs))
        assert got == want

    def test_singleton_queries_route_through_staged_dispatch(self, baseline):
        qs, want = baseline
        svc = _mk(StagedBatchingService)
        got = _fingerprints([svc.submit(q) for q in qs])
        assert got == want
        # a size-1 staged dispatch is not a coalesce: stats must not claim one
        assert svc.stats.batches == 0 and svc.stats.batched_queries == 0

    def test_staging_buffers_are_reused_not_leaked(self, baseline):
        qs, _ = baseline
        svc = _mk(StagedBatchingService)
        svc.serve(qs)
        n_sets = len(svc._assembler._tls.bufs)
        assert n_sets >= 1
        svc.serve(qs)
        # one buffer set per (spec, bucket), not per call: repeats don't grow it
        assert len(svc._assembler._tls.bufs) == n_sets


# --------------------------------------------------------------------------- #
# pooled service: async dispatch, ordering, isolation
# --------------------------------------------------------------------------- #


class TestPooledService:
    @pytest.fixture(scope="class")
    def baseline(self):
        svc = _mk()
        qs = _queries(16)
        return qs, _fingerprints(svc.serve(qs))

    def test_pooled_replies_bit_identical_and_ordered(self, baseline):
        qs, want = baseline
        with _mk(PooledDesignService, workers=2) as pool:
            replies = pool.serve(qs)
        assert [r.qid for r in replies] == [q.qid for q in qs]
        assert all(r.ok for r in replies)
        assert _fingerprints(replies) == want

    def test_ticket_api(self, baseline):
        qs, want = baseline
        with _mk(PooledDesignService, workers=2) as pool:
            tickets = [pool.enqueue(q) for q in qs]
            assert pool.join(timeout=60)
            replies = [pool.take(t) for t in tickets]
            assert _fingerprints(replies) == want
            assert pool.take(tickets[0]) is None  # a reply pops exactly once

    def test_poison_query_is_isolated(self):
        qs = _queries(6)
        qs[2] = DesignQuery(qid=2, kind="simulate", workload="no_such_workload_xyz")
        with _mk(PooledDesignService, workers=2) as pool:
            replies = pool.serve(qs)
        assert [r.qid for r in replies] == [0, 1, 2, 3, 4, 5]
        assert not replies[2].ok and replies[2].error.code == "client-error"
        assert all(r.ok for i, r in enumerate(replies) if i != 2)
        st = pool.stats
        assert st.queries == 6 and st.ok == 5

    def test_enqueue_after_close_raises(self):
        pool = _mk(PooledDesignService, workers=1)
        pool.close()
        pool.close()  # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            pool.enqueue(_queries(1)[0])


# --------------------------------------------------------------------------- #
# satellite 1: ServiceStats.merge — lossless aggregation
# --------------------------------------------------------------------------- #


def _stats(**kw):
    base = dict(programs=1, hits=0, misses=0, traces=0, queries=0, ok=0,
                retries=0, deadline_misses=0, degraded=0, errors={},
                stragglers=(), breakers={})
    base.update(kw)
    return ServiceStats(**base)


class TestStatsMerge:
    def test_counters_sum_and_errors_merge_keywise(self):
        a = _stats(queries=5, ok=4, retries=2, errors={"transient": 1},
                   stragglers=((1, 0.5),))
        b = _stats(queries=3, ok=3, errors={"transient": 2, "numeric": 1},
                   stragglers=((7, 0.9),))
        m = a.merge(b)
        assert (m.queries, m.ok, m.retries) == (8, 7, 2)
        assert m.errors == {"transient": 3, "numeric": 1}
        assert m.stragglers == ((1, 0.5), (7, 0.9))
        assert m.availability == 7 / 8

    def test_add_operator_reduces_a_fleet(self):
        parts = [_stats(queries=i, ok=i) for i in (1, 2, 3)]
        total = sum(parts[1:], parts[0])
        assert total.queries == 6 and total.availability == 1.0

    def test_breaker_lanes_merge_keywise(self):
        a = _stats(breakers={("simulate", (1, 32)): dict(open=False, failures=1,
                                                         trips=0, rejected=0)})
        b = _stats(breakers={("simulate", (1, 32)): dict(open=True, failures=3,
                                                         trips=1, rejected=2),
                             ("explain", (1, 32)): dict(open=False, failures=0,
                                                        trips=0, rejected=0)})
        m = a.merge(b).breakers
        assert m[("simulate", (1, 32))] == dict(open=True, failures=4, trips=1,
                                                rejected=2)
        assert ("explain", (1, 32)) in m

    def test_partitioned_workers_sum_to_the_sequential_ledger(self):
        """The property the fleet view rests on: per-worker stats summed over
        any partition of a query stream equal the sequential run's ledger —
        chaos, retries and deadlines key on the query, never the worker."""
        chaos = ChaosConfig(seed=5, p_transient=0.3, p_nan=0.2,
                            p_latency=0.2, latency_s=0.0)
        n = 24
        seq_programs: dict = {}
        seq = DesignService("base", chaos=ChaosInjector(chaos),
                            request_bucket=POLICY.max_batch,
                            programs=seq_programs)
        seq.serve(_queries(n))
        want = seq.stats

        for k in (2, 3):
            part_programs: dict = {}
            workers = [
                DesignService("base", chaos=ChaosInjector(chaos),
                              request_bucket=POLICY.max_batch,
                              programs=part_programs)
                for _ in range(k)
            ]
            for i, q in enumerate(_queries(n)):
                workers[i % k].submit(q)
            merged = workers[0].stats
            for w in workers[1:]:
                merged = merged + w.stats
            for fld in ("queries", "ok", "retries", "deadline_misses",
                        "degraded", "errors", "hits", "misses", "traces",
                        "batches", "batched_queries"):
                assert getattr(merged, fld) == getattr(want, fld), (k, fld)
            assert merged.availability == want.availability


# --------------------------------------------------------------------------- #
# satellite 3: chaos determinism under concurrency
# --------------------------------------------------------------------------- #


class TestChaosDeterminismUnderConcurrency:
    CHAOS = ChaosConfig(seed=11, p_transient=0.3, p_nan=0.2, p_latency=0.3,
                        latency_s=0.001)

    def _outcomes(self, replies):
        return [
            (r.qid, r.ok, r.attempts, r.error.code if r.error else None)
            for r in sorted(replies, key=lambda r: r.qid)
        ]

    def test_same_seed_same_schedule_regardless_of_worker_count(self):
        """The chaos schedule is a pure function of (seed, qid): 1-worker
        and 3-worker pools must observe identical per-query faults, retry
        counts and (bit-identical) results — completion order is the only
        thing allowed to differ."""
        qs = _queries(16)
        runs = {}
        for workers in (1, 3):
            inj = ChaosInjector(self.CHAOS)
            with _mk(PooledDesignService, workers=workers, chaos=inj) as pool:
                replies = pool.serve([DesignQuery(**q.__dict__) for q in qs])
            runs[workers] = (self._outcomes(replies), _fingerprints(replies),
                            dict(inj.injected))
        assert runs[1] == runs[3]

    def test_pooled_chaos_outcomes_match_sequential(self):
        qs = _queries(16)
        seq = _mk(chaos=ChaosInjector(self.CHAOS))
        want = (self._outcomes(seq.serve(qs)), _fingerprints(seq.replies))
        inj = ChaosInjector(self.CHAOS)
        with _mk(PooledDesignService, workers=2, chaos=inj) as pool:
            replies = pool.serve([DesignQuery(**q.__dict__) for q in qs])
        assert (self._outcomes(replies), _fingerprints(replies)) == want

    def test_worker_kill_draw_appends_to_the_schedule(self):
        """Adding p_worker_kill must not reshuffle the historical fault
        schedule — new fault classes draw LAST."""
        base = ChaosInjector(ChaosConfig(seed=3, p_transient=0.4, p_nan=0.3))
        extended = ChaosInjector(ChaosConfig(seed=3, p_transient=0.4, p_nan=0.3,
                                             p_worker_kill=0.5))
        for qid in range(64):
            a, b = base.plan(qid), extended.plan(qid)
            assert (a.transient, a.compile_fail, a.nan, a.latency,
                    a.cache_corrupt) == (b.transient, b.compile_fail, b.nan,
                                         b.latency, b.cache_corrupt)
        assert any(extended.plan(q).worker_kill for q in range(64))
        assert not any(base.plan(q).worker_kill for q in range(64))


# --------------------------------------------------------------------------- #
# multi-process serving: shared AOT cache, crash containment
# --------------------------------------------------------------------------- #


class TestMultiProcess:
    @pytest.fixture(scope="class")
    def warmed(self, tmp_path_factory):
        """A preheated shared cache + the sequential baseline replies."""
        cache_dir = str(tmp_path_factory.mktemp("pool-aot"))
        seq = BatchingDesignService("base", policy=POLICY, cache_dir=cache_dir)
        seq.warmup(["lstm", "gcn"])
        qs = _queries(12)
        return cache_dir, qs, _fingerprints(seq.serve(qs))

    def test_two_workers_bit_identical_zero_compile(self, warmed):
        cache_dir, qs, want = warmed
        with MultiProcessDesignService("base", workers=2, cache_dir=cache_dir,
                                       policy=POLICY) as mp:
            replies = mp.serve(qs)
            st = mp.stats
        assert [r.qid for r in replies] == [q.qid for q in qs]
        assert all(r.ok for r in replies)
        assert _fingerprints(replies) == want
        # both workers rehydrated the parent's executables: nothing compiled
        assert st.traces == 0
        assert st.queries == len(qs) and st.ok == len(qs)

    def test_worker_kill_is_requeued_and_availability_holds(self, warmed):
        cache_dir, qs, want = warmed
        chaos = ChaosConfig(seed=7, p_worker_kill=0.15)
        with MultiProcessDesignService("base", workers=2, cache_dir=cache_dir,
                                       policy=POLICY, chaos=chaos,
                                       worker_timeout_s=6.0) as mp:
            replies = mp.serve(qs)
            info = mp.pool_info
        assert info["kills"] >= 1 and info["requeues"] >= 1
        assert all(r.ok for r in replies)  # availability == 1.0
        assert _fingerprints(replies) == want  # requeued answers are exact

    def test_heartbeat_silence_is_worker_death(self, warmed, tmp_path):
        """A hung worker (handshakes, then never beacons) must be detected
        by heartbeat timeout and its in-flight queries resolved — here to
        structured errors, since no live worker remains."""
        cache_dir, qs, _ = warmed
        stub = tmp_path / "stub_worker.py"
        stub.write_text(textwrap.dedent("""
            import argparse, os, socket, time
            from repro.serving import protocol

            ap = argparse.ArgumentParser()
            ap.add_argument("--socket"), ap.add_argument("--id", type=int)
            args = ap.parse_args()
            conn = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            conn.connect(args.socket)
            protocol.send_frame(conn, "hello", {"worker": args.id, "pid": os.getpid()})
            tag, cfg = protocol.recv_frame(conn)
            protocol.send_frame(conn, "ready", {"worker": args.id, "disk_loaded": 0})
            time.sleep(60)  # hang: no heartbeats, no replies
        """))
        mp = MultiProcessDesignService(
            "base", workers=2, cache_dir=cache_dir, policy=POLICY,
            heartbeat_s=0.1, worker_timeout_s=0.8,
            worker_cmd=[sys.executable, str(stub)],
        )
        with mp:
            replies = mp.serve(qs[:4])
        assert mp.pool_info["alive"] == 0
        assert len(replies) == 4  # serve() returned instead of hanging
        assert all(not r.ok for r in replies)
        assert all(r.error.code == "transient" for r in replies)

    def test_cache_dir_is_required(self):
        with pytest.raises(ValueError, match="cache_dir"):
            MultiProcessDesignService("base", workers=2)

    def test_architecture_must_cross_the_process_boundary(self, warmed):
        cache_dir, _, _ = warmed
        from repro.api import Architecture

        with pytest.raises(TypeError, match="process boundary"):
            MultiProcessDesignService(Architecture("edge"), cache_dir=cache_dir)


# --------------------------------------------------------------------------- #
# satellite 2: AOT cache multi-writer contention
# --------------------------------------------------------------------------- #

_HAMMER = """
import pickle, sys
sys.path.insert(0, {src!r})
from repro.kernels import runtime
runtime.serialize_compiled = lambda fn: pickle.dumps(fn)
runtime.deserialize_compiled = pickle.loads
from repro.serving.aotcache import AotCache

cache = AotCache({path!r})
ok = 0
for r in range(4):
    for k in range(50):
        cache.put(("stress", k), {{"payload": k, "round": r}})
        ok += 1
print(ok)
"""


class TestAotCacheContention:
    def test_two_processes_racing_the_same_keys_never_tear(self, tmp_path):
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        path = str(tmp_path / "shared-aot")
        script = _HAMMER.format(src=os.path.abspath(src), path=path)
        procs = [
            subprocess.Popen([sys.executable, "-c", script],
                             stdout=subprocess.PIPE, stderr=subprocess.PIPE)
            for _ in range(2)
        ]
        for p in procs:
            out, err = p.communicate(timeout=120)
            assert p.returncode == 0, err.decode()
            assert out.strip() == b"200"

        import repro.kernels.runtime as runtime
        from repro.serving.aotcache import AotCache

        orig = (runtime.serialize_compiled, runtime.deserialize_compiled)
        runtime.serialize_compiled = lambda fn: pickle.dumps(fn)
        runtime.deserialize_compiled = pickle.loads
        try:
            cache = AotCache(path)
            entries = cache.load_all()
        finally:
            runtime.serialize_compiled, runtime.deserialize_compiled = orig
        # every key readable, no torn entries quarantined, no tmp litter
        assert len(entries) == 50
        assert sorted(k for _, k in entries) == list(range(50))
        assert cache.quarantined == 0
        leftovers = [n for n in os.listdir(path) if n.endswith(".tmp")]
        assert leftovers == []
        assert not any(n.endswith(".quarantined") for n in os.listdir(path))
