"""Tier-1: dragonlint — registry pins, per-rule bad/good fixtures, Pass B.

Every registered rule gets a minimal bad fixture it must fire on and a good
twin it must stay silent on (the acceptance contract for the lint suite);
the registry itself is pinned so a rule can't vanish without this file
noticing.  Pass B is exercised through ``Session.trace_programs`` (all four
program kinds) and through crafted jaxprs for each hazard class.
"""
from __future__ import annotations

import os
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from tools.dragonlint import RULES, lint_source, run_pass_a  # noqa: E402
from tools.dragonlint.engine import Finding, suppressions, write_report  # noqa: E402


def lint(rel: str, src: str) -> list[Finding]:
    return lint_source(rel, textwrap.dedent(src))


def names(findings) -> set[str]:
    return {f.rule for f in findings}


# --------------------------------------------------------------------------- #
# registry pins
# --------------------------------------------------------------------------- #

EXPECTED_RULES = (
    "api-surface",
    "dhdl-corpus",
    "float64-promotion",
    "fork-unsafe",
    "host-sync",
    "kernel-seam",
    "retrace-hazard",
    "scan-donate",
    "stale-oracle-tag",
    "stray-debug",
    "swallowed-fault",
)


class TestRegistry:
    def test_registry_pinned(self):
        assert tuple(sorted(RULES)) == EXPECTED_RULES

    def test_every_rule_documented(self):
        for r in RULES.values():
            assert r.doc, f"rule {r.name} has no doc line"
            assert r.scope in ("file", "repo")
            if r.scope == "file":
                assert r.scan, f"file rule {r.name} scans nothing"

    def test_rule_catalog_in_docs(self):
        catalog = open(os.path.join(os.path.dirname(__file__), "..", "docs", "lint.md")).read()
        for name in EXPECTED_RULES:
            assert f"`{name}`" in catalog, f"docs/lint.md missing rule {name}"

    def test_duplicate_rule_rejected(self):
        from tools.dragonlint.engine import rule

        with pytest.raises(ValueError, match="duplicate"):
            rule("kernel-seam", doc="dup", scan=("src/",))(lambda *a: [])


# --------------------------------------------------------------------------- #
# absorbed rules
# --------------------------------------------------------------------------- #


class TestKernelSeam:
    BAD = """
        import jax.experimental.pallas as pl
        out = pl.pallas_call(kernel, grid=(1,))
        """
    GOOD = """
        from repro.kernels import runtime
        out = runtime.dragon_pallas_call(kernel, grid=(1,))
        """

    def test_fires_on_fragile_spelling(self):
        assert "kernel-seam" in names(lint("src/repro/kernels/sscan.py", self.BAD))

    def test_silent_on_runtime_wrapper(self):
        assert not lint("src/repro/kernels/sscan.py", self.GOOD)

    def test_runtime_seam_itself_is_allowed(self):
        assert not lint("src/repro/kernels/runtime.py", self.BAD)

    def test_out_of_scope_path_ignored(self):
        assert not lint("examples/demo.py", self.BAD)

    # the executable-serialization spellings joined the seam with the AOT
    # cache: only kernels/runtime.py may touch jax.experimental.serialize_executable
    BAD_SERIALIZE = """
        from jax.experimental import serialize_executable as se
        blob = se.serialize(compiled)
        fn = se.deserialize_and_load(*blob)
        """
    GOOD_SERIALIZE = """
        from repro.kernels import runtime
        blob = runtime.serialize_compiled(compiled)
        fn = runtime.deserialize_compiled(blob)
        """

    def test_fires_on_executable_serialization_spelling(self):
        found = names(lint("src/repro/serving/aotcache.py", self.BAD_SERIALIZE))
        assert "kernel-seam" in found

    def test_silent_on_runtime_serialization_wrapper(self):
        assert not lint("src/repro/serving/aotcache.py", self.GOOD_SERIALIZE)

    def test_serialization_allowed_in_runtime_seam(self):
        assert not lint("src/repro/kernels/runtime.py", self.BAD_SERIALIZE)


class TestApiSurface:
    def test_fires_on_engine_module_import(self):
        bad = "from repro.core.dsim import simulate\n"
        assert "api-surface" in names(lint("benchmarks/bench_x.py", bad))

    def test_fires_on_engine_entry_via_aggregate(self):
        bad = "from repro.core import optimize\n"
        assert "api-surface" in names(lint("examples/demo.py", bad))

    def test_fires_on_wrapped_parenthesized_import(self):
        bad = "from repro.core import (\n    clamp_params,\n    pareto_dse,\n)\n"
        assert "api-surface" in names(lint("tools/sweep.py", bad))

    def test_silent_on_facade(self):
        good = "from repro.api import Session, Architecture, Workload\n"
        assert not lint("benchmarks/bench_x.py", good)

    def test_oracle_tag_is_the_escape_hatch(self):
        tagged = "from repro.core.refsim import simulate_ref  # engine-oracle\n"
        assert not lint("benchmarks/bench_x.py", tagged)

    def test_src_is_out_of_scope(self):
        assert not lint("src/repro/serving/engine.py", "from repro.core.dsim import simulate\n")


class TestStaleOracleTag:
    def test_fires_on_tag_without_engine_import(self):
        bad = "import numpy as np  # engine-oracle\n"
        assert "stale-oracle-tag" in names(lint("benchmarks/bench_x.py", bad))

    def test_silent_on_live_tag(self):
        good = "from repro.core.dsim import simulate  # engine-oracle\n"
        assert not lint("benchmarks/bench_x.py", good)

    def test_silent_on_docstring_mention(self):
        good = '"""tagged ``# engine-oracle`` for the API-surface lint."""\n'
        assert not lint("benchmarks/bench_x.py", good)


# --------------------------------------------------------------------------- #
# serving-contract rules
# --------------------------------------------------------------------------- #


class TestHostSync:
    def test_fires_on_float_of_traced_value(self):
        bad = """
            import jax

            @jax.jit
            def f(x):
                return float(x) * 2.0
            """
        assert "host-sync" in names(lint("src/repro/core/x.py", bad))

    def test_fires_on_item_and_device_get(self):
        bad = """
            import jax

            @jax.jit
            def f(x):
                y = jax.device_get(x)
                return y.item()
            """
        assert names(lint("src/repro/core/x.py", bad)) == {"host-sync"}

    def test_fires_in_locally_called_helper(self):
        bad = """
            import jax
            import numpy as np

            def helper(x):
                return np.asarray(x)

            @jax.jit
            def f(x):
                return helper(x)
            """
        assert "host-sync" in names(lint("src/repro/core/x.py", bad))

    def test_silent_outside_traced_region(self):
        good = """
            import numpy as np

            def driver(x):
                return float(np.asarray(x).sum())
            """
        assert not lint("src/repro/core/x.py", good)

    def test_silent_on_host_scalar_param_cast(self):
        good = """
            import jax

            @jax.jit
            def f(x, decay: float):
                return x * float(decay)
            """
        assert not lint("src/repro/core/x.py", good)

    def test_silent_on_host_container_table(self):
        good = """
            import jax
            import numpy as np

            @jax.jit
            def f(x):
                idx = np.array([i for i in range(4)], np.int32)
                return x[idx]
            """
        assert not lint("src/repro/core/x.py", good)


class TestScanDonate:
    BAD = """
        import jax

        def step(c, _):
            return c + 1, None

        @jax.jit
        def chunk(state):
            return jax.lax.scan(step, state, None, length=8)
        """
    GOOD = """
        import functools
        import jax

        def step(c, _):
            return c + 1, None

        @functools.partial(jax.jit, donate_argnums=(0,))
        def chunk(state):
            return jax.lax.scan(step, state, None, length=8)
        """

    def test_fires_on_undonated_scan_carry(self):
        assert "scan-donate" in names(lint("src/repro/core/x.py", self.BAD))

    def test_silent_when_donated(self):
        assert not lint("src/repro/core/x.py", self.GOOD)

    def test_silent_on_jit_without_scan(self):
        good = """
            import jax

            @jax.jit
            def f(x):
                return x + 1
            """
        assert not lint("src/repro/core/x.py", good)


class TestRetraceHazard:
    def test_fires_on_float_static_argname(self):
        bad = """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("lr",))
            def f(x, lr: float):
                return x * lr
            """
        assert "retrace-hazard" in names(lint("src/repro/core/x.py", bad))

    def test_fires_on_float_default(self):
        bad = """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("lr",))
            def f(x, lr=0.05):
                return x * lr
            """
        assert "retrace-hazard" in names(lint("src/repro/core/x.py", bad))

    def test_silent_when_float_is_traced(self):
        good = """
            import jax

            @jax.jit
            def f(x, lr: float):
                return x * lr
            """
        assert not lint("src/repro/core/x.py", good)

    def test_silent_on_structural_statics(self):
        good = """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("spec", "n"))
            def f(x, spec, n: int):
                return x[:n]
            """
        assert not lint("src/repro/core/x.py", good)


class TestStrayDebug:
    def test_fires_on_jax_debug_print(self):
        bad = """
            import jax

            def f(x):
                jax.debug.print("x={}", x)
                return x
            """
        assert "stray-debug" in names(lint("src/repro/core/x.py", bad))

    def test_fires_on_breakpoint(self):
        bad = """
            def f(x):
                breakpoint()
                return x
            """
        assert "stray-debug" in names(lint("src/repro/core/x.py", bad))

    def test_fires_on_print_under_trace(self):
        bad = """
            import jax

            @jax.jit
            def f(x):
                print("tracing", x)
                return x
            """
        assert "stray-debug" in names(lint("src/repro/core/x.py", bad))

    def test_silent_on_driver_print(self):
        good = """
            def report(rows):
                print(len(rows), "rows")
            """
        assert not lint("src/repro/core/x.py", good)


class TestSwallowedFault:
    def test_fires_on_bare_except(self):
        bad = """
            def f(x):
                try:
                    return 1 / x
                except:
                    return 0
            """
        assert "swallowed-fault" in names(lint("src/repro/core/x.py", bad))

    def test_fires_on_except_exception_pass(self):
        bad = """
            def f(x):
                try:
                    return 1 / x
                except Exception:
                    pass
                return 0
            """
        assert "swallowed-fault" in names(lint("src/repro/core/x.py", bad))

    def test_silent_on_typed_handler(self):
        good = """
            def f(x):
                try:
                    return 1 / x
                except ZeroDivisionError:
                    pass
                return 0
            """
        assert "swallowed-fault" not in names(lint("src/repro/core/x.py", good))

    def test_silent_on_handled_broad_exception(self):
        good = """
            def f(x):
                try:
                    return 1 / x
                except Exception as e:
                    raise ValueError(f"bad input: {e}")
            """
        assert "swallowed-fault" not in names(lint("src/repro/core/x.py", good))


class TestFloat64Promotion:
    def test_fires_on_float64_dtype_in_trace(self):
        bad = """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                return x.astype(jnp.float64)
            """
        assert "float64-promotion" in names(lint("src/repro/core/x.py", bad))

    def test_fires_on_bare_float_dtype(self):
        bad = """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                return jnp.asarray(x, dtype=float)
            """
        assert "float64-promotion" in names(lint("src/repro/core/x.py", bad))

    def test_silent_on_float32(self):
        good = """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                return x.astype(jnp.float32)
            """
        assert not lint("src/repro/core/x.py", good)

    def test_silent_on_host_side_float64(self):
        good = """
            import numpy as np

            def summarize(xs):
                return np.asarray(xs, np.float64).mean()
            """
        assert not lint("src/repro/core/x.py", good)


class TestForkUnsafe:
    def test_fires_on_os_fork(self):
        bad = """
            import os

            def spawn_worker():
                pid = os.fork()
            """
        assert "fork-unsafe" in names(lint("src/repro/serving/pool.py", bad))

    def test_fires_on_default_multiprocessing_process(self):
        bad = """
            import multiprocessing

            def spawn_worker(fn):
                p = multiprocessing.Process(target=fn)
                p.start()
            """
        assert "fork-unsafe" in names(lint("src/repro/serving/pool.py", bad))

    def test_fires_on_explicit_fork_context(self):
        bad = """
            import multiprocessing

            ctx = multiprocessing.get_context("fork")
            """
        assert "fork-unsafe" in names(lint("src/repro/serving/pool.py", bad))

    def test_silent_on_subprocess_spawn(self):
        good = """
            import subprocess
            import sys

            def spawn_worker(argv):
                return subprocess.Popen([sys.executable, "-m", "repro.serving.worker"] + argv)
            """
        assert not lint("src/repro/serving/pool.py", good)

    def test_silent_on_spawn_context(self):
        good = """
            import multiprocessing

            ctx = multiprocessing.get_context("spawn")
            """
        assert not lint("src/repro/serving/pool.py", good)

    def test_out_of_scope_path_ignored(self):
        assert not lint("benchmarks/bench_x.py", "import os\npid = os.fork()\n")


# --------------------------------------------------------------------------- #
# engine mechanics: suppressions, parse errors, file mode
# --------------------------------------------------------------------------- #


class TestEngine:
    BAD_LINE = "import jax\n\n@jax.jit\ndef f(x):\n    return float(x)\n"

    def test_suppression_same_line(self):
        src = self.BAD_LINE.replace("return float(x)",
                                    "return float(x)  # dragonlint: disable=host-sync")
        assert not lint("src/repro/core/x.py", src)

    def test_suppression_comment_above(self):
        src = self.BAD_LINE.replace(
            "    return float(x)",
            "    # host scalar by contract -- dragonlint: disable=host-sync\n    return float(x)",
        )
        assert not lint("src/repro/core/x.py", src)

    def test_suppression_all(self):
        src = self.BAD_LINE.replace("return float(x)",
                                    "return float(x)  # dragonlint: disable=all")
        assert not lint("src/repro/core/x.py", src)

    def test_suppression_wrong_rule_does_not_mask(self):
        src = self.BAD_LINE.replace("return float(x)",
                                    "return float(x)  # dragonlint: disable=kernel-seam")
        assert "host-sync" in names(lint("src/repro/core/x.py", src))

    def test_suppressions_parser(self):
        sup = suppressions("x = 1  # dragonlint: disable=a,b\n# dragonlint: disable=c\ny = 2\n")
        assert sup[1] == {"a", "b"}
        assert sup[3] == {"c"}

    def test_parse_error_is_a_finding(self):
        out = lint("src/repro/core/x.py", "def f(:\n")
        assert names(out) == {"parse-error"}

    def test_repo_pass_a_is_clean(self):
        # the acceptance gate: the repo's own tree has no Pass A findings
        findings = run_pass_a(rules=[n for n in RULES if RULES[n].scope == "file"])
        assert findings == [], "\n".join(f.format() for f in findings)

    def test_files_mode_scopes_to_given_files(self, tmp_path):
        findings = run_pass_a(files=["benchmarks/bench_roofline.py"])
        assert findings == []

    def test_write_report_shape(self, tmp_path):
        f = Finding("host-sync", "src/x.py", 3, "msg", "snippet")
        out = write_report(tmp_path, [f], {"findings": [], "coverage": []},
                           path="out/report.json")
        import json

        payload = json.loads(out.read_text())
        assert payload["ok"] is False
        assert payload["pass_a"]["findings"][0]["rule"] == "host-sync"
        assert set(payload["rules"]) == set(RULES)


# --------------------------------------------------------------------------- #
# Pass B: jaxpr hazards + Session.trace_programs coverage
# --------------------------------------------------------------------------- #


class TestJaxprHazards:
    def test_callback_detected(self):
        import jax

        def f(x):
            jax.debug.print("x={}", x)
            return x + 1

        import jax.numpy as jnp

        closed = jax.make_jaxpr(f)(jnp.zeros(4))
        from tools.dragonlint.rules_jaxpr import hazards_in

        assert "jaxpr-callback" in names(hazards_in(closed, "t/cb"))

    def test_large_folded_const_detected(self):
        import jax
        import jax.numpy as jnp
        import numpy as np

        table = jnp.asarray(np.ones(8192, np.float32))

        def f(x):
            return x + table

        closed = jax.make_jaxpr(f)(jnp.zeros(8192))
        from tools.dragonlint.rules_jaxpr import hazards_in

        assert "jaxpr-const" in names(hazards_in(closed, "t/const"))

    def test_seam_unsafe_primitive_detected(self):
        import jax
        import jax.numpy as jnp

        def f(x):
            return jnp.fft.fft(x)

        closed = jax.make_jaxpr(f)(jnp.zeros(8, jnp.complex64))
        from tools.dragonlint.rules_jaxpr import hazards_in

        assert "jaxpr-seam" in names(hazards_in(closed, "t/seam"))

    def test_clean_program_is_clean(self):
        import jax
        import jax.numpy as jnp

        def f(x, y):
            return jnp.sum(x * y)

        closed = jax.make_jaxpr(f)(jnp.zeros(16), jnp.ones(16))
        from tools.dragonlint.rules_jaxpr import hazards_in

        assert hazards_in(closed, "t/clean") == []

    def test_recurses_into_scan_bodies(self):
        import jax
        import jax.numpy as jnp

        def step(c, _):
            jax.debug.print("c={}", c)
            return c + 1, None

        def f(c):
            return jax.lax.scan(step, c, None, length=3)

        closed = jax.make_jaxpr(f)(jnp.float32(0.0))
        from tools.dragonlint.rules_jaxpr import hazards_in

        assert "jaxpr-callback" in names(hazards_in(closed, "t/scan"))


class TestTraceProgramsCoverage:
    def test_all_four_kinds_lower_and_are_hazard_free(self):
        from repro.api import Architecture, Session

        from tools.dragonlint.rules_jaxpr import KINDS, hazards_in

        sess = Session(Architecture("edge"))
        progs = sess.trace_programs("bfs_graph")
        assert tuple(sorted(progs)) == tuple(sorted(KINDS))
        for kind, closed in progs.items():
            assert hazards_in(closed, f"edge/{kind}") == []

    def test_kinds_match_session_surface(self):
        from tools.dragonlint.rules_jaxpr import KINDS

        assert set(KINDS) == {"simulate", "explain", "optimize", "frontier"}

    def test_trace_programs_does_not_pollute_session_stats(self):
        from repro.api import Architecture, Session

        sess = Session(Architecture("base"))
        sess.trace_programs("bfs_graph")
        assert sess.stats.traces == 0  # probes hit engine tags, not session tags
        assert sess.stats.programs == 0  # nothing entered the program cache


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
