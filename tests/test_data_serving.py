"""Data pipeline determinism/resumability + serving engine behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, Prefetcher, make_batch
from repro.models import build_model
from repro.serving import Engine, Request

SHAPE = ShapeConfig("tiny", 32, 4, "train")


class TestPipeline:
    def test_deterministic_per_step(self):
        cfg = get_config("granite-3-8b").reduced()
        a = make_batch(cfg, SHAPE, 7)
        b = make_batch(cfg, SHAPE, 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_different_steps_differ(self):
        cfg = get_config("granite-3-8b").reduced()
        a = make_batch(cfg, SHAPE, 7)
        b = make_batch(cfg, SHAPE, 8)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_continuation(self):
        cfg = get_config("granite-3-8b").reduced()
        b = make_batch(cfg, SHAPE, 0)
        # labels[t] == tokens[t+1] by construction
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_injected_periodicity_learnable_structure(self):
        cfg = get_config("granite-3-8b").reduced()
        dcfg = DataConfig(period=17, copy_prob=0.9)
        b = make_batch(cfg, ShapeConfig("t", 512, 2, "train"), 0, dcfg)
        t = b["tokens"][0]
        match = (t[17:] == t[:-17]).mean()
        assert match > 0.5  # strong copy structure present

    def test_vision_and_audio_shapes(self):
        v = get_config("llama-3.2-vision-11b").reduced()
        b = make_batch(v, SHAPE, 0)
        assert b["vision"].shape == (4, v.vision.n_patches, v.vision.d_vision)
        a = get_config("musicgen-large").reduced()
        b = make_batch(a, SHAPE, 0)
        assert b["tokens"].shape == (4, 32, a.audio.n_codebooks)

    def test_prefetcher_resumes_in_order(self):
        cfg = get_config("granite-3-8b").reduced()
        pf = Prefetcher(cfg, SHAPE, start_step=5, depth=2)
        steps = [next(pf)[0] for _ in range(4)]
        pf.close()
        assert steps == [5, 6, 7, 8]


class TestServing:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(), dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        return cfg, m, params

    def test_completes_all_requests(self, setup):
        cfg, m, params = setup
        eng = Engine(m, params, slots=2, max_len=64)
        for i in range(5):
            eng.submit(Request(rid=i, prompt=np.arange(6, dtype=np.int32) + i, max_tokens=4))
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.generated) == 4 for r in done)

    def test_greedy_deterministic(self, setup):
        cfg, m, params = setup
        outs = []
        for _ in range(2):
            eng = Engine(m, params, slots=2, max_len=64)
            eng.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32), max_tokens=5))
            done = eng.run()
            outs.append([int(t) for t in done[0].generated])
        assert outs[0] == outs[1]

    def test_batched_matches_unbatched_greedy(self, setup):
        """Continuous batching must not change any request's greedy output."""
        cfg, m, params = setup
        prompts = [np.arange(6, dtype=np.int32) + i for i in range(3)]
        solo = []
        for i, p in enumerate(prompts):
            eng = Engine(m, params, slots=1, max_len=64)
            eng.submit(Request(rid=i, prompt=p, max_tokens=4))
            solo.append([int(t) for t in eng.run()[0].generated])
        eng = Engine(m, params, slots=3, max_len=64)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_tokens=4))
        done = sorted(eng.run(), key=lambda r: r.rid)
        batched = [[int(t) for t in r.generated] for r in done]
        assert batched == solo

    def test_eos_stops_early(self, setup):
        cfg, m, params = setup
        eng = Engine(m, params, slots=1, max_len=64)
        # find the greedy first token, then use it as eos
        eng.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32), max_tokens=8))
        first = int(eng.run()[0].generated[1])
        eng2 = Engine(m, params, slots=1, max_len=64)
        eng2.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                            max_tokens=8, eos=first))
        done = eng2.run()[0]
        assert len(done.generated) <= 8
