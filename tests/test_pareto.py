"""core.pareto: non-dominated filtering + hypervolume invariants.

Property tests (hypothesis, degrading to skips without it via
_hypothesis_compat) pin the three contract invariants the DSE driver
relies on:

  1. the extracted front is *mutually* non-dominated;
  2. every dropped point is dominated by some *front* member (not merely
     by another dropped point — domination chains must terminate on the
     front);
  3. hypervolume is monotone under adding points (with the shared
     sample-box convention for the Monte-Carlo estimator), and invariant
     under adding dominated points for the exact 2-objective sweep.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.pareto import (
    dominates,
    hv_ref_point,
    hypervolume,
    non_dominated_mask,
    pareto_front,
)


def _points(seed: int, n: int, m: int) -> np.ndarray:
    """Deterministic random cost points with duplicates + dominated rows."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-3.0, 3.0, size=(n, m)).astype(np.float32)
    if n >= 4:
        pts[n // 2] = pts[0]  # exact duplicate
        pts[-1] = pts[1] + 0.5  # strictly dominated by row 1
    return pts


# --------------------------------------------------------------------------- #
# example-based anchors
# --------------------------------------------------------------------------- #


class TestExamples:
    def test_domination_matrix(self):
        a = jnp.array([1.0, 1.0])
        b = jnp.array([2.0, 1.0])
        assert bool(dominates(a, b)) and not bool(dominates(b, a))
        assert not bool(dominates(a, a))  # never self-dominating

    def test_front_mask_known(self):
        pts = jnp.array([[1.0, 3.0], [2.0, 1.0], [1.5, 2.5], [3.0, 3.0]])
        np.testing.assert_array_equal(
            np.asarray(non_dominated_mask(pts)), [True, True, True, False]
        )
        np.testing.assert_array_equal(pareto_front(pts), [0, 1, 2])

    def test_hypervolume_2d_staircase(self):
        # union of [1,4]x[3,4] and [2,4]x[1,4]: 3 + 6 - 2 = 7
        pts = jnp.array([[1.0, 3.0], [2.0, 1.0]])
        assert float(hypervolume(pts, jnp.array([4.0, 4.0]))) == pytest.approx(7.0)

    def test_hypervolume_2d_clip_beyond_ref(self):
        # a point beyond ref on one axis dominates only a measure-zero slice
        pts = jnp.array([[1.0, 3.0], [5.0, 0.0]])
        assert float(hypervolume(pts, jnp.array([4.0, 4.0]))) == pytest.approx(3.0)

    def test_hypervolume_3d_single_point_exact_box(self):
        ref = jnp.array([1.0, 2.0, 3.0])
        got = hypervolume(jnp.array([[0.0, 0.0, 0.0]]), ref, lo=jnp.zeros(3))
        assert float(got) == pytest.approx(6.0, rel=0.05)

    def test_infeasible_neither_fronts_nor_shadows(self):
        pts = jnp.array([[0.0, 0.0], [1.0, 1.0]])  # 0 dominates 1
        feas = jnp.array([False, True])
        np.testing.assert_array_equal(
            np.asarray(non_dominated_mask(pts, feas)), [False, True]
        )

    def test_hv_ref_point_strictly_beyond(self):
        pts = _points(0, 12, 3)
        ref = np.asarray(hv_ref_point(pts))
        assert np.all(ref > pts.max(axis=0))


# --------------------------------------------------------------------------- #
# properties
# --------------------------------------------------------------------------- #


class TestFrontProperties:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10**6), st.integers(2, 40), st.integers(2, 4))
    def test_front_is_mutually_non_dominated(self, seed, n, m):
        pts = _points(seed, n, m)
        idx = pareto_front(pts)
        assert idx.size >= 1
        sub = pts[idx]
        dom = np.asarray(dominates(jnp.asarray(sub)[:, None], jnp.asarray(sub)[None, :]))
        assert not dom.any()

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10**6), st.integers(2, 40), st.integers(2, 4))
    def test_every_dropped_point_dominated_by_a_front_member(self, seed, n, m):
        pts = _points(seed, n, m)
        mask = np.asarray(non_dominated_mask(jnp.asarray(pts)))
        front = pts[mask]
        for p in pts[~mask]:
            dom = np.asarray(dominates(jnp.asarray(front), jnp.asarray(p)[None]))
            assert dom.any(), f"dropped point {p} not dominated by any front member"

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10**6), st.integers(2, 16))
    def test_duplicates_survive_together(self, seed, n):
        pts = _points(seed, max(n, 4), 3)
        mask = np.asarray(non_dominated_mask(jnp.asarray(pts)))
        # row n//2 is an exact duplicate of row 0: identical fate
        assert mask[0] == mask[len(pts) // 2]


class TestHypervolumeProperties:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10**6), st.integers(2, 20))
    def test_exact_2d_monotone_under_adding_point(self, seed, n):
        pts = _points(seed, n, 2)
        rng = np.random.default_rng(seed + 1)
        extra = rng.uniform(-3.0, 3.0, size=(1, 2)).astype(np.float32)
        ref = jnp.asarray(np.maximum(pts.max(0), extra.max(0)) + 0.5)
        hv0 = float(hypervolume(jnp.asarray(pts), ref))
        hv1 = float(hypervolume(jnp.asarray(np.concatenate([pts, extra])), ref))
        assert hv1 >= hv0 - 1e-5

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10**6), st.integers(2, 20))
    def test_exact_2d_invariant_under_adding_dominated_point(self, seed, n):
        pts = _points(seed, n, 2)
        ref = jnp.asarray(pts.max(0) + 0.5)
        dominated = (pts[0] + 0.25)[None]  # strictly worse than row 0
        hv0 = float(hypervolume(jnp.asarray(pts), ref))
        hv1 = float(hypervolume(jnp.asarray(np.concatenate([pts, dominated])), ref))
        assert hv1 == pytest.approx(hv0, rel=1e-5, abs=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10**6), st.integers(2, 16), st.integers(3, 4))
    def test_mc_monotone_with_shared_box(self, seed, n, m):
        """With a common (lo, ref, key) sample box, the quasi-MC estimate is
        exactly monotone: the dominated-sample set can only grow."""
        pts = _points(seed, n, m)
        rng = np.random.default_rng(seed + 2)
        extra = rng.uniform(-3.0, 3.0, size=(1, m)).astype(np.float32)
        allp = np.concatenate([pts, extra])
        lo = jnp.asarray(allp.min(0) - 0.1)
        ref = jnp.asarray(allp.max(0) + 0.5)
        key = jax.random.PRNGKey(seed % 2**30)
        hv0 = float(hypervolume(jnp.asarray(pts), ref, lo=lo, key=key, n_samples=2048))
        hv1 = float(hypervolume(jnp.asarray(allp), ref, lo=lo, key=key, n_samples=2048))
        assert hv1 >= hv0

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10**6), st.integers(2, 16))
    def test_mc_bounded_by_box_volume(self, seed, n):
        pts = _points(seed, n, 3)
        lo = jnp.asarray(pts.min(0) - 0.1)
        ref = jnp.asarray(pts.max(0) + 0.5)
        hv = float(hypervolume(jnp.asarray(pts), ref, lo=lo, n_samples=1024))
        box = float(np.prod(np.asarray(ref) - np.asarray(lo)))
        assert 0.0 <= hv <= box + 1e-5

    def test_mc_agrees_with_exact_on_separable_3d(self):
        # one point: dominated volume is a box — MC must land close
        ref = jnp.array([2.0, 2.0, 2.0])
        pt = jnp.array([[0.5, 1.0, 0.0]])
        exact = 1.5 * 1.0 * 2.0
        got = float(hypervolume(pt, ref, lo=jnp.zeros(3) - 0.0, n_samples=32768))
        assert got == pytest.approx(exact, rel=0.05)
