"""DSim-vs-reference-simulator accuracy as *enforced* tier-1 coverage.

The paper's §8.1 claim (80-97% accuracy vs stepped cycle-level tools) was
previously only *measured* in benchmarks/bench_sim_speed.py; this promotes
it to an asserted invariant: for every workload family (classic CNN/LSTM /
LM / GNN / non-AI) x a set of library `.dhd` architectures, the DSim
closed-form cycle count must stay within a per-workload relative-error
tolerance of the reference per-tile cycle walker.

Tolerances are ~2.5x the worst error observed across the full 7-arch
library matrix at the time of writing (max 3.3%), so they catch real
drift in either simulator without being flaky.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.dhdl import load_arch
from repro.core.refsim import reference_simulate
from repro.workloads import get_workload, lm_cell

# workload name -> (family, builder, relative-error tolerance)
MATRIX = {
    "resnet50": ("classic", lambda: get_workload("resnet50"), 0.05),
    "lstm": ("classic", lambda: get_workload("lstm"), 0.08),
    "bert_base": ("classic", lambda: get_workload("bert_base"), 0.03),
    "dlrm": ("classic", lambda: get_workload("dlrm"), 0.06),
    "gcn": ("gnn", lambda: get_workload("gcn"), 0.08),
    "graphsage": ("gnn", lambda: get_workload("graphsage"), 0.09),
    "stencil2d": ("nonai", lambda: get_workload("stencil2d"), 0.08),
    "merge_sort": ("nonai", lambda: get_workload("merge_sort"), 0.08),
    "bfs_graph": ("nonai", lambda: get_workload("bfs_graph"), 0.06),
    "granite-3-8b:train_4k": ("lm", lambda: lm_cell("granite-3-8b", "train_4k"), 0.02),
    "qwen2.5-32b:prefill_32k": ("lm", lambda: lm_cell("qwen2.5-32b", "prefill_32k"), 0.02),
}

ARCHS = ["base", "datacenter", "edge"]

_g_cache: dict = {}
_chw_cache: dict = {}


def _graph(name):
    if name not in _g_cache:
        _g_cache[name] = MATRIX[name][1]()
    return _g_cache[name]


def _arch(name):
    if name not in _chw_cache:
        ca = load_arch(name)
        _chw_cache[name] = (ca, ca.specialize())
    return _chw_cache[name]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("workload", sorted(MATRIX))
def test_dsim_tracks_reference_walker(workload, arch):
    family, _, tol = MATRIX[workload]
    ca, chw = _arch(arch)
    g = _graph(workload)
    cyc_dsim = float(ca.simulate(g).cycles)
    cyc_ref = reference_simulate(chw, g)["cycles"]
    rel = abs(cyc_dsim - cyc_ref) / max(cyc_ref, 1.0)
    assert rel <= tol, (
        f"[{family}] {workload} on {arch}: DSim {cyc_dsim:.4g} vs ref {cyc_ref:.4g} "
        f"(rel err {rel:.4f} > tol {tol})"
    )


def test_matrix_covers_all_families_and_two_archs():
    """The satellite's coverage floor, asserted so it can't silently shrink."""
    families = {fam for fam, _, _ in MATRIX.values()}
    assert {"classic", "lm", "gnn", "nonai"} <= families
    assert len(ARCHS) >= 2
