"""DOpt (gradient-descent hardware optimization) behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArchSpec, TechParams, optimize, simulate, ArchParams
from repro.core.dopt import derive_tech_targets, tech_param_names
from repro.workloads import get_workload


@pytest.fixture(scope="module")
def lstm():
    return get_workload("lstm")


class TestDOpt:
    def test_edp_improves(self, lstm):
        res = optimize(lstm, objective="edp", steps=20, lr=0.1)
        assert res.history["edp"][-1] < res.history["edp"][0] / 2

    def test_importance_ranking_complete_and_sorted(self, lstm):
        res = optimize(lstm, objective="edp", steps=5, lr=0.05)
        names = [n for n, _ in res.importance]
        assert set(names) == set(tech_param_names())
        vals = [v for _, v in res.importance]
        assert vals == sorted(vals, reverse=True)

    def test_bounds_respected(self, lstm):
        res = optimize(lstm, objective="edp", steps=15, lr=0.5)
        lo, hi = TechParams.bounds()
        for leaf, l, h in zip(
            jnp.concatenate([jnp.atleast_1d(x) for x in res.tech.__dict__.values()]),
            jnp.concatenate([jnp.atleast_1d(x) for x in lo.__dict__.values()]),
            jnp.concatenate([jnp.atleast_1d(x) for x in hi.__dict__.values()]),
        ):
            assert l - 1e-6 <= leaf <= h + 1e-6

    def test_area_constraint_binds(self, lstm):
        free = optimize(lstm, objective="time", opt_over="arch", steps=20, lr=0.2)
        constrained = optimize(lstm, objective="time", opt_over="arch", steps=20,
                               lr=0.2, area_constraint=50.0)
        assert constrained.history["area"][-1] < free.history["area"][-1]

    def test_opt_over_tech_only_keeps_arch(self, lstm):
        res = optimize(lstm, opt_over="tech", steps=3, lr=0.1)
        default = ArchParams.default()
        np.testing.assert_allclose(
            float(res.arch.sys_arr_x), float(default.sys_arr_x), rtol=1e-5
        )

    def test_dopt2_type_weights_valid(self, lstm):
        res = optimize(lstm, opt_over="both+types", steps=4, lr=0.1)
        tw = np.asarray(res.type_weights)
        assert tw.shape == (3, 3)
        np.testing.assert_allclose(tw.sum(-1), 1.0, rtol=1e-5)


class TestTechTargets:
    def test_targets_reach_factor(self, lstm):
        out = derive_tech_targets(lstm, goal_factor=5.0, steps=60, lr=0.15)
        assert out["achieved_factor"] >= 5.0
        assert out["epochs"] <= 60
        # targets say which parameter must improve by how much
        assert all(v["factor"] > 0 for v in out["targets"].values())

    def test_single_pass_beats_grid_asymptotics(self, lstm):
        # the paper's claim is structural: one gradient pass touches each
        # parameter once per epoch; a sweep is exponential. We check the
        # pass runs in a bounded number of epochs.
        out = derive_tech_targets(lstm, goal_factor=3.0, steps=40, lr=0.15)
        assert out["epochs"] < 40
