"""Fault tolerance: crash/restart recovery, straggler detection, exact
resume semantics (the restored run must replay the identical data stream)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.ft import FailureInjector, SimulatedFailure, StragglerMonitor
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer, TrainerConfig


SHAPE = ShapeConfig("tiny", 64, 4, "train")


def make_trainer(tmp_path, steps=12, injector=None, seed_cfg="granite-3-8b"):
    cfg = get_config(seed_cfg).reduced()
    m = build_model(cfg)
    return Trainer(
        m, SHAPE, AdamWConfig(lr=1e-3, schedule=None), TrainConfig(),
        TrainerConfig(steps=steps, ckpt_every=4, ckpt_dir=str(tmp_path), log_every=0),
        injector=injector, log_fn=lambda s: None,
    )


class TestCrashRecovery:
    def test_restart_resumes_and_finishes(self, tmp_path):
        tr = make_trainer(tmp_path, injector=FailureInjector(fail_at=(6,)))
        out = tr.run()
        assert int(out["state"]["step"]) == 12
        assert out["losses"][-1] < out["losses"][0]

    def test_too_many_failures_raise(self, tmp_path):
        inj = FailureInjector(fail_at=(5,))
        inj.fired = set()

        class AlwaysFail(FailureInjector):
            def maybe_fail(self, step):
                if step == 5:
                    raise SimulatedFailure("persistent failure")

        tr = make_trainer(tmp_path, injector=AlwaysFail())
        with pytest.raises(SimulatedFailure):
            tr.run()

    def test_resume_replays_identical_stream(self, tmp_path):
        """Run A: uninterrupted. Run B: crash at step 6, restore from step 4.
        Both must end with identical parameters (deterministic data + ckpt)."""
        tr_a = make_trainer(tmp_path / "a", steps=10)
        out_a = tr_a.run()
        tr_b = make_trainer(tmp_path / "b", steps=10,
                            injector=FailureInjector(fail_at=(6,)))
        out_b = tr_b.run()
        for x, y in zip(
            jax.tree.leaves(out_a["state"]["params"]),
            jax.tree.leaves(out_b["state"]["params"]),
        ):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32), atol=1e-6)


class TestStragglerMonitor:
    def test_flags_outlier(self):
        mon = StragglerMonitor(warmup_steps=3)
        for i in range(10):
            assert not mon.record(i, 0.10 + 0.001 * (i % 2))
        assert mon.record(10, 0.50)  # 5x slower
        assert mon.flagged and mon.flagged[0][0] == 10

    def test_adapts_to_new_regime(self):
        mon = StragglerMonitor(warmup_steps=3)
        for i in range(8):
            mon.record(i, 0.1)
        mon.record(8, 0.5)  # flagged
        for i in range(9, 40):
            mon.record(i, 0.5)  # new normal
        assert not mon.record(40, 0.52)

    def test_injected_slow_steps_detected_in_training(self, tmp_path):
        tr = make_trainer(tmp_path, steps=14,
                          injector=FailureInjector(slow_at=(10,), slow_secs=3.0))
        out = tr.run()
        assert any(s == 10 for s, _ in out["stragglers"]), out["stragglers"]
