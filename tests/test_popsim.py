"""Population DSE: shared batched-workload path, mesh-robust shardings, and
the population-scale multi-objective engine (vmapped chunks, spmd sharding,
budget constraints, .dhd round-trips)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import ArchParams, TechParams, optimize, simulate
from repro.core.dhdl import load_arch, parse_arch, serialize_arch
from repro.core.dopt import from_log, to_log
from repro.core.dsim import (
    PARETO_METRICS,
    mixed_log_objective,
    stacked_log_objective,
)
from repro.core.graph import Graph
from repro.core.params import ArchSpec
from repro.core.popsim import (
    dse_in_shardings,
    init_population_state,
    pareto_dse,
    population_chunk,
    population_log_metrics,
    population_objective,
    sample_objective_mixes,
    seed_population,
)
from repro.workloads import get_workload


def _stack(names):
    return Graph.stack([get_workload(n) for n in names])


def _mesh(axis_names):
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(axis_names))
    return Mesh(devs, axis_names)


class TestPopulationObjective:
    def test_matches_single_candidate_path(self):
        """The population path is literally DOpt's batched loss, vmapped."""
        gs = _stack(["lstm", "merge_sort"])
        tech, arch = TechParams.default(), ArchParams.default()
        pop = jax.tree.map(lambda x: x[None], (tech, arch))
        got = population_objective(pop, gs)
        want, _ = stacked_log_objective(tech, arch, gs)
        assert got.shape == (1,)
        np.testing.assert_allclose(float(got[0]), float(want), rtol=1e-5)

    def test_population_axis_shape(self):
        gs = _stack(["lstm"])
        tech, arch = TechParams.default(), ArchParams.default()
        pop = jax.tree.map(lambda x: jnp.stack([x, x * 1.1]), (tech, arch))
        out = population_objective(pop, gs)
        assert out.shape == (2,)
        assert np.all(np.isfinite(np.asarray(out)))


class TestPopsimKernelPadding:
    def test_pad_vertices_free_in_popsim_kernel(self):
        """The Pallas population kernel and its oracle price Graph.pad_to's
        no-op vertices at zero, matching the mapper (Graph.stack convention)."""
        from repro.kernels import pack_chw, pack_graph, popsim, ref
        from repro.core import specialize

        g = get_workload("lstm")
        chw = jax.tree.map(lambda x: x[None], specialize(TechParams.default(), ArchParams.default()))
        cp = pack_chw(chw)
        out0 = np.asarray(popsim(pack_graph(g), cp))
        out1 = np.asarray(popsim(pack_graph(g.pad_to(g.n_vertices + 17)), cp))
        np.testing.assert_allclose(out1, out0, rtol=1e-6)
        ref1 = np.asarray(ref.popsim_reference(pack_graph(g.pad_to(g.n_vertices + 17)), cp))
        np.testing.assert_allclose(ref1, out0, rtol=1e-5)


def _jittered_starts(n, key, sigma=0.2):
    """n log-normal-jittered copies of the default design point."""
    leaves, td = jax.tree.flatten((TechParams.default(), ArchParams.default()))
    keys = jax.random.split(key, len(leaves))
    stacked = [
        jnp.exp(jnp.log(l)[None] + sigma * jax.random.normal(k, (n,) + l.shape))
        for l, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(td, stacked)


def _onehot(metric, n):
    i = PARETO_METRICS.index(metric)
    return jnp.zeros((n, len(PARETO_METRICS))).at[:, i].set(1.0)


class TestMixedObjective:
    def test_onehot_mix_equals_string_objective(self):
        """A one-hot weight reproduces the single-objective loss exactly —
        the off-metric terms are exact float zeros."""
        gs = _stack(["lstm", "merge_sort"])
        tech, arch = TechParams.default(), ArchParams.default()
        for metric in PARETO_METRICS:
            w = _onehot(metric, 1)[0]
            got, _ = mixed_log_objective(tech, arch, gs, w)
            want, _ = stacked_log_objective(tech, arch, gs, metric)
            assert float(got) == float(want), metric

    def test_onehot_mix_grads_equal_string_objective_grads(self):
        gs = _stack(["lstm"])
        tz, az = to_log(TechParams.default()), to_log(ArchParams.default())

        def mixed(tz, az):
            return mixed_log_objective(from_log(tz), from_log(az), gs, _onehot("edp", 1)[0])[0]

        def plain(tz, az):
            return stacked_log_objective(from_log(tz), from_log(az), gs, "edp")[0]

        gm = jax.grad(mixed, argnums=(0, 1))(tz, az)
        gp = jax.grad(plain, argnums=(0, 1))(tz, az)
        for a, b in zip(jax.tree.leaves(gm), jax.tree.leaves(gp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_inf_budgets_are_exact_noops(self):
        gs = _stack(["lstm"])
        tech, arch = TechParams.default(), ArchParams.default()
        w = jnp.asarray([0.25, 0.25, 0.25, 0.25])
        free, _ = mixed_log_objective(tech, arch, gs, w)
        gated, _ = mixed_log_objective(
            tech, arch, gs, w, jnp.float32(jnp.inf), jnp.float32(jnp.inf), 3.0
        )
        assert float(free) == float(gated)

    def test_optimize_rejects_mismatched_constraint_args(self):
        """Constraint/mix arguments that the chosen objective would silently
        ignore are rejected loudly instead."""
        g = get_workload("lstm")
        with pytest.raises(ValueError, match="only apply"):
            optimize(g, objective="edp", area_budget=500.0, steps=1)
        with pytest.raises(ValueError, match="objective_weights"):
            optimize(g, objective="mixed", steps=1)
        with pytest.raises(ValueError, match="area_constraint"):
            optimize(g, objective="mixed", objective_weights=[0, 0, 0, 1.0],
                     area_constraint=500.0, steps=1)

    def test_binding_budget_raises_objective(self):
        gs = _stack(["lstm"])
        tech, arch = TechParams.default(), ArchParams.default()
        perf = simulate(tech, arch, get_workload("lstm"))
        w = jnp.asarray([0.0, 0.0, 0.0, 1.0])
        free, _ = mixed_log_objective(tech, arch, gs, w)
        tight, _ = mixed_log_objective(
            tech, arch, gs, w, jnp.float32(float(perf.area) * 0.5), None, 1.0
        )
        assert float(tight) > float(free)


class TestPopulationEquivalence:
    """The vmapped P-member chunk IS P sequential optimize(fused=True) runs."""

    def test_chunk_matches_sequential_optimize_trajectories(self):
        gl = [get_workload("lstm"), get_workload("merge_sort")]
        gstack = Graph.stack(list(gl))
        n_pop, steps = 2, 4
        techP, archP = _jittered_starts(n_pop, jax.random.PRNGKey(7))
        mixes = (_onehot("edp", n_pop), jnp.full((n_pop,), jnp.inf), jnp.full((n_pop,), jnp.inf))
        state = init_population_state(techP, archP)
        state, m = population_chunk(state, mixes, gstack, 0.05, jnp.ones(steps))
        popt, popa = from_log(state[0]), from_log(state[1])

        for i in range(n_pop):
            t_i = jax.tree.map(lambda x: x[i], techP)
            a_i = jax.tree.map(lambda x: x[i], archP)
            res = optimize(gl, tech=t_i, arch=a_i, objective="edp", steps=steps, lr=0.05, fused=True)
            np.testing.assert_allclose(
                np.asarray(res.history["objective"]), np.asarray(m[:, i, 0]), rtol=1e-5
            )
            for got, want in zip(
                jax.tree.leaves((jax.tree.map(lambda x: x[i], popt), jax.tree.map(lambda x: x[i], popa))),
                jax.tree.leaves((res.tech, res.arch)),
            ):
                np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_chunk_matches_sequential_mixed_optimize(self):
        """objective="mixed" optimize() is the sequential form of one member —
        including a non-trivial weight mix and a binding budget."""
        gl = [get_workload("lstm")]
        gstack = Graph.stack(list(gl))
        steps = 3
        w = jnp.asarray([[0.5, 0.3, 0.2, 0.0]])
        ab = jnp.asarray([300.0])
        state = init_population_state(*jax.tree.map(lambda x: x[None], (TechParams.default(), ArchParams.default())))
        state, m = population_chunk(
            state, (w, ab, jnp.full((1,), jnp.inf)), gstack, 0.08, jnp.full(steps, 2.0)
        )
        res = optimize(
            gl, objective="mixed", objective_weights=w[0], area_budget=300.0,
            penalty_weight=2.0, steps=steps, lr=0.08, fused=True,
        )
        np.testing.assert_allclose(
            np.asarray(res.history["objective"]), np.asarray(m[:, 0, 0]), rtol=1e-5
        )

    def test_population_grads_match_per_member_grads(self):
        """vmapped value_and_grad == per-member value_and_grad, member by member."""
        gstack = _stack(["lstm"])
        n_pop = 3
        techP, archP = _jittered_starts(n_pop, jax.random.PRNGKey(3))
        w = sample_objective_mixes(n_pop)
        tzP, azP = to_log(techP), to_log(archP)

        def loss(tz, az, wi):
            return mixed_log_objective(from_log(tz), from_log(az), gstack, wi)[0]

        vals, grads = jax.vmap(jax.value_and_grad(loss, argnums=(0, 1)), in_axes=(0, 0, 0))(tzP, azP, w)
        for i in range(n_pop):
            vi, gi = jax.value_and_grad(loss, argnums=(0, 1))(
                jax.tree.map(lambda x: x[i], tzP), jax.tree.map(lambda x: x[i], azP), w[i]
            )
            np.testing.assert_allclose(float(vals[i]), float(vi), rtol=1e-6)
            for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(gi)):
                np.testing.assert_allclose(np.asarray(a[i]), np.asarray(b), rtol=2e-5, atol=1e-7)


class TestShardedPopulation:
    def test_sharded_matches_single_device(self):
        """spmd_map-sharded chunk == single-device chunk (float32 tolerance).
        Skips cleanly when only one device is present."""
        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices for a sharded mesh")
        n_dev = 2
        gstack = _stack(["lstm"])
        n_pop, steps = 2 * n_dev, 2
        (tech, arch), spec, _ = seed_population(n_pop, ("base", "edge"), jax.random.PRNGKey(0))
        mixes = (sample_objective_mixes(n_pop), jnp.full((n_pop,), 300.0), jnp.full((n_pop,), jnp.inf))
        sched = jnp.linspace(0.5, 2.0, steps)
        s1, m1 = population_chunk(init_population_state(tech, arch), mixes, gstack, 0.1, sched, spec=spec)
        mesh = Mesh(np.array(jax.devices()[:n_dev]).reshape(n_dev), ("pop",))
        s2, m2 = population_chunk(
            init_population_state(tech, arch), mixes, gstack, 0.1, sched, spec=spec, mesh=mesh
        )
        np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5, atol=1e-6)
        for l1, l2 in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
            np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-6)

    @pytest.mark.slow
    def test_sharded_matches_single_device_subprocess(self):
        """The same check on a forced 4-device CPU platform, in a subprocess
        (the in-process platform is pinned to 1 device by conftest)."""
        script = textwrap.dedent(
            """
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import Mesh
            from repro.core.graph import Graph
            from repro.core.popsim import (
                init_population_state, population_chunk, sample_objective_mixes, seed_population,
            )
            from repro.workloads import get_workload

            assert len(jax.devices()) == 4, jax.devices()
            gstack = Graph.stack([get_workload("lstm")])
            n_pop, steps = 8, 2
            (tech, arch), spec, _ = seed_population(n_pop, ("base", "edge"), jax.random.PRNGKey(0))
            mixes = (sample_objective_mixes(n_pop), jnp.full((n_pop,), 300.0), jnp.full((n_pop,), jnp.inf))
            sched = jnp.linspace(0.5, 2.0, steps)
            s1, m1 = population_chunk(init_population_state(tech, arch), mixes, gstack, 0.1, sched, spec=spec)
            mesh = Mesh(np.array(jax.devices()).reshape(4), ("pop",))
            s2, m2 = population_chunk(
                init_population_state(tech, arch), mixes, gstack, 0.1, sched, spec=spec, mesh=mesh
            )
            np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5, atol=1e-6)
            for l1, l2 in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
                np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-6)
            print("SHARDED_EQUIV_OK")
            """
        )
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4").strip()
        env["JAX_PLATFORMS"] = "cpu"
        src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = os.path.abspath(src_dir) + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-c", script], env=env, capture_output=True, text=True, timeout=600
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "SHARDED_EQUIV_OK" in out.stdout


class TestSeedingAndMixes:
    def test_pristine_seeds_bit_exact(self):
        (tech, arch), spec, names = seed_population(5, ("base", "edge"), jax.random.PRNGKey(0))
        assert names == ("base", "edge", "base", "edge", "base")
        for nm, i in (("base", 0), ("edge", 1)):
            ca = load_arch(nm)
            for got, want in zip(
                jax.tree.leaves(jax.tree.map(lambda x: x[i], (tech, arch))),
                jax.tree.leaves((ca.tech, ca.arch)),
            ):
                assert np.array_equal(np.asarray(got), np.asarray(want)), nm

    def test_jittered_members_within_bounds(self):
        (tech, arch), _, _ = seed_population(16, ("base",), jax.random.PRNGKey(1), sigma=3.0)
        for tree, bounds in ((tech, TechParams.bounds()), (arch, ArchParams.bounds())):
            for leaf, lo, hi in zip(
                jax.tree.leaves(tree), jax.tree.leaves(bounds[0]), jax.tree.leaves(bounds[1])
            ):
                assert np.all(np.asarray(leaf) >= np.asarray(lo) * (1 - 1e-6))
                assert np.all(np.asarray(leaf) <= np.asarray(hi) * (1 + 1e-6))

    def test_spec_mismatch_raises(self):
        with pytest.raises(ValueError, match="ArchSpec"):
            seed_population(4, ("base", "rram_cim"), jax.random.PRNGKey(0))

    def test_mixes_are_simplex_weights_with_corners(self):
        w = np.asarray(sample_objective_mixes(10, ("time", "energy", "area")))
        assert w.shape == (10, 4)
        np.testing.assert_allclose(w.sum(axis=1), 1.0, rtol=1e-5)
        assert np.all(w[:, PARETO_METRICS.index("edp")] == 0.0)  # unused metric untouched
        np.testing.assert_allclose(w[0], [1, 0, 0, 0], atol=1e-6)  # pure latency corner
        np.testing.assert_allclose(w[1], [0, 1, 0, 0], atol=1e-6)


class TestConstraintCorrectness:
    def test_optimized_design_meets_budgets(self):
        """Binding area+power budgets are met within tolerance after descent."""
        g = get_workload("lstm")
        perf0 = simulate(TechParams.default(), ArchParams.default(), g)
        area_b = float(perf0.area) * 0.7
        power_b = float(perf0.power) * 0.8
        res = optimize(
            g, objective="mixed", objective_weights=[0.0, 0.0, 0.0, 1.0],
            area_budget=area_b, power_budget=power_b, penalty_weight=4.0,
            opt_over="both", steps=40, lr=0.1,
        )
        perf = simulate(res.tech, res.arch, g)
        assert float(perf.area) <= area_b * 1.05, (float(perf.area), area_b)
        assert float(perf.power) <= power_b * 1.05, (float(perf.power), power_b)

    def test_penalty_gradient_finite_difference(self):
        """AD == central finite differences through the *binding* budget
        penalty, on smooth coordinates (the test_dhdl FD pattern)."""
        ca = load_arch("edge")
        gs = _stack(["lstm", "merge_sort"])
        perf = simulate(ca.tech, ca.arch, get_workload("lstm"), ca.spec)
        area_b = jnp.float32(float(perf.area) * 0.6)  # binding
        power_b = jnp.float32(float(perf.power) * 0.7)  # binding
        w = jnp.asarray([0.3, 0.3, 0.2, 0.2])
        coords = [
            ("tech", "cell_read_power", 2),
            ("tech", "cell_area", 1),
            ("arch", "bw_scale", 2),
            ("arch", "frequency", None),
        ]
        for tree, fname, idx in coords:
            def f(s):
                t, a = ca.tech, ca.arch
                obj = t if tree == "tech" else a
                v = getattr(obj, fname)
                v2 = v * s if idx is None else v.at[idx].mul(s)
                obj2 = dataclasses.replace(obj, **{fname: v2})
                return mixed_log_objective(
                    obj2 if tree == "tech" else t,
                    a if tree == "tech" else obj2,
                    gs, w, area_b, power_b, 2.0, ca.spec,
                )[0]

            val, grad = jax.value_and_grad(f)(jnp.float32(1.0))
            assert np.isfinite(float(val))
            eps = 0.05
            fd = (float(f(jnp.float32(1 + eps))) - float(f(jnp.float32(1 - eps)))) / (2 * eps)
            assert float(grad) == pytest.approx(fd, rel=5e-2, abs=1e-5), (
                f"{tree}.{fname}[{idx}]: AD {float(grad)} vs FD {fd}"
            )


class TestParetoDse:
    @pytest.fixture(scope="class")
    def result(self):
        return pareto_dse(
            [get_workload("lstm")], seeds=("base", "edge"), population=8, steps=6,
            lr=0.1, area_budget=400.0, power_budget=80.0, key=0,
        )

    def test_front_is_feasible_and_non_dominated(self, result):
        assert result.front.size >= 1
        assert result.feasible[result.front].all()
        from repro.core.pareto import dominates

        sub = jnp.asarray(result.front_log_metrics)
        dom = np.asarray(dominates(sub[:, None], sub[None, :]))
        assert not dom.any()
        assert result.hypervolume > 0.0

    def test_history_covers_every_epoch(self, result):
        assert result.history.shape == (6, 8, 5)
        assert np.isfinite(result.history).all()

    def test_winners_round_trip_bit_exact(self, result):
        """Every Pareto winner serializes to .dhd text that parses back to
        the identical pytrees — serialize -> parse -> serialize is the
        identity, bit for bit."""
        assert result.winners
        for w in result.winners:
            i = w["index"]
            ca = parse_arch(w["dhd"])
            want_t = jax.tree.map(lambda x: x[i], result.tech)
            want_a = jax.tree.map(lambda x: x[i], result.arch)
            assert ca.spec == result.spec
            for got, want in zip(
                jax.tree.leaves((ca.tech, ca.arch)), jax.tree.leaves((want_t, want_a))
            ):
                assert np.array_equal(np.asarray(got), np.asarray(want))
            again = serialize_arch(ca)
            assert again == w["dhd"]

    def test_unsupported_opt_over_raises(self):
        """An opt_over the member step would silently no-op on is rejected."""
        gstack = _stack(["lstm"])
        state = init_population_state(
            *jax.tree.map(lambda x: x[None], (TechParams.default(), ArchParams.default()))
        )
        mixes = (_onehot("edp", 1), jnp.full((1,), jnp.inf), jnp.full((1,), jnp.inf))
        with pytest.raises(ValueError, match="opt_over"):
            population_chunk(state, mixes, gstack, 0.1, jnp.ones(1), opt_over="both+types")

    def test_chunked_run_matches_single_dispatch(self):
        kw = dict(
            seeds=("base",), population=4, steps=4, lr=0.1, area_budget=400.0, key=3,
        )
        a = pareto_dse([get_workload("lstm")], chunk=None, **kw)
        b = pareto_dse([get_workload("lstm")], chunk=2, **kw)
        np.testing.assert_allclose(a.history, b.history, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(a.log_metrics, b.log_metrics, rtol=1e-5)


class TestDseInShardings:
    def test_no_model_axis_does_not_raise(self):
        """Regression: mesh.shape["model"] used to KeyError on meshes
        without a model axis; now workloads are replicated instead."""
        mesh = _mesh(("pod", "data"))
        gs = _stack(["lstm", "merge_sort"])
        pop = jax.tree.map(lambda x: x[None], (TechParams.default(), ArchParams.default()))
        pop_s, g_s = dse_in_shardings(mesh, pop, gs)
        for s in jax.tree.leaves(g_s):
            assert s.spec == P()
        for s in jax.tree.leaves(pop_s):
            assert s.spec == P(("pod", "data"))

    def test_model_axis_shards_dividing_leading_dims(self):
        mesh = _mesh(("data", "model"))
        gs = _stack(["lstm", "merge_sort"])  # leading dim 2 % 1 == 0
        pop = jax.tree.map(lambda x: x[None], (TechParams.default(), ArchParams.default()))
        _, g_s = dse_in_shardings(mesh, pop, gs)
        specs = {s.spec for s in jax.tree.leaves(g_s)}
        assert P("model") in specs
