"""Population DSE: shared batched-workload path + mesh-robust shardings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import ArchParams, TechParams
from repro.core.dsim import stacked_log_objective
from repro.core.graph import Graph
from repro.core.popsim import dse_in_shardings, population_objective
from repro.workloads import get_workload


def _stack(names):
    return Graph.stack([get_workload(n) for n in names])


def _mesh(axis_names):
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(axis_names))
    return Mesh(devs, axis_names)


class TestPopulationObjective:
    def test_matches_single_candidate_path(self):
        """The population path is literally DOpt's batched loss, vmapped."""
        gs = _stack(["lstm", "merge_sort"])
        tech, arch = TechParams.default(), ArchParams.default()
        pop = jax.tree.map(lambda x: x[None], (tech, arch))
        got = population_objective(pop, gs)
        want, _ = stacked_log_objective(tech, arch, gs)
        assert got.shape == (1,)
        np.testing.assert_allclose(float(got[0]), float(want), rtol=1e-5)

    def test_population_axis_shape(self):
        gs = _stack(["lstm"])
        tech, arch = TechParams.default(), ArchParams.default()
        pop = jax.tree.map(lambda x: jnp.stack([x, x * 1.1]), (tech, arch))
        out = population_objective(pop, gs)
        assert out.shape == (2,)
        assert np.all(np.isfinite(np.asarray(out)))


class TestPopsimKernelPadding:
    def test_pad_vertices_free_in_popsim_kernel(self):
        """The Pallas population kernel and its oracle price Graph.pad_to's
        no-op vertices at zero, matching the mapper (Graph.stack convention)."""
        from repro.kernels import pack_chw, pack_graph, popsim, ref
        from repro.core import specialize

        g = get_workload("lstm")
        chw = jax.tree.map(lambda x: x[None], specialize(TechParams.default(), ArchParams.default()))
        cp = pack_chw(chw)
        out0 = np.asarray(popsim(pack_graph(g), cp))
        out1 = np.asarray(popsim(pack_graph(g.pad_to(g.n_vertices + 17)), cp))
        np.testing.assert_allclose(out1, out0, rtol=1e-6)
        ref1 = np.asarray(ref.popsim_reference(pack_graph(g.pad_to(g.n_vertices + 17)), cp))
        np.testing.assert_allclose(ref1, out0, rtol=1e-5)


class TestDseInShardings:
    def test_no_model_axis_does_not_raise(self):
        """Regression: mesh.shape["model"] used to KeyError on meshes
        without a model axis; now workloads are replicated instead."""
        mesh = _mesh(("pod", "data"))
        gs = _stack(["lstm", "merge_sort"])
        pop = jax.tree.map(lambda x: x[None], (TechParams.default(), ArchParams.default()))
        pop_s, g_s = dse_in_shardings(mesh, pop, gs)
        for s in jax.tree.leaves(g_s):
            assert s.spec == P()
        for s in jax.tree.leaves(pop_s):
            assert s.spec == P(("pod", "data"))

    def test_model_axis_shards_dividing_leading_dims(self):
        mesh = _mesh(("data", "model"))
        gs = _stack(["lstm", "merge_sort"])  # leading dim 2 % 1 == 0
        pop = jax.tree.map(lambda x: x[None], (TechParams.default(), ArchParams.default()))
        _, g_s = dse_in_shardings(mesh, pop, gs)
        specs = {s.spec for s in jax.tree.leaves(g_s)}
        assert P("model") in specs
