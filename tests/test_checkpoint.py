"""Checkpointing: atomic roundtrip, pruning, crash consistency, Q8 leaves."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.optim import AdamWConfig, init_opt_state


def tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture
def state(rng):
    params = {"w": jax.random.normal(rng, (16, 16)), "b": jnp.zeros((16,))}
    opt = init_opt_state(params, AdamWConfig(int8_states=True))
    return {"params": params, "opt": opt, "step": jnp.int32(7)}


class TestRoundtrip:
    def test_save_restore_exact(self, tmp_path, state):
        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(7, state, extra={"data_step": 7})
        like = jax.eval_shape(lambda: state)
        restored, extra = ck.restore(None, like)
        tree_eq(state, restored)
        assert extra["data_step"] == 7

    def test_async_save(self, tmp_path, state):
        ck = Checkpointer(str(tmp_path), async_save=True)
        ck.save(1, state)
        ck.wait()
        assert ck.latest_step() == 1

    def test_q8_leaves_roundtrip(self, tmp_path, state):
        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(1, state)
        restored, _ = ck.restore(1, jax.eval_shape(lambda: state))
        m = state["opt"]["m"]["w"]
        mr = restored["opt"]["m"]["w"]
        np.testing.assert_array_equal(np.asarray(m.codes), np.asarray(mr.codes))
        np.testing.assert_array_equal(np.asarray(m.scale), np.asarray(mr.scale))


class TestDurability:
    def test_keep_k_pruning(self, tmp_path, state):
        ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            ck.save(s, state)
        dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert len(dirs) == 2
        assert ck.latest_step() == 4

    def test_torn_tmp_dir_ignored(self, tmp_path, state):
        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(1, state)
        # simulate a crash mid-save at step 2
        os.makedirs(tmp_path / "step_0000000002.tmp")
        (tmp_path / "step_0000000002.tmp" / "leaf_00000.npy").write_bytes(b"garbage")
        assert ck.latest_step() == 1
        restored, _ = ck.restore(None, jax.eval_shape(lambda: state))
        tree_eq(state, restored)

    def test_missing_checkpoint_raises(self, tmp_path, state):
        ck = Checkpointer(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            ck.restore(None, jax.eval_shape(lambda: state))

    def test_double_save_same_step_is_noop(self, tmp_path, state):
        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(5, state)
        ck.save(5, state)  # must not raise (deterministic content)
        assert ck.latest_step() == 5


class TestElastic:
    def test_restore_with_shardings(self, tmp_path, state):
        """Restore places leaves under provided (new-mesh) shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(1, state)
        mesh = jax.make_mesh((1,), ("data",))
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: state)
        )
        restored, _ = ck.restore(1, jax.eval_shape(lambda: state), shardings)
        tree_eq(state, restored)
        leaf = restored["params"]["w"]
        assert leaf.sharding == NamedSharding(mesh, jax.sharding.PartitionSpec())

    def test_restore_dtype_cast(self, tmp_path):
        """Elastic restore can cast (e.g. fp32 checkpoint -> bf16 serve)."""
        ck = Checkpointer(str(tmp_path), async_save=False)
        state = {"w": jnp.ones((4,), jnp.float32)}
        ck.save(1, state)
        like = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
        restored, _ = ck.restore(1, like)
        assert restored["w"].dtype == jnp.bfloat16


@pytest.mark.slow
def test_elastic_reshard_across_device_counts(tmp_path):
    """Save on an 8-device (4x2) mesh with sharded params; restore on 1
    device — values identical (the elastic restart path)."""
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(f"""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import sys; sys.path.insert(0, 'src')
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import Checkpointer
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh, P('data', 'model')))
        ck = Checkpointer({str(tmp_path)!r}, async_save=False)
        ck.save(1, {{'w': w}})
        print('SAVED_OK')
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=".", timeout=300)
    assert "SAVED_OK" in r.stdout, r.stdout + r.stderr
    # restore in THIS process (1 CPU device)
    ck = Checkpointer(str(tmp_path))
    restored, _ = ck.restore(1, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64.0).reshape(8, 8))
