"""Tier-1: the trace-time probe (core/instrument.py).

This counter is the runtime oracle behind the serving contract — every
"never retraces" claim (Session.stats, bench_api's hard gates, dragonlint's
static analysis) is validated against it — so its semantics get pinned
here: bumps happen at trace time only, nested jit traces both bodies,
vmap/grad trace without caching, per-Session prefixes stay isolated
(session1 vs session10), and reset is prefix-scoped.
"""
from __future__ import annotations

import uuid

import jax
import jax.numpy as jnp
import pytest

from repro.core import instrument


def _tag() -> str:
    return f"test.instrument.{uuid.uuid4().hex[:8]}"


class TestCountSemantics:
    def test_counts_traces_not_calls(self):
        tag = _tag()

        @jax.jit
        def f(x):
            instrument.count_trace(tag)
            return x * 2.0

        assert instrument.trace_count(tag) == 0
        f(jnp.float32(1.0))
        assert instrument.trace_count(tag) == 1
        for _ in range(5):  # warm dispatches replay the executable
            f(jnp.float32(3.0))
        assert instrument.trace_count(tag) == 1

    def test_new_shape_or_dtype_retraces(self):
        tag = _tag()

        @jax.jit
        def f(x):
            instrument.count_trace(tag)
            return x + 1

        f(jnp.zeros(3))
        f(jnp.zeros(3))
        assert instrument.trace_count(tag) == 1
        f(jnp.zeros(4))  # new shape -> new program
        assert instrument.trace_count(tag) == 2
        f(jnp.zeros(4, jnp.int32))  # new dtype -> new program
        assert instrument.trace_count(tag) == 3

    def test_static_arg_retraces_traced_arg_does_not(self):
        tag = _tag()

        from functools import partial

        @partial(jax.jit, static_argnames=("k",))
        def f(x, k):
            instrument.count_trace(tag)
            return x * k

        f(jnp.float32(1.0), k=2)
        f(jnp.float32(5.0), k=2)  # value change on traced arg: no retrace
        assert instrument.trace_count(tag) == 1
        f(jnp.float32(1.0), k=3)  # static change: retrace
        assert instrument.trace_count(tag) == 2

    def test_nested_jit_bumps_both_counters_once(self):
        inner_tag, outer_tag = _tag(), _tag()

        @jax.jit
        def inner(x):
            instrument.count_trace(inner_tag)
            return x + 1.0

        @jax.jit
        def outer(x):
            instrument.count_trace(outer_tag)
            return inner(x) * 2.0

        outer(jnp.float32(1.0))
        assert instrument.trace_count(outer_tag) == 1
        assert instrument.trace_count(inner_tag) == 1
        outer(jnp.float32(2.0))
        assert instrument.trace_count(outer_tag) == 1
        assert instrument.trace_count(inner_tag) == 1
        # the inner program was traced inside outer's trace; calling it
        # standalone hits its own jit cache entry only if shapes match the
        # nested trace's abstract values — same shape here, so no new trace
        inner(jnp.float32(3.0))
        assert instrument.trace_count(inner_tag) <= 2

    def test_grad_and_vmap_trace_without_jit_cache(self):
        tag = _tag()

        def f(x):
            instrument.count_trace(tag)
            return jnp.sum(x * x)

        jax.grad(f)(jnp.float32(2.0))
        n1 = instrument.trace_count(tag)
        assert n1 >= 1
        jax.vmap(f)(jnp.zeros((3, 2)))
        assert instrument.trace_count(tag) > n1  # un-jitted transforms re-trace

    def test_make_jaxpr_counts_as_a_trace(self):
        # abstract lowering runs the Python body: dragonlint Pass B bumps
        # the engine probes, which is why benches must gate on deltas
        tag = _tag()

        def f(x):
            instrument.count_trace(tag)
            return x

        jax.make_jaxpr(f)(jnp.float32(0.0))
        assert instrument.trace_count(tag) == 1


class TestPrefixIsolation:
    def test_prefix_sums_only_matching_tags(self):
        base = _tag()
        instrument.count_trace(f"{base}.a")
        instrument.count_trace(f"{base}.b")
        instrument.count_trace(f"{base}.b")
        assert instrument.trace_count(prefix=f"{base}.") == 3
        assert instrument.trace_count(tag=f"{base}.b") == 2

    def test_session1_does_not_see_session10(self):
        # the Session tag scheme ends with "." exactly so numeric suffixes
        # never alias; pin the property the façade relies on
        base = _tag()
        instrument.count_trace(f"{base}1.simulate")
        instrument.count_trace(f"{base}10.simulate")
        instrument.count_trace(f"{base}10.report")
        assert instrument.trace_count(prefix=f"{base}1.") == 1
        assert instrument.trace_count(prefix=f"{base}10.") == 2

    def test_per_session_cachestats_isolation(self):
        from repro.api import Session, Workload

        w = Workload("bfs_graph")
        s1, s2 = Session(), Session()
        s1.perf(w)
        assert s1.stats.traces == 1
        assert s2.stats.traces == 0  # s2 never compiled anything
        assert s2.stats.programs == 0
        s2.perf(w)
        # same bucket+spec: program cache is per-session, so s2 traces its
        # own program (counter isolation, not executable sharing)
        assert s2.stats.traces == 1
        assert s1.stats.traces == 1
        s1.perf(w)  # warm: no new trace anywhere
        assert s1.stats.traces == 1
        assert s1.stats.hits == 1


class TestResetAndSnapshot:
    def test_reset_prefix_scoped(self):
        a, b = _tag(), _tag()
        instrument.count_trace(a)
        instrument.count_trace(b)
        instrument.reset(prefix=a)
        assert instrument.trace_count(a) == 0
        assert instrument.trace_count(b) == 1

    def test_snapshot_is_immutable_copy(self):
        tag = _tag()
        instrument.count_trace(tag)
        snap = instrument.snapshot()
        assert snap[tag] == 1
        snap[tag] = 99
        assert instrument.trace_count(tag) == 1

    def test_reset_does_not_uncompile(self):
        tag = _tag()

        @jax.jit
        def f(x):
            instrument.count_trace(tag)
            return x - 1.0

        f(jnp.float32(1.0))
        instrument.reset(prefix=tag)
        f(jnp.float32(2.0))  # cached executable replays: no re-trace
        assert instrument.trace_count(tag) == 0


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
