"""Graceful degradation when hypothesis is not installed.

Pinned test deps live in requirements.txt / pyproject.toml, but the suite
must still *collect* on a bare interpreter (the seed environment ships JAX
without hypothesis). Importing from this module instead of hypothesis keeps
module-level ``@given``/``@settings`` decorators valid either way: with
hypothesis installed the real objects are re-exported; without it the
property-based tests are individually skipped (same effect as
``pytest.importorskip("hypothesis")`` but scoped to the property tests, so
the example-based tests in the same module still run).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """st.<anything>(...) placeholder; never drawn from (tests skip)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _StrategyStub()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
