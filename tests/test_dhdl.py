"""DHDL front-end: parsing, compilation, serialization, and the
equivalence guarantees that make text architectures first-class citizens
(same values AND same gradients as dataclass-built ones)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import dhdl
from repro.core.dhdl import (
    CompiledArch,
    DhdlError,
    compile_arch,
    library_archs,
    load_arch,
    parse,
    parse_arch,
    serialize_arch,
)
from repro.core.dopt import optimize
from repro.core.dsim import simulate, stacked_log_objective
from repro.core.graph import Graph
from repro.core.params import (
    COMP_CLS,
    MEM_CLS,
    MEM_TYPES,
    ArchParams,
    ArchSpec,
    TechParams,
)
from repro.workloads import get_workload


def _trees_equal(a, b) -> bool:
    return all(
        bool(np.array_equal(np.asarray(x), np.asarray(y)))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# --------------------------------------------------------------------------- #
# parsing + lowering semantics
# --------------------------------------------------------------------------- #


class TestParse:
    def test_units(self):
        ca = parse_arch(
            """
            arch a {
              frequency = 2 GHz
              memory globalBuf { capacity = 4MiB  bank_size = 32 KiB }
              tech { memory mainMem { cell_read_latency = 10 ns } }
            }
            """,
            env={},
        )
        assert float(ca.arch.frequency) == 2e9
        assert float(ca.arch.capacity[1]) == 4 * 2**20
        assert float(ca.arch.bank_size[1]) == 32 * 2**10
        assert float(ca.tech.cell_read_latency[2]) == pytest.approx(10e-9)

    def test_comments_and_defaults(self):
        ca = parse_arch("# hi\narch a { // nothing overridden\n }\n", env={})
        assert _trees_equal(ca.arch, ArchParams.default())
        assert _trees_equal(ca.tech, TechParams.default())
        assert ca.spec == ArchSpec()

    def test_inherit_and_multiplier(self):
        ca = parse_arch(
            """
            arch parent { memory globalBuf { capacity = 10 MiB } }
            arch child inherits parent {
              memory globalBuf { capacity *= 2 }
              tech { memory globalBuf { cell_read_latency *= 0.5 } }
            }
            """,
            env={},
        )
        assert float(ca.arch.capacity[1]) == 20 * 2**20
        assert float(ca.tech.cell_read_latency[1]) == pytest.approx(
            float(TechParams.default().cell_read_latency[1]) * 0.5
        )

    def test_banks_derives_bank_size(self):
        ca = parse_arch(
            "arch a { memory mainMem { capacity = 1 GiB  banks = 1024 } }", env={}
        )
        assert float(ca.arch.bank_size[2]) == 2**30 / 1024

    def test_enabled_false_removes_unit_from_spec(self):
        ca = parse_arch(
            "arch a { compute fpu { enabled = false } memory localMem { enabled = false } }",
            env={},
        )
        assert "fpu" not in ca.spec.comp_units
        assert "localMem" not in ca.spec.mem_units
        # masked out of the concrete model, still present in the pytrees
        chw = ca.specialize()
        assert float(chw.comp_area[3]) == 0.0
        assert float(chw.mem_area[0]) == 0.0

    def test_mem_type_selection(self):
        ca = parse_arch("arch a { memory globalBuf { type = rram } }", env={})
        assert ca.spec.mem_type == ("sram", "rram", "dram")

    def test_vdd_folds_into_energy_refs(self):
        hi = parse_arch("arch a { tech { vdd = 0.9 } }", env={})
        lo = parse_arch("arch a { tech { vdd = 0.45 } }", env={})
        ratio = np.asarray(lo.tech.cell_read_power) / np.asarray(hi.tech.cell_read_power)
        np.testing.assert_allclose(ratio, 0.25, rtol=1e-6)  # ~V^2

    def test_vdd_multiplier_scales_inherited_voltage(self):
        ca = parse_arch(
            "arch a { tech { vdd = 1.2 } }\n"
            "arch b inherits a { tech { vdd *= 0.5 } }",
            env={},
        )
        # 1.2 V * 0.5 = 0.6 V -> energy refs scaled by (0.6/0.9)^2
        ratio = np.asarray(ca.tech.cell_read_power) / np.asarray(
            TechParams.default().cell_read_power
        )
        np.testing.assert_allclose(ratio, (0.6 / 0.9) ** 2, rtol=1e-6)

    def test_muleq_rejected_on_non_numeric_fields(self):
        for src in (
            "arch a { memory mainMem { type *= 2 } }",
            "arch a { compute fpu { enabled *= 0 } }",
        ):
            with pytest.raises(DhdlError, match="does not support"):
                parse_arch(src, env={})

    def test_last_arch_selected_by_default(self):
        src = "arch a { frequency = 1 GHz }\narch b { frequency = 2 GHz }"
        assert float(parse_arch(src, env={}).arch.frequency) == 2e9
        assert float(parse_arch(src, name="a", env={}).arch.frequency) == 1e9


class TestErrors:
    def _err(self, src, **kw):
        with pytest.raises(DhdlError) as ei:
            parse_arch(src, env={}, **kw)
        return str(ei.value)

    def test_unknown_unit_located(self):
        msg = self._err("arch a {\n  frequency = 2 GHzz\n}", filename="x.dhd")
        assert "unknown unit 'GHzz'" in msg
        assert "x.dhd:2:3" in msg
        assert "^" in msg  # caret under the offending line

    def test_unknown_field_lists_candidates(self):
        msg = self._err("arch a { memory mainMem { capcity = 1 GiB } }")
        assert "unknown memory field 'capcity'" in msg
        assert "capacity" in msg

    def test_unknown_memory_unit(self):
        msg = self._err("arch a { memory l2cache { capacity = 1 MiB } }")
        assert "unknown memory unit 'l2cache'" in msg
        assert "globalBuf" in msg

    def test_banks_and_bank_size_conflict(self):
        msg = self._err("arch a { memory mainMem { banks = 4 bank_size = 1 MiB } }")
        assert "both 'banks' and 'bank_size'" in msg

    def test_unknown_parent(self):
        msg = self._err("arch a inherits ghost { }")
        assert "unknown architecture 'ghost'" in msg

    def test_inherit_cycle(self):
        msg = self._err("arch a inherits b { }\narch b inherits a { }")
        assert "cycle" in msg

    def test_duplicate_arch(self):
        msg = self._err("arch a { }\narch a { }")
        assert "duplicate architecture 'a'" in msg

    def test_nonpositive_value(self):
        msg = self._err("arch a { memory mainMem { capacity = 0 } }")
        assert "must be > 0" in msg

    def test_bad_mem_type(self):
        msg = self._err("arch a { memory mainMem { type = flash } }")
        assert "sram, rram, dram" in msg

    def test_unclosed_block(self):
        msg = self._err("arch a { memory mainMem { capacity = 1 GiB ")
        assert "unclosed" in msg


# --------------------------------------------------------------------------- #
# the acceptance equivalence: text == dataclasses, values and gradients
# --------------------------------------------------------------------------- #


class TestEquivalence:
    def test_base_dhd_is_bitwise_default(self):
        ca = load_arch("base")
        assert _trees_equal(ca.arch, ArchParams.default())
        assert _trees_equal(ca.tech, TechParams.default())
        assert ca.spec == ArchSpec()

    def test_simulate_matches_dataclass_path(self):
        g = get_workload("lstm")
        ca = load_arch("base")
        p_txt = simulate(ca.tech, ca.arch, g, ca.spec)
        p_dc = simulate(TechParams.default(), ArchParams.default(), g)
        np.testing.assert_allclose(float(p_txt.runtime), float(p_dc.runtime), rtol=1e-6)
        np.testing.assert_allclose(float(p_txt.energy), float(p_dc.energy), rtol=1e-6)
        np.testing.assert_allclose(float(p_txt.area), float(p_dc.area), rtol=1e-6)

    def test_value_and_grad_match_dataclass_path(self):
        gs = Graph.stack([get_workload("lstm")])
        ca = load_arch("base")

        def f(tech, arch):
            return stacked_log_objective(tech, arch, gs, "edp")[0]

        (v_t, g_t) = jax.value_and_grad(f, argnums=(0, 1))(ca.tech, ca.arch)
        (v_d, g_d) = jax.value_and_grad(f, argnums=(0, 1))(
            TechParams.default(), ArchParams.default()
        )
        np.testing.assert_allclose(float(v_t), float(v_d), rtol=1e-6)
        for a, b in zip(jax.tree.leaves(g_t), jax.tree.leaves(g_d)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=0)

    def test_optimize_runs_end_to_end_from_text(self):
        ca = load_arch("edge")
        res = optimize(
            get_workload("lstm"), tech=ca.tech, arch=ca.arch, spec=ca.spec,
            objective="edp", steps=4, lr=0.05,
        )
        assert len(res.history["edp"]) == 4
        assert all(np.isfinite(res.history["edp"]))
        assert np.isfinite(float(res.arch.frequency))

    def test_every_library_arch_compiles_and_simulates(self):
        g = get_workload("merge_sort")
        assert len(library_archs()) >= 6
        for name in library_archs():
            ca = load_arch(name)
            perf = ca.simulate(g)
            assert np.isfinite(float(perf.runtime)) and float(perf.runtime) > 0
            assert np.isfinite(float(perf.energy)) and float(perf.energy) > 0


# --------------------------------------------------------------------------- #
# round-trip + determinism (property-based)
# --------------------------------------------------------------------------- #


def _interp_log(lo, hi, u: float) -> float:
    return float(np.exp(np.log(lo) + (np.log(hi) - np.log(lo)) * u))


def _random_triple(data) -> CompiledArch:
    """Draw a random architecture inside the DOpt bounds."""
    a_lo, a_hi = ArchParams.bounds()
    t_lo, t_hi = TechParams.bounds()

    def draw_tree(lo_tree, hi_tree, cls):
        kw = {}
        for f in dataclasses.fields(cls):
            lo = np.atleast_1d(np.asarray(getattr(lo_tree, f.name)))
            hi = np.atleast_1d(np.asarray(getattr(hi_tree, f.name)))
            us = [
                data.draw(st.floats(0.0, 1.0, allow_nan=False), label=f"{f.name}[{i}]")
                for i in range(lo.shape[0])
            ]
            vals = np.asarray(
                [_interp_log(l, h, u) for l, h, u in zip(lo, hi, us)], np.float32
            )
            orig = np.asarray(getattr(lo_tree, f.name))
            kw[f.name] = jnp.asarray(vals if orig.ndim else vals[0], jnp.float32)
        return cls(**kw)

    arch = draw_tree(a_lo, a_hi, ArchParams)
    tech = draw_tree(t_lo, t_hi, TechParams)
    mem_type = tuple(data.draw(st.sampled_from(MEM_TYPES), label=f"type{i}") for i in range(3))
    comp_on = [data.draw(st.booleans(), label=f"comp{i}") for i in range(len(COMP_CLS))]
    if not any(comp_on):
        comp_on[0] = True
    mem_on = [data.draw(st.booleans(), label=f"mem{i}") for i in range(len(MEM_CLS))]
    spec = ArchSpec(
        mem_units=tuple(m for m, e in zip(MEM_CLS, mem_on) if e),
        comp_units=tuple(c for c, e in zip(COMP_CLS, comp_on) if e),
        mem_type=mem_type,
    )
    return CompiledArch(name="prop", spec=spec, arch=arch, tech=tech)


class TestRoundTrip:
    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_parse_serialize_parse_identity(self, data):
        ca = _random_triple(data)
        text = serialize_arch(ca)
        ca2 = parse_arch(text, env={})
        assert ca2.spec == ca.spec
        assert _trees_equal(ca2.arch, ca.arch)  # bit-exact float32 round-trip
        assert _trees_equal(ca2.tech, ca.tech)
        assert serialize_arch(ca2) == text  # canonical form is a fixed point

    @settings(max_examples=10, deadline=None)
    @given(data=st.data())
    def test_compile_deterministic(self, data):
        ca = _random_triple(data)
        text = serialize_arch(ca)
        c1, c2 = parse_arch(text, env={}), parse_arch(text, env={})
        assert _trees_equal(c1.arch, c2.arch) and _trees_equal(c1.tech, c2.tech)
        assert c1.spec == c2.spec

    def test_library_archs_round_trip(self):
        for name in library_archs():
            ca = load_arch(name)
            ca2 = parse_arch(serialize_arch(ca), env={})
            assert ca2.spec == ca.spec
            assert _trees_equal(ca2.arch, ca.arch) and _trees_equal(ca2.tech, ca.tech)

    def test_compile_deterministic_on_library_source(self):
        env1 = dhdl.load_library(refresh=True)
        a1 = compile_arch(env1["wafer_scale"], env1)
        env2 = dhdl.load_library(refresh=True)
        a2 = compile_arch(env2["wafer_scale"], env2)
        assert _trees_equal(a1.arch, a2.arch) and _trees_equal(a1.tech, a2.tech)


# --------------------------------------------------------------------------- #
# golden corpus (same check CI runs via tools/check_dhdl_corpus.py)
# --------------------------------------------------------------------------- #


class TestGoldenCorpus:
    @pytest.fixture(scope="class")
    def corpus(self):
        import importlib.util
        import os

        path = os.path.join(os.path.dirname(__file__), "..", "tools", "check_dhdl_corpus.py")
        spec = importlib.util.spec_from_file_location("check_dhdl_corpus", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    def test_valid_corpus_compiles_and_round_trips(self, corpus):
        assert corpus.check_valid_corpus() == []

    def test_invalid_corpus_errors_match_expected_snippets(self, corpus):
        assert corpus.check_invalid_corpus() == []


# --------------------------------------------------------------------------- #
# finite-difference gradient check through a parsed .dhd model
# --------------------------------------------------------------------------- #


class TestFiniteDifference:
    # coordinates with smooth (non-STE-surrogate) dependence; the STE knobs
    # (capacity tiling, systolic wave quantization) intentionally carry
    # surrogate gradients and are excluded by design
    COORDS = [
        ("tech", "cell_read_power", 2),
        ("tech", "cell_area", 1),
        ("tech", "node", 1),
        ("arch", "bw_scale", 2),
        ("arch", "frequency", None),
        ("arch", "vect_n", None),
    ]

    def test_value_and_grad_vs_central_difference(self):
        ca = load_arch("edge")
        gs = Graph.stack([get_workload("lstm"), get_workload("merge_sort")])

        def logobj(tech, arch):
            return stacked_log_objective(tech, arch, gs, "edp", spec=ca.spec)[0]

        for tree, fname, idx in self.COORDS:
            def f(s):
                t, a = ca.tech, ca.arch
                obj = t if tree == "tech" else a
                v = getattr(obj, fname)
                v2 = v * s if idx is None else v.at[idx].mul(s)
                obj2 = dataclasses.replace(obj, **{fname: v2})
                return logobj(obj2 if tree == "tech" else t,
                              a if tree == "tech" else obj2)

            val, grad = jax.value_and_grad(f)(jnp.float32(1.0))
            assert np.isfinite(float(val))
            eps = 0.05
            fd = (float(f(jnp.float32(1 + eps))) - float(f(jnp.float32(1 - eps)))) / (2 * eps)
            assert float(grad) == pytest.approx(fd, rel=5e-2, abs=1e-5), (
                f"{tree}.{fname}[{idx}]: AD {float(grad)} vs FD {fd}"
            )
