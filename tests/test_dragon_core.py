"""DRAGON core (DGen + DSim + mapper) behaviour and invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ArchParams,
    ArchSpec,
    GraphBuilder,
    TechParams,
    map_workload,
    simulate,
    specialize,
    workload_optimize,
)
from repro.core.graph import MATMUL, ELEMWISE, compute_merge
from repro.core.mapper import MapperCfg, ceil_ste, gate_below_ste
from repro.workloads import get_workload


def small_graph():
    b = GraphBuilder()
    b.add("mm1", MATMUL, 2 * 512 * 512 * 512, gbuf_read=2 * 512 * 512 * 2,
          gbuf_write=512 * 512 * 2, main_read=512 * 512 * 2, alloc=3 * 512 * 512 * 2,
          dims=(512, 512, 512))
    b.add("act", ELEMWISE, 512 * 512 * 4, gbuf_read=512 * 512 * 2,
          gbuf_write=512 * 512 * 2, alloc=2 * 512 * 512 * 2, dims=(512 * 512, 1, 1))
    return b.build()


class TestDGen:
    def test_specialize_finite_positive(self):
        chw = specialize(TechParams.default(), ArchParams.default())
        for leaf in jax.tree.leaves(chw):
            assert jnp.all(jnp.isfinite(leaf))
        assert float(chw.total_area) > 0
        assert float(chw.frequency) > 0

    def test_frequency_capped_by_critical_path(self):
        arch = dataclasses.replace(ArchParams.default(), frequency=jnp.float32(1e12))
        chw = specialize(TechParams.default(), arch)
        assert float(chw.frequency) < 1e12  # timing-feasibility clamp

    def test_smaller_node_is_faster_and_denser(self):
        t40 = TechParams.default()
        t7 = dataclasses.replace(t40, node=jnp.full(4, 7.0), peripheral_node=jnp.full(3, 7.0))
        c40 = specialize(t40, ArchParams.default())
        c7 = specialize(t7, ArchParams.default())
        assert float(c7.frequency) > float(c40.frequency)
        assert float(jnp.sum(c7.comp_area)) < float(jnp.sum(c40.comp_area))

    def test_memtype_changes_metrics(self):
        sram = specialize(TechParams.default(), ArchParams.default(),
                          ArchSpec(mem_type=("sram", "sram", "dram")))
        rram = specialize(TechParams.default(), ArchParams.default(),
                          ArchSpec(mem_type=("sram", "rram", "dram")))
        assert float(rram.write_latency[1]) > float(sram.write_latency[1])


class TestDSim:
    def test_measurements_positive(self):
        perf = simulate(TechParams.default(), ArchParams.default(), small_graph())
        for v in perf.measurements().values():
            assert float(v) > 0 and np.isfinite(float(v))

    def test_power_runtime_energy_consistent(self):
        perf = simulate(TechParams.default(), ArchParams.default(), small_graph())
        assert float(perf.power) == pytest.approx(
            float(perf.energy) / float(perf.runtime), rel=1e-5
        )
        assert float(perf.edp) == pytest.approx(
            float(perf.energy) * float(perf.runtime), rel=1e-5
        )

    def test_energy_decomposition(self):
        perf = simulate(TechParams.default(), ArchParams.default(), small_graph())
        assert float(perf.energy) == pytest.approx(
            float(perf.energy_mem + perf.energy_comp + perf.energy_leak), rel=1e-5
        )

    def test_runtime_monotone_in_cell_latency(self):
        g = get_workload("lstm")
        base = TechParams.default()
        slow = dataclasses.replace(base, cell_read_latency=base.cell_read_latency * 10)
        r0 = float(simulate(base, ArchParams.default(), g).runtime)
        r1 = float(simulate(slow, ArchParams.default(), g).runtime)
        assert r1 >= r0

    def test_energy_monotone_in_read_power(self):
        g = get_workload("lstm")
        base = TechParams.default()
        hot = dataclasses.replace(base, cell_read_power=base.cell_read_power * 5)
        e0 = float(simulate(base, ArchParams.default(), g).energy)
        e1 = float(simulate(hot, ArchParams.default(), g).energy)
        assert e1 > e0

    def test_bigger_systolic_array_not_slower_on_big_matmuls(self):
        g = small_graph()
        a_small = dataclasses.replace(ArchParams.default(), sys_arr_x=jnp.float32(32.0),
                                      sys_arr_y=jnp.float32(32.0))
        a_big = dataclasses.replace(ArchParams.default(), sys_arr_x=jnp.float32(256.0),
                                    sys_arr_y=jnp.float32(256.0))
        r_small = float(simulate(TechParams.default(), a_small, g).runtime)
        r_big = float(simulate(TechParams.default(), a_big, g).runtime)
        assert r_big <= r_small * 1.01

    def test_grad_matches_finite_difference(self):
        """The paper's central claim: gradients through the mapper are correct."""
        g = get_workload("lstm")

        def f(x):
            tech = TechParams.default()
            tech = dataclasses.replace(
                tech, cell_read_power=tech.cell_read_power.at[1].mul(x)
            )
            return simulate(tech, ArchParams.default(), g).energy

        x0 = jnp.float32(1.3)
        grad = float(jax.grad(f)(x0))
        # energy is linear in read_power, so a large central difference is
        # exact and beats fp32 cancellation noise
        eps = 0.25
        fd = (float(f(x0 + eps)) - float(f(x0 - eps))) / (2 * eps)
        assert grad == pytest.approx(fd, rel=2e-2)

    def test_jit_vmap_composable(self):
        g = small_graph()
        techs = jax.vmap(
            lambda s: dataclasses.replace(
                TechParams.default(),
                cell_read_latency=TechParams.default().cell_read_latency * s,
            )
        )(jnp.linspace(0.5, 2.0, 4))
        f = jax.jit(jax.vmap(lambda t: simulate(t, ArchParams.default(), g).runtime))
        out = f(techs)
        assert out.shape == (4,)
        assert bool(jnp.all(jnp.diff(out) >= 0))  # monotone in latency scale


class TestMapper:
    def test_tiles_are_integers(self):
        ms = map_workload(
            specialize(TechParams.default(), ArchParams.default()), small_graph()
        )
        assert float(ms.n_tiles) == int(ms.n_tiles)

    def test_tiling_triggers_when_over_capacity(self):
        arch = ArchParams.default()
        tiny = dataclasses.replace(arch, capacity=arch.capacity.at[1].set(64 * 1024.0))
        chw_big = specialize(TechParams.default(), arch)
        chw_tiny = specialize(TechParams.default(), tiny)
        g = small_graph()
        assert float(map_workload(chw_tiny, g).n_tiles) > float(map_workload(chw_big, g).n_tiles)

    def test_prefetch_hides_main_memory_time(self):
        chw = specialize(TechParams.default(), ArchParams.default())
        g = small_graph()
        on = map_workload(chw, g, MapperCfg(prefetch=True, streaming=True))
        off = map_workload(chw, g, MapperCfg(prefetch=False, streaming=False))
        assert float(on.cycles) <= float(off.cycles)
        assert float(off.t_exposed_main) >= float(on.t_exposed_main)

    def test_ceil_ste_forward_exact_backward_smooth(self):
        x = jnp.float32(3.4)
        assert float(ceil_ste(x)) == 4.0
        assert float(jax.grad(lambda v: ceil_ste(v))(x)) == 1.0

    def test_gate_ste_hard_forward(self):
        assert float(gate_below_ste(jnp.float32(0.5), jnp.float32(1.0))) == 1.0
        assert float(gate_below_ste(jnp.float32(1.5), jnp.float32(1.0))) == 0.0


class TestGraphOpt:
    def test_compute_merge_preserves_totals(self):
        g = get_workload("lstm")
        merged = compute_merge(g, flops_threshold=1e9)
        assert merged.n_vertices <= g.n_vertices
        np.testing.assert_allclose(
            np.asarray(merged.n_comp).sum(), np.asarray(g.n_comp).sum(), rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(merged.n_read).sum(), np.asarray(g.n_read).sum(), rtol=1e-6
        )

    def test_merge_reduces_mapper_overhead(self):
        g = get_workload("lstm")
        chw = specialize(TechParams.default(), ArchParams.default())
        merged = workload_optimize(g, merge_threshold=1e8)
        r_m = float(map_workload(chw, merged).cycles)
        r_g = float(map_workload(chw, g).cycles)
        assert r_m <= r_g * 1.05  # merging never makes it much worse

    def test_pad_to(self):
        g = small_graph()
        p = g.pad_to(10)
        assert p.n_vertices == 10
        np.testing.assert_allclose(
            np.asarray(p.n_comp).sum(), np.asarray(g.n_comp).sum(), rtol=1e-6
        )
