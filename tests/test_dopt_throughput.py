"""Regression guard for PR 2's device-resident DOpt throughput win.

The fused chunked-scan loop is what makes population-scale DSE viable; a
refactor that silently unfuses it (per-epoch host syncs, per-call
retracing) would pass every correctness test and only show up in the
benches.  This tier-1 test re-measures warm fused epochs/sec on the same
3-workload stack the recorded baseline used and asserts it stays within a
*generous* factor of ``results/bench/dopt_throughput.json`` — wide enough
for slow CI machines, tight enough that losing the fusion (a >20x cliff on
the recorded hardware) fails loudly.
"""
import json
import os
import time

from repro.core import optimize
from repro.workloads import get_workload

BASELINE = os.path.join(
    os.path.dirname(__file__), "..", "results", "bench", "dopt_throughput.json"
)
GENEROUS_FACTOR = 20.0  # machine-variance headroom below the recorded rate


def test_warm_fused_epochs_per_sec_vs_recorded_baseline():
    with open(BASELINE) as f:
        recorded = json.load(f)
    recorded_eps = float(recorded["after"]["epochs_per_s_warm"])
    assert recorded_eps > 0, recorded

    gl = [get_workload(n) for n in recorded["workloads"]]
    steps = 40
    optimize(gl, objective="edp", steps=steps, lr=0.05, fused=True)  # compile
    t0 = time.perf_counter()
    optimize(gl, objective="edp", steps=steps, lr=0.05, fused=True)
    warm_eps = steps / (time.perf_counter() - t0)

    floor = recorded_eps / GENEROUS_FACTOR
    assert warm_eps >= floor, (
        f"warm fused DOpt throughput {warm_eps:.0f} epochs/s fell below "
        f"{floor:.0f} (recorded {recorded_eps:.0f} / factor {GENEROUS_FACTOR}) — "
        f"did a refactor unfuse the device-resident loop?"
    )
