"""Workload tracer validation: DFG totals vs closed-form model FLOPs,
plus hypothesis properties over the chunked-xent / attention helpers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis

from repro.configs import SHAPES, all_archs, get_config
from repro.configs.base import ShapeConfig
from repro.core.trace import model_flops, trace_lm
from repro.workloads import lm_cell


@pytest.mark.parametrize("arch", all_archs())
def test_train_dfg_flops_vs_6nd(arch):
    """Traced DFG FLOPs should be ~6*N_active*D for train (plus attention,
    which 6ND ignores — so ratio in [0.95, 3.0])."""
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    g = trace_lm(cfg, shape)
    traced = float(np.asarray(g.total_flops).sum())
    closed = model_flops(cfg, shape)
    ratio = traced / closed
    assert 0.9 < ratio < 3.0, (arch, ratio)


@pytest.mark.parametrize("arch", all_archs())
def test_decode_dfg_much_smaller_than_prefill(arch):
    cfg = get_config(arch)
    if not cfg.subquadratic() and arch == "skip":
        pytest.skip()
    dec = float(np.asarray(trace_lm(cfg, SHAPES["decode_32k"]).total_flops).sum())
    pre = float(np.asarray(trace_lm(cfg, SHAPES["prefill_32k"]).total_flops).sum())
    assert dec < pre / 10


def test_moe_dfg_counts_active_experts_only():
    k2 = get_config("kimi-k2-1t-a32b")
    g = trace_lm(k2, SHAPES["train_4k"])
    traced = float(np.asarray(g.total_flops).sum())
    all_experts = 6.0 * k2.param_count() * SHAPES["train_4k"].seq_len * SHAPES["train_4k"].global_batch
    assert traced < all_experts / 5  # active << total


def test_vertex_stats_nonnegative():
    for arch in all_archs():
        g = lm_cell(arch, "train_4k")
        for f in (g.n_comp, g.n_read, g.n_write, g.n_alloc):
            assert float(jnp.min(f)) >= 0.0


class TestChunkedXentProperty:
    @settings(max_examples=10, deadline=None)
    @given(
        S=st.sampled_from([8, 12, 16]),
        chunk=st.sampled_from([3, 4, 8, 16]),
        seed=st.integers(0, 10),
    )
    def test_equals_full_xent(self, S, chunk, seed):
        from repro.models import build_model
        from repro.models import transformer as T

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(), dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(seed))
        tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, S), 0, cfg.vocab_size)
        h, _, _ = m.forward(params, tokens, head=False)
        logits, _, _ = m.forward(params, tokens, head=True)
        full = float(T.xent_loss(logits, tokens))
        chunked = float(T.chunked_xent(cfg, params, h, tokens, chunk=chunk))
        assert chunked == pytest.approx(full, rel=1e-5)


class TestTrainStepEquivalence:
    def test_microbatch_accumulation_matches_full(self, rng):
        from repro.models import build_model
        from repro.optim import AdamWConfig
        from repro.train import TrainConfig, init_train_state, make_train_step

        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(), dtype="float32")
        m = build_model(cfg)
        batch = {
            "tokens": jax.random.randint(rng, (4, 16), 0, cfg.vocab_size),
            "labels": jax.random.randint(rng, (4, 16), 0, cfg.vocab_size),
        }
        ocfg = AdamWConfig(lr=1e-3, schedule=None)
        outs = []
        for mb in (1, 2):
            state = init_train_state(m, jax.random.PRNGKey(3), ocfg)
            step = jax.jit(make_train_step(m, ocfg, TrainConfig(microbatches=mb)))
            state, _ = step(state, batch)
            outs.append(state["params"])
        for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestGNNWorkloads:
    """Paper Table 1 claims GNN support — validate the message-passing DFGs."""

    def test_gcn_simulates(self):
        from repro.core import ArchParams, TechParams, simulate
        from repro.workloads import get_workload

        g = get_workload("gcn")
        p = simulate(TechParams.default(), ArchParams.default(), g)
        assert float(p.runtime) > 0 and np.isfinite(float(p.energy))

    def test_gather_dominates_mainmem_traffic(self):
        """GNNs are gather/aggregation-bound — mainMem reads exceed weight
        traffic by a wide margin (the property that distinguishes them from
        CNNs in the paper's Table 3 analysis)."""
        from repro.workloads import get_workload

        g = get_workload("gcn")
        main_reads = float(np.asarray(g.n_read)[:, 2].sum())
        flops = float(np.asarray(g.n_comp).sum())
        # arithmetic intensity well below a dense CNN's
        assert flops / main_reads < 100.0

    def test_degree_scales_gather(self):
        from repro.workloads import get_workload

        lo = get_workload("graphsage", avg_degree=4)
        hi = get_workload("graphsage", avg_degree=32)
        # mainMem gather traffic scales with degree (weight traffic doesn't)
        assert (float(np.asarray(hi.n_read)[:, 2].sum())
                > 4 * float(np.asarray(lo.n_read)[:, 2].sum()))
