"""The persistent AOT executable cache, proven across process boundaries.

Three suites (ISSUE 9 satellites):

* **Cross-process restart** — one subprocess preheats a tmp ``cache_dir``;
  a second subprocess constructs ``Session(cache_dir=...)`` and must serve
  simulate/explain with ZERO traces (instrument probe) and replies
  bit-identical (``to_json`` string-equal) to the preheating process's
  fresh-compiled session — the persistent-cache analogue of PR 8's
  pinned-bucket identity gate.

* **Cache-key properties** (hypothesis via the shim) — equal
  ``(kind, ArchSpec, MapperCfg, bucket[, objective][, request bucket])``
  tuples digest equal across processes; any single-field perturbation
  changes the digest; the digest covers the schema version and the
  jax/jaxlib/backend fingerprint so upgrades miss cleanly.

* **Corruption robustness** — truncated / bit-flipped / zero-length /
  garbage entries classify as transient, fall back to a fresh compile,
  quarantine (rename) the bad file, and never poison the in-memory
  program cache; the chaos harness injects the same fault class
  (``ChaosConfig.p_cache_corrupt``) and retry must clear it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import subprocess
import sys

import pytest

from repro.api import Session, Workload
from repro.core.mapper import MapperCfg
from repro.core.params import ArchSpec
from repro.kernels import runtime
from repro.serving import aotcache
from repro.serving.aotcache import (
    AotCache,
    CacheCorruption,
    cache_key_digest,
    canonical_key_text,
)
from repro.serving.resilience import classify_exception
from tests._hypothesis_compat import given, settings, st

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run_child(code: str, *argv: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-c", code, *argv],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"child failed:\n{out.stderr}"
    return json.loads(out.stdout.strip().splitlines()[-1])


# --------------------------------------------------------------------------- #
# cross-process restart
# --------------------------------------------------------------------------- #

# Preheats AND serves: preheat AOT-compiled the programs in this process, so
# its replies are by construction those of a freshly-compiled session.
_PREHEAT_CHILD = r"""
import json, sys
from repro.api import Session
sess = Session("base", cache_dir=sys.argv[1])
info = sess.preheat(["lstm"], objectives=("edp",), kinds=("simulate", "explain"))
sim = sess.simulate("lstm").to_json()
expl = sess.explain("lstm", objective="edp").to_json()
print(json.dumps(dict(info=info, sim=sim, expl=expl)))
"""

_RESTART_CHILD = r"""
import json, sys
from repro.api import Session
from repro.core import instrument
sess = Session("base", cache_dir=sys.argv[1])
rep = sess.simulate("lstm")
expl = sess.explain("lstm", objective="edp")
print(json.dumps(dict(traces=sess.stats.traces,
                      global_traces=instrument.trace_count(),
                      disk_loaded=sess.disk_loaded,
                      hits=sess.stats.hits, misses=sess.stats.misses,
                      sim=rep.to_json(), expl=expl.to_json())))
"""


@pytest.fixture(scope="module")
def restart_run(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("aot-restart"))
    pre = _run_child(_PREHEAT_CHILD, d)
    post = _run_child(_RESTART_CHILD, d)
    return pre, post


class TestCrossProcessRestart:
    def test_preheat_builds_and_persists(self, restart_run):
        pre, _ = restart_run
        assert pre["info"]["built"] == 2  # report + explain(edp)
        assert pre["info"]["persisted"] == 2

    def test_restarted_process_serves_with_zero_traces(self, restart_run):
        _, post = restart_run
        assert post["disk_loaded"] == 2
        assert post["traces"] == 0
        assert post["global_traces"] == 0  # nothing else traced either

    def test_restarted_replies_bit_identical(self, restart_run):
        pre, post = restart_run
        assert post["sim"] == pre["sim"]
        assert post["expl"] == pre["expl"]

    def test_restarted_cache_lookups_are_hits(self, restart_run):
        _, post = restart_run
        assert post["misses"] == 0
        assert post["hits"] >= 2


# --------------------------------------------------------------------------- #
# cache-key properties
# --------------------------------------------------------------------------- #

_BASE_KEY = ("report", ArchSpec(), MapperCfg(), (1, 32))

# every entry perturbs exactly one component of _BASE_KEY (or its length)
_PERTURBATIONS = (
    ("kind", lambda k: ("explain",) + k[1:]),
    ("spec.mem_type", lambda k: (k[0], dataclasses.replace(k[1], mem_type=("sram", "rram", "dram")), k[2], k[3])),
    ("spec.mem_units", lambda k: (k[0], dataclasses.replace(k[1], mem_units=("l0", "l1", "l2")), k[2], k[3])),
    ("mcfg.headroom", lambda k: (k[0], k[1], dataclasses.replace(k[2], headroom=0.8), k[3])),
    ("mcfg.prefetch", lambda k: (k[0], k[1], dataclasses.replace(k[2], prefetch=False), k[3])),
    ("mcfg.scan_impl", lambda k: (k[0], k[1], dataclasses.replace(k[2], scan_impl="ref"), k[3])),
    ("bucket.w", lambda k: (k[0], k[1], k[2], (2, 32))),
    ("bucket.v", lambda k: (k[0], k[1], k[2], (1, 64))),
    ("objective appended", lambda k: k + ("edp",)),
    ("request bucket appended", lambda k: k + ("edp", 8)),
)

_DIGEST_CHILD = r"""
import json
from repro.core.mapper import MapperCfg
from repro.core.params import ArchSpec
from repro.serving.aotcache import cache_key_digest
keys = [
    ("report", ArchSpec(), MapperCfg(), (1, 32)),
    ("explain", ArchSpec(), MapperCfg(), (1, 32), "edp"),
    ("report_batched", ArchSpec(), MapperCfg(), (4, 64), 8),
    ("explain_batched", ArchSpec(), MapperCfg(), (1, 32), "mixed", 16),
]
print(json.dumps(dict(digests=[cache_key_digest(k) for k in keys])))
"""


class TestCacheKeyDigest:
    def test_equal_tuples_equal_digest(self):
        # fresh, structurally-equal dataclasses — not the same objects
        k2 = ("report", ArchSpec(), MapperCfg(), (1, 32))
        assert cache_key_digest(_BASE_KEY) == cache_key_digest(k2)

    def test_digest_stable_across_processes(self):
        local = [
            cache_key_digest(("report", ArchSpec(), MapperCfg(), (1, 32))),
            cache_key_digest(("explain", ArchSpec(), MapperCfg(), (1, 32), "edp")),
            cache_key_digest(("report_batched", ArchSpec(), MapperCfg(), (4, 64), 8)),
            cache_key_digest(("explain_batched", ArchSpec(), MapperCfg(), (1, 32), "mixed", 16)),
        ]
        assert _run_child(_DIGEST_CHILD)["digests"] == local

    @pytest.mark.parametrize("label,perturb", _PERTURBATIONS, ids=[p[0] for p in _PERTURBATIONS])
    def test_any_single_field_perturbation_changes_digest(self, label, perturb):
        assert cache_key_digest(perturb(_BASE_KEY)) != cache_key_digest(_BASE_KEY), label

    def test_perturbations_pairwise_distinct(self):
        digests = {cache_key_digest(_BASE_KEY)}
        for label, perturb in _PERTURBATIONS:
            d = cache_key_digest(perturb(_BASE_KEY))
            assert d not in digests, f"collision via {label}"
            digests.add(d)

    def test_digest_covers_schema_version(self, monkeypatch):
        d0 = cache_key_digest(_BASE_KEY)
        monkeypatch.setattr(aotcache, "SCHEMA_VERSION", aotcache.SCHEMA_VERSION + 1)
        assert cache_key_digest(_BASE_KEY) != d0

    def test_digest_covers_runtime_fingerprint(self, monkeypatch):
        d0 = cache_key_digest(_BASE_KEY)
        monkeypatch.setattr(
            runtime, "executable_fingerprint",
            lambda: "jax=9.9.9|jaxlib=9.9.9|backend=tpu",
        )
        assert cache_key_digest(_BASE_KEY) != d0

    def test_unsupported_component_rejected(self):
        with pytest.raises(TypeError, match="unsupported"):
            canonical_key_text(("report", object()))

    @given(
        kind=st.sampled_from(["simulate", "report", "explain", "report_batched"]),
        w=st.integers(1, 64),
        v=st.sampled_from([32, 64, 128, 256]),
        headroom=st.floats(0.05, 0.99, allow_nan=False),
        prefetch=st.booleans(),
        objective=st.sampled_from(["edp", "energy", "time", "mixed"]),
    )
    @settings(max_examples=40, deadline=None)
    def test_digest_equality_iff_canonical_equality(
        self, kind, w, v, headroom, prefetch, objective
    ):
        base = ("report", ArchSpec(), MapperCfg(), (1, 32), "edp")
        drawn = (
            kind, ArchSpec(), MapperCfg(headroom=headroom, prefetch=prefetch),
            (w, v), objective,
        )
        same_text = canonical_key_text(drawn) == canonical_key_text(base)
        same_digest = cache_key_digest(drawn) == cache_key_digest(base)
        assert same_text == same_digest

    @given(h1=st.floats(0.05, 0.99, allow_nan=False), h2=st.floats(0.05, 0.99, allow_nan=False))
    @settings(max_examples=40, deadline=None)
    def test_float_fields_injective(self, h1, h2):
        k1 = ("report", ArchSpec(), MapperCfg(headroom=h1), (1, 32))
        k2 = ("report", ArchSpec(), MapperCfg(headroom=h2), (1, 32))
        assert (cache_key_digest(k1) == cache_key_digest(k2)) == (h1 == h2)


# --------------------------------------------------------------------------- #
# corruption robustness
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def preheated(tmp_path_factory):
    """One in-process preheated cache dir (a single report program) plus the
    fresh-compile reference reply — copied per corruption test."""
    d = str(tmp_path_factory.mktemp("aot-pristine"))
    sess = Session("base", cache_dir=d)
    info = sess.preheat(["lstm"], kinds=("simulate",))
    assert info["persisted"] == 1
    return dict(dir=d, ref=sess.simulate("lstm").to_json())


def _copy_cache(preheated, tmp_path) -> str:
    dst = str(tmp_path / "cache")
    shutil.copytree(preheated["dir"], dst)
    return dst


def _entry_path(d: str) -> str:
    entries = [n for n in os.listdir(d) if n.endswith(".aotx")]
    assert len(entries) == 1
    return os.path.join(d, entries[0])


def _corrupt(path: str, mode: str) -> None:
    data = open(path, "rb").read()
    if mode == "truncate":
        data = data[: len(data) // 2]
    elif mode == "zero_length":
        data = b""
    elif mode == "bit_flip":
        body = bytearray(data)
        body[len(body) // 2] ^= 0xFF
        data = bytes(body)
    elif mode == "garbage":
        data = b"not a cache entry at all"
    else:  # pragma: no cover
        raise AssertionError(mode)
    with open(path, "wb") as f:
        f.write(data)


class TestCorruptionRobustness:
    MODES = ("truncate", "zero_length", "bit_flip", "garbage")

    @pytest.mark.parametrize("mode", MODES)
    def test_corrupt_entry_quarantined_and_recompiled(self, mode, tmp_path, preheated):
        d = _copy_cache(preheated, tmp_path)
        _corrupt(_entry_path(d), mode)
        sess = Session("base", cache_dir=d)
        # nothing loaded, in-memory cache not poisoned
        assert sess.disk_loaded == 0
        assert sess.programs == {}
        # the bad file left the cache namespace, bytes kept for post-mortem
        names = os.listdir(d)
        assert not any(n.endswith(".aotx") for n in names)
        assert any(".quarantined" in n for n in names)
        # serving falls back to a fresh compile with the identical reply
        rep = sess.simulate("lstm")
        assert sess.stats.traces == 1
        assert rep.to_json() == preheated["ref"]
        # and the recompiled program is warm — the corruption cost one compile
        assert sess.simulate("lstm").to_json() == preheated["ref"]
        assert sess.stats.traces == 1

    @pytest.mark.parametrize("mode", MODES)
    def test_lazy_get_never_raises(self, mode, tmp_path, preheated):
        d = _copy_cache(preheated, tmp_path)
        path = _entry_path(d)
        _corrupt(path, mode)
        cache = AotCache(d)
        key = ("report", ArchSpec(), MapperCfg(), (1, 32))
        assert cache.get(key) is None
        assert cache.load_all() == {}
        assert cache.quarantined >= 1

    def test_quarantine_survives_repeat_corruption(self, tmp_path, preheated):
        d = _copy_cache(preheated, tmp_path)
        path = _entry_path(d)
        _corrupt(path, "bit_flip")
        cache = AotCache(d)
        assert cache.load_all() == {}
        # a second bad file with the same name quarantines alongside, not over
        shutil.copy(os.path.join(preheated["dir"], os.path.basename(path)), path)
        _corrupt(path, "truncate")
        assert cache.load_all() == {}
        assert sum(".quarantined" in n for n in os.listdir(d)) == 2

    def test_foreign_fingerprint_is_clean_miss_not_quarantine(
        self, tmp_path, preheated, monkeypatch
    ):
        d = _copy_cache(preheated, tmp_path)
        monkeypatch.setattr(
            runtime, "executable_fingerprint",
            lambda: "jax=9.9.9|jaxlib=9.9.9|backend=tpu",
        )
        cache = AotCache(d)
        assert cache.load_all() == {}
        assert cache.rejected == 1
        assert cache.quarantined == 0
        # the entry stays on disk: it belongs to another runtime, not the bin
        assert any(n.endswith(".aotx") for n in os.listdir(d))

    def test_pristine_copy_still_loads(self, tmp_path, preheated):
        d = _copy_cache(preheated, tmp_path)
        sess = Session("base", cache_dir=d)
        assert sess.disk_loaded == 1
        assert sess.simulate("lstm").to_json() == preheated["ref"]
        assert sess.stats.traces == 0

    def test_cache_corruption_classifies_transient(self):
        fault = classify_exception(CacheCorruption("torn entry"))
        assert fault.code == "transient"
        assert fault.retryable

    def test_chaos_injected_corruption_clears_on_retry(self):
        from repro.serving import (
            ChaosConfig,
            ChaosInjector,
            DesignQuery,
            DesignService,
            RetryPolicy,
        )

        inj = ChaosInjector(ChaosConfig(seed=11, p_cache_corrupt=1.0), sleep=lambda s: None)
        svc = DesignService(
            "base", chaos=inj, retry=RetryPolicy(max_attempts=3, base_s=0.001)
        )
        r = svc.submit(DesignQuery(0, "simulate", "lstm"))
        assert r.ok and r.attempts == 2
        assert inj.summary() == {"cache_corrupt": 1}
        assert svc.stats.availability == 1.0


# --------------------------------------------------------------------------- #
# preheat semantics (in-process)
# --------------------------------------------------------------------------- #


class TestPreheat:
    def test_preheat_idempotent_and_disk_warm(self, preheated):
        sess = Session("base", cache_dir=preheated["dir"])
        assert sess.disk_loaded == 1
        info = sess.preheat(["lstm"], kinds=("simulate",))
        assert info == dict(
            programs=1, built=0, reused=1, persisted=0, seconds=info["seconds"]
        )
        assert sess.stats.traces == 0
        assert sess.simulate("lstm").to_json() == preheated["ref"]
        assert sess.stats.traces == 0

    def test_preheat_by_bare_bucket_tuple(self, tmp_path, preheated):
        # shapes are all compilation needs: a zero-filled synthetic stack
        # preheats the very program that serves the real workload
        sess = Session("base", cache_dir=str(tmp_path))
        info = sess.preheat([(1, 32)], kinds=("simulate",))
        assert info["built"] == 1 and info["persisted"] == 1
        assert sess.stats.traces == 1  # the preheat compile itself
        rep = sess.simulate("lstm")  # lstm stacks into bucket (1, 32)
        assert sess.stats.traces == 1  # the serve added none
        assert rep.to_json() == preheated["ref"]

    def test_preheat_rejects_unknown_kind(self):
        with pytest.raises(ValueError, match="preheat kinds"):
            Session("base").preheat(["lstm"], kinds=("simulate", "frontier"))

    def test_preheat_without_cache_dir_is_in_memory_only(self):
        sess = Session("base")
        info = sess.preheat([(1, 32)], kinds=("simulate",))
        assert info["built"] == 1 and info["persisted"] == 0
        assert sess.stats.traces == 1
        sess.simulate("lstm")
        assert sess.stats.traces == 1  # AOT program serves, no retrace

    def test_bucket_dedupe_one_build_per_bucket(self, tmp_path):
        sess = Session("base", cache_dir=str(tmp_path))
        # lstm and merge_sort share bucket (1, 32): one program, not two
        info = sess.preheat(["lstm", "merge_sort"], kinds=("simulate",))
        assert info == dict(
            programs=1, built=1, reused=0, persisted=1, seconds=info["seconds"]
        )
