"""Per-arch smoke tests (reduced configs, CPU) + decode/prefill consistency.

Every assigned architecture: instantiate a reduced same-family config, run
one forward/train step, assert output shapes + finiteness; then verify the
serving path (prefill + decode with KV/SSM caches) matches the full forward
position-by-position — the strongest end-to-end correctness property the
zoo has.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_archs, cell_status, get_config
from repro.models import build_model
from repro.models.defs import param_count as defs_param_count


def batch_for(cfg, key, B=2, S=16):
    shape = (B, S, cfg.audio.n_codebooks) if cfg.audio else (B, S)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.vision:
        batch["vision"] = jax.random.normal(
            key, (B, cfg.vision.n_patches, cfg.vision.d_vision)
        )
    return batch


@pytest.mark.parametrize("arch", all_archs())
class TestArchSmoke:
    def test_forward_and_loss(self, arch, rng):
        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        params = m.init(rng)
        batch = batch_for(cfg, rng)
        loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
        assert jnp.isfinite(loss), arch
        assert float(loss) > 0
        logits, _, _ = m.forward(params, batch["tokens"], vision=batch.get("vision"))
        expect = (2, 16, cfg.audio.n_codebooks, cfg.vocab_size) if cfg.audio \
            else (2, 16, cfg.vocab_size)
        assert logits.shape == expect
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_reduces_loss(self, arch, rng):
        from repro.optim import AdamWConfig
        from repro.train import TrainConfig, init_train_state, make_train_step

        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        step = jax.jit(make_train_step(m, AdamWConfig(lr=5e-3, schedule=None)))
        state = init_train_state(m, rng, AdamWConfig(lr=5e-3))
        batch = batch_for(cfg, rng)  # fixed batch: loss must drop
        losses = []
        for _ in range(5):
            state, metrics = step(state, batch)
            losses.append(float(metrics["total_loss"]))
        assert losses[-1] < losses[0], (arch, losses)

    def test_decode_matches_forward(self, arch, rng):
        cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
        m = build_model(cfg)
        params = m.init(rng)
        B, S, t0 = 2, 12, 8
        batch = batch_for(cfg, rng, B, S)
        tokens = batch["tokens"]
        vision = batch.get("vision")
        logits_full, _, _ = m.forward(params, tokens, vision=vision)
        _, cache = m.prefill(params, tokens[:, :t0], max_len=S + 4, vision=vision)
        for t in range(t0, S):
            lg, cache = m.decode_step(params, tokens[:, t : t + 1], cache)
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(logits_full[:, t]), atol=5e-4,
                err_msg=f"{arch} step {t}",
            )


@pytest.mark.parametrize("arch", all_archs())
def test_param_count_matches_defs(arch):
    """configs/base.py closed-form param_count == declared ParamDef tree."""
    cfg = get_config(arch)
    m = build_model(cfg)
    assert m.param_count() == cfg.param_count(), arch


def test_assigned_table_dimensions():
    """The 10 configs carry exactly the assigned architecture table."""
    expect = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }
    for arch, (L, d, H, KV, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab_size == V, arch
        if cfg.family != "ssm":
            assert cfg.n_heads == H and cfg.n_kv_heads == KV, arch
        if cfg.family not in ("ssm",):
            assert cfg.d_ff == ff, arch
    # MoE / SSM extras
    k2 = get_config("kimi-k2-1t-a32b").moe
    assert (k2.n_experts, k2.top_k) == (384, 8)
    l4 = get_config("llama4-scout-17b-a16e").moe
    assert (l4.n_experts, l4.top_k) == (16, 1)
    assert get_config("falcon-mamba-7b").ssm.d_state == 16
    assert get_config("zamba2-1.2b").ssm.d_state == 64


def test_cell_grid_is_40_with_documented_skips():
    from repro.configs import all_cells

    cells = all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2] != "run"]
    assert len(skips) == 8  # long_500k x 8 full-attention archs
    assert all(c[1] == "long_500k" for c in skips)
    runs = {(a, s) for a, s, st in cells if st == "run"}
    assert ("falcon-mamba-7b", "long_500k") in runs
    assert ("zamba2-1.2b", "long_500k") in runs


def test_kimi_param_count_is_a_trillion():
    cfg = get_config("kimi-k2-1t-a32b")
    n = cfg.param_count()
    assert 0.9e12 < n < 1.3e12, n
    active = cfg.active_param_count()
    assert 25e9 < active < 40e9, active
