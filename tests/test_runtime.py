"""Version-adaptive runtime layer: API-spelling resolution under monkeypatch
(TPUCompilerParams/CompilerParams present or absent, jax.shard_map present or
absent), interpret-mode auto-fallback, keyword adaptation, block clamping."""
import functools
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import runtime


class NewStyleParams:
    def __init__(self, dimension_semantics=None):
        self.dimension_semantics = dimension_semantics


class OldStyleParams:
    def __init__(self, dimension_semantics=None):
        self.dimension_semantics = dimension_semantics


class TestCompilerParams:
    def test_prefers_new_spelling(self, monkeypatch):
        fake = SimpleNamespace(CompilerParams=NewStyleParams, TPUCompilerParams=OldStyleParams)
        monkeypatch.setattr(runtime, "pltpu", fake)
        p = runtime.tpu_compiler_params(dimension_semantics=("parallel",))
        assert isinstance(p, NewStyleParams)
        assert p.dimension_semantics == ("parallel",)

    def test_falls_back_to_old_spelling(self, monkeypatch):
        fake = SimpleNamespace(TPUCompilerParams=OldStyleParams)
        monkeypatch.setattr(runtime, "pltpu", fake)
        p = runtime.tpu_compiler_params(dimension_semantics=("arbitrary",))
        assert isinstance(p, OldStyleParams)

    def test_neither_spelling_returns_none(self, monkeypatch):
        monkeypatch.setattr(runtime, "pltpu", SimpleNamespace())
        assert runtime.tpu_compiler_params(dimension_semantics=("parallel",)) is None

    def test_no_tpu_module_returns_none(self, monkeypatch):
        monkeypatch.setattr(runtime, "pltpu", None)
        assert runtime.tpu_compiler_params(dimension_semantics=("parallel",)) is None

    def test_unknown_kwargs_dropped(self, monkeypatch):
        fake = SimpleNamespace(CompilerParams=NewStyleParams)
        monkeypatch.setattr(runtime, "pltpu", fake)
        p = runtime.tpu_compiler_params(
            dimension_semantics=("parallel",), serial_iteration_hints=123
        )
        assert isinstance(p, NewStyleParams)

    def test_real_install_resolves(self):
        # whatever JAX is installed, one of the two spellings must resolve
        p = runtime.tpu_compiler_params(dimension_semantics=("parallel",))
        assert p is not None


class TestShardMapResolution:
    def test_prefers_stable_spelling(self, monkeypatch):
        sentinel = lambda *a, **k: "stable"  # noqa: E731
        monkeypatch.setattr(jax, "shard_map", sentinel, raising=False)
        assert runtime.resolve_shard_map() is sentinel

    def test_falls_back_to_experimental(self, monkeypatch):
        # ensure the stable spelling is truly absent, then expect the
        # experimental module's entry point
        monkeypatch.delattr(jax, "shard_map", raising=False)
        fn = runtime.resolve_shard_map()
        from jax.experimental.shard_map import shard_map as legacy

        assert fn is legacy

    def test_spmd_map_adapts_check_rep_keyword(self, monkeypatch):
        seen = {}

        def fake_sm(f, *, mesh, in_specs, out_specs, check_rep=True):
            seen.update(mesh=mesh, check_rep=check_rep)
            return f

        monkeypatch.setattr(jax, "shard_map", fake_sm, raising=False)
        body = lambda x: x  # noqa: E731
        out = runtime.spmd_map(body, mesh="M", in_specs=(), out_specs=(), check=False)
        assert out is body
        assert seen == {"mesh": "M", "check_rep": False}

    def test_spmd_map_adapts_check_vma_keyword(self, monkeypatch):
        seen = {}

        def fake_sm(f, *, mesh, in_specs, out_specs, check_vma=True):
            seen.update(check_vma=check_vma)
            return f

        monkeypatch.setattr(jax, "shard_map", fake_sm, raising=False)
        runtime.spmd_map(lambda x: x, mesh="M", in_specs=(), out_specs=(), check=True)
        assert seen == {"check_vma": True}

    def test_spmd_map_warns_when_check_kw_unadaptable(self, monkeypatch):
        def fake_sm(f, *, mesh, in_specs, out_specs):  # a third rename: no check kw
            return f

        monkeypatch.setattr(jax, "shard_map", fake_sm, raising=False)
        with pytest.warns(RuntimeWarning, match="check=False could not be forwarded"):
            runtime.spmd_map(lambda x: x, mesh="M", in_specs=(), out_specs=(), check=False)

    def test_missing_everywhere_raises(self, monkeypatch):
        monkeypatch.delattr(jax, "shard_map", raising=False)
        import jax.experimental.shard_map as sm_mod

        monkeypatch.delattr(sm_mod, "shard_map", raising=False)
        assert runtime.resolve_shard_map() is None
        with pytest.raises(RuntimeError, match="shard-map"):
            runtime.spmd_map(lambda x: x, mesh=None, in_specs=(), out_specs=())


class TestDispatch:
    def test_auto_interpret_tracks_backend(self, monkeypatch):
        monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
        assert runtime.auto_interpret() is True
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        assert runtime.auto_interpret() is False

    @pytest.mark.parametrize("backend,expect_interpret", [("cpu", True), ("tpu", False)])
    def test_dragon_pallas_call_mode_selection(self, monkeypatch, backend, expect_interpret):
        captured = {}

        def fake_pallas_call(kernel, **kwargs):
            captured.update(kwargs)
            return lambda *operands: None

        monkeypatch.setattr(jax, "default_backend", lambda: backend)
        monkeypatch.setattr(runtime.pl, "pallas_call", fake_pallas_call)
        runtime.dragon_pallas_call(
            lambda x_ref, o_ref: None,
            grid=(1,),
            in_specs=[],
            out_specs=None,
            out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
            dimension_semantics=("parallel",),
        )()
        assert captured["interpret"] is expect_interpret
        assert captured["compiler_params"] is not None
        assert captured["compiler_params"].dimension_semantics == ("parallel",)

    def test_dragon_pallas_call_omits_params_when_unresolvable(self, monkeypatch):
        captured = {}

        def fake_pallas_call(kernel, **kwargs):
            captured.update(kwargs)
            return lambda *operands: None

        monkeypatch.setattr(runtime.pl, "pallas_call", fake_pallas_call)
        monkeypatch.setattr(runtime, "pltpu", None)
        runtime.dragon_pallas_call(
            lambda x_ref, o_ref: None,
            grid=(1,),
            in_specs=[],
            out_specs=None,
            out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
            dimension_semantics=("parallel",),
            interpret=True,
        )()
        assert "compiler_params" not in captured

    def test_vmem_scratch_without_tpu_module_raises_descriptively(self, monkeypatch):
        monkeypatch.setattr(runtime, "pltpu", None)
        with pytest.raises(RuntimeError, match="no portable scratch spelling"):
            runtime.vmem_scratch((4, 4), jnp.float32)

    def test_end_to_end_interpret_kernel(self):
        """A real (tiny) kernel through the seam in interpret mode."""
        from jax.experimental import pallas as pl

        def double(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        y = runtime.dragon_pallas_call(
            double,
            grid=(2,),
            in_specs=[pl.BlockSpec((1, 4), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, 4), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            dimension_semantics=("parallel",),
            interpret=None,  # auto: CPU backend -> interpret
        )(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2.0)

    def test_spmd_map_end_to_end(self):
        """Real shard-map through the seam on the 1-device CPU mesh."""
        from jax.sharding import PartitionSpec as P

        mesh = jax.make_mesh((1,), ("data",))
        fn = runtime.spmd_map(
            functools.partial(jax.lax.psum, axis_name="data"),
            mesh=mesh,
            in_specs=P("data"),
            out_specs=P(),
            check=False,
        )
        x = jnp.ones((4,), jnp.float32)
        np.testing.assert_allclose(np.asarray(fn(x)), np.ones(4))


class TestBlockClamping:
    def test_clamp_block(self):
        assert runtime.clamp_block(512, 128) == 128
        assert runtime.clamp_block(64, 128) == 64

    def test_clamp_block_rejects_non_tiling(self):
        with pytest.raises(ValueError, match="block_q"):
            runtime.clamp_block(128, 300, name="block_q")

    def test_gcd_block_always_tiles(self):
        for block, size in [(128, 300), (128, 128), (7, 13), (1000, 4)]:
            b = runtime.gcd_block(block, size)
            assert b >= 1 and size % b == 0
