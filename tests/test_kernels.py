"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArchParams, TechParams, specialize
from repro.kernels import (
    flash_attention,
    pack_chw,
    pack_graph,
    popsim,
    ref,
    ssd_chunk_scan,
)
from repro.models.layers import chunked_attention, decode_attention
from repro.workloads import get_workload


def _qkv(key, B, Hq, Hkv, Sq, Skv, D, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Hq, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    return q, k, v


SHAPES = [
    # B, Hq, Hkv, Sq, Skv, D, block
    (1, 4, 4, 128, 128, 64, 64),     # MHA
    (2, 8, 2, 256, 256, 64, 128),    # GQA 4:1
    (1, 8, 1, 128, 128, 32, 64),     # MQA
    (2, 4, 4, 64, 256, 64, 64),      # cross/suffix window
]


class TestFlashAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,blk", SHAPES)
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, rng, B, Hq, Hkv, Sq, Skv, D, blk, causal):
        q, k, v = _qkv(rng, B, Hq, Hkv, Sq, Skv, D, jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk)
        expect = ref.reference_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_dtypes(self, rng, dtype):
        q, k, v = _qkv(rng, 1, 4, 2, 128, 128, 64, dtype)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        expect = ref.reference_attention(q, k, v, causal=True)
        assert out.dtype == dtype
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
        )


class TestChunkedAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,blk", SHAPES)
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, rng, B, Hq, Hkv, Sq, Skv, D, blk, causal):
        q, k, v = _qkv(rng, B, Hq, Hkv, Sq, Skv, D, jnp.float32)
        out = chunked_attention(q, k, v, causal=causal, block_q=blk, block_k=blk)
        expect = ref.reference_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    def test_custom_vjp_matches_autodiff(self, rng):
        q, k, v = _qkv(rng, 2, 4, 2, 128, 128, 32, jnp.float32)
        do = jax.random.normal(rng, q.shape)

        g1 = jax.grad(
            lambda q, k, v: jnp.vdot(
                chunked_attention(q, k, v, causal=True, block_q=64, block_k=64), do
            ),
            argnums=(0, 1, 2),
        )(q, k, v)
        g2 = jax.grad(
            lambda q, k, v: jnp.vdot(ref.reference_attention(q, k, v, causal=True), do),
            argnums=(0, 1, 2),
        )(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)

    def test_decode_attention_masks_by_length(self, rng):
        q, k, v = _qkv(rng, 2, 4, 2, 1, 64, 32, jnp.float32)
        lens = jnp.array([13, 64])
        out = decode_attention(q, k, v, lens)
        for b, L in enumerate([13, 64]):
            expect = ref.reference_attention(
                q[b : b + 1], k[b : b + 1, :, :L], v[b : b + 1, :, :L], causal=False
            )
            np.testing.assert_allclose(np.asarray(out[b]), np.asarray(expect[0]), atol=2e-5)


class TestSSD:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (1, 64, 2, 16, 8, 16),
        (2, 128, 4, 32, 16, 32),
        (1, 32, 1, 64, 4, 8),
    ])
    def test_matches_recurrence(self, rng, B, S, H, P, N, chunk):
        ks = jax.random.split(rng, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)))
        Bm = jax.random.normal(ks[3], (B, S, N))
        C = jax.random.normal(ks[4], (B, S, N))
        y, state = ssd_chunk_scan(x, dt, A, Bm, C, chunk=chunk)
        y_ref, state_ref = ref.ssd_reference(x, dt, A, Bm, C)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref), atol=1e-4)


class TestPopsim:
    def test_matches_reference_on_real_workloads(self):
        chw = specialize(TechParams.default(), ArchParams.default())
        for wl in ("lstm", "dlrm"):
            g = get_workload(wl)
            gp, cp = pack_graph(g), pack_chw(chw)
            out = popsim(gp, cp)
            expect = ref.popsim_reference(gp, cp)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-3
            )

    def test_population_batch(self, rng):
        import dataclasses

        scales = jnp.linspace(0.5, 2.0, 8)
        chws = jax.vmap(
            lambda s: specialize(
                dataclasses.replace(
                    TechParams.default(),
                    cell_read_latency=TechParams.default().cell_read_latency * s,
                ),
                ArchParams.default(),
            )
        )(scales)
        g = get_workload("lstm")
        gp, cp = pack_graph(g), pack_chw(chws)
        out = popsim(gp, cp, block_pop=4)
        expect = ref.popsim_reference(gp, cp)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-3)
        # runtime (col 0) monotone in latency scale
        assert bool(jnp.all(jnp.diff(out[:, 0]) >= -1e-3))


class TestSelectiveScanKernel:
    @pytest.mark.parametrize("B,S,C,N,chunk,bc", [
        (1, 32, 16, 8, 8, 16),
        (2, 64, 32, 16, 16, 16),
        (1, 128, 8, 4, 32, 8),
    ])
    def test_matches_chunked_oracle(self, rng, B, S, C, N, chunk, bc):
        from repro.kernels import selective_scan as ss_kernel
        from repro.models.mamba import selective_scan as ss_oracle

        ks = jax.random.split(rng, 6)
        u = jax.random.normal(ks[0], (B, S, C))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, C)))
        A = -jnp.exp(jax.random.normal(ks[2], (C, N)))
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(ks[4], (B, S, N))
        D = jax.random.normal(ks[5], (C,))
        y = ss_kernel(u, dt, A, Bm, Cm, D, chunk=chunk, block_c=bc)
        y_ref, _ = ss_oracle(u, dt, A, Bm, Cm, D, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
