import os
import sys

# tests must see the real (1-device) CPU platform — the 512-device flag is
# reserved for launch/dryrun.py. Keep determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test (full smoke matrix)")
