"""Façade behaviour: Session parity with the engines + the compiled-program
cache contract (tier-1).

Parity: every Session method must be numerically identical to the direct
engine call it wraps, evaluated on the same bucketed workload stack — the
engine layer is the oracle.  Cache: warm same-bucket calls must trigger
zero new traces (counted via the trace-side-effect probe in
repro.core.instrument, not inferred from wall time), and a changed
objective mix / design point must reuse the compiled program (weights and
parameters are traced arguments).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Architecture, Session, Workload
from repro.core import instrument
from repro.core.dhdl import load_arch, parse_arch
from repro.core.dopt import optimize
from repro.core.dsim import simulate, simulate_stacked
from repro.core.graph import Graph
from repro.core.mapper import MapperCfg
from repro.core.params import ArchParams, ArchSpec, TechParams
from repro.core.popsim import pareto_dse
from repro.workloads import get_workload


# --------------------------------------------------------------------------- #
# Workload / Architecture construction + validation
# --------------------------------------------------------------------------- #


class TestWorkload:
    def test_bucketing_pow2_min32(self):
        assert Workload("lstm").bucket == (1, 32)  # 9 vertices -> 32
        assert Workload("bert_base").bucket == (1, 128)  # 109 -> 128
        assert Workload(["lstm", "merge_sort"]).bucket == (2, 32)

    def test_same_bucket_same_structure(self):
        a, b = Workload("lstm").stacked, Workload("merge_sort").stacked
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        assert [x.shape for x in la] == [x.shape for x in lb]
        assert jax.tree.structure(a) == jax.tree.structure(b)  # names stripped

    def test_sources(self):
        g = get_workload("lstm")
        assert Workload(g).n_workloads == 1
        assert Workload([g, "dlrm"]).labels == ("workload0", "dlrm")
        w = Workload(["lstm"])
        assert Workload(w).labels == w.labels

    def test_validation(self):
        with pytest.raises(ValueError):
            Workload([])
        with pytest.raises((KeyError, TypeError)):
            Workload("no_such_workload")
        g = get_workload("lstm")
        import dataclasses

        bad = dataclasses.replace(g, n_read=g.n_read.at[0, 0].set(-1.0))
        with pytest.raises(ValueError, match="finite and >= 0"):
            Workload(bad)
        stacked = Graph.stack([g, g])
        with pytest.raises(ValueError, match="already stacked"):
            Workload(stacked)

    def test_padding_is_exact(self):
        g = get_workload("lstm")
        w = Workload(g)
        tech, arch = TechParams.default(), ArchParams.default()
        padded = simulate_stacked(tech, arch, w.stacked)
        raw = simulate(tech, arch, g, mcfg=MapperCfg(scan_impl="assoc"))
        np.testing.assert_allclose(
            np.asarray(padded.cycles)[0], np.asarray(raw.cycles), rtol=1e-6
        )


class TestArchitecture:
    def test_one_constructor_all_spellings(self):
        lib = Architecture("edge")
        ca = load_arch("edge")
        txt = Architecture(lib.to_dhd())
        raw = Architecture(tech=ca.tech, arch=ca.arch, spec=ca.spec, name="edge")
        for other in (Architecture(ca), txt, raw):
            for a, b in zip(jax.tree.leaves((lib.tech, lib.arch)), jax.tree.leaves((other.tech, other.arch))):
                assert np.array_equal(np.asarray(a), np.asarray(b))
        assert lib.spec == txt.spec == raw.spec

    def test_to_dhd_roundtrip(self):
        a = Architecture("datacenter")
        again = Architecture(a.to_dhd())
        for x, y in zip(jax.tree.leaves((a.tech, a.arch)), jax.tree.leaves((again.tech, again.arch))):
            assert np.array_equal(np.asarray(x), np.asarray(y))

    def test_validation(self):
        import dataclasses

        bad = dataclasses.replace(ArchParams.default(), frequency=jnp.float32(-1.0))
        with pytest.raises(ValueError, match="non-positive"):
            Architecture(arch=bad)
        with pytest.raises(TypeError):
            Architecture(123)

    def test_names_sanitized_to_dhd_identifiers(self):
        # every Architecture must serialize to parseable text, whatever the
        # display name — "scale-sim 32x32" would break the .dhd grammar
        a = Architecture("base", name="scale-sim 32x32")
        assert a.name == "scale_sim_32x32"
        assert Architecture(a.to_dhd()).name == a.name  # text round-trips
        assert Architecture("base", name="4chip").name == "_4chip"


# --------------------------------------------------------------------------- #
# parity with the engine oracle
# --------------------------------------------------------------------------- #


class TestParity:
    def test_simulate_identical_to_engine(self):
        w = Workload(["lstm", "bert_base"])
        a = Architecture("edge")
        sess = Session(a)
        perfs = sess.perf(w)
        # oracle: the jitted engine call on the identical bucketed stack
        oracle = jax.jit(
            lambda t, ar, g: simulate_stacked(t, ar, g, a.spec, MapperCfg())
        )(a.tech, a.arch, w.stacked)
        for got, want in zip(jax.tree.leaves(perfs), jax.tree.leaves(oracle)):
            assert np.array_equal(np.asarray(got), np.asarray(want))
        # and the report repeats the same numbers
        rep = sess.simulate(w)
        np.testing.assert_allclose(
            [wr.runtime_s for wr in rep.workloads], np.asarray(oracle.runtime), rtol=1e-6
        )
        # unpadded per-workload engine calls agree to float tolerance
        for wr, g in zip(rep.workloads, w.graphs):
            direct = simulate(a.tech, a.arch, g, a.spec, MapperCfg(scan_impl="assoc"))
            np.testing.assert_allclose(wr.runtime_s, float(direct.runtime), rtol=1e-5)
            np.testing.assert_allclose(wr.energy_j, float(direct.energy), rtol=1e-5)

    def test_optimize_identical_to_engine(self):
        w = Workload(["lstm", "dlrm"])
        sess = Session("base")
        res = sess.optimize(w, objective="edp", steps=8, lr=0.05)
        oracle = optimize(w.stacked, objective="edp", steps=8, lr=0.05)
        import math

        np.testing.assert_array_equal(
            [math.exp(v) for v in oracle.history["objective"]], np.asarray(res.objective_history)
        )
        assert [n for n, _ in oracle.importance] == [
            a.parameter.removeprefix("tech.") for a in res.importance
        ]
        # the serialized design is the oracle's design, bit for bit
        ca = parse_arch(res.to_dhd())
        for got, want in zip(
            jax.tree.leaves((ca.tech, ca.arch)), jax.tree.leaves((oracle.tech, oracle.arch))
        ):
            assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_frontier_identical_to_engine(self):
        w = Workload("lstm")
        sess = Session()
        fr = sess.frontier(w, population=6, steps=3, key=0)
        oracle = pareto_dse(w.stacked, population=6, steps=3, key=0)
        assert len(fr.front) == int(oracle.front.size)
        assert fr.hypervolume == pytest.approx(oracle.hypervolume)
        for p, win in zip(fr.front, oracle.winners):
            assert p.dhd == win["dhd"]
            assert p.time_s == win["time_s"]

    def test_explain_matches_direct_gradient(self):
        w = Workload("lstm")
        a = Architecture("base")
        rep = Session(a).explain(w, objective="edp")
        assert rep.objective == "edp"
        # oracle elasticity for the named tech parameters
        from repro.core.dopt import _flatten_tech, from_log, tech_param_names, to_log

        tz = to_log(a.tech)
        g = jax.grad(
            lambda tz: jnp.mean(
                jnp.log(
                    simulate_stacked(from_log(tz), a.arch, w.stacked, a.spec).edp
                )
            )
        )(tz)
        want = {f"tech.{n}": float(v) for n, v in zip(tech_param_names(), np.asarray(_flatten_tech(g)))}
        got = {at.parameter: at.elasticity for at in rep.attribution if at.parameter.startswith("tech.")}
        for k, v in want.items():
            np.testing.assert_allclose(got[k], v, rtol=1e-4, atol=1e-7)

    def test_report_breakdowns_consistent(self):
        rep = Session("edge").simulate(["lstm", "bert_base"])
        for wr in rep.workloads:
            # per-vertex times sum to the runtime; energies to the total
            np.testing.assert_allclose(
                sum(v.time_s for v in wr.vertices), wr.runtime_s, rtol=1e-4
            )
            np.testing.assert_allclose(
                sum(v.energy_j for v in wr.vertices), wr.energy_j, rtol=1e-4
            )
            # per-level + per-class energies cover the total exactly
            total = sum(l.dynamic_energy_j + l.leakage_energy_j for l in wr.levels) + sum(
                c.dynamic_energy_j + c.leakage_energy_j for c in wr.compute
            )
            np.testing.assert_allclose(total, wr.energy_j, rtol=1e-4)
        import json

        parsed = json.loads(rep.to_json())
        assert parsed["architecture"] == "edge"
        assert len(parsed["workloads"]) == 2


# --------------------------------------------------------------------------- #
# the compiled-program cache contract
# --------------------------------------------------------------------------- #


class TestCache:
    def test_warm_same_bucket_zero_retrace(self):
        """The serving pattern: after the first call, same-bucket queries —
        same workload, different workload, different design point — replay
        the compiled programs with zero new traces."""
        sess = Session("base")
        sess.simulate("lstm")  # cold: compiles
        t0 = sess.stats.traces
        assert t0 >= 1
        sess.simulate("lstm")  # warm, identical
        sess.simulate("merge_sort")  # warm: same (1, 32) bucket, new workload
        sess.simulate("dlrm", architecture=Architecture("edge"))  # new design point
        assert sess.stats.traces == t0, "warm same-bucket simulate retraced"
        assert sess.stats.hits >= 3  # one report program, three warm calls
        # a new bucket is a genuine miss and compiles once more
        sess.simulate("bert_base")  # (1, 128)
        t1 = sess.stats.traces
        assert t1 > t0
        sess.simulate("bert_base")
        assert sess.stats.traces == t1

    def test_changed_objective_mix_reuses_program(self):
        """Weights/budgets are traced args (PR 4): switching the mix — or the
        budgets — must not retrace the DOpt step."""
        sess = Session("base")
        w = Workload(["lstm", "dlrm"])
        sess.optimize(w, objective="mixed", objective_weights=[1.0, 0.0, 0.0, 0.0], steps=4)
        before = instrument.trace_count("dopt._dopt_step")
        r2 = sess.optimize(
            w,
            objective="mixed",
            objective_weights=[0.0, 1.0, 0.0, 0.0],
            area_budget=900.0,
            penalty_weight=2.0,
            steps=4,
        )
        assert instrument.trace_count("dopt._dopt_step") == before, (
            "changed objective mix retraced the DOpt step"
        )
        assert r2.epochs == 4

    def test_warm_optimize_zero_retrace_across_workloads(self):
        sess = Session("base")
        sess.optimize("lstm", steps=4)
        before = instrument.trace_count("dopt._dopt_step")
        sess.optimize("merge_sort", steps=4)  # same bucket (1->32)
        assert instrument.trace_count("dopt._dopt_step") == before
        assert sess.stats.hits >= 1

    def test_explain_program_cached(self):
        sess = Session("base")
        sess.explain("lstm")
        t0 = sess.stats.traces
        sess.explain("merge_sort")  # same bucket
        assert sess.stats.traces == t0
        sess.explain("lstm", objective="time")  # new objective signature
        assert sess.stats.traces > t0

    def test_sessions_do_not_share_stats(self):
        s1, s2 = Session("base"), Session("base")
        s1.simulate("lstm")
        assert s2.stats.traces == 0 and s2.stats.programs == 0
