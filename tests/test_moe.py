"""MoE dispatch correctness: scatter path, shard_map path, capacity drops."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis

from repro.models.moe import (
    distributed_cumsum,
    moe_capacity,
    moe_ffn,
    moe_ffn_dense_ref,
)


def make_inputs(key, T=64, d=16, E=8, f=32):
    ks = jax.random.split(key, 5)
    return (
        jax.random.normal(ks[0], (T, d)),
        jax.random.normal(ks[1], (d, E)) * 0.1,
        jax.random.normal(ks[2], (E, d, f)) * 0.1,
        jax.random.normal(ks[3], (E, d, f)) * 0.1,
        jax.random.normal(ks[4], (E, f, d)) * 0.1,
    )


class TestScatterPath:
    @pytest.mark.parametrize("top_k", [1, 2, 4])
    def test_matches_dense_ref_when_no_drops(self, rng, top_k):
        x, rw, wg, wu, wd = make_inputs(rng)
        out = moe_ffn(x, rw, wg, wu, wd, top_k=top_k, capacity_factor=8.0, cumsum_blocks=4)
        y_ref = moe_ffn_dense_ref(x, rw, wg, wu, wd, top_k=top_k)
        np.testing.assert_allclose(np.asarray(out.y), np.asarray(y_ref), atol=1e-5)
        assert float(out.dropped_frac) == 0.0

    def test_aux_loss_uniform_router_is_one(self, rng):
        x, _, wg, wu, wd = make_inputs(rng)
        rw = jnp.zeros((16, 8))  # uniform router
        x = jax.random.normal(rng, (64, 16))
        out = moe_ffn(x, rw, wg, wu, wd, top_k=2, capacity_factor=8.0, cumsum_blocks=4)
        # perfectly balanced switch loss == 1
        assert float(out.aux_loss) == pytest.approx(1.0, rel=0.05)

    def test_grads_flow_to_router_and_experts(self, rng):
        x, rw, wg, wu, wd = make_inputs(rng)

        def loss(rw, wg):
            return jnp.sum(
                moe_ffn(x, rw, wg, wu, wd, top_k=2, capacity_factor=8.0, cumsum_blocks=4).y ** 2
            )

        g_rw, g_wg = jax.grad(loss, argnums=(0, 1))(rw, wg)
        assert float(jnp.abs(g_rw).sum()) > 0
        assert float(jnp.abs(g_wg).sum()) > 0


class TestCapacity:
    def test_capacity_formula(self):
        assert moe_capacity(1024, 8, 2, 1.0) == 256
        assert moe_capacity(1024, 8, 2, 1.25) == 384  # 320 rounded up to 128
        assert moe_capacity(4, 384, 8, 1.25, multiple=4) >= 4

    @settings(max_examples=20, deadline=None)
    @given(blocks=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 100))
    def test_distributed_cumsum_exact(self, blocks, seed):
        e = jax.random.randint(jax.random.PRNGKey(seed), (64,), 0, 8)
        onehot = jax.nn.one_hot(e, 8)
        got = distributed_cumsum(onehot, blocks)
        want = jnp.cumsum(onehot, axis=0) - onehot
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


SHARDMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import jax, jax.numpy as jnp, numpy as np
    import sys
    sys.path.insert(0, 'src')
    from repro.models.moe import moe_ffn_shardmap, moe_ffn_dense_ref
    mesh = jax.make_mesh((4, 2), ('data', 'model'))
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    T, d, E, f, topk = 64, 16, 8, 32, 2
    x = jax.random.normal(ks[0], (T, d))
    rw = jax.random.normal(ks[1], (d, E)) * 0.1
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.1
    with mesh:
        out = jax.jit(lambda *a: moe_ffn_shardmap(
            *a, top_k=topk, capacity_factor=8.0, mesh=mesh,
            fsdp_axes=('data',), compute_dtype=jnp.float32))(x, rw, wg, wu, wd)
        g = jax.jit(jax.grad(lambda wg: moe_ffn_shardmap(
            x, rw, wg, wu, wd, top_k=topk, capacity_factor=8.0, mesh=mesh,
            fsdp_axes=('data',), compute_dtype=jnp.float32).y.sum()))(wg)
    y_ref = moe_ffn_dense_ref(x, rw, wg, wu, wd, top_k=topk)
    err = float(jnp.max(jnp.abs(out.y - y_ref)))
    assert err < 1e-5, err
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).sum()) > 0
    print('SHARDMAP_OK', err)
""")


@pytest.mark.slow
def test_shardmap_path_on_8_devices():
    """The expert-parallel shard_map path (used at scale) equals the dense
    oracle on a real 4x2 device mesh (subprocess: needs own XLA_FLAGS)."""
    r = subprocess.run([sys.executable, "-c", SHARDMAP_SCRIPT], capture_output=True,
                       text=True, cwd=".", timeout=300)
    assert "SHARDMAP_OK" in r.stdout, r.stdout + r.stderr
