"""Sharding-spec derivation properties (repair, relocation, FSDP policy)."""
from types import SimpleNamespace

import jax
import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis
from jax.sharding import PartitionSpec as P

from repro.configs import all_archs, get_config
from repro.models import build_model
from repro.models import defs as D
from repro.models.sharding import logical_to_spec, param_specs, repair_spec


def fake_mesh(data=16, model=16, pod=None):
    shape = {}
    if pod:
        shape["pod"] = pod
    shape.update({"data": data, "model": model})
    return SimpleNamespace(shape=shape, axis_names=tuple(shape))


def nshards(mesh, entry):
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape.get(a, 1)
    return n


class TestRepairSpec:
    def test_drops_nondividing(self):
        m = fake_mesh()
        spec = repair_spec(P("model"), (40,), m)
        assert spec[0] is None or 40 % nshards(m, spec[0]) == 0

    def test_relocates_to_free_dim(self):
        m = fake_mesh()
        # vocab 49155 not divisible by 16 -> model moves to d (4096)
        spec = repair_spec(P(None, "model", None), (1, 49155, 4096), m)
        assert spec[1] is None
        assert spec[2] == "model"

    def test_no_relocate_for_head_dims(self):
        m = fake_mesh()
        spec = repair_spec(P(None, "model", None), (4096, 40, 128), m,
                           axes_names=("embed", "heads", None))
        assert tuple(spec) == (None, None, None)

    @settings(max_examples=60, deadline=None)
    @given(
        dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
        data=st.sampled_from([2, 4, 16]),
        model=st.sampled_from([2, 8, 16]),
        which=st.integers(0, 3),
    )
    def test_result_always_valid(self, dims, data, model, which):
        """Repaired spec always divides and never reuses a mesh axis."""
        m = fake_mesh(data=data, model=model)
        entries = [None] * len(dims)
        entries[which % len(dims)] = "model"
        if len(dims) > 1:
            entries[(which + 1) % len(dims)] = "data"
        spec = repair_spec(P(*entries), tuple(dims), m)
        used = []
        for e, dim in zip(tuple(spec) + (None,) * len(dims), dims):
            assert dim % nshards(m, e) == 0
            if e is not None:
                names = e if isinstance(e, tuple) else (e,)
                used += list(names)
        assert len(used) == len(set(used))


class TestParamSpecs:
    @pytest.mark.parametrize("arch", all_archs())
    def test_all_leaf_specs_valid(self, arch):
        """Every param leaf of every arch gets a dividing spec on the
        production mesh shape (this is what makes the dry-run lower)."""
        mesh = fake_mesh(pod=2)
        model = build_model(get_config(arch))
        defs = model.param_defs()
        specs = param_specs(defs, mesh, model.fsdp_axes())
        flat_d = jax.tree.leaves(defs, is_leaf=D.is_def)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        assert len(flat_d) == len(flat_s)
        for d, s in zip(flat_d, flat_s):
            entries = tuple(s) + (None,) * (len(d.shape) - len(tuple(s)))
            for e, dim in zip(entries, d.shape):
                assert dim % nshards(mesh, e) == 0, (arch, d.shape, s)

    def test_fsdp_policy(self):
        assert build_model(get_config("kimi-k2-1t-a32b")).fsdp_axes() == ("data", "pod")
        assert build_model(get_config("granite-3-8b")).fsdp_axes() == ("data",)

    def test_big_tensors_are_sharded_on_production_mesh(self):
        """No >256MB fp32 leaf may end up fully replicated (HBM discipline)."""
        mesh = fake_mesh()
        import numpy as np

        for arch in all_archs():
            model = build_model(get_config(arch))
            defs = model.param_defs()
            specs = param_specs(defs, mesh, model.fsdp_axes())
            for d, s in zip(
                jax.tree.leaves(defs, is_leaf=D.is_def),
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
            ):
                size = int(np.prod(d.shape)) * 4
                if size > 256 * 2**20:
                    assert any(e is not None for e in tuple(s)), (arch, d.shape, s)


class TestLogicalMapping:
    def test_tp_dims(self):
        ax = ("data", "model")
        assert tuple(logical_to_spec(("vocab", "embed"), ax, ("data",))) == ("model", "data")
        assert tuple(logical_to_spec(("layers", "embed", "ff"), ax, ())) == (None, None, "model")

    def test_missing_axes_dropped(self):
        spec = logical_to_spec(("batch", None), ("x", "y"), ())
        assert tuple(spec) == (None, None)
