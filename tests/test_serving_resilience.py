"""Fault-contained design serving: taxonomy, retry/deadline/breaker policy,
non-finite containment, the seeded chaos harness, and the engine-level
NaN-rollback guards (docs/serving.md)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import popsim
from repro.core.dopt import optimize
from repro.ft.straggler import StragglerMonitor
from repro.serving import (
    ChaosConfig,
    ChaosInjector,
    CircuitBreaker,
    ClientError,
    DeadlineConfig,
    DesignQuery,
    DesignService,
    NumericFault,
    RetryPolicy,
    TransientFault,
    classify_exception,
    nonfinite_in,
    run_guarded,
)
from repro.serving.chaos import poison
from repro.workloads import get_workload


class FakeClock:
    """Deterministic time source: sleep() advances the clock."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.sleeps.append(s)
        self.t += s


# --------------------------------------------------------------------------- #
# taxonomy + guarded-call policy (no engine involved)
# --------------------------------------------------------------------------- #


class TestTaxonomy:
    def test_classify_maps_foreign_exceptions(self):
        assert classify_exception(ValueError("x")).code == "client-error"
        assert classify_exception(KeyError("x")).code == "client-error"
        assert classify_exception(FloatingPointError("x")).code == "numeric"
        assert classify_exception(RuntimeError("x")).code == "transient"

    def test_typed_faults_pass_through(self):
        f = TransientFault("boom")
        assert classify_exception(f) is f

    def test_retryable_bits(self):
        assert TransientFault.retryable and NumericFault.retryable
        assert not ClientError.retryable


class TestRunGuarded:
    def _policy(self):
        return RetryPolicy(max_attempts=4, base_s=0.01)

    def test_retry_recovers_with_deterministic_backoff(self):
        clk = FakeClock()
        calls = []

        def fn(attempt):
            calls.append(attempt)
            if attempt < 2:
                raise TransientFault("flaky")
            return "answer"

        pol = self._policy()
        out = run_guarded(fn, policy=pol, deadline_s=10.0, token=42,
                          clock=clk, sleep=clk.sleep, validate=None)
        assert out.ok and out.result == "answer"
        assert out.attempts == 3 and out.retries == 2
        assert calls == [0, 1, 2]
        # backoff schedule is a pure function of (policy, token, retry index)
        assert clk.sleeps == [pol.backoff_s(0, 42), pol.backoff_s(1, 42)]

    def test_backoff_replays_identically(self):
        pol = self._policy()
        a = [pol.backoff_s(i, token=7) for i in range(4)]
        b = [pol.backoff_s(i, token=7) for i in range(4)]
        assert a == b
        assert a != [pol.backoff_s(i, token=8) for i in range(4)]  # jitter keyed on token

    def test_client_error_never_retried(self):
        clk = FakeClock()
        calls = []

        def fn(attempt):
            calls.append(attempt)
            raise ValueError("bad input")

        out = run_guarded(fn, policy=self._policy(), deadline_s=10.0,
                          clock=clk, sleep=clk.sleep, validate=None)
        assert not out.ok and out.fault.code == "client-error"
        assert calls == [0] and clk.sleeps == []

    def test_exhausted_attempts_degrade(self):
        clk = FakeClock()
        out = run_guarded(lambda a: (_ for _ in ()).throw(TransientFault("always")),
                          policy=self._policy(), deadline_s=10.0,
                          clock=clk, sleep=clk.sleep, validate=None)
        assert not out.ok and out.fault.code == "transient"
        assert out.attempts == 4 and len(clk.sleeps) == 3

    def test_late_answer_is_deadline_exceeded(self):
        clk = FakeClock()

        def fn(attempt):
            clk.t += 5.0  # the work itself blows the budget
            return "late"

        out = run_guarded(fn, policy=self._policy(), deadline_s=2.0,
                          clock=clk, sleep=clk.sleep, validate=None)
        assert not out.ok and out.fault.code == "deadline-exceeded"
        assert out.attempts == 1

    def test_backoff_never_burns_exhausted_budget(self):
        # remaining budget cannot cover the pause -> degrade immediately,
        # without sleeping
        clk = FakeClock()
        pol = RetryPolicy(max_attempts=4, base_s=1.0, jitter=0.5)
        out = run_guarded(lambda a: (_ for _ in ()).throw(TransientFault("x")),
                          policy=pol, deadline_s=0.2, clock=clk, sleep=clk.sleep,
                          validate=None)
        assert not out.ok and out.fault.code == "deadline-exceeded"
        assert clk.sleeps == []

    def test_validation_failure_retries_as_numeric(self):
        clk = FakeClock()

        def fn(attempt):
            return "poisoned" if attempt == 0 else "clean"

        out = run_guarded(fn, policy=self._policy(), deadline_s=10.0,
                          clock=clk, sleep=clk.sleep,
                          validate=lambda r: "field" if r == "poisoned" else None)
        assert out.ok and out.result == "clean" and out.attempts == 2

    def test_never_raises(self):
        out = run_guarded(lambda a: (_ for _ in ()).throw(MemoryError("oom")),
                          policy=self._policy(), deadline_s=1.0,
                          clock=FakeClock(), sleep=lambda s: None, validate=None)
        assert not out.ok and out.fault.code == "transient"


class TestDeadlineConfig:
    def test_cold_vs_warm_and_optimize_scale(self):
        d = DeadlineConfig(warm_s=2.0, cold_s=30.0, optimize_scale=4.0)
        assert d.budget_s(cold=True) == 30.0
        assert d.budget_s(cold=False) == 2.0
        assert d.budget_s(cold=False, kind="optimize") == 8.0
        assert d.budget_s(cold=True, kind="frontier") == 120.0


class TestCircuitBreaker:
    def test_trips_cools_down_and_half_open_recovers(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=3, cooldown_s=5.0, clock=clk)
        for _ in range(3):
            assert br.allow("k")
            br.record("k", ok=False)
        assert not br.allow("k")  # open: fast-fail
        clk.t += 6.0
        assert br.allow("k")  # half-open probe
        br.record("k", ok=True)  # probe succeeds -> closed
        assert br.allow("k")
        snap = br.snapshot()["k"]
        assert snap["trips"] == 1 and snap["rejected"] == 1 and not snap["open"]

    def test_failed_probe_reopens(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=2, cooldown_s=5.0, clock=clk)
        br.record("k", ok=False)
        br.record("k", ok=False)
        clk.t += 6.0
        assert br.allow("k")  # probe
        br.record("k", ok=False)  # probe fails -> fresh cooldown
        assert not br.allow("k")

    def test_success_resets_consecutive_count(self):
        br = CircuitBreaker(failure_threshold=2, clock=FakeClock())
        br.record("k", ok=False)
        br.record("k", ok=True)
        br.record("k", ok=False)
        assert br.allow("k")  # never reached 2 consecutive

    def test_keys_are_independent(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=1, cooldown_s=5.0, clock=clk)
        br.record(("simulate", (1, 32)), ok=False)
        assert not br.allow(("simulate", (1, 32)))
        assert br.allow(("explain", (1, 32)))


# --------------------------------------------------------------------------- #
# non-finite containment + chaos schedule (engine results involved)
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def report():
    from repro.api import Session, Workload

    return Session("base").simulate(Workload("lstm"))


class TestNonFiniteContainment:
    def test_clean_report_passes(self, report):
        assert nonfinite_in(report) is None

    def test_poisoned_report_named(self, report):
        assert nonfinite_in(poison(report)) == "area_mm2"

    def test_nan_workload_field_named(self, report):
        wl = dataclasses.replace(report.workloads[0], energy_j=float("nan"))
        bad = dataclasses.replace(report, workloads=(wl, *report.workloads[1:]))
        assert nonfinite_in(bad).endswith(".energy_j")

    def test_infinite_budgets_are_valid(self, report):
        # inf is the canonical spelling of "no budget" — must not be flagged
        assert nonfinite_in(report) is None


class TestChaosInjector:
    CFG = ChaosConfig(seed=99, p_transient=0.5, p_compile_fail=0.3,
                      p_nan=0.4, p_latency=0.3)

    def test_schedule_is_seed_deterministic(self):
        a = ChaosInjector(self.CFG).schedule(range(32))
        b = ChaosInjector(self.CFG).schedule(range(32))
        assert [p.to_json() for p in a] == [p.to_json() for p in b]
        c = ChaosInjector(dataclasses.replace(self.CFG, seed=100)).schedule(range(32))
        assert [p.to_json() for p in a] != [p.to_json() for p in c]

    def test_plan_is_order_independent(self):
        inj = ChaosInjector(self.CFG)
        first = inj.plan(7)
        for q in (3, 11, 0):
            inj.plan(q)
        assert inj.plan(7) == first

    def test_faults_consume_leading_attempts_only(self):
        # any plan clears within min_attempts -- the availability==1.0 gate
        inj = ChaosInjector(self.CFG, sleep=lambda s: None)
        for p in inj.schedule(range(16)):
            assert p.min_attempts <= 4  # depth=1: at most 3 faulted attempts
            for attempt in range(p.min_attempts - 1):
                with pytest.raises(TransientFault):
                    if inj.call(lambda: "clean", qid=p.qid, attempt=attempt) == "clean":
                        raise TransientFault("nan attempts return poisoned, not clean")
            assert inj.call(lambda: "clean", qid=p.qid, attempt=p.min_attempts - 1) == "clean"


# --------------------------------------------------------------------------- #
# the service: isolation, quarantine, breaker degradation, chaos gates
# --------------------------------------------------------------------------- #


def _mixed_queries(n=8):
    kinds = ("simulate", "explain")
    loads = ("lstm", "merge_sort")  # same (1, 32) bucket: warm after 4 colds
    return [DesignQuery(i, kinds[i % 2], loads[(i // 2) % 2]) for i in range(n)]


class TestDesignService:
    def test_per_query_isolation(self):
        svc = DesignService("base")
        queries = [
            DesignQuery(0, "simulate", "lstm"),
            DesignQuery(1, "decompile", "lstm"),  # unknown kind
            DesignQuery(2, "simulate", "no_such_workload"),  # poison intake
            DesignQuery(3, "explain", "lstm"),
        ]
        replies = svc.serve(queries)
        assert [r.qid for r in replies] == [0, 1, 2, 3]
        assert [r.ok for r in replies] == [True, False, False, True]
        assert replies[1].error.code == "client-error"
        assert "decompile" in replies[1].error.message
        assert replies[2].error.code == "client-error"
        st = svc.stats
        assert st.queries == 4 and st.ok == 2 and st.errors == {"client-error": 2}
        assert st.availability == 0.5

    def test_submit_never_raises_even_on_malformed_query(self):
        svc = DesignService("base")
        r = svc.submit(DesignQuery(0, "simulate", object()))  # unresolvable workload
        assert not r.ok and r.error.code == "client-error"

    def test_client_errors_do_not_trip_breaker(self):
        svc = DesignService("base",
                            breaker=CircuitBreaker(failure_threshold=1, cooldown_s=1e9))
        svc.serve([DesignQuery(i, "bogus", "lstm") for i in range(5)])
        assert svc.stats.degraded == 0

    def test_breaker_degrades_after_consecutive_failures(self):
        # depth >= max_attempts: every attempt of every query raises, so the
        # (kind, bucket) lane accumulates consecutive failures and trips
        chaos = ChaosInjector(ChaosConfig(seed=1, p_transient=1.0, depth=8))
        svc = DesignService(
            "base", chaos=chaos,
            retry=RetryPolicy(max_attempts=2, base_s=0.001),
            breaker=CircuitBreaker(failure_threshold=2, cooldown_s=1e9),
        )
        replies = svc.serve([DesignQuery(i, "simulate", "lstm") for i in range(5)])
        assert [r.ok for r in replies] == [False] * 5
        assert [r.error.code for r in replies] == \
            ["transient", "transient", "circuit-open", "circuit-open", "circuit-open"]
        st = svc.stats
        assert st.degraded == 3 and st.errors["circuit-open"] == 3
        (bstate,) = st.breakers.values()
        assert bstate["open"] and bstate["trips"] == 1 and bstate["rejected"] == 3

    def test_transient_chaos_clears_at_full_availability(self):
        chaos = ChaosInjector(
            ChaosConfig(seed=5, p_transient=0.5, p_compile_fail=0.3),
            sleep=lambda s: None,
        )
        svc = DesignService("base", chaos=chaos,
                            retry=RetryPolicy(max_attempts=4, base_s=0.001))
        replies = svc.serve(_mixed_queries(8))
        assert all(r.ok for r in replies)
        assert svc.stats.availability == 1.0
        assert svc.stats.retries > 0  # chaos actually fired

    def test_chaos_replay_is_deterministic(self):
        cfg = ChaosConfig(seed=11, p_transient=0.4, p_compile_fail=0.2, p_nan=0.3)

        def one_run():
            inj = ChaosInjector(cfg, sleep=lambda s: None)
            svc = DesignService("base", chaos=inj,
                                retry=RetryPolicy(max_attempts=4, base_s=0.001))
            replies = svc.serve(_mixed_queries(8))
            sched = [p.to_json() for p in inj.schedule(range(8))]
            outcomes = [(r.qid, r.ok, r.attempts,
                         r.error.code if r.error else None) for r in replies]
            results = {r.qid: r.result.to_json() for r in replies if r.ok}
            return sched, outcomes, results, svc.stats.availability

        assert one_run() == one_run()

    def test_chaos_leaves_clean_queries_bit_identical(self):
        queries = _mixed_queries(8)
        base = {r.qid: r.result.to_json()
                for r in DesignService("base").serve(queries) if r.ok}
        inj = ChaosInjector(
            ChaosConfig(seed=2, p_transient=0.4, p_nan=0.4), sleep=lambda s: None
        )
        svc = DesignService("base", chaos=inj,
                            retry=RetryPolicy(max_attempts=4, base_s=0.001))
        replies = svc.serve(queries)
        clean = {p.qid for p in inj.schedule(range(8)) if p.clean}
        assert clean, "seed must leave some queries untouched"
        for r in replies:
            if r.qid in clean and r.ok:
                assert r.result.to_json() == base[r.qid]

    def test_cold_compiles_reprime_not_flag(self):
        svc = DesignService("base")
        replies = svc.serve(_mixed_queries(8))
        assert all(r.ok for r in replies)
        assert any(r.compiled for r in replies)  # cold shapes were paid
        # the ~1000x cold/warm gap must not register as straggling
        assert not any(r.straggler for r in replies if r.compiled)

    def test_per_query_deadline_override(self):
        svc = DesignService("base")
        r = svc.submit(DesignQuery(0, "simulate", "lstm", deadline_s=123.0))
        assert r.deadline_s == 123.0


class TestStragglerWiring:
    def test_reprime_resets_baseline(self):
        m = StragglerMonitor()
        m.reprime(1.0)  # a cold compile lands as the new steady state
        assert m.ewma == 1.0 and not m.flagged
        m.reprime(0.001)  # warm regime re-primed
        assert not m.record(1, 0.0011)  # nominal warm step
        for i in range(2, 6):
            m.record(i, 0.001)
        assert m.record(99, 1.0)  # genuine warm outlier is flagged
        assert m.flagged[-1][0] == 99


# --------------------------------------------------------------------------- #
# engine guards: dopt rollback, popsim divergence containment
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def lstm():
    return get_workload("lstm")


class TestDOptRollback:
    def test_nan_epochs_roll_back_to_last_finite_state(self, lstm):
        # Poisoning every epoch after k must leave the descent bit-equal to
        # stopping at k: faulted steps select the previous state exactly
        # (jnp.where), and the same chunked program keeps arithmetic
        # bit-reproducible across both runs.
        clean = optimize(lstm, objective="edp", steps=6, lr=0.1, chunk=3)
        faulted = optimize(lstm, objective="edp", steps=12, lr=0.1, chunk=3,
                           nan_epochs=tuple(range(6, 12)))
        for a, b in zip(jax.tree.leaves(clean.tech.__dict__),
                        jax.tree.leaves(faulted.tech.__dict__)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert faulted.history["fault"] == [0.0] * 6 + [1.0] * 6
        for key in ("edp", "runtime", "energy"):
            assert np.isfinite(faulted.history[key]).all()

    def test_fault_free_history_has_no_fault_flags(self, lstm):
        res = optimize(lstm, objective="edp", steps=4, lr=0.1, chunk=2)
        assert res.history["fault"] == [0.0] * 4

    def test_lr_backoff_halves_and_recovers(self, lstm):
        # one poisoned epoch mid-run: the run still ends finite and improves
        res = optimize(lstm, objective="edp", steps=10, lr=0.1, chunk=5,
                       nan_epochs=(4,))
        assert res.history["fault"][4] == 1.0
        assert np.isfinite(res.history["edp"]).all()
        assert res.history["edp"][-1] < res.history["edp"][0]


class TestPopsimContainment:
    def test_diverged_member_is_infeasible_and_off_front(self, lstm, monkeypatch):
        real = popsim.population_log_metrics

        def corrupting(tech, arch, gstack, spec, mcfg):
            logm, area, power = real(tech, arch, gstack, spec, mcfg)
            logm = np.asarray(logm).copy()
            logm[0, :] = np.nan  # member 0 "diverged"
            return logm, area, power

        monkeypatch.setattr(popsim, "population_log_metrics", corrupting)
        res = popsim.pareto_dse(lstm, population=6, steps=2, key=0)
        assert not res.feasible[0]
        assert 0 not in res.front
        assert np.isfinite(res.hypervolume)
