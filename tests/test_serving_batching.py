"""Cross-request batching: flush policy, coalescing plan, bit-identity with
sequential serving, isolation inside a batch, tenant cache sharing, and the
token-engine prompt-bucket / PRNG-stream fixes (docs/serving.md)."""
import dataclasses
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    BatchingDesignService,
    ChaosConfig,
    ChaosInjector,
    DeadlineConfig,
    DesignQuery,
    DesignService,
    Engine,
    FlushPolicy,
    IntakeQueue,
    Request,
    RetryPolicy,
)
from repro.serving.batching import batch_key, make_chunk_handlers, plan_chunks


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------- #
# mechanics: policy, queue, chunk planning (no engine involved)
# --------------------------------------------------------------------------- #


class TestFlushPolicy:
    def test_validation(self):
        with pytest.raises(ValueError):
            FlushPolicy(max_batch=0)
        with pytest.raises(ValueError):
            FlushPolicy(max_batch=4, min_batch=5)
        with pytest.raises(ValueError):
            FlushPolicy(max_delay_s=-1.0)

    def test_queue_flushes_by_size(self):
        clk = FakeClock()
        q = IntakeQueue(clock=clk)
        pol = FlushPolicy(max_batch=3, max_delay_s=10.0)
        q.push("a"), q.push("b")
        assert not q.due(pol)  # young and under-size
        q.push("c")
        assert q.due(pol)  # size trigger fires regardless of age

    def test_queue_flushes_by_age(self):
        clk = FakeClock()
        q = IntakeQueue(clock=clk)
        pol = FlushPolicy(max_batch=100, max_delay_s=0.5)
        q.push("a")
        assert not q.due(pol)
        clk.t = 0.6  # oldest query is now past the delay budget
        assert q.due(pol)

    def test_drain_preserves_arrival_order_and_empties(self):
        clk = FakeClock()
        q = IntakeQueue(clock=clk)
        for i in range(3):
            clk.t = float(i)
            q.push(i)
        items = q.drain()
        assert [x for _, x in items] == [0, 1, 2]
        assert [t for t, _ in items] == [0.0, 1.0, 2.0]
        assert len(q) == 0 and not q.due(FlushPolicy())


def _adm(kind, spec="s", bucket=(1, 32), objective="edp"):
    return SimpleNamespace(
        q=SimpleNamespace(kind=kind, objective=objective),
        arch=SimpleNamespace(spec=spec),
        w=SimpleNamespace(bucket=bucket),
    )


class TestChunkPlanning:
    def test_batch_key_shape(self):
        assert batch_key(_adm("simulate")) == ("simulate", "s", (1, 32), None)
        assert batch_key(_adm("explain")) == ("explain", "s", (1, 32), "edp")
        assert batch_key(_adm("optimize")) is None  # stateful kinds never coalesce

    def test_groups_same_key_and_isolates_singletons(self):
        admitted = [
            (0, _adm("simulate")),
            (1, _adm("optimize")),
            (2, _adm("simulate")),
            (3, _adm("simulate", spec="other")),
        ]
        chunks = plan_chunks(admitted, max_batch=8)
        assert [[i for i, _ in c] for c in chunks] == [[0, 2], [1], [3]]

    def test_overflow_starts_fresh_chunk(self):
        admitted = [(i, _adm("simulate")) for i in range(5)]
        chunks = plan_chunks(admitted, max_batch=2)
        assert [[i for i, _ in c] for c in chunks] == [[0, 1], [2, 3], [4]]

    def test_chunk_handlers_dispatch_once_and_memoize(self):
        chunk = [(10, _adm("simulate")), (11, _adm("simulate"))]
        calls = []

        def dispatch(adms):
            calls.append(len(adms))
            return ["r10", "r11"]

        handlers = make_chunk_handlers(chunk, dispatch)
        assert handlers[11]() == "r11"  # any lane may arrive first
        assert handlers[10]() == "r10"
        assert handlers[11]() == "r11"  # a retry re-reads the memo
        assert calls == [2]  # the coalesced dispatch ran exactly once

    def test_failed_dispatch_leaves_memo_empty_for_retry(self):
        chunk = [(0, _adm("simulate"))]
        calls = []

        def dispatch(adms):
            calls.append(1)
            if len(calls) == 1:
                raise RuntimeError("transient")
            return ["ok"]

        (handler,) = make_chunk_handlers(chunk, dispatch).values()
        with pytest.raises(RuntimeError):
            handler()
        assert handler() == "ok"  # the retry re-dispatches
        assert calls == [1, 1]


# --------------------------------------------------------------------------- #
# service level: bit-identity, isolation, warmth ledger, tenants
# --------------------------------------------------------------------------- #


def _mixed_queries(n):
    kinds = ("simulate", "explain")
    loads = ("lstm", "merge_sort")  # same (1, 32) bucket -> coalescible
    archs = (None, "edge")
    return [
        DesignQuery(i, kinds[i % 2], loads[(i // 2) % 2],
                    architecture=archs[(i // 4) % 2])
        for i in range(n)
    ]


class TestBatchedBitIdentity:
    def test_batched_replies_equal_sequential_to_json(self):
        """The acceptance pin: coalescing must not change a single bit of any
        reply — ``to_json`` serializes every float, so string equality is
        value equality.  Both services share the default pinned request
        bucket (FlushPolicy.max_batch == DesignService request_bucket == 8)."""
        queries = _mixed_queries(8)
        seq = {r.qid: r.result.to_json()
               for r in DesignService("base").serve(queries)}
        bat = BatchingDesignService("base")
        replies = bat.serve(queries)
        assert [r.qid for r in replies] == list(range(8))  # original order
        assert all(r.ok for r in replies)
        for r in replies:
            assert r.result.to_json() == seq[r.qid]
        st = bat.stats
        assert st.batches >= 1 and st.batched_queries >= 2

    def test_batched_flag_and_size_reported(self):
        bat = BatchingDesignService("base")
        replies = bat.serve([DesignQuery(i, "simulate", "lstm") for i in range(3)])
        assert all(r.batched and r.batch_size == 3 for r in replies)
        solo = bat.submit(DesignQuery(9, "simulate", "lstm"))
        assert solo.ok and not solo.batched and solo.batch_size == 1


class TestIsolationInsideBatch:
    def test_poison_query_costs_only_itself(self):
        bat = BatchingDesignService("base")
        queries = [
            DesignQuery(0, "simulate", "lstm"),
            DesignQuery(1, "simulate", "no_such_workload"),  # intake poison
            DesignQuery(2, "simulate", "lstm"),
            DesignQuery(3, "explain", "lstm"),
        ]
        replies = bat.serve(queries)
        assert [r.ok for r in replies] == [True, False, True, True]
        assert replies[1].error.code == "client-error"
        assert not replies[1].batched  # quarantined before grouping
        # the survivors still coalesced: poison never breaks up a batch
        assert replies[0].batched and replies[2].batched
        assert replies[0].batch_size == 2

    def test_chaos_fault_on_one_lane_leaves_batchmates_clean(self):
        queries = [DesignQuery(i, "simulate", "lstm") for i in range(4)]
        base = {r.qid: r.result.to_json()
                for r in DesignService("base").serve(queries)}
        inj = ChaosInjector(ChaosConfig(seed=2, p_nan=0.5), sleep=lambda s: None)
        bat = BatchingDesignService(
            "base", chaos=inj, retry=RetryPolicy(max_attempts=4, base_s=0.001))
        replies = bat.serve(queries)
        clean = {p.qid for p in inj.schedule(range(4)) if p.clean}
        assert clean, "seed must leave some lanes untouched"
        assert all(r.ok for r in replies)  # NaN poisoning clears on retry
        for r in replies:
            if r.qid in clean:
                assert r.result.to_json() == base[r.qid]


class TestWarmthLedger:
    def test_failed_cold_query_does_not_grant_warm_deadline(self):
        """Regression: a query that died before its program compiled used to
        mark the shape warm anyway, so the next query got the 2 s warm
        budget against a 30 s cold compile."""
        inj = ChaosInjector(
            ChaosConfig(seed=3, p_compile_fail=1.0, depth=8), sleep=lambda s: None)
        svc = DesignService("base", chaos=inj,
                            retry=RetryPolicy(max_attempts=1, base_s=0.001))
        r0 = svc.submit(DesignQuery(0, "simulate", "lstm"))
        assert not r0.ok and not r0.compiled
        r1 = svc.submit(DesignQuery(1, "simulate", "lstm"))
        assert r1.deadline_s == DeadlineConfig().cold_s  # shape is STILL cold

    def test_successful_query_warms_the_shape(self):
        svc = DesignService("base")
        r0 = svc.submit(DesignQuery(0, "simulate", "lstm"))
        assert r0.ok and r0.deadline_s == DeadlineConfig().cold_s
        r1 = svc.submit(DesignQuery(1, "simulate", "lstm"))
        assert r1.deadline_s == DeadlineConfig().warm_s

    @pytest.fixture(scope="class")
    def preheated_service(self, tmp_path_factory):
        cache_dir = str(tmp_path_factory.mktemp("warmth-aot"))
        svc = DesignService("base", cache_dir=cache_dir)
        svc.warmup(["lstm"], kinds=("simulate",))
        return svc, cache_dir

    def test_preheated_shape_is_predicted_warm_on_first_serve(
        self, preheated_service
    ):
        """Regression (ISSUE 9): before the disk-warmth check, a preheated
        service still predicted its very first query cold and granted the
        30 s budget for a sub-ms replay."""
        svc, _ = preheated_service
        r = svc.submit(DesignQuery(0, "simulate", "lstm"))
        assert r.ok and not r.compiled
        assert r.deadline_s == DeadlineConfig().warm_s

    def test_restarted_service_over_cache_dir_is_warm_from_query_one(
        self, preheated_service
    ):
        _, cache_dir = preheated_service
        svc = DesignService("base", cache_dir=cache_dir)
        r = svc.submit(DesignQuery(0, "simulate", "lstm"))
        assert r.ok and not r.compiled
        assert r.deadline_s == DeadlineConfig().warm_s
        assert svc.stats.traces == 0

    def test_unpreheated_kind_stays_cold(self, preheated_service):
        """Disk warmth is per-(kind, objective): simulate was preheated,
        explain was not — its first query still deserves the cold budget."""
        svc, _ = preheated_service
        r = svc.submit(DesignQuery(9, "explain", "lstm", objective="edp"))
        assert r.ok
        assert r.deadline_s == DeadlineConfig().cold_s


class TestTenants:
    def test_tenant_sessions_share_the_compiled_program_cache(self):
        svc = DesignService("base")
        assert svc.submit(DesignQuery(0, "simulate", "lstm")).ok
        traces = svc.stats.traces
        r = svc.submit(DesignQuery(1, "simulate", "lstm", tenant="acme"))
        assert r.ok
        st = svc.stats
        assert st.traces == traces  # warm across tenants: no retrace
        assert st.tenants == 2

    def test_cross_tenant_coalescing_is_exact(self):
        q0 = DesignQuery(0, "simulate", "lstm")
        q1 = DesignQuery(1, "simulate", "lstm", tenant="acme")
        base = DesignService("base").submit(dataclasses.replace(q0)).result.to_json()
        bat = BatchingDesignService("base")
        replies = bat.serve([q0, q1])
        assert all(r.ok and r.batched for r in replies)
        assert replies[0].result.to_json() == base
        assert replies[1].result.to_json() == base


# --------------------------------------------------------------------------- #
# token engine: prompt bucketing + per-request PRNG streams
# --------------------------------------------------------------------------- #


class TestEngineFixes:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                                  dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        return cfg, m, params

    def test_bucketed_prefill_matches_exact(self, setup):
        """Padding a prompt to its pow2 bucket must not change a single
        greedy token: the head reads the true last position and the cache
        length masks the padding out of attention."""
        cfg, m, params = setup
        for plen in (3, 6, 9, 17):
            prompt = (np.arange(plen, dtype=np.int32) % cfg.vocab_size)
            outs = []
            for bucketed in (True, False):
                eng = Engine(m, params, slots=1, max_len=64)
                eng._bucket_prompts = bucketed
                eng.submit(Request(rid=0, prompt=prompt, max_tokens=5))
                outs.append([int(t) for t in eng.run()[0].generated])
            assert outs[0] == outs[1], f"prompt length {plen}"

    def test_recurrent_families_keep_exact_prefill(self):
        cfg = get_config("falcon-mamba-7b").reduced()
        m = build_model(cfg)
        eng = Engine(m, m.init(jax.random.PRNGKey(0)), slots=1, max_len=64)
        assert not eng._bucket_prompts  # ssm state would absorb the padding

    def test_sampled_streams_differ_across_rids(self, setup):
        """Regression: ``PRNGKey(seed + len(generated))`` gave every request
        with the same seed the SAME sample stream (and adjacent seeds
        overlapping streams).  fold_in(rid) separates them."""
        cfg, m, params = setup
        prompt = np.arange(6, dtype=np.int32)

        def gen(rid, seed):
            eng = Engine(m, params, slots=1, max_len=64)
            eng.submit(Request(rid=rid, prompt=prompt, max_tokens=8,
                               temperature=1.0, seed=seed))
            return [int(t) for t in eng.run()[0].generated]

        assert gen(0, 7) == gen(0, 7)  # replay: still deterministic
        assert gen(0, 7) != gen(5, 7)  # same seed, different request
        assert gen(0, 7) != gen(0, 8)  # different seed
