"""GPipe pipeline parallelism: numerical equality with the sequential stack
and gradient flow, on a real 4-device stage mesh (subprocess for XLA_FLAGS)."""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import jax, jax.numpy as jnp
    import sys
    sys.path.insert(0, 'src')
    from repro.train.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ('stage',))
    L, d, B = 8, 16, 8
    W = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d))

    def layer_fn(w, h):
        return jnp.tanh(h @ w)

    def seq(W, x):
        def body(h, w):
            return layer_fn(w, h), None
        h, _ = jax.lax.scan(body, x, W)
        return h

    y_ref = seq(W, x)
    with mesh:
        y = jax.jit(lambda W, x: pipeline_apply(mesh, layer_fn, W, x, n_microbatches=4))(W, x)
        g = jax.jit(jax.grad(lambda W: pipeline_apply(mesh, layer_fn, W, x, n_microbatches=4).sum()))(W)
    g_ref = jax.grad(lambda W: seq(W, x).sum())(W)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-6
    assert float(jnp.max(jnp.abs(g - g_ref))) < 1e-5
    # microbatch count must not change the math
    with mesh:
        y2 = jax.jit(lambda W, x: pipeline_apply(mesh, layer_fn, W, x, n_microbatches=8))(W, x)
    assert float(jnp.max(jnp.abs(y2 - y_ref))) < 1e-6
    print('PIPELINE_OK')
""")


@pytest.mark.slow
def test_gpipe_matches_sequential_on_4_stages():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, cwd=".", timeout=300)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
