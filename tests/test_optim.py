"""Optimizer stack: AdamW, int8 moment states, EF gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis

from repro.optim import (
    AdamWConfig,
    adamw_update,
    ef_compress_tree,
    global_norm,
    init_error_buffer,
    init_opt_state,
    q8_dequantize,
    q8_quantize,
    warmup_cosine,
)


def toy_loss(p):
    return jnp.sum((p["w"] @ p["w"].T - jnp.eye(8)) ** 2)


def run_adamw(int8: bool, steps=150, lr=1e-2):
    p = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 300)) * 0.3}
    cfg = AdamWConfig(lr=lr, weight_decay=0.0, int8_states=int8,
                      schedule=warmup_cosine(10, steps))
    st_ = init_opt_state(p, cfg)

    @jax.jit
    def step(p, st_):
        g = jax.grad(toy_loss)(p)
        return adamw_update(p, g, st_, cfg)

    for _ in range(steps):
        p, st_, _ = step(p, st_)
    return float(toy_loss(p))


class TestQ8:
    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(1, 5),
        cols=st.integers(1, 700),
        scale=st.floats(1e-6, 1e6),
        nonlinear=st.booleans(),
    )
    def test_roundtrip_error_bounded(self, rows, cols, scale, nonlinear):
        x = jax.random.normal(jax.random.PRNGKey(rows * 1000 + cols), (rows, cols)) * scale
        xr = q8_dequantize(q8_quantize(x, nonlinear=nonlinear), nonlinear=nonlinear)
        # error per block bounded by absmax/127 (linear) or looser (quadratic
        # map trades top-end precision for near-zero resolution)
        bound = (np.abs(np.asarray(x)).max() / 127.0) * (4.0 if nonlinear else 1.01)
        assert float(jnp.max(jnp.abs(x - xr))) <= bound + 1e-30

    def test_zero_preserved(self):
        x = jnp.zeros((3, 300))
        assert float(jnp.abs(q8_dequantize(q8_quantize(x))).max()) == 0.0

    def test_scale_shape_mirrors_leading_dims(self):
        q = q8_quantize(jnp.ones((4, 7, 1000)))
        assert q.codes.shape == (4, 7, 1000)
        assert q.scale.shape == (4, 7, 4)  # ceil(1000/256)


class TestAdamW:
    def test_fp32_converges(self):
        assert run_adamw(False) < 1e-4

    def test_int8_parity(self):
        assert run_adamw(True) < 1e-3  # within noise of fp32 path

    def test_grad_clip_caps_update(self):
        p = {"w": jnp.ones((4, 4))}
        cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0, schedule=None)
        st_ = init_opt_state(p, cfg)
        g = {"w": jnp.full((4, 4), 1e6)}
        p2, _, metrics = adamw_update(p, g, st_, cfg)
        assert float(metrics["grad_norm"]) > 1e5
        assert float(jnp.max(jnp.abs(p2["w"] - p["w"]))) < 10.0  # clipped

    def test_weight_decay_shrinks(self):
        p = {"w": jnp.ones((4, 4)) * 10}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, schedule=None)
        st_ = init_opt_state(p, cfg)
        p2, _, _ = adamw_update(p, {"w": jnp.zeros((4, 4))}, st_, cfg)
        assert float(jnp.max(p2["w"])) < 10.0


class TestEFCompression:
    def test_error_feedback_unbiased_over_time(self):
        """Sum of (compressed + carried error) telescopes to the true sum."""
        key = jax.random.PRNGKey(0)
        err = init_error_buffer({"g": jnp.zeros((512,))})
        true_sum = jnp.zeros((512,))
        sent_sum = jnp.zeros((512,))
        for i in range(20):
            g = {"g": jax.random.normal(jax.random.fold_in(key, i), (512,))}
            true_sum = true_sum + g["g"]
            cg, err = ef_compress_tree(g, err)
            sent_sum = sent_sum + cg["g"]
        resid = float(jnp.max(jnp.abs(true_sum - sent_sum - err["g"])))
        assert resid < 1e-3  # telescoping identity

    def test_compressed_sgd_converges(self):
        p = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 300)) * 0.3}
        err = init_error_buffer(p)
        for _ in range(300):
            g = jax.grad(toy_loss)(p)
            cg, err = ef_compress_tree(g, err)
            p = jax.tree.map(lambda w, gg: w - 3e-3 * gg, p, cg)
        assert float(toy_loss(p)) < 1e-2


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,))}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(7.0), rel=1e-6)


def test_schedule_shapes():
    s = warmup_cosine(10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=0.01)
    assert float(s(100)) == pytest.approx(0.1, abs=0.05)
