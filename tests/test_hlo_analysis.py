"""HLO analysis tooling: trip-count-weighted costs + collective accounting."""
import numpy as np
import pytest

from repro.launch.hlo_costs import HloModule, hlo_costs
from repro.launch.hlo_stats import collective_stats, while_trip_counts

# hand-written HLO module: a dot inside a while body with trip count 40,
# plus a gradient all-reduce in the same body and one top-level all-gather.
HLO = """
HloModule test

%cond.1 (p: (s32[], f32[8,16]{1,0})) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(40)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[8,16]{1,0})) -> (s32[], f32[8,16]{1,0}) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%add.1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ni, %ar)
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.1 (x0: (s32[], f32[8,16]{1,0})) -> (s32[], f32[8,16]{1,0}) {
  %x0 = (s32[], f32[8,16]{1,0}) parameter(0)
  %w2 = f32[4,4]{1,0} constant({...})
  %ag = f32[64,4]{1,0} all-gather(%w2), replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %out = (s32[], f32[8,16]{1,0}) while(%x0), condition=%cond.1, body=%body.1
}
"""


class TestHloCosts:
    def test_dot_flops_weighted_by_trip(self):
        c = hlo_costs(HLO)
        # dot: 2*8*16*16 = 4096 flops x 40 trips
        assert c["flops_by_op"]["dot"] == pytest.approx(4096 * 40)

    def test_while_condition_trip_parse(self):
        assert while_trip_counts(HLO) == [40]


class TestCollectiveStats:
    def test_trip_weighting_and_ring_factors(self):
        s = collective_stats(HLO)
        # all-reduce of 8*16*4 bytes over g=16, ring factor 2*(g-1)/g, x40
        ar = 2 * (8 * 16 * 4) * 15 / 16 * 40
        assert s["bytes_by_kind"]["all-reduce"] == pytest.approx(ar, rel=1e-6)
        # all-gather: output 64*4*4 bytes, (g-1)/g, once
        ag = (64 * 4 * 4) * 15 / 16
        assert s["bytes_by_kind"]["all-gather"] == pytest.approx(ag, rel=1e-6)
        assert s["counts"]["all-reduce"] == 40

    def test_empty_module(self):
        assert collective_stats("HloModule empty")["total_bytes"] == 0


class TestOnRealModule:
    """End-to-end: lower a tiny jit program and check the analyses run."""

    def test_real_lowering(self):
        import jax
        import jax.numpy as jnp

        def f(x, w):
            def body(c, _):
                return c @ w, None

            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        x = jnp.ones((8, 32)), jnp.ones((32, 32))
        txt = jax.jit(f).lower(*x).compile().as_text()
        c = hlo_costs(txt)
        # 7 iterations x 2*8*32*32
        assert c["flops"] >= 7 * 2 * 8 * 32 * 32
        assert c["flops"] < 7 * 2 * 8 * 32 * 32 * 1.5
