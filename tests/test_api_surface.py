"""Golden pin of the public façade surface (tier-1).

The façade is the suite's served API: accidental renames, dropped exports
or result-dataclass field changes are breaking changes for every client,
so the exact surface is pinned here.  If a failure is *intentional*, update
the goldens in the same PR that changes the surface — and the docs
(docs/api.md, README.md) with them.
"""
import dataclasses
import inspect

import repro
import repro.api as api
from repro.core import report


def fields(cls) -> tuple[str, ...]:
    return tuple(f.name for f in dataclasses.fields(cls))


# --------------------------------------------------------------------------- #
# module exports
# --------------------------------------------------------------------------- #

API_ALL = (
    "Workload",
    "Architecture",
    "Session",
    "CacheStats",
    "SimReport",
    "OptResult",
    "FrontierResult",
    "Attribution",
    "Graph",
    "MapperCfg",
    "ArchParams",
    "ArchSpec",
    "TechParams",
    "PerfEstimate",
    "PARETO_METRICS",
    "get_workload",
)

TOP_LEVEL = (
    "__version__",
    "Session",
    "Architecture",
    "Workload",
    "CacheStats",
    "SimReport",
    "OptResult",
    "FrontierResult",
    "Attribution",
    "Graph",
    "MapperCfg",
    "ArchParams",
    "ArchSpec",
    "TechParams",
    "get_workload",
)


def test_api_module_exports():
    assert tuple(api.__all__) == API_ALL
    for name in API_ALL:
        assert getattr(api, name) is not None


def test_top_level_lazy_exports():
    assert tuple(repro.__all__) == TOP_LEVEL
    for name in TOP_LEVEL:
        assert getattr(repro, name) is not None
    assert repro.Session is api.Session
    assert isinstance(repro.__version__, str) and repro.__version__[0].isdigit()


def test_top_level_deprecated_shims_warn_and_forward():
    import importlib
    import warnings

    import repro.core.dsim as dsim

    # the shim warns; the engine spelling stays warning-free (it's the oracle)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        fn = repro.simulate
    assert fn is dsim.simulate
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        importlib.reload(dsim)
    assert not rec


# --------------------------------------------------------------------------- #
# result dataclasses: frozen, with pinned fields
# --------------------------------------------------------------------------- #

REPORT_FIELDS = {
    report.Attribution: ("parameter", "elasticity"),
    report.MemoryLevelReport: (
        "level",
        "reads_bytes",
        "writes_bytes",
        "transfer_time_s",
        "dynamic_energy_j",
        "leakage_energy_j",
        "bw_utilization",
    ),
    report.ComputeClassReport: ("unit", "flops", "dynamic_energy_j", "leakage_energy_j"),
    report.VertexReport: ("name", "time_s", "energy_j", "time_share"),
    report.WorkloadReport: (
        "label",
        "runtime_s",
        "energy_j",
        "power_w",
        "edp",
        "cycles",
        "energy_mem_j",
        "energy_comp_j",
        "energy_leak_j",
        "levels",
        "compute",
        "vertices",
    ),
    report.SimReport: ("architecture", "objective", "area_mm2", "workloads", "attribution"),
    report.OptResult: (
        "objective",
        "opt_over",
        "epochs",
        "improvement",
        "objective_history",
        "importance",
        "baseline",
        "optimized",
        "dhd",
    ),
    report.FrontierPoint: (
        "index",
        "seed",
        "weights",
        "time_s",
        "energy_j",
        "area_mm2",
        "power_w",
        "edp",
        "dhd",
    ),
    report.FrontierResult: (
        "metrics",
        "population",
        "epochs",
        "feasible",
        "hypervolume",
        "area_budget",
        "power_budget",
        "front",
        "raw",
    ),
}


def test_report_dataclass_fields_pinned():
    for cls, want in REPORT_FIELDS.items():
        assert fields(cls) == want, f"{cls.__name__} fields changed"
        assert cls.__dataclass_params__.frozen, f"{cls.__name__} must be frozen"


def test_report_methods_pinned():
    for cls in (report.SimReport, report.OptResult, report.FrontierResult):
        assert callable(getattr(cls, "to_json"))
    for cls in (report.OptResult, report.FrontierResult):
        assert callable(getattr(cls, "to_dhd"))
    for prop in ("runtime_s", "energy_j", "power_w", "edp"):
        assert isinstance(getattr(report.SimReport, prop), property)


# --------------------------------------------------------------------------- #
# façade types: pinned methods and signatures
# --------------------------------------------------------------------------- #

SESSION_METHODS = (
    "simulate",
    "explain",
    "simulate_batch",
    "explain_batch",
    "optimize",
    "frontier",
    "tech_targets",
    "perf",
    "trace_programs",
    "preheat",
)


def test_session_surface():
    for name in SESSION_METHODS:
        assert callable(getattr(api.Session, name)), f"Session.{name} missing"
    assert isinstance(api.Session.stats, property)
    sig = inspect.signature(api.Session.preheat)
    for p in ("workloads", "objectives", "kinds", "request_buckets"):
        assert p in sig.parameters
    sig = inspect.signature(api.Session.optimize)
    for p in ("objective", "steps", "lr", "opt_over", "architecture"):
        assert p in sig.parameters
    sig = inspect.signature(api.Session.frontier)
    for p in ("seeds", "population", "steps", "metrics", "area_budget", "power_budget"):
        assert p in sig.parameters
    assert fields(api.CacheStats) == ("programs", "hits", "misses", "traces")


def test_workload_architecture_surface():
    for prop in ("bucket", "stacked", "n_workloads"):
        assert hasattr(api.Workload, prop)
    for prop in ("name", "spec", "arch", "tech", "compiled"):
        assert isinstance(getattr(api.Architecture, prop), property)
    assert callable(api.Architecture.to_dhd)
    assert callable(api.Architecture.peaks)


def test_trace_programs_signature():
    sig = inspect.signature(api.Session.trace_programs)
    assert "objective" in sig.parameters
    assert "architecture" in sig.parameters
