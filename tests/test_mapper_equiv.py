"""Equivalence of the parallel-depth mapper and the device-resident DOpt loop
against their sequential references.

  * associative-scan mapper (MapperCfg.scan_impl="assoc", the default) vs the
    O(V) ``lax.scan`` reference ("ref") — values and gradients;
  * the opt-in Pallas affine-scan dispatch ("pallas") — values and gradients;
  * fused chunked-scan optimize() vs the per-step Python loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArchParams, TechParams, optimize, simulate, specialize
from repro.core.dopt import from_log, to_log
from repro.core.graph import Graph
from repro.core.mapper import (
    MapperCfg,
    affine_prefix_assoc,
    map_workload,
    map_workload_scan,
    minaffine_prefix_assoc,
)
from repro.workloads import get_workload, lm_cell

CLASSIC = ["lstm", "bert_base", "resnet50", "dlrm", "merge_sort"]
LM = [("granite-3-8b", "train_4k"), ("qwen2.5-32b", "prefill_32k")]


def _graphs():
    for n in CLASSIC:
        yield n, get_workload(n)
    for a, s in LM:
        yield f"{a}:{s}", lm_cell(a, s)


@pytest.fixture(scope="module")
def chw():
    return specialize(TechParams.default(), ArchParams.default())


class TestScanPrimitives:
    def test_affine_prefix_matches_python(self):
        x = jnp.asarray(np.random.default_rng(0).uniform(0, 2, 97), jnp.float32)
        out = np.asarray(affine_prefix_assoc(0.8, x))
        s, expect = 0.0, []
        for v in np.asarray(x):
            s = 0.8 * s + v
            expect.append(s)
        np.testing.assert_allclose(out, expect, rtol=1e-5)

    def test_minaffine_prefix_matches_python(self):
        x = jnp.asarray(np.random.default_rng(1).uniform(0, 3, 131), jnp.float32)
        cap = jnp.float32(2.5)
        out = np.asarray(minaffine_prefix_assoc(0.5, x, cap))
        s, expect = 0.0, []
        for v in np.asarray(x):
            s = min(0.5 * s + v, 2.5)
            expect.append(s)
        np.testing.assert_allclose(out, expect, rtol=1e-5)
        assert out.max() <= 2.5 + 1e-6

    def test_pallas_affine_scan_matches_and_differentiates(self):
        from repro.kernels.sscan import affine_scan

        x = jnp.asarray(np.random.default_rng(2).uniform(0, 1, 70), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(affine_scan(0.8, x)), np.asarray(affine_prefix_assoc(0.8, x)), rtol=1e-5
        )
        g_pl = jax.grad(lambda v: jnp.sum(affine_scan(0.8, v) ** 2))(x)
        g_as = jax.grad(lambda v: jnp.sum(affine_prefix_assoc(0.8, v) ** 2))(x)
        np.testing.assert_allclose(np.asarray(g_pl), np.asarray(g_as), rtol=1e-4, atol=1e-6)


class TestMapperEquivalence:
    @pytest.mark.parametrize("name,g", list(_graphs()), ids=[n for n, _ in _graphs()])
    def test_state_matches_reference(self, chw, name, g):
        ref = map_workload_scan(chw, g, MapperCfg(scan_impl="ref"))
        for impl in ("assoc",):
            got = map_workload(chw, g, MapperCfg(scan_impl=impl))
            np.testing.assert_allclose(float(got.cycles), float(ref.cycles), rtol=1e-4)
            for f in ("reads", "writes", "peak_alloc"):
                np.testing.assert_allclose(
                    np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)), rtol=1e-4
                )

    @pytest.mark.parametrize(
        "name,g",
        [(n, g) for n, g in _graphs() if n in ("lstm", "bert_base", "granite-3-8b:train_4k")],
        ids=["lstm", "bert_base", "granite"],
    )
    def test_grad_of_edp_matches_reference(self, name, g):
        arch_z = to_log(ArchParams.default())
        tech_z = to_log(TechParams.default())

        def make(cfg):
            def loss(tz, az):
                perf = simulate(from_log(tz), from_log(az), g, mcfg=cfg)
                return jnp.log(perf.edp)

            return jax.grad(loss, argnums=(0, 1))

        g_assoc = make(MapperCfg(scan_impl="assoc"))(tech_z, arch_z)
        g_ref = make(MapperCfg(scan_impl="ref"))(tech_z, arch_z)
        for a, r in zip(jax.tree.leaves(g_assoc), jax.tree.leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-6)

    def test_pallas_dispatch_matches_reference(self, chw):
        g = get_workload("lstm")
        ref = map_workload_scan(chw, g, MapperCfg(scan_impl="ref"))
        got = map_workload(chw, g, MapperCfg(scan_impl="pallas"))
        np.testing.assert_allclose(float(got.cycles), float(ref.cycles), rtol=1e-4)

        def loss(tz, cfg):
            return jnp.log(simulate(from_log(tz), ArchParams.default(), g, mcfg=cfg).edp)

        gp = jax.grad(loss)(to_log(TechParams.default()), MapperCfg(scan_impl="pallas"))
        gr = jax.grad(loss)(to_log(TechParams.default()), MapperCfg(scan_impl="ref"))
        for a, r in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-6)

    def test_unknown_impl_raises(self, chw):
        with pytest.raises(ValueError):
            map_workload(chw, get_workload("lstm"), MapperCfg(scan_impl="nope"))


class TestStackedWorkloads:
    def test_stack_pads_and_preserves_totals(self):
        gs = [get_workload("lstm"), get_workload("bert_base")]
        st = Graph.stack(gs)
        vmax = max(g.n_vertices for g in gs)
        assert st.n_comp.shape[:2] == (2, vmax)
        np.testing.assert_allclose(
            np.asarray(st.n_comp).sum(), sum(float(g.total_flops) for g in gs), rtol=1e-6
        )

    def test_padding_is_free_in_the_mapper(self):
        chw = specialize(TechParams.default(), ArchParams.default())
        g = get_workload("lstm")
        padded = g.pad_to(g.n_vertices + 50)
        for impl in ("assoc", "ref"):
            m0 = map_workload(chw, g, MapperCfg(scan_impl=impl))
            m1 = map_workload(chw, padded, MapperCfg(scan_impl=impl))
            for f in ("cycles", "n_tiles", "t_mem", "t_comp", "t_exposed_main"):
                np.testing.assert_allclose(
                    float(getattr(m1, f)), float(getattr(m0, f)), rtol=1e-6
                )


class TestFusedOptimizeEquivalence:
    def test_fused_reproduces_per_step_loop(self):
        gs = [get_workload("lstm"), get_workload("merge_sort")]
        kw = dict(objective="edp", steps=12, lr=0.1)
        rf = optimize(gs, fused=True, **kw)
        rl = optimize(gs, fused=False, **kw)
        for k in rf.history:
            np.testing.assert_allclose(rf.history[k], rl.history[k], rtol=1e-4)
        for a, b in zip(jax.tree.leaves((rf.tech, rf.arch)), jax.tree.leaves((rl.tech, rl.arch))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4)

    def test_chunked_matches_single_dispatch(self):
        g = get_workload("lstm")
        r1 = optimize(g, steps=10, lr=0.1, fused=True, chunk=10)
        r2 = optimize(g, steps=10, lr=0.1, fused=True, chunk=3)
        np.testing.assert_allclose(r1.history["objective"], r2.history["objective"], rtol=1e-5)

    def test_zero_steps_is_a_noop(self):
        g = get_workload("lstm")
        res = optimize(g, steps=0, lr=0.1)
        assert res.history["objective"] == []
        np.testing.assert_allclose(
            np.asarray(res.tech.cell_read_latency),
            np.asarray(TechParams.default().cell_read_latency),
            rtol=1e-6,
        )
