"""Benchmark harness — one benchmark per paper table/figure.

  python -m benchmarks.run [--quick] [--only sim_speed,dse,...]

| benchmark     | paper artifact                 |
|---------------|--------------------------------|
| sim_speed     | §8.1 Fig.4/Table 1 (accuracy + ~1000x speed) |
| dse           | §8.2 Table 4/Fig.7 (derived accelerators)    |
| tech_targets  | §8.3 Table 3/Fig.3 (importance + 100x EDP)   |
| edp_gain      | abstract (5x vs published baselines)          |
| roofline      | EXPERIMENTS.md §Roofline (from the dry-run)   |
| pareto        | constrained latency/energy/area frontier (population DSE) |
| api           | Session compiled-program cache (cold/warm, zero-retrace gates) |
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_api,
        bench_dse,
        bench_edp_gain,
        bench_pareto,
        bench_roofline,
        bench_serving,
        bench_sim_speed,
        bench_tech_targets,
    )

    table = {
        "sim_speed": bench_sim_speed.run,
        "dse": bench_dse.run,
        "tech_targets": bench_tech_targets.run,
        "edp_gain": bench_edp_gain.run,
        "roofline": bench_roofline.run,
        "serving": bench_serving.run,
        "pareto": bench_pareto.run,
        "api": bench_api.run,
    }
    names = args.only.split(",") if args.only else list(table)
    failures = []
    for name in names:
        t0 = time.time()
        print(f"=== bench {name} ===", flush=True)
        try:
            table[name](quick=args.quick)
            print(f"=== bench {name} done in {time.time()-t0:.1f}s ===", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("ALL BENCHMARKS PASSED")


if __name__ == "__main__":
    main()
