"""Serving benchmark: the continuous-batching engine on a reduced qwen
config — throughput, per-token latency and TTFT with mixed request sizes.
(The paper-side serving numbers are the decode/prefill roofline cells;
this measures the ENGINE's scheduling overhead end-to-end on CPU.)"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import Engine, Request


def run(quick: bool = False) -> dict:
    cfg = get_config("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    n_req = 6 if quick else 16
    out = {}
    for slots in (1, 4):
        eng = Engine(model, params, slots=slots, max_len=128)
        t0 = time.perf_counter()
        for i in range(n_req):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab_size, (int(rng.integers(4, 20)),)).astype(np.int32),
                max_tokens=8, temperature=0.0, seed=i))
        done = eng.run()
        wall = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        ttft = float(np.mean([r.t_first - r.t_submit for r in done]))
        row = dict(slots=slots, requests=len(done), tok_per_s=round(toks / wall, 1),
                   mean_ttft_ms=round(ttft * 1e3, 1), wall_s=round(wall, 2))
        out[f"slots{slots}"] = row
        emit("serving", row)
    gain = out["slots4"]["tok_per_s"] / max(out["slots1"]["tok_per_s"], 1e-9)
    emit("serving", dict(batching_throughput_gain=round(gain, 2)))
    out["batching_gain"] = gain
    save_json("serving", out, quick=quick)
    return out


if __name__ == "__main__":
    run()
