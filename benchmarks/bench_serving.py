"""Serving benchmark: token engine + fault-contained design service.

Two sections, both written to ``results/bench/serving.json``:

* **token** — the continuous-batching engine on a reduced qwen config:
  throughput, per-token latency and TTFT with mixed request sizes (the
  paper-side serving numbers are the decode/prefill roofline cells; this
  measures the ENGINE's scheduling overhead end-to-end on CPU);

* **chaos** — the :class:`repro.serving.DesignService` resilience layer
  under the seeded chaos harness (docs/serving.md): availability (fraction
  of queries answered ok within deadline), p50/p99 reply latency, retry and
  injection counts, plus three hard gates —

    1. *isolation*: every batch completes, one reply per query, zero
       uncaught exceptions;
    2. *transient-only availability == 1.0*: every fault class that clears
       on retry MUST clear under the default policy (the CI probe's gate);
    3. *bit-identity*: replies for queries the chaos schedule left clean
       are bit-identical (``to_json`` string equality) to a no-chaos run,
       and the seeded schedule itself replays identically.

``--quick --chaos`` is the CI probe: design-service section only, writing
``serving_quick.json`` (the canonical ``serving.json`` comes from a full
run on an idle machine).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import (
    ChaosConfig,
    ChaosInjector,
    DesignQuery,
    DesignService,
    Engine,
    Request,
    RetryPolicy,
)


def token_bench(quick: bool = False) -> dict:
    cfg = get_config("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    n_req = 6 if quick else 16
    out = {}
    for slots in (1, 4):
        eng = Engine(model, params, slots=slots, max_len=128)
        t0 = time.perf_counter()
        for i in range(n_req):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab_size, (int(rng.integers(4, 20)),)).astype(np.int32),
                max_tokens=8, temperature=0.0, seed=i))
        done = eng.run()
        wall = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        ttft = float(np.mean([r.t_first - r.t_submit for r in done]))
        row = dict(slots=slots, requests=len(done), tok_per_s=round(toks / wall, 1),
                   mean_ttft_ms=round(ttft * 1e3, 1), wall_s=round(wall, 2))
        out[f"slots{slots}"] = row
        emit("serving", row)
    gain = out["slots4"]["tok_per_s"] / max(out["slots1"]["tok_per_s"], 1e-9)
    emit("serving", dict(batching_throughput_gain=round(gain, 2)))
    out["batching_gain"] = gain
    return out


# --------------------------------------------------------------------------- #
# design-service chaos probe
# --------------------------------------------------------------------------- #

_SEED = 20260808


def _queries(n: int, optimize_every: int = 0) -> list[DesignQuery]:
    """A deterministic mixed stream over one shape bucket (lstm/merge_sort
    share (1, 32)), so after the first cold queries everything is warm —
    the regime availability and p99 are defined on."""
    kinds = ("simulate", "explain")
    loads = ("lstm", "merge_sort")
    qs = []
    for i in range(n):
        if optimize_every and i and i % optimize_every == 0:
            qs.append(DesignQuery(i, "optimize", loads[i % 2],
                                  params=dict(steps=6, report=False)))
        else:
            qs.append(DesignQuery(i, kinds[i % 2], loads[(i // 2) % 2]))
    return qs


def _fingerprints(replies) -> dict:
    """qid -> canonical result text for ok replies (bit-identity oracle:
    report objects serialize every float, so string equality is value
    equality down to the last bit)."""
    return {r.qid: r.result.to_json() for r in replies if r.ok}


def _serve(queries, chaos=None, retry=None) -> tuple:
    svc = DesignService("base", chaos=chaos,
                        retry=retry or RetryPolicy(max_attempts=4, base_s=0.005))
    t0 = time.perf_counter()
    replies = svc.serve(queries)
    wall = time.perf_counter() - t0
    return svc, replies, wall


def _latency(replies, st) -> dict:
    walls = np.asarray([r.wall_s for r in replies if r.ok], np.float64)
    return dict(
        queries=len(replies),
        ok=int(sum(r.ok for r in replies)),
        availability=round(st.availability, 6),
        retries=st.retries,
        deadline_misses=st.deadline_misses,
        degraded=st.degraded,
        errors=dict(st.errors),
        stragglers=len(st.stragglers),
        p50_ms=round(float(np.percentile(walls, 50)) * 1e3, 2) if walls.size else None,
        p99_ms=round(float(np.percentile(walls, 99)) * 1e3, 2) if walls.size else None,
    )


def chaos_bench(quick: bool = False) -> dict:
    n = 24 if quick else 96
    queries = _queries(n, optimize_every=0 if quick else 24)
    out: dict = {"seed": _SEED, "queries": n}

    # 1) clean baseline: no chaos — also the bit-identity oracle
    svc0, replies0, wall0 = _serve(queries)
    base = _fingerprints(replies0)
    out["clean"] = {**_latency(replies0, svc0.stats), "wall_s": round(wall0, 2)}
    assert len(replies0) == len(queries), "isolation: batch must always complete"
    emit("serving.chaos", dict(mode="clean", **{k: out["clean"][k] for k in ("availability", "p50_ms", "p99_ms")}))

    # 2) transient-only chaos: every fault clears on retry -> the hard gate
    inj_t = ChaosInjector(ChaosConfig(seed=_SEED, p_transient=0.35, p_compile_fail=0.2))
    svc_t, replies_t, wall_t = _serve(queries, chaos=inj_t)
    out["transient_only"] = {**_latency(replies_t, svc_t.stats),
                             "injected": inj_t.summary(), "wall_s": round(wall_t, 2)}
    emit("serving.chaos", dict(mode="transient_only",
                               availability=out["transient_only"]["availability"],
                               injected=sum(inj_t.summary().values())))
    if out["transient_only"]["availability"] != 1.0:
        raise SystemExit(
            f"GATE FAILED: transient-only chaos availability "
            f"{out['transient_only']['availability']} != 1.0 — retryable faults "
            "must always clear under the default RetryPolicy"
        )

    # 3) full chaos: transients + NaN poisoning + latency spikes
    cfg = ChaosConfig(seed=_SEED, p_transient=0.3, p_compile_fail=0.1,
                      p_nan=0.25, p_latency=0.2, latency_s=0.02)
    inj_f = ChaosInjector(cfg)
    svc_f, replies_f, wall_f = _serve(queries, chaos=inj_f)
    stats_f = svc_f.stats
    plans = inj_f.schedule([q.qid for q in queries])
    clean_qids = {p.qid for p in plans if p.clean}
    fp_f = _fingerprints(replies_f)
    mismatch = [q for q in clean_qids if q in base and q in fp_f and base[q] != fp_f[q]]
    out["full"] = {
        **_latency(replies_f, stats_f),
        "injected": inj_f.summary(),
        "wall_s": round(wall_f, 2),
        "clean_queries": len(clean_qids),
        "bit_identical_clean": len(clean_qids) - len(mismatch),
        "schedule": [p.to_json() for p in plans if not p.clean],
    }
    emit("serving.chaos", dict(mode="full", availability=out["full"]["availability"],
                               p99_ms=out["full"]["p99_ms"],
                               injected=sum(inj_f.summary().values())))
    assert len(replies_f) == len(queries), "isolation: batch must always complete"
    if mismatch:
        raise SystemExit(
            f"GATE FAILED: {len(mismatch)} fault-free replies differ from the "
            f"no-chaos run (qids {sorted(mismatch)[:8]}) — chaos must not perturb "
            "untouched queries"
        )
    if out["full"]["availability"] < 0.99:
        raise SystemExit(
            f"GATE FAILED: full-chaos availability {out['full']['availability']} < 0.99"
        )

    # 4) determinism: same seed -> identical schedule and identical outcomes
    inj_r = ChaosInjector(cfg)
    svc_r, replies_r, _ = _serve(queries, chaos=inj_r)
    same_sched = [p.to_json() for p in inj_r.schedule([q.qid for q in queries])] == \
        [p.to_json() for p in inj_f.schedule([q.qid for q in queries])]
    same_outcome = [(r.qid, r.ok, r.error.code if r.error else None) for r in replies_r] == \
        [(r.qid, r.ok, r.error.code if r.error else None) for r in replies_f]
    same_results = _fingerprints(replies_r) == fp_f
    out["replay"] = dict(same_schedule=same_sched, same_outcomes=same_outcome,
                         same_results=same_results,
                         availability=round(svc_r.stats.availability, 6))
    if not (same_sched and same_outcome and same_results):
        raise SystemExit("GATE FAILED: seeded chaos replay diverged (schedule/outcomes/results)")
    emit("serving.chaos", dict(mode="replay", deterministic=True))
    return out


def run(quick: bool = False, chaos_only: bool = False) -> dict:
    out: dict = {}
    if not chaos_only:
        out.update(token_bench(quick))
    out["chaos"] = chaos_bench(quick)
    save_json("serving", out, quick=quick)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI probe sizes; writes serving_quick.json")
    ap.add_argument("--chaos", action="store_true",
                    help="design-service chaos probe only (skip the token-engine bench)")
    args = ap.parse_args()
    run(quick=args.quick, chaos_only=args.chaos)
